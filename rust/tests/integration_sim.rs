//! Integration: the cycle model reproduces the paper's quantitative
//! claims end-to-end (the §V numbers, beyond the per-module unit tests).

use swiftkv::model::{LlmConfig, TokenCost};
use swiftkv::report;
use swiftkv::sim::{edge_hw, layer_sched, power, AttentionAlg, ArchConfig};

#[test]
fn paper_headline_claims_hold() {
    let h = report::headlines(&ArchConfig::default());
    // §V/abstract: 7.16× over native attention
    assert!((h.swiftkv_speedup - 7.16).abs() < 0.25, "{}", h.swiftkv_speedup);
    // §V: attention 3.19 % of end-to-end; 13.48× lower than DFX's 43 %
    assert!((h.attention_share - 0.0319).abs() < 0.012, "{}", h.attention_share);
    // Table III: 81.5 token/s; 17.4 % over EdgeLLM
    assert!((h.tokens_per_s - 81.5).abs() < 8.0, "{}", h.tokens_per_s);
    assert!((h.speed_gain_vs_best_prior - 0.174).abs() < 0.12, "{}", h.speed_gain_vs_best_prior);
    // §V: 1.98× token efficiency; 1100.3 GOPS; 60.12 GOPS/W
    assert!((h.token_eff_gain - 1.98).abs() < 0.35, "{}", h.token_eff_gain);
    assert!((h.gops - 1100.3).abs() < 120.0, "{}", h.gops);
    assert!((h.gops_per_w - 60.12).abs() < 9.0, "{}", h.gops_per_w);
}

#[test]
fn fig7a_curve_shapes() {
    // SwiftKV ~4N; Flash curves above it and stepping at block boundaries
    let arch = ArchConfig::default();
    let contexts: Vec<usize> = (1..=16).map(|i| i * 256).collect();
    let curves = edge_hw::fig7a_curves(&arch, &contexts, 128);
    let (swift_label, swift) = &curves[0];
    assert!(swift_label.contains("SwiftKV"));
    // near-linear: us(2n) ≈ 2·us(n)
    for i in 0..swift.len() / 2 {
        let (n1, t1) = swift[i];
        let (n2, t2) = swift[2 * i + 1];
        assert_eq!(n2, 2 * n1);
        assert!((t2 / t1 - 2.0).abs() < 0.1, "nonlinear at {n1}");
    }
}

#[test]
fn speedup_persists_across_context_lengths() {
    let arch = ArchConfig::default();
    for n in [128usize, 512, 2048, 8192] {
        let native = edge_hw::attention_cycles(&arch, AttentionAlg::Native, n, 128).total as f64;
        let swift = edge_hw::attention_cycles(&arch, AttentionAlg::SwiftKv, n, 128).total as f64;
        let ratio = native / swift;
        assert!((6.5..7.5).contains(&ratio), "n={n}: {ratio}");
    }
}

#[test]
fn table3_ordering_and_energy() {
    // our latency beats EdgeLLM's on both models; token/J roughly doubles
    let arch = ArchConfig::default();
    let llama = layer_sched::simulate_token(&arch, &LlmConfig::llama2_7b(), 512);
    let glm = layer_sched::simulate_token(&arch, &LlmConfig::chatglm_6b(), 512);
    assert!(llama.latency_ms < 14.4, "llama2 {}", llama.latency_ms);
    assert!(glm.latency_ms < 11.7, "chatglm {}", glm.latency_ms);
    assert!(glm.latency_ms < llama.latency_ms);
    let p = power::power(&arch, 1.0);
    let tpj = power::tokens_per_joule(llama.tokens_per_s, p.system_w());
    assert!(tpj > 2.0, "token/J {tpj}");
}

#[test]
fn gop_per_token_consistent_with_simulated_gops() {
    let arch = ArchConfig::default();
    let cfg = LlmConfig::llama2_7b();
    let sim = layer_sched::simulate_token(&arch, &cfg, 512);
    let cost = TokenCost::of(&cfg, 512);
    let gops = cost.gops_at(sim.latency_ms / 1e3);
    // must stay below the array's 1.84 TOPS peak and above 50% of paper
    assert!(gops < 1843.0);
    assert!(gops > 550.0);
}

#[test]
fn ablation_fewer_processors_slower_attention() {
    // design ablation: halving the SKV array serializes heads → 2× attn
    let full = ArchConfig::default();
    let half = ArchConfig { n_processors: 16, ..ArchConfig::default() };
    let a_full = swiftkv::sim::array::attention_cycles(&full, 32, 128, 512);
    let a_half = swiftkv::sim::array::attention_cycles(&half, 32, 128, 512);
    assert_eq!(a_half, 2 * a_full);
}

#[test]
fn ablation_bandwidth_bound_decode() {
    // doubling HBM bandwidth must cut weight-bound latency substantially
    let base = ArchConfig::default();
    let fast = ArchConfig { hbm_gbps: 920.0, ..ArchConfig::default() };
    let cfg = LlmConfig::llama2_7b();
    let t_base = layer_sched::simulate_token(&base, &cfg, 512).latency_ms;
    let t_fast = layer_sched::simulate_token(&fast, &cfg, 512).latency_ms;
    assert!(
        t_fast < t_base * 0.9,
        "2x HBM should help a weight-bound decode: {t_base} → {t_fast}"
    );
}
