//! Sync-primitive alias layer for model checking.
//!
//! The pool ([`super::pool`]) and the paged-KV free list
//! ([`super::paged`]) import every synchronization primitive from this
//! module instead of `std`. A normal build re-exports `std` types
//! one-for-one (zero cost — they are the same items). A `--cfg loom`
//! build swaps in the instrumented twins from [`crate::util::mc`], so
//! `rust/tests/loom_pool.rs` can exhaustively model-check the epoch
//! publication / park / wake / panic choreography and the free-list
//! grant/release protocol without touching the production source. The
//! engine's idle-park gate (`coordinator::submit::EngineGate`) rides the
//! same layer and is checked by `rust/tests/loom_engine.rs`.
//!
//! Under `--cfg loom`, code using these primitives must run inside a
//! [`crate::util::mc::model`] closure (the CI loom job builds only the
//! `loom_pool` / `loom_engine` test targets, so the rest of the test
//! suite never meets the instrumented types).

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::mc::sync::{Arc, Condvar, Mutex, MutexGuard};

/// `std::sync::atomic` (or the instrumented subset under `--cfg loom`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use crate::util::mc::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// The `std::thread` surface the pool uses (spawn / yield / join).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use crate::util::mc::thread::{spawn, yield_now, JoinHandle};
}

/// Busy-wait hint; a no-op under the model checker.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub use crate::util::mc::thread::spin_loop;
}

/// Condvar wait with an optional wall-clock bound, recovering from
/// poisoned locks (a panicking peer must not wedge the waiter).
///
/// The model checker has no clock, so under `--cfg loom` the timeout is
/// ignored and this is a plain `wait` — which is exactly the discipline
/// the parking protocol needs anyway: *correctness* (no lost wakeups,
/// shutdown always terminates) must never depend on a timeout firing.
/// Timeouts exist only so the `std` build can honor scheduled arrival
/// times (`gap_ms`) while parked.
#[cfg(not(loom))]
pub fn wait_ms<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout_ms: Option<u64>,
) -> MutexGuard<'a, T> {
    use std::sync::PoisonError;
    match timeout_ms {
        Some(ms) => {
            cv.wait_timeout(guard, std::time::Duration::from_millis(ms))
                .unwrap_or_else(PoisonError::into_inner)
                .0
        }
        None => cv.wait(guard).unwrap_or_else(PoisonError::into_inner),
    }
}

/// Loom twin of [`wait_ms`]: always an untimed wait (see above).
#[cfg(loom)]
pub fn wait_ms<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    _timeout_ms: Option<u64>,
) -> MutexGuard<'a, T> {
    use std::sync::PoisonError;
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}
