//! Baseline accelerator operating points — the comparison rows of
//! Tables III/IV and Fig. 8(b).
//!
//! Each baseline is encoded from its paper's published numbers (platform,
//! DSP usage, frequency, latency, power); derived columns (token/s,
//! token/J, GOPS/W) are *recomputed* from the primitives so the comparison
//! harness exercises the same arithmetic for every row, and so the tests
//! can check the published derived values against the recomputation.

use crate::model::{LlmConfig, TokenCost};

/// One accelerator operating point as published.
#[derive(Debug, Clone)]
pub struct AcceleratorPoint {
    pub name: &'static str,
    pub platform: &'static str,
    pub model: &'static str,
    pub quant: &'static str,
    pub hbm_gbps: f64,
    pub freq_mhz: f64,
    pub dsp: u64,
    /// Decode latency per token (ms).
    pub latency_ms: f64,
    /// System power (W).
    pub system_power_w: f64,
    pub source: &'static str,
}

impl AcceleratorPoint {
    pub fn tokens_per_s(&self) -> f64 {
        1000.0 / self.latency_ms
    }

    pub fn tokens_per_joule(&self) -> f64 {
        self.tokens_per_s() / self.system_power_w
    }

    /// Throughput in GOPS for the model it runs (at context 512, the
    /// paper's setting).
    pub fn gops(&self) -> f64 {
        let cfg = config_for(self.model);
        TokenCost::of(&cfg, 512).gops_at(self.latency_ms / 1000.0)
    }
}

fn config_for(model: &str) -> LlmConfig {
    match model {
        "Llama-2-7B" => LlmConfig::llama2_7b(),
        "ChatGLM-6B" => LlmConfig::chatglm_6b(),
        _ => panic!("unknown model {model}"),
    }
}

/// Table III rows: FlightLLM [13] and EdgeLLM [9] under the paper's
/// "identical experimental settings" (W4A8, 460 GB/s HBM, 225 MHz).
pub fn table3_baselines() -> Vec<AcceleratorPoint> {
    vec![
        AcceleratorPoint {
            name: "FlightLLM",
            platform: "U280",
            model: "Llama-2-7B",
            quant: "~W4A8",
            hbm_gbps: 460.0,
            freq_mhz: 225.0,
            dsp: 6345,
            latency_ms: 18.2,
            system_power_w: 45.0,
            source: "[13] FPGA'24",
        },
        AcceleratorPoint {
            name: "EdgeLLM",
            platform: "VCU128",
            model: "Llama-2-7B",
            quant: "W4A8",
            hbm_gbps: 460.0,
            freq_mhz: 225.0,
            dsp: 4563,
            latency_ms: 14.4,
            system_power_w: 56.8,
            source: "[9] TCAS-I",
        },
        AcceleratorPoint {
            name: "EdgeLLM",
            platform: "VCU128",
            model: "ChatGLM-6B",
            quant: "W4A8",
            hbm_gbps: 460.0,
            freq_mhz: 225.0,
            dsp: 4563,
            latency_ms: 11.7,
            system_power_w: 56.8,
            source: "[9] TCAS-I",
        },
    ]
}

/// A Table IV row: prior FPGA transformer accelerators (published
/// throughput/efficiency; models outside our config set, so GOPS and
/// GOPS/W are carried as published).
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    pub name: &'static str,
    pub platform: &'static str,
    pub model: &'static str,
    pub freq_mhz: f64,
    pub gops: f64,
    pub gops_per_w: f64,
}

/// Table IV comparison rows.
pub fn table4_baselines() -> Vec<ThroughputPoint> {
    vec![
        ThroughputPoint {
            name: "DFX (MICRO'22)",
            platform: "Alveo U280",
            model: "GPT2-1.5B",
            freq_mhz: 200.0,
            gops: 184.1,
            gops_per_w: 4.09,
        },
        ThroughputPoint {
            name: "TCAS-I'23",
            platform: "ZCU102",
            model: "Vision Transformer",
            freq_mhz: 300.0,
            gops: 726.7,
            gops_per_w: 28.2,
        },
        ThroughputPoint {
            name: "ASP-DAC'24",
            platform: "Alveo U280",
            model: "BERT-base",
            freq_mhz: 220.0,
            gops: 757.4,
            gops_per_w: 25.1,
        },
        ThroughputPoint {
            name: "TCAS-I'25",
            platform: "Alveo U50",
            model: "Swin Transformer",
            freq_mhz: 170.0,
            gops: 830.3,
            gops_per_w: 45.12,
        },
    ]
}

/// The attention-latency share baseline of Fig. 8(a): DFX [5] reports
/// attention at 43.0 % of end-to-end decode latency.
pub const DFX_ATTENTION_SHARE: f64 = 0.43;

#[cfg(test)]
mod tests {
    use super::*;

    /// The published derived columns must be recoverable from the
    /// primitives (Table III's internal consistency).
    #[test]
    fn table3_published_derived_columns() {
        let rows = table3_baselines();
        // FlightLLM: 55 token/s, 1.22 token/J
        assert!((rows[0].tokens_per_s() - 55.0).abs() < 1.0);
        assert!((rows[0].tokens_per_joule() - 1.22).abs() < 0.03);
        // EdgeLLM llama: 69.4 token/s, 1.22 token/J
        assert!((rows[1].tokens_per_s() - 69.4).abs() < 0.5);
        assert!((rows[1].tokens_per_joule() - 1.22).abs() < 0.03);
        // EdgeLLM chatglm: 85.8 token/s, 1.51 token/J
        assert!((rows[2].tokens_per_s() - 85.5).abs() < 0.5);
        assert!((rows[2].tokens_per_joule() - 1.51).abs() < 0.03);
    }

    #[test]
    fn our_token_efficiency_gain_matches_headline() {
        // §V: 1.98× token-efficiency improvement over the best prior work
        let ours = 81.5 / 33.8; // token/J (Table III, this work, llama2)
        let best_prior = table3_baselines()
            .iter()
            .filter(|r| r.model == "Llama-2-7B")
            .map(|r| r.tokens_per_joule())
            .fold(0.0f64, f64::max);
        let gain = ours / best_prior;
        assert!((gain - 1.98).abs() < 0.06, "gain {gain:.2} vs paper 1.98×");
    }

    #[test]
    fn speed_gain_17_4_pct_over_edgellm() {
        // §V: generation speed 17.4% higher than EdgeLLM (llama2)
        let edgellm = table3_baselines()[1].tokens_per_s();
        let ours = 81.5;
        let gain = ours / edgellm - 1.0;
        assert!((gain - 0.174).abs() < 0.01, "gain {:.1}%", gain * 100.0);
    }

    #[test]
    fn table4_ours_highest() {
        // our 1100.3 GOPS / 60.12 GOPS/W top every prior row
        for r in table4_baselines() {
            assert!(r.gops < 1100.3, "{}", r.name);
            assert!(r.gops_per_w < 60.12, "{}", r.name);
        }
    }

    #[test]
    fn gops_recomputation_plausible() {
        // FlightLLM at 18.2 ms on llama2 ≈ 13.5/0.0182 ≈ 740 GOPS
        let rows = table3_baselines();
        let g = rows[0].gops();
        assert!((600.0..850.0).contains(&g), "{g}");
    }
}
