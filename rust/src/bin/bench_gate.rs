//! `bench_gate` — CI perf-regression gate over `swiftkv-bench-v1` JSON.
//!
//! ```text
//! bench_gate <BENCH_baseline.json> <BENCH_current.json> \
//!     [--max-regress-pct 15] [--gate fused,gemm_w4a8,simd/] [--require-baseline]
//! ```
//!
//! Compares median ns/op of every benchmark present in both documents
//! and prints a markdown delta table (pipe it into `$GITHUB_STEP_SUMMARY`
//! for the job summary). Exits non-zero when any benchmark whose name
//! contains one of the comma-separated gate substrings (default
//! `fused,gemm_w4a8,simd/` — the fused-sweep hot paths, the
//! batch-amortized W4A8 GEMM, and the dispatched SIMD microkernel
//! benches) regressed by more than the threshold, so a slow hot path
//! fails the job instead of shipping silently. A gate substring that
//! matches zero benchmarks in either document is reported as a loud
//! warning in the table — the gate may have silently lost coverage.
//!
//! An empty baseline gates nothing, and the report says **which kind**
//! of empty it is: the committed placeholder (zero benchmarks plus a
//! self-describing `note` — the gate was simply never armed) prints a
//! `BASELINE PLACEHOLDER — never armed` banner, while an empty document
//! without the note (an armed baseline that lost its data) prints
//! `BASELINE EMPTY — gate is vacuous`. Without `--require-baseline`
//! both are a vacuous pass; **with** `--require-baseline` (what CI
//! passes) both are a hard failure, so the gate can never silently run
//! unarmed. Refresh `BENCH_baseline.json` from a trusted CI-class bench
//! run to arm it. Comparison logic lives in
//! [`swiftkv::util::bench::compare_bench_json`] (unit-tested in-tree).

use swiftkv::util::bench::compare_bench_json;
use swiftkv::util::cli::Args;
use swiftkv::util::Json;

fn main() {
    // Last-resort net: a malformed input that slips past the explicit
    // validation must still fail the job with a one-line diagnostic and
    // a nonzero exit, never a raw backtrace.
    let outcome = std::panic::catch_unwind(run);
    match outcome {
        Ok(Ok(passed)) => {
            if !passed {
                std::process::exit(1);
            }
        }
        Ok(Err(e)) => {
            eprintln!("bench_gate: {e}");
            std::process::exit(2);
        }
        Err(cause) => {
            let msg = cause
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| cause.downcast_ref::<&str>().copied())
                .unwrap_or("unknown panic");
            eprintln!("bench_gate: internal error while comparing benchmarks: {msg}");
            std::process::exit(2);
        }
    }
}

fn run() -> Result<bool, String> {
    let args = Args::parse(&["max-regress-pct", "gate"], &["help", "require-baseline"])?;
    if args.get_bool("help") || args.positional().len() != 2 {
        return Err(
            "usage: bench_gate <baseline.json> <current.json> \
             [--max-regress-pct 15] [--gate fused,gemm_w4a8,simd/] [--require-baseline]"
                .into(),
        );
    }
    let max_regress_pct = args.get_f64("max-regress-pct", 15.0)?;
    let gate = args.get_or("gate", "fused,gemm_w4a8,simd/");
    let require_baseline = args.get_bool("require-baseline");
    let load = |path: &str| -> Result<Json, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| {
            format!(
                "{path}: not valid swiftkv-bench-v1 JSON ({e}); \
                 the file may be truncated or hand-edited — refresh it \
                 from a trusted bench run"
            )
        })
    };
    let baseline = load(&args.positional()[0])?;
    let current = load(&args.positional()[1])?;
    let report = compare_bench_json(&baseline, &current, gate, max_regress_pct)?;
    println!("{}", report.to_markdown());
    if report.baseline_empty() {
        // loud on stderr too, so the warning survives summary-only
        // readers — and name which empty state this is: a never-armed
        // placeholder reads very differently from a stripped baseline
        if report.baseline_placeholder {
            eprintln!(
                "bench_gate: BASELINE PLACEHOLDER — never armed ({} is still \
                 the committed placeholder; no bench run has populated it)",
                args.positional()[0]
            );
        } else {
            eprintln!(
                "bench_gate: BASELINE EMPTY — gate is vacuous ({} has zero \
                 benchmarks and is NOT the placeholder; an armed baseline may \
                 have been stripped)",
                args.positional()[0]
            );
        }
        if require_baseline {
            eprintln!("bench_gate: --require-baseline set: failing the run");
            return Ok(false);
        }
    }
    Ok(report.passed())
}
