//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate closure is
//! cached), so the small generic dependencies a project like this would
//! normally pull from crates.io are implemented here from scratch:
//!
//! - [`rng`] — deterministic SplitMix64/xoshiro256** PRNG with uniform,
//!   range and Gaussian sampling (replaces `rand::SmallRng`),
//! - [`json`] — a minimal JSON parser + writer for `artifacts/manifest.json`
//!   and report emission (replaces `serde_json`),
//! - [`bench`] — a warmup/measure timing harness with criterion-style
//!   output used by `rust/benches/*` (replaces `criterion`), plus the
//!   baseline-comparison logic behind the `bench_gate` CI binary,
//! - [`cli`] — a tiny flag parser for the `swiftkv` binary and examples
//!   (replaces `clap`),
//! - [`prop`] — a seeded random-case property-test driver with failure
//!   reporting (replaces `proptest` for our invariant sweeps; the base
//!   seed is pinned via the `SWIFTKV_PROP_SEED` env var in CI),
//! - [`oracle`] — a deliberately naive scalar GQA/MQA/MHA attention
//!   oracle (materialized scores, two-pass softmax) used as ground truth
//!   by the fused-kernel property tests,
//! - [`mc`] — a miniature loom-style model checker (token-passing
//!   scheduler over real threads, DFS over preemption points) backing
//!   the `--cfg loom` builds of `rust/tests/loom_pool.rs`,
//! - [`lint`] — the repo-invariant lint engine behind `src/bin/lint.rs`
//!   (SAFETY-comment coverage, kernel-table parity, hotpath discipline,
//!   bench-gate coverage), run as a tier-1 CI job.

pub mod bench;
pub mod cli;
pub mod json;
pub mod lint;
pub mod mc;
pub mod oracle;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
