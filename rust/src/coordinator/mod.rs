//! L3 coordinator — the decode serving layer in front of the PJRT engine.
//!
//! Shaped like a serving-system router (the SwiftKV-MHA accelerator is a
//! decode engine; this is the host side that keeps it fed):
//!
//! - [`session`] — per-request decode sessions (prompt feed → generation),
//! - [`batcher`] — continuous batching over the engine's fixed lane count:
//!   free lanes are re-admitted from the queue every iteration, and the
//!   compiled batch variant is chosen by occupancy,
//! - [`server`] — the synchronous decode loop: gather (token, position)
//!   per lane, one engine step, scatter logits, greedy-sample, retire
//!   finished sessions,
//! - [`metrics`] — per-request latency/throughput accounting plus the
//!   simulated SwiftKV-MHA timing for the same schedule (via
//!   [`crate::sim::layer_sched`]), so the E2E example reports both
//!   wall-clock (CPU PJRT) and modelled-accelerator numbers.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod session;

pub use batcher::{Batcher, LaneState};
pub use metrics::{Percentiles, ServeMetrics};
pub use server::{ServeOptions, ServeReport, Server};
pub use session::{Session, SessionPhase};
