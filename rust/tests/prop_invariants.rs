//! Property tests (seeded random-case sweeps via `util::prop`): the
//! coordinator/batching invariants and the core numeric invariants the
//! paper's algorithm depends on.

use swiftkv::attention::{native, swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::coordinator::Batcher;
use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::model::Request;
use swiftkv::util::prop;

#[test]
fn prop_swiftkv_equals_softmax_attention() {
    prop::check("swiftkv == softmax·V", 40, |rng, _| {
        let d = [4, 8, 16, 32][rng.gen_range(0, 4)];
        let len = rng.gen_range(1, 200);
        let scale = [0.5f32, 1.0, 4.0][rng.gen_range(0, 3)];
        let q = rng.uniform_vec(d, scale);
        let k = rng.uniform_vec(d * len, scale);
        let v = rng.uniform_vec(d * len, scale);
        let p = HeadProblem::new(&q, &k, &v, d, len);
        let a = swiftkv_attn::attend(&p);
        let b = native::attend(&p);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
    });
}

#[test]
fn prop_exp_lut_bounds_and_monotonicity() {
    let lut = Exp2Lut::new();
    prop::check("exp LUT ∈ (0,1], monotone", 60, |rng, _| {
        let x1 = -20.0 * rng.gen_f64();
        let x2 = x1 - 5.0 * rng.gen_f64();
        let e1 = lut.exp_neg(Fxp32::from_f64(x1));
        let e2 = lut.exp_neg(Fxp32::from_f64(x2));
        assert!(e1 <= Fxp32::ONE && e1.raw() >= 0);
        assert!(e2 <= e1, "exp({x2}) > exp({x1})");
        // relative accuracy vs f64 when not underflowed
        if x1 > -15.0 {
            let want = x1.exp();
            assert!((e1.to_f64() - want).abs() < 1e-4 + want * 1e-3);
        }
    });
}

#[test]
fn prop_fxp_mul_bounded_error() {
    prop::check("Q15.17 multiply error ≤ 1 ulp-ish", 100, |rng, _| {
        let a = (rng.gen_f64() - 0.5) * 200.0;
        let b = (rng.gen_f64() - 0.5) * 200.0;
        let q = Fxp32::from_f64(a) * Fxp32::from_f64(b);
        let want = a * b;
        if want.abs() < 16000.0 {
            // quantized inputs already carry ≤ half-ulp each; product error
            // is bounded by |a|+|b| halves plus the rounding
            let tol = (a.abs() + b.abs() + 2.0) * (1.0 / 131072.0);
            assert!((q.to_f64() - want).abs() <= tol, "{a}*{b}: {q}");
        }
    });
}

#[test]
fn prop_fxp_dot_matches_f64() {
    prop::check("wide-accumulator dot", 40, |rng, _| {
        let n = rng.gen_range(1, 300);
        let a = rng.uniform_vec(n, 2.0);
        let b = rng.uniform_vec(n, 2.0);
        let qa = vector::quantize(&a);
        let qb = vector::quantize(&b);
        let got = vector::dot(&qa, &qb).to_f64();
        let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        assert!((got - want).abs() < 1e-4 * n as f64 + 1e-4, "{got} vs {want}");
    });
}

#[test]
fn prop_batcher_conservation() {
    // every submitted request is eventually either finished or rejected;
    // no token is generated for a request that was never admitted
    prop::check("batcher conserves requests", 30, |rng, case| {
        let lanes = rng.gen_range(1, 5);
        let n_ctx = 32;
        let mut b = Batcher::new(lanes, n_ctx);
        let n_req = rng.gen_range(1, 12);
        let mut submitted = 0u64;
        let mut rejected = 0u64;
        for i in 0..n_req {
            let plen = rng.gen_range(1, 20);
            let glen = rng.gen_range(1, 20);
            let prompt: Vec<u32> = (0..plen as u32).collect();
            let r = Request::new(case * 1000 + i as u64, prompt).gen_len(glen);
            match b.submit(r) {
                Ok(()) => submitted += 1,
                Err(_) => rejected += 1,
            }
        }
        // drive with a deterministic fake sampler
        let mut iter = 0u64;
        while !b.is_drained() {
            b.admit(iter);
            let (_, _, _) = b.gather_inputs();
            let samples = vec![1u32; lanes];
            b.scatter_outputs(&samples, iter);
            iter += 1;
            assert!(iter < 10_000, "batcher did not drain");
        }
        assert_eq!(b.finished.len() as u64, submitted);
        assert_eq!(b.counters(), (submitted, rejected));
        for s in &b.finished {
            assert_eq!(s.generated.len(), s.request.gen_len);
            assert!(s.max_context() <= n_ctx);
        }
    });
}

#[test]
fn prop_z_recurrence_bounds() {
    // Z_t ∈ (0, t] and μ_t is the running max — the §III invariants
    prop::check("Z and mu invariants", 40, |rng, _| {
        let d = 8;
        let len = rng.gen_range(2, 128);
        let q = rng.uniform_vec(d, 3.0);
        let k = rng.uniform_vec(d * len, 3.0);
        let v = rng.uniform_vec(d * len, 1.0);
        let p = HeadProblem::new(&q, &k, &v, d, len);
        let scale = p.scale();
        let mut st = swiftkv_attn::SwiftKvState::new(d);
        let mut true_max = f32::NEG_INFINITY;
        for t in 0..len {
            let s = swiftkv::attention::dot_f32(p.q, p.key(t)) * scale;
            true_max = true_max.max(s);
            st.update(s, p.value(t));
            assert!(st.z > 0.0 && st.z <= (t + 1) as f32 + 1e-3);
            assert!((st.mu - true_max).abs() < 1e-6, "mu != running max");
        }
    });
}
