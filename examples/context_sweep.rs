//! Context-length sweep (the Fig. 7(a) experiment, extended): attention
//! cycles and full-token latency as the context grows, for every
//! algorithm and every paper model.
//!
//! ```sh
//! cargo run --release --example context_sweep -- [--max-ctx 4096]
//! ```

use swiftkv::model::LlmConfig;
use swiftkv::sim::{edge_hw, layer_sched, ArchConfig, AttentionAlg};
use swiftkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["max-ctx"], &[]).map_err(|e| anyhow::anyhow!(e))?;
    let max_ctx = args.get_usize("max-ctx", 4096).unwrap();
    let arch = ArchConfig::default();

    // --- attention algorithms on the shared hardware set ---------------
    println!("attention cycles per decode step (d_head = 128):");
    println!(
        "{:>8}{:>12}{:>12}{:>12}{:>12}{:>12}",
        "ctx", "native", "flash8", "flash32", "stream", "swiftkv"
    );
    let mut n = 64;
    while n <= max_ctx {
        let c = |alg| edge_hw::attention_cycles(&arch, alg, n, 128).total;
        println!(
            "{:>8}{:>12}{:>12}{:>12}{:>12}{:>12}",
            n,
            c(AttentionAlg::Native),
            c(AttentionAlg::Flash { block: 8 }),
            c(AttentionAlg::Flash { block: 32 }),
            c(AttentionAlg::Streaming),
            c(AttentionAlg::SwiftKv),
        );
        n *= 2;
    }

    // --- full-token latency per model ------------------------------------
    println!("\nper-token decode latency (ms) on SwiftKV-MHA:");
    let models = LlmConfig::paper_models();
    print!("{:>8}", "ctx");
    for m in &models {
        print!("{:>14}", m.name);
    }
    println!();
    let mut n = 128;
    while n <= max_ctx {
        print!("{n:>8}");
        for m in &models {
            let sim = layer_sched::simulate_token(&arch, m, n);
            print!("{:>11.2} ms", sim.latency_ms);
        }
        println!();
        n *= 2;
    }
    println!(
        "\nnote: decode is weight-bound under W4A8 — latency grows sub-linearly \
         with context (the attention stage is ~3 % of the total; Fig. 8(a))."
    );
    Ok(())
}
