"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes and cache-fill lengths; every case
asserts allclose against the references in ``compile.kernels.ref``.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.gemv import gemv_w4a8, gemv_w4a8_batched
from compile.kernels.rope import rope_decode_step
from compile.kernels.swiftkv import swiftkv_attention

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# SwiftKV attention kernel
# ---------------------------------------------------------------------------

class TestSwiftKVKernel:
    @given(
        rows=st.integers(1, 6),
        d=st.sampled_from([8, 16, 32, 64]),
        nb=st.integers(1, 6),
        block_k=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_native_attention(self, rows, d, nb, block_k, seed):
        r = rng(seed)
        n = nb * block_k
        q = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        lens = jnp.asarray(r.integers(1, n + 1, size=rows), jnp.int32)
        got = swiftkv_attention(q, k, v, lens, block_k=block_k)
        want = ref.native_attention_rows(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_scan_reference_equals_native(self, seed):
        """Eqs. (5)-(8) are an *exact* reformulation of softmax attention."""
        r = rng(seed)
        n, d = 96, 16
        q = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        k = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
        got = ref.swiftkv_attention_scan(q, k, v, n)
        want = ref.native_attention(q, k, v, n)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_block_k_one_is_per_token_recurrence(self):
        """With block_k=1 the kernel is the literal per-token pipeline."""
        r = rng(7)
        rows, n, d = 3, 32, 16
        q = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        lens = jnp.asarray([1, 15, 32], jnp.int32)
        got = swiftkv_attention(q, k, v, lens, block_k=1)
        want = ref.swiftkv_attention_scan_rows(q, k, v, lens)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_block_size_invariance(self):
        """Single-pass merge must be independent of the KV tiling."""
        r = rng(11)
        rows, n, d = 2, 64, 32
        q = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        lens = jnp.asarray([64, 40], jnp.int32)
        outs = [swiftkv_attention(q, k, v, lens, block_k=b)
                for b in (1, 8, 16, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)

    def test_length_one(self):
        """A single valid token attends only to itself: out = v_0."""
        r = rng(3)
        rows, n, d = 2, 32, 8
        q = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        lens = jnp.ones((rows,), jnp.int32)
        got = swiftkv_attention(q, k, v, lens, block_k=8)
        np.testing.assert_allclose(got, v[:, 0, :], rtol=1e-5, atol=1e-5)

    def test_large_score_range_stable(self):
        """Running-max rescaling keeps exp() in (0,1] even for huge scores."""
        r = rng(5)
        rows, n, d = 1, 64, 16
        q = jnp.asarray(r.normal(size=(rows, d)) * 30.0, jnp.float32)
        k = jnp.asarray(r.normal(size=(rows, n, d)) * 30.0, jnp.float32)
        v = jnp.asarray(r.normal(size=(rows, n, d)), jnp.float32)
        lens = jnp.asarray([n], jnp.int32)
        got = swiftkv_attention(q, k, v, lens, block_k=16)
        want = ref.native_attention_rows(q, k, v, lens)
        assert bool(jnp.all(jnp.isfinite(got)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_indivisible_context_rejected(self):
        q = jnp.zeros((1, 8), jnp.float32)
        k = jnp.zeros((1, 50, 8), jnp.float32)
        v = jnp.zeros((1, 50, 8), jnp.float32)
        with pytest.raises(ValueError):
            swiftkv_attention(q, k, v, jnp.ones((1,), jnp.int32), block_k=16)


# ---------------------------------------------------------------------------
# Decoder-specialized RoPE kernel
# ---------------------------------------------------------------------------

class TestRopeKernel:
    @given(
        bsz=st.integers(1, 3),
        h=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([8, 16, 32, 64]),
        m=st.integers(0, 500),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_direct_rope(self, bsz, h, d, m, seed):
        r = rng(seed)
        omega = jnp.asarray(ref.rope_freqs(d), jnp.float32)
        a, b = jnp.cos(omega), jnp.sin(omega)
        th = m * omega
        cos_m = jnp.broadcast_to(jnp.cos(th), (bsz, d // 2))
        sin_m = jnp.broadcast_to(jnp.sin(th), (bsz, d // 2))
        q = jnp.asarray(r.normal(size=(bsz * h, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(bsz * h, d)), jnp.float32)
        qo, ko, cos_n, sin_n = rope_decode_step(q, k, cos_m, sin_m, a, b,
                                                heads_per_seq=h)
        np.testing.assert_allclose(qo, ref.rope_standard(q, m + 1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ko, ref.rope_standard(k, m + 1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            cos_n, jnp.broadcast_to(jnp.cos((m + 1) * omega), (bsz, d // 2)),
            rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            sin_n, jnp.broadcast_to(jnp.sin((m + 1) * omega), (bsz, d // 2)),
            rtol=1e-4, atol=1e-4)

    def test_recurrence_drift_over_long_decode(self):
        """Iterating Eq. (11) 2048 times stays close to direct cos/sin —
        the incremental RoPE does not accumulate harmful error."""
        d = 64
        omega = jnp.asarray(ref.rope_freqs(d), jnp.float64)
        a, b = jnp.cos(omega), jnp.sin(omega)
        cos, sin = jnp.cos(-omega), jnp.sin(-omega)
        cos32 = cos.astype(jnp.float32)
        sin32 = sin.astype(jnp.float32)
        a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
        for m in range(2048):
            cos32, sin32 = ref.rope_incremental_step(cos32, sin32, a32, b32)
        want_c = jnp.cos(2047 * omega)
        want_s = jnp.sin(2047 * omega)
        np.testing.assert_allclose(cos32, want_c, atol=2e-3)
        np.testing.assert_allclose(sin32, want_s, atol=2e-3)

    def test_rotation_preserves_norm(self):
        r = rng(9)
        d = 32
        omega = jnp.asarray(ref.rope_freqs(d), jnp.float32)
        a, b = jnp.cos(omega), jnp.sin(omega)
        cos_m = jnp.cos(13 * omega)[None]
        sin_m = jnp.sin(13 * omega)[None]
        q = jnp.asarray(r.normal(size=(1, d)), jnp.float32)
        qo, _, _, _ = rope_decode_step(q, q, cos_m, sin_m, a, b)
        np.testing.assert_allclose(jnp.linalg.norm(qo), jnp.linalg.norm(q),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# W4A8 GEMV kernel
# ---------------------------------------------------------------------------

class TestGemvKernel:
    @given(
        din=st.sampled_from([32, 64, 128, 256]),
        dout=st.sampled_from([32, 96, 128, 384]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_exactly(self, din, dout, seed):
        """INT32 accumulation is exact: kernel == reference bit-for-bit."""
        r = rng(seed)
        x = jnp.asarray(r.normal(size=(din,)), jnp.float32)
        w = jnp.asarray(r.normal(size=(din, dout)), jnp.float32)
        xq, xs = ref.quantize_int8(x)
        wq, ws = ref.quantize_int4(w)
        got = gemv_w4a8(xq, xs, wq, ws)
        want = ref.gemv_w4a8(xq, xs, wq, ws)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(bsz=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
    def test_batched_rows_independent(self, bsz, seed):
        r = rng(seed)
        din, dout = 64, 128
        x = jnp.asarray(r.normal(size=(bsz, din)), jnp.float32)
        w = jnp.asarray(r.normal(size=(din, dout)), jnp.float32)
        wq, ws = ref.quantize_int4(w)
        xqs = [ref.quantize_int8(x[i]) for i in range(bsz)]
        xq = jnp.stack([q for q, _ in xqs])
        xs = jnp.stack([s for _, s in xqs])
        got = gemv_w4a8_batched(xq, xs, wq, ws)
        for i in range(bsz):
            want = ref.gemv_w4a8(xq[i], xs[i], wq, ws)
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))

    @given(seed=st.integers(0, 2**31 - 1))
    def test_quantized_close_to_f32(self, seed):
        """W4A8 end-to-end error stays within the usual quant envelope."""
        r = rng(seed)
        din, dout = 256, 256
        x = jnp.asarray(r.normal(size=(din,)), jnp.float32)
        w = jnp.asarray(r.normal(size=(din, dout)), jnp.float32)
        xq, xs = ref.quantize_int8(x)
        wq, ws = ref.quantize_int4(w)
        got = gemv_w4a8(xq, xs, wq, ws)
        want = x @ w
        denom = float(jnp.max(jnp.abs(want))) + 1e-6
        assert float(jnp.max(jnp.abs(got - want))) / denom < 0.25

    def test_int4_range(self):
        r = rng(1)
        w = jnp.asarray(r.normal(size=(64, 64)) * 10, jnp.float32)
        wq, _ = ref.quantize_int4(w)
        assert int(jnp.max(wq)) <= 7 and int(jnp.min(wq)) >= -7
