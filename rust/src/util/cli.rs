//! Tiny CLI flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Unknown flags are an error (catches typos).

use std::collections::BTreeMap;

/// Parsed arguments: flags + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (excluding argv[0]).
    /// `bool_flags` lists flags that take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        args: I,
        known: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&name.as_str()) && !bool_flags.contains(&name.as_str()) {
                    return Err(format!("unknown flag --{name}"));
                }
                let value = if bool_flags.contains(&name.as_str()) {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next().ok_or_else(|| format!("--{name} needs a value"))?
                };
                out.flags.insert(name, value);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn parse(known: &[&str], bool_flags: &[&str]) -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1), known, bool_flags)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true" | "1" | "yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], known: &[&str], bools: &[&str]) -> Result<Args, String> {
        Args::parse_from(args.iter().map(|s| s.to_string()), known, bools)
    }

    #[test]
    fn flag_styles() {
        let a = parse(
            &["--ctx", "512", "--model=llama2-7b", "--verbose", "cmd"],
            &["ctx", "model"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get_usize("ctx", 0).unwrap(), 512);
        assert_eq!(a.get("model"), Some("llama2-7b"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["cmd".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["--nope", "1"], &["ctx"], &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--ctx"], &["ctx"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &["ctx"], &[]).unwrap();
        assert_eq!(a.get_usize("ctx", 128).unwrap(), 128);
        assert_eq!(a.get_or("ctx", "x"), "x");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn bad_number_reported() {
        let a = parse(&["--ctx", "abc"], &["ctx"], &[]).unwrap();
        assert!(a.get_usize("ctx", 0).is_err());
    }
}
