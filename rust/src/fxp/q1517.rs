//! Q15.17 saturating fixed-point scalar.
//!
//! Layout: 1 sign bit, 14 integer bits, 17 fractional bits (the paper's
//! "FXP32, Q15.17"). Resolution is 2⁻¹⁷ ≈ 7.63e-6, which is what gives the
//! paper its "precision better than 10⁻⁵" claim for attention.
//!
//! All arithmetic saturates instead of wrapping: DSP48E2 accumulators are
//! wider than 32 bits internally and the RTL clamps on writeback, so
//! saturation (not two's-complement wraparound) is the faithful model.

use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Number of fractional bits in the Q15.17 format.
pub const FRAC_BITS: u32 = 17;
/// The value 1.0 in raw Q15.17 representation.
pub const ONE: i32 = 1 << FRAC_BITS;
/// Smallest representable increment (2⁻¹⁷).
pub const RESOLUTION: f64 = 1.0 / ONE as f64;

/// A Q15.17 fixed-point number stored in an `i32`.
///
/// `repr(transparent)` guarantees the layout matches `i32` exactly, so
/// the SIMD microkernels (`kernels::simd_avx2`) may reinterpret
/// `&[Fxp32]` as a run of raw `i32` lanes.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
#[repr(transparent)]
pub struct Fxp32(pub i32);

impl Fxp32 {
    pub const ZERO: Fxp32 = Fxp32(0);
    pub const ONE: Fxp32 = Fxp32(ONE);
    pub const MAX: Fxp32 = Fxp32(i32::MAX);
    pub const MIN: Fxp32 = Fxp32(i32::MIN);

    /// Construct from raw Q15.17 bits.
    #[inline]
    pub const fn from_raw(raw: i32) -> Self {
        Fxp32(raw)
    }

    /// Raw Q15.17 bits.
    #[inline]
    pub const fn raw(self) -> i32 {
        self.0
    }

    /// Quantize an `f64` to Q15.17 (round-to-nearest, saturating).
    #[inline]
    pub fn from_f64(x: f64) -> Self {
        let scaled = (x * ONE as f64).round();
        if scaled >= i32::MAX as f64 {
            Fxp32::MAX
        } else if scaled <= i32::MIN as f64 {
            Fxp32::MIN
        } else {
            Fxp32(scaled as i32)
        }
    }

    /// Quantize an `f32` to Q15.17.
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        Self::from_f64(x as f64)
    }

    /// Exact conversion back to `f64`.
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.0 as f64 * RESOLUTION
    }

    /// Lossy conversion to `f32`.
    #[inline]
    pub fn to_f32(self) -> f32 {
        self.to_f64() as f32
    }

    /// Saturating addition (DSP post-adder with clamp).
    #[inline]
    pub fn sat_add(self, rhs: Self) -> Self {
        Fxp32(self.0.saturating_add(rhs.0))
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sat_sub(self, rhs: Self) -> Self {
        Fxp32(self.0.saturating_sub(rhs.0))
    }

    /// Q15.17 × Q15.17 → Q15.17 with round-to-nearest and saturation.
    ///
    /// Models the 4-DSP 32×32 fixed-point multiply of §IV-B: the 64-bit
    /// product is rounded at bit 17 and clamped into 32 bits.
    #[inline]
    pub fn sat_mul(self, rhs: Self) -> Self {
        let wide = self.0 as i64 * rhs.0 as i64;
        // round-to-nearest at the 17-bit boundary
        let rounded = (wide + (1i64 << (FRAC_BITS - 1))) >> FRAC_BITS;
        Fxp32(clamp_i64(rounded))
    }

    /// Q15.17 ÷ Q15.17 → Q15.17 (iterative divider; round-to-nearest).
    #[inline]
    pub fn sat_div(self, rhs: Self) -> Self {
        if rhs.0 == 0 {
            return if self.0 >= 0 { Fxp32::MAX } else { Fxp32::MIN };
        }
        let num = (self.0 as i64) << FRAC_BITS;
        let den = rhs.0 as i64;
        // round-to-nearest division
        let half = den.abs() / 2;
        let q = if (num >= 0) == (den > 0) {
            (num + if num >= 0 { half } else { -half }) / den
        } else {
            (num - if num >= 0 { half } else { -half }) / den
        };
        Fxp32(clamp_i64(q))
    }

    /// Absolute value (saturating at `i32::MIN`).
    #[inline]
    pub fn abs(self) -> Self {
        Fxp32(self.0.saturating_abs())
    }

    /// Max of two values.
    #[inline]
    pub fn max(self, rhs: Self) -> Self {
        if self.0 >= rhs.0 {
            self
        } else {
            rhs
        }
    }

    /// Min of two values.
    #[inline]
    pub fn min(self, rhs: Self) -> Self {
        if self.0 <= rhs.0 {
            self
        } else {
            rhs
        }
    }

    /// Arithmetic shift right (divide by 2ⁿ with truncation toward −∞),
    /// the hardware's `2^{-n}` scaling step in Eq. (9).
    #[inline]
    pub fn shr(self, n: u32) -> Self {
        if n >= 31 {
            Fxp32(self.0 >> 31)
        } else {
            Fxp32(self.0 >> n)
        }
    }

    /// Saturating shift left (multiply by 2ⁿ).
    #[inline]
    pub fn shl(self, n: u32) -> Self {
        let wide = (self.0 as i64) << n.min(62);
        Fxp32(clamp_i64(wide))
    }
}

#[inline]
fn clamp_i64(x: i64) -> i32 {
    if x > i32::MAX as i64 {
        i32::MAX
    } else if x < i32::MIN as i64 {
        i32::MIN
    } else {
        x as i32
    }
}

impl Add for Fxp32 {
    type Output = Fxp32;
    #[inline]
    fn add(self, rhs: Self) -> Self {
        self.sat_add(rhs)
    }
}

impl Sub for Fxp32 {
    type Output = Fxp32;
    #[inline]
    fn sub(self, rhs: Self) -> Self {
        self.sat_sub(rhs)
    }
}

impl Mul for Fxp32 {
    type Output = Fxp32;
    #[inline]
    fn mul(self, rhs: Self) -> Self {
        self.sat_mul(rhs)
    }
}

impl Div for Fxp32 {
    type Output = Fxp32;
    #[inline]
    fn div(self, rhs: Self) -> Self {
        self.sat_div(rhs)
    }
}

impl Neg for Fxp32 {
    type Output = Fxp32;
    #[inline]
    fn neg(self) -> Self {
        Fxp32(self.0.saturating_neg())
    }
}

impl fmt::Debug for Fxp32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Fxp32({:.6} raw={})", self.to_f64(), self.0)
    }
}

impl fmt::Display for Fxp32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}", self.to_f64())
    }
}

impl From<f32> for Fxp32 {
    fn from(x: f32) -> Self {
        Fxp32::from_f32(x)
    }
}

impl From<f64> for Fxp32 {
    fn from(x: f64) -> Self {
        Fxp32::from_f64(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_resolution() {
        // Q15.17 resolution is 2^-17 < 1e-5: the paper's precision claim.
        for &x in &[0.0, 1.0, -1.0, 0.5, 3.14159, -2.71828, 100.25, -999.875] {
            let q = Fxp32::from_f64(x);
            assert!((q.to_f64() - x).abs() <= RESOLUTION / 2.0 + 1e-12, "x={x}");
        }
        assert!(RESOLUTION < 1e-5);
    }

    #[test]
    fn exact_small_values() {
        assert_eq!(Fxp32::from_f64(1.0).raw(), ONE);
        assert_eq!(Fxp32::from_f64(-1.0).raw(), -ONE);
        assert_eq!(Fxp32::from_f64(0.5).raw(), ONE / 2);
        assert_eq!(Fxp32::ZERO.raw(), 0);
    }

    #[test]
    fn mul_matches_float() {
        let cases = [(1.5, 2.0), (-3.25, 0.125), (7.75, -7.75), (0.001, 0.001)];
        for &(a, b) in &cases {
            let q = Fxp32::from_f64(a) * Fxp32::from_f64(b);
            assert!(
                (q.to_f64() - a * b).abs() < 2.0 * RESOLUTION,
                "{a}*{b} => {q}"
            );
        }
    }

    #[test]
    fn div_matches_float() {
        let cases = [(1.0, 3.0), (-10.0, 7.0), (0.5, 0.25), (100.0, -9.0)];
        for &(a, b) in &cases {
            let q = Fxp32::from_f64(a) / Fxp32::from_f64(b);
            assert!(
                (q.to_f64() - a / b).abs() < 2.0 * RESOLUTION,
                "{a}/{b} => {q}"
            );
        }
    }

    #[test]
    fn div_by_zero_saturates() {
        assert_eq!(Fxp32::from_f64(1.0) / Fxp32::ZERO, Fxp32::MAX);
        assert_eq!(Fxp32::from_f64(-1.0) / Fxp32::ZERO, Fxp32::MIN);
    }

    #[test]
    fn saturation_add_mul() {
        let big = Fxp32::from_f64(16000.0);
        assert_eq!(big + big, Fxp32::MAX);
        assert_eq!(big * big, Fxp32::MAX);
        assert_eq!(-big - big, Fxp32::MIN);
    }

    #[test]
    fn shifts() {
        let x = Fxp32::from_f64(4.0);
        assert_eq!(x.shr(2).to_f64(), 1.0);
        assert_eq!(x.shl(2).to_f64(), 16.0);
        assert_eq!(Fxp32::from_f64(12000.0).shl(4), Fxp32::MAX);
    }

    #[test]
    fn ordering_matches_value() {
        let a = Fxp32::from_f64(-3.5);
        let b = Fxp32::from_f64(2.25);
        assert!(a < b);
        assert_eq!(a.max(b), b);
        assert_eq!(a.min(b), a);
    }
}
