//! Streaming / online-softmax attention (two-phase, ITA-style [15], [19]).
//!
//! Phase 1 streams the scores once, maintaining the running max `m` and the
//! online normalizer `Z` (Milakov–Gimelshein). The scores are still
//! materialized, because phase 2 needs them to form `P·V`.
//! Phase 2 re-reads the buffer, applies `exp(s_t − m)/Z` and accumulates
//! the value rows.
//!
//! Compared with SwiftKV this performs the same exp work but takes *two*
//! passes and keeps an N-element score buffer — the gap the cycle model
//! prices in Fig. 7(b) (2.15× vs 7.16×).

use super::{dot_f32, HeadProblem};

/// Result of the phase-1 stream: running max and normalizer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OnlineNorm {
    pub max: f32,
    pub z: f32,
}

/// Phase 1: one streaming pass computing scores, the running max and the
/// online normalizer. Returns the materialized scores plus the norm state.
pub fn stream_pass(p: &HeadProblem) -> (Vec<f32>, OnlineNorm) {
    let scale = p.scale();
    let mut scores = Vec::with_capacity(p.len);
    let mut m = f32::NEG_INFINITY;
    let mut z = 0.0f32;
    for t in 0..p.len {
        let s = dot_f32(p.q, p.key(t)) * scale;
        // online normalizer update: rescale Z when the max grows
        if s > m {
            z = z * (m - s).exp() + 1.0;
            m = s;
        } else {
            z += (s - m).exp();
        }
        scores.push(s);
    }
    (scores, OnlineNorm { max: m, z })
}

/// Phase 2: weighted accumulation of the value cache from the buffered
/// scores and the final norm state.
pub fn accumulate_pass(p: &HeadProblem, scores: &[f32], norm: OnlineNorm) -> Vec<f32> {
    let inv_z = 1.0 / norm.z;
    let mut out = vec![0.0f32; p.d];
    for (t, &s) in scores.iter().enumerate() {
        let w = (s - norm.max).exp() * inv_z;
        for (o, &v) in out.iter_mut().zip(p.value(t)) {
            *o += w * v;
        }
    }
    out
}

/// Full two-phase streaming attention.
pub fn attend(p: &HeadProblem) -> Vec<f32> {
    let (scores, norm) = stream_pass(p);
    accumulate_pass(p, &scores, norm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{assert_close, ProblemData};
    use crate::attention::{native, swiftkv};

    #[test]
    fn matches_native() {
        for seed in 0..6 {
            let data = ProblemData::random(seed, 24, 64 + seed as usize * 9, 1.5);
            let p = data.problem();
            assert_close(&attend(&p), &native::attend(&p), 1e-5, "online vs native");
        }
    }

    #[test]
    fn online_normalizer_equals_two_pass() {
        let data = ProblemData::random(77, 16, 128, 3.0);
        let p = data.problem();
        let (scores, norm) = stream_pass(&p);
        let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = scores.iter().map(|s| (s - max).exp()).sum();
        assert!((norm.max - max).abs() < 1e-6);
        assert!((norm.z - z).abs() / z < 1e-5, "{} vs {z}", norm.z);
    }

    #[test]
    fn agrees_with_swiftkv() {
        let data = ProblemData::random(4, 32, 200, 1.0);
        let p = data.problem();
        assert_close(&attend(&p), &swiftkv::attend(&p), 1e-5, "online vs swiftkv");
    }

    #[test]
    fn stable_at_large_magnitudes() {
        let data = ProblemData::random(8, 16, 64, 50.0);
        let out = attend(&data.problem());
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
