//! Runtime ISA dispatch for the hot-loop microkernels.
//!
//! Every inner loop the fused decode path leans on — the f32
//! `dot`/`axpy`/`scale_axpy`/`scale` primitives, the Q15.17 wide-dot and
//! AXPY updates, the INT8 dot and the INT4-unpack W4A8 column MAC — is
//! reached through one [`KernelTable`] of plain `fn` pointers. The table
//! is selected exactly once per process (CPU feature probing via
//! `is_x86_feature_detected!`, overridable with `SWIFTKV_ISA`) and cached
//! in a [`OnceLock`], so steady-state dispatch is a single relaxed load —
//! no per-call feature re-detection, no allocation
//! (`tests/alloc_hotpath.rs` enforces both).
//!
//! ## Numerics contract (per entry, across every dispatch target)
//!
//! - `dot_f32`: within normal f32 re-association noise of the scalar
//!   multi-accumulator version (the AVX2 kernel uses FMA); **not**
//!   bit-identical across ISAs.
//! - `axpy_f32` / `scale_axpy_f32` / `scale_f32`: element-wise, one
//!   IEEE multiply + add per element in scalar program order —
//!   **bit-identical** across all ISAs (the AVX2 kernels deliberately
//!   use mul-then-add, not FMA).
//! - `dot_fxp_wide`, `axpy_fxp`, `scale_axpy_fxp`, `dot_i8`, `w4a8_col`:
//!   exact integer arithmetic — **bit-exact** across all ISAs.
//!
//! `tests/prop_simd_dispatch.rs` enforces the contract by running the
//! scalar table against the natively selected one on the same inputs.
//!
//! ## Override
//!
//! `SWIFTKV_ISA=scalar|avx2|neon` pins the table (panicking with a clear
//! message when the requested ISA is not available on this machine);
//! empty or `native` keeps autodetection. CI runs the tier-1 suite under
//! both `scalar` and `native`.
//!
//! lint: hotpath

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::fxp::Fxp32;

/// The instruction sets a [`KernelTable`] can be built for. All variants
/// exist on every architecture (selection, not compilation, is gated) so
/// `SWIFTKV_ISA` parsing and diagnostics behave identically everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar fallback (the `chunks_exact` multi-accumulator
    /// loops) — always available.
    Scalar,
    /// x86-64 AVX2 + FMA microkernels.
    Avx2,
    /// aarch64 NEON microkernels (f32 lanes; integer entries fall back
    /// to scalar — see `simd_neon.rs`).
    Neon,
}

impl Isa {
    /// Parse a `SWIFTKV_ISA` value. `None` for unknown names; the
    /// special value `native` (or empty) is handled by [`active`], not
    /// here.
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// One fn pointer per dispatched microkernel. Selected once per process;
/// see the module docs for each entry's cross-ISA numerics guarantee.
pub struct KernelTable {
    /// Human-readable ISA name (`"scalar"`, `"avx2"`, `"neon"`).
    pub name: &'static str,
    /// Which ISA this table implements.
    pub isa: Isa,
    /// `Σ a[i]·b[i]` (f32, re-association tolerance).
    pub dot_f32: fn(&[f32], &[f32]) -> f32,
    /// `y ← y + β·x` (f32, bit-identical).
    pub axpy_f32: fn(f32, &mut [f32], &[f32]),
    /// `y ← α·y + x` (f32, bit-identical).
    pub scale_axpy_f32: fn(f32, &mut [f32], &[f32]),
    /// `y ← α·y` (f32, bit-identical).
    pub scale_f32: fn(f32, &mut [f32]),
    /// `Σ raw(a[i])·raw(b[i])` as an unrounded wide i64 — the caller
    /// rounds Q34→Q17 once on writeback (bit-exact).
    pub dot_fxp_wide: fn(&[Fxp32], &[Fxp32]) -> i64,
    /// `y ← y sat+ round(β·x)` per element (bit-exact).
    pub axpy_fxp: fn(Fxp32, &mut [Fxp32], &[Fxp32]),
    /// `y ← round(α·y) sat+ x` per element (bit-exact).
    pub scale_axpy_fxp: fn(Fxp32, &mut [Fxp32], &[Fxp32]),
    /// `Σ a[i]·b[i]` over i8 with an i32 accumulator (bit-exact; callers
    /// keep `len·|a|·|b| ≪ 2³¹` — the W4A8 panels do by construction).
    pub dot_i8: fn(&[i8], &[i8]) -> i32,
    /// One packed-INT4 column MAC'd against an INT8 activation row:
    /// `(packed_col, din, xs) → Σ w[k]·x[k]` (bit-exact).
    pub w4a8_col: fn(&[u8], usize, &[i8]) -> i32,
}

/// The portable fallback table — scalar on every architecture.
pub static SCALAR: KernelTable = KernelTable {
    name: "scalar",
    isa: Isa::Scalar,
    dot_f32: super::simd::scalar::dot,
    axpy_f32: super::simd::scalar::axpy,
    scale_axpy_f32: super::simd::scalar::scale_axpy,
    scale_f32: super::simd::scalar::scale,
    dot_fxp_wide: crate::fxp::vector::dot_wide_scalar,
    axpy_fxp: crate::fxp::vector::axpy_scalar,
    scale_axpy_fxp: crate::fxp::vector::scale_axpy_scalar,
    dot_i8: crate::quant::gemv::dot_i8_scalar,
    w4a8_col: crate::quant::gemv::w4a8_col_scalar,
};

static ACTIVE: OnceLock<&'static KernelTable> = OnceLock::new();
static DETECTIONS: AtomicUsize = AtomicUsize::new(0);

/// The process-wide kernel table: env override or best available ISA,
/// selected on first call and cached forever.
#[inline]
pub fn active() -> &'static KernelTable {
    ACTIVE.get_or_init(select)
}

/// Name of the active table (for startup logging / bench annotations).
pub fn active_name() -> &'static str {
    active().name
}

/// How many times the selection path (env read + CPU feature probing)
/// has run in this process. `tests/alloc_hotpath.rs` asserts this stays
/// at 1 no matter how many kernel calls are made.
pub fn detections() -> usize {
    DETECTIONS.load(Ordering::Relaxed)
}

/// The table for a specific ISA, or `None` when this machine (or this
/// build target) cannot run it. `Scalar` always succeeds — tests use
/// `table_for(Isa::Scalar)` as the reference implementation.
pub fn table_for(isa: Isa) -> Option<&'static KernelTable> {
    match isa {
        Isa::Scalar => Some(&SCALAR),
        Isa::Avx2 => {
            // `not(miri)` mirrors the `simd_avx2` module gate: under Miri
            // the intrinsic tables do not exist and only scalar runs.
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            let t = if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                Some(&super::simd_avx2::TABLE)
            } else {
                None
            };
            #[cfg(not(all(target_arch = "x86_64", not(miri))))]
            let t = None;
            t
        }
        Isa::Neon => {
            // NEON is baseline on aarch64 — no runtime probe needed.
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            let t = Some(&super::simd_neon::TABLE);
            #[cfg(not(all(target_arch = "aarch64", not(miri))))]
            let t = None;
            t
        }
    }
}

/// Best table this machine can run (ignoring the env override).
fn best_available() -> &'static KernelTable {
    for isa in [Isa::Avx2, Isa::Neon] {
        if let Some(t) = table_for(isa) {
            return t;
        }
    }
    &SCALAR
}

fn select() -> &'static KernelTable {
    DETECTIONS.fetch_add(1, Ordering::Relaxed);
    let raw = std::env::var("SWIFTKV_ISA").unwrap_or_default();
    let want = raw.trim();
    if want.is_empty() || want == "native" {
        return best_available();
    }
    let isa = Isa::parse(want).unwrap_or_else(|| {
        panic!("SWIFTKV_ISA='{want}' is not a known ISA (expected scalar|avx2|neon|native)")
    });
    table_for(isa).unwrap_or_else(|| {
        panic!("SWIFTKV_ISA='{want}' requested but this machine/build cannot run it")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_isa_names_only() {
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("avx2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("neon"), Some(Isa::Neon));
        assert_eq!(Isa::parse("avx512"), None);
        assert_eq!(Isa::parse("AVX2"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn scalar_table_is_always_available() {
        let t = table_for(Isa::Scalar).expect("scalar must exist");
        assert_eq!(t.name, "scalar");
        assert_eq!(t.isa, Isa::Scalar);
    }

    #[test]
    fn active_selects_once_and_matches_a_real_table() {
        let a = active();
        let b = active();
        assert!(std::ptr::eq(a, b), "active() must cache its selection");
        assert!(
            table_for(a.isa).is_some_and(|t| std::ptr::eq(t, a)),
            "active table must be reachable via table_for"
        );
        let before = detections();
        assert!(before >= 1);
        for _ in 0..64 {
            let _ = active();
        }
        assert_eq!(detections(), before, "repeat calls must not re-detect");
    }

    #[test]
    fn unavailable_tables_are_none_not_panics() {
        // At most one of avx2/neon can exist on a given target; the
        // other must report None rather than panicking or mis-selecting.
        let have: Vec<Isa> = [Isa::Avx2, Isa::Neon]
            .into_iter()
            .filter(|&i| table_for(i).is_some())
            .collect();
        assert!(have.len() <= 1, "avx2 and neon are mutually exclusive");
        for isa in have {
            let t = table_for(isa).expect("checked above");
            assert_eq!(t.isa, isa);
        }
    }
}
