//! CPU continuous-batching serving over the pure-Rust tiny model — the
//! default-feature serving path (no PJRT required).
//!
//! The engine is **continuous**: work enters through a live intake
//! channel (a [`ServeHandle`]), and the iteration loop polls that
//! channel every step, so a request submitted mid-flight joins the
//! batch as soon as its arrival time passes and a lane frees — there is
//! no drain barrier. The offline entry point
//! ([`CpuServer::serve`]) is a thin wrapper that pre-loads the intake
//! and closes it, which reproduces the old fixed-list scheduling
//! exactly; [`CpuServer::serve_continuous`] runs the engine on its own
//! thread and hands the caller a cloneable [`ServeHandle`] for
//! mid-flight submission with per-request token streams.
//!
//! Prompt tokens are consumed **chunked**: a prefill lane feeds up to
//! [`ServeConfig::prefill_chunk`] prompt tokens per iteration through
//! the fused causal sweep ([`TinyModel::prefill_into`]) instead of one
//! decode step per token, computing the logits projection only when the
//! chunk reaches the last prompt token — the TTFT win of chunked
//! prefill. The chunk is bounded by default so one long prompt cannot
//! stall the decode lanes sharing the iteration; with
//! [`ServeConfig::adaptive_prefill`] the bound additionally **shrinks**
//! when decode lanes are live (`chunk / (1 + n_decode)`, floor 1),
//! because batch-step wall time is the max over lanes — a full-width
//! prefill chunk next to decode lanes stretches every decode lane's
//! inter-token latency by the whole chunk.
//!
//! Decoding is weight-bandwidth bound, so the batch step batches at the
//! **operator** level instead of lane-per-thread: every decode-phase
//! lane (single-token sampling chunk) joins one
//! [`TinyModel::decode_steps_into`] call that streams each packed
//! weight matrix **once for the whole batch** (B lanes pay 1 weight
//! pass per step, not B — surfaced as
//! [`ServeMetrics::weight_passes_per_step`]), while prefill lanes run
//! their chunks per lane. Parallelism comes from a **persistent**
//! [`crate::kernels::WorkerPool`] that lives for the whole run. A lone
//! decode lane skips the pool and runs the inline solo step, so
//! single-lane latency does not regress. Each lane owns its
//! [`DecodeState`]; the KV rows live in **one shared
//! [`crate::kernels::BlockPool`]** sized by
//! [`ServeConfig::kv_block_len`] / [`ServeConfig::kv_pool_blocks`].
//! Recycled lanes restart at position 0 via
//! [`DecodeState::reset_for_reuse`], which returns their blocks to the
//! pool for other lanes — reclamation, not re-allocation. Continuous
//! admission preserves the per-lane bit-exactness contract: a request's
//! tokens are identical to its solo `generate()` run no matter when it
//! joined (tests/prop_continuous.rs asserts this end to end).

use super::admission::{AdmissionDecision, AdmissionPolicy, StepEstimate};
use super::batcher::{Batcher, CancelKind};
use super::faults::{FaultKind, FaultPlan};
use super::metrics::{Percentiles, ServeMetrics};
use super::session::{Session, SessionOutcome, SessionPhase};
use super::submit::{EngineCtl, ServeHandle, Submission, TokenEvent};
use crate::kernels::{BlockPool, SharedMut, WorkerPool};
use crate::model::tiny::{argmax, panic_message, BatchLane, DecodeState};
use crate::model::{LlmConfig, NumericsMode, Request, TinyModel, DEFAULT_KV_BLOCK_LEN};
use crate::sim::{layer_sched, ArchConfig};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Default prompt tokens a lane may consume in one chunked-prefill step
/// (`swiftkv serve --prefill-chunk` overrides; `0` = whole prompt).
/// Bounded so one long prompt cannot monopolize an iteration: step wall
/// time is the max over lanes, so an unbounded prefill chunk would stall
/// every decode lane for the whole prompt instead of `8` tokens' worth.
pub const DEFAULT_PREFILL_CHUNK: usize = 8;

/// CPU serving configuration.
///
/// Construct through [`ServeConfig::builder`] — the struct is
/// `#[non_exhaustive]`, so downstream code cannot build it as a literal
/// (and new knobs can land without breaking call sites). The builder
/// validates at build time what used to be asserts deep inside the
/// serve loop.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct ServeConfig {
    /// Number of decode lanes (threads at full occupancy).
    pub lanes: usize,
    /// Numerics mode every lane decodes in.
    pub mode: NumericsMode,
    /// Safety cap on batch iterations (0 = unlimited).
    pub max_iterations: u64,
    /// Model config used for the simulated-accelerator metrics.
    pub sim_model: LlmConfig,
    /// Tokens per KV cache block in the shared pool.
    pub kv_block_len: usize,
    /// Total blocks in the shared pool; `0` sizes it for the worst case
    /// (`lanes × blocks_per_seq`, i.e. every lane at full context).
    pub kv_pool_blocks: usize,
    /// Max prompt tokens per lane per iteration (chunked prefill
    /// through the fused causal sweep); `0` = whole remaining prompt in
    /// one step. `1` reproduces the old one-decode-step-per-prompt-token
    /// prefill.
    pub prefill_chunk: usize,
    /// Shrink the prefill chunk when decode lanes are live
    /// (`prefill_chunk / (1 + n_decode)`, floor 1): batch-step wall time
    /// is the max over lanes, so a full chunk beside decode lanes
    /// stretches their inter-token latency. Off by default — the fixed
    /// chunk keeps iteration schedules reproducible for the pinned
    /// scheduling tests; the load generator and `--adaptive-prefill`
    /// turn it on.
    pub adaptive_prefill: bool,
    /// OS threads stepping the engine (the serving thread plus
    /// `workers - 1` persistent pool workers); `0` = one per available
    /// CPU, `1` = fully inline (no pool).
    pub workers: usize,
    /// Deterministic fault plan injected into the run (`swiftkv serve
    /// --faults`, `SWIFTKV_FAULTS`, `SWIFTKV_FAULT_SEED`); `None` (the
    /// default) serves faithfully.
    pub faults: Option<FaultPlan>,
    /// Times one request may be preempted-and-requeued before it is
    /// retired as failed (bounded retry — no preemption livelock when
    /// the pool cannot ever fit the request).
    pub max_requeues: u32,
    /// Admission-queue depth cap: arrivals past this many waiting
    /// requests are shed with [`SessionOutcome::Shed`] (`503 +
    /// Retry-After` at the front door). `0` = unbounded (the
    /// pre-overload-layer behavior).
    pub max_queue_depth: usize,
    /// Graceful-shutdown drain bound, milliseconds: after a shutdown
    /// request, running lanes get this long to finish before they are
    /// cancelled ([`CancelKind::Drain`]). `0` cancels immediately.
    pub drain_ms: u64,
    /// Capacity of each request's bounded event stream (tokens a client
    /// may fall behind before it is cancelled as a slow client). Must be
    /// ≥ 1; sized well above any sane `gen_len` by default so only a
    /// genuinely stalled client ever hits it.
    pub event_buffer: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            lanes: 4,
            mode: NumericsMode::DesktopF32,
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
            kv_block_len: DEFAULT_KV_BLOCK_LEN,
            kv_pool_blocks: 0,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            adaptive_prefill: false,
            workers: 0,
            faults: None,
            max_requeues: 3,
            max_queue_depth: 0,
            drain_ms: 5_000,
            event_buffer: 256,
        }
    }
}

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder {
            cfg: ServeConfig::default(),
        }
    }
}

/// Validating builder for [`ServeConfig`]. Every setter mirrors a
/// config field; [`ServeConfigBuilder::build`] rejects inconsistent
/// shapes (zero lanes, zero-token KV blocks) before a server is ever
/// constructed.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn lanes(mut self, n: usize) -> Self {
        self.cfg.lanes = n;
        self
    }
    pub fn mode(mut self, mode: NumericsMode) -> Self {
        self.cfg.mode = mode;
        self
    }
    pub fn max_iterations(mut self, n: u64) -> Self {
        self.cfg.max_iterations = n;
        self
    }
    pub fn sim_model(mut self, m: LlmConfig) -> Self {
        self.cfg.sim_model = m;
        self
    }
    pub fn kv_block_len(mut self, n: usize) -> Self {
        self.cfg.kv_block_len = n;
        self
    }
    pub fn kv_pool_blocks(mut self, n: usize) -> Self {
        self.cfg.kv_pool_blocks = n;
        self
    }
    pub fn prefill_chunk(mut self, n: usize) -> Self {
        self.cfg.prefill_chunk = n;
        self
    }
    pub fn adaptive_prefill(mut self, on: bool) -> Self {
        self.cfg.adaptive_prefill = on;
        self
    }
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }
    pub fn faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.cfg.faults = plan;
        self
    }
    pub fn max_requeues(mut self, n: u32) -> Self {
        self.cfg.max_requeues = n;
        self
    }
    pub fn max_queue_depth(mut self, n: usize) -> Self {
        self.cfg.max_queue_depth = n;
        self
    }
    pub fn drain_ms(mut self, ms: u64) -> Self {
        self.cfg.drain_ms = ms;
        self
    }
    pub fn event_buffer(mut self, n: usize) -> Self {
        self.cfg.event_buffer = n;
        self
    }

    /// Validate and produce the config. Errors name the offending knob:
    /// at least one lane, at least one token per KV block, and — when
    /// the pool is explicitly sized — at least one block to draw from.
    pub fn build(self) -> Result<ServeConfig, String> {
        let c = &self.cfg;
        if c.lanes == 0 {
            return Err("serve config: lanes must be >= 1".to_string());
        }
        if c.kv_block_len == 0 {
            return Err("serve config: kv_block_len must be >= 1 token per block".to_string());
        }
        if c.event_buffer == 0 {
            return Err("serve config: event_buffer must be >= 1 event".to_string());
        }
        if c.kv_pool_blocks > 0 && c.kv_pool_blocks < c.lanes.min(2) {
            // a 1-block pool can still serve (one lane at a time, the
            // preemption path schedules the rest), but 0 explicit blocks
            // would deadlock every lane forever — reject the nonsense
            // shape where an explicit pool cannot hold even one block
            return Err(format!(
                "serve config: kv_pool_blocks = {} cannot back even one lane",
                c.kv_pool_blocks
            ));
        }
        Ok(self.cfg)
    }
}

/// One prefill-phase lane's work for an iteration: a prompt chunk fed
/// through the fused causal sweep (`samples` = the chunk ends on the
/// last prompt token, so its logits are wanted).
struct PrefillTask<'a> {
    /// Global lane index (maps a contained fault back to its lane).
    lane: usize,
    st: &'a mut DecodeState,
    tokens: &'a [u32],
    samples: bool,
    out: &'a mut [f32],
    /// Fault injection: panic inside this task (contained by the
    /// runner, like any organic panic would be).
    inject_panic: bool,
    /// A contained panic's message, when the task faulted.
    fault: Option<String>,
}

/// Result of a CPU serving run.
pub struct CpuServeReport {
    pub sessions: Vec<Session>,
    pub metrics: ServeMetrics,
    /// The shared KV block pool the lanes served from (all blocks are
    /// back on its free list by the time the engine returns).
    pub kv_pool: Arc<BlockPool>,
}

/// Per-request event sink: the streaming half of one submission, plus
/// how many tokens have been streamed (so a preempted request's
/// bit-identical re-decode never re-sends a position) and the client
/// health the engine has observed through `try_send`.
struct EventSink {
    tx: SyncSender<TokenEvent>,
    streamed: usize,
    /// The receiver is gone (dropped `PendingRequest` / dead SSE
    /// socket, or an injected `disconnect@` fault): cancel the lane at
    /// the next iteration boundary.
    client_gone: bool,
    /// The bounded stream filled (or a `slowclient@` fault fired): the
    /// client cannot keep up; cancel rather than buffer unboundedly.
    slow: bool,
}

/// The engine's intake state: submissions received but not yet due
/// (arrival-time gating), per-request event sinks, and submission
/// timestamps for the time-in-queue percentiles.
struct Intake {
    /// Received, arrival time not yet passed (kept in receipt order —
    /// ties admit in submission order, like the old sorted VecDeque).
    pending: Vec<Request>,
    sinks: BTreeMap<u64, EventSink>,
    /// Wall ms (engine clock) each request id reached the engine.
    submit_ms: BTreeMap<u64, f64>,
    /// Whether any `ServeHandle` clone is still alive.
    open: bool,
}

impl Intake {
    fn accept(&mut self, sub: Submission, now_ms: f64) {
        if let Some(tx) = sub.events {
            self.sinks.insert(
                sub.request.id,
                EventSink {
                    tx,
                    streamed: 0,
                    client_gone: false,
                    slow: false,
                },
            );
        }
        self.submit_ms.insert(sub.request.id, now_ms);
        self.pending.push(sub.request);
    }

    /// Non-blocking drain of the intake channel.
    fn drain(&mut self, rx: &Receiver<Submission>, now_ms: f64) {
        while self.open {
            match rx.try_recv() {
                Ok(sub) => self.accept(sub, now_ms),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.open = false;
                    break;
                }
            }
        }
    }
}

/// Send `Done` events for sessions retired since the last scan.
/// `try_send`, never `send`: a full buffer means the client is being
/// cancelled for slowness anyway, and a blocking send here would let
/// one dead-slow client stall every lane in the engine.
fn notify_finished(finished: &[Session], seen: &mut usize, sinks: &mut BTreeMap<u64, EventSink>) {
    for s in &finished[*seen..] {
        if let Some(sink) = sinks.remove(&s.request.id) {
            // a gone or stalled receiver just means the submitter
            // stopped caring
            let _ = sink.tx.try_send(TokenEvent::Done(s.outcome.clone()));
        }
    }
    *seen = finished.len();
}

/// The CPU decode server.
pub struct CpuServer<'m> {
    model: &'m TinyModel,
    cfg: ServeConfig,
}

impl<'m> CpuServer<'m> {
    pub fn new(model: &'m TinyModel, cfg: ServeConfig) -> Self {
        assert!(cfg.lanes >= 1, "need at least one lane");
        assert!(cfg.kv_block_len >= 1, "need at least one token per KV block");
        assert!(
            model.n_kv_heads >= 1 && model.n_heads % model.n_kv_heads == 0,
            "model GQA shape invalid: {} query heads over {} KV heads",
            model.n_heads,
            model.n_kv_heads
        );
        CpuServer { model, cfg }
    }

    /// Blocks the shared pool will hold: the configured count, or the
    /// worst case (every lane at full context) when unset.
    fn pool_blocks(&self) -> usize {
        if self.cfg.kv_pool_blocks > 0 {
            self.cfg.kv_pool_blocks
        } else {
            self.cfg.lanes * self.model.blocks_per_seq(self.cfg.kv_block_len)
        }
    }

    /// Serve a fixed request list to completion (the offline path):
    /// pre-loads the intake with every request and closes it, then runs
    /// the engine inline. Arrival times are honoured in iteration order,
    /// and the iteration schedule is identical to pre-continuous
    /// serving — the engine sees the whole list before its first step.
    pub fn serve(&self, requests: Vec<Request>) -> CpuServeReport {
        let (tx, rx) = std::sync::mpsc::channel();
        for r in requests {
            // the receiver is alive in this scope: send cannot fail
            let _ = tx.send(Submission {
                request: r,
                events: None,
            });
        }
        drop(tx);
        self.run_engine(rx, EngineCtl::new(self.cfg.event_buffer))
    }

    /// Run the engine continuously on its own (scoped) thread and give
    /// `f` a [`ServeHandle`] to submit against — requests join
    /// mid-flight as lanes free. The engine drains and retires once `f`
    /// returns and every handle clone is dropped; an engine panic is
    /// re-raised on this thread after `f` completes.
    pub fn serve_continuous<R>(&self, f: impl FnOnce(&ServeHandle) -> R) -> (CpuServeReport, R) {
        let (tx, rx) = std::sync::mpsc::channel();
        let ctl = EngineCtl::new(self.cfg.event_buffer);
        let handle = ServeHandle::new(tx, ctl.clone());
        std::thread::scope(|s| {
            let engine = s.spawn(move || self.run_engine(rx, ctl));
            let out = f(&handle);
            // close the intake (gate latch + channel disconnect): the
            // engine finishes what it holds, then exits its loop
            drop(handle);
            match engine.join() {
                Ok(report) => (report, out),
                Err(cause) => std::panic::resume_unwind(cause),
            }
        })
    }

    /// The continuous-batching engine loop: poll the intake, gate
    /// arrivals, run admission control, admit into free lanes, take one
    /// chunked batch step, stream sampled tokens, retire finished
    /// sessions — every iteration, with no drain barrier anywhere. When
    /// every lane is idle the engine parks on `ctl`'s gate (woken by
    /// submission, intake close, or shutdown) instead of polling.
    fn run_engine(&self, rx: Receiver<Submission>, ctl: Arc<EngineCtl>) -> CpuServeReport {
        let lanes = self.cfg.lanes;
        let model = self.model;
        let mode = self.cfg.mode;
        let vocab = model.vocab;
        let mut batcher = Batcher::new(lanes, model.n_ctx);
        // one block pool for every lane: blocks migrate between lanes as
        // sequences retire (reclamation in reset_for_reuse / Drop)
        let kv_pool = model.new_pool(self.pool_blocks(), self.cfg.kv_block_len);
        let mut states: Vec<DecodeState> = (0..lanes)
            .map(|_| model.new_state_in(kv_pool.clone()))
            .collect();
        let mut logits = vec![0.0f32; lanes * vocab];

        let mut intake = Intake {
            pending: Vec::new(),
            sinks: BTreeMap::new(),
            submit_ms: BTreeMap::new(),
            open: true,
        };
        let mut finished_seen = 0usize;

        // the persistent worker pool for the whole run: the batched
        // decode step splits its GEMMs by output columns and its
        // attention phase by lane, prefill chunks run one task per lane
        // — no per-iteration thread spawns
        let threads = if self.cfg.workers > 0 {
            self.cfg.workers
        } else {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        };
        let worker_pool = (threads > 1).then(|| WorkerPool::new(threads - 1));
        let mut batch_scratch = model.new_batch_scratch();

        let t0 = Instant::now();
        let mut iteration = 0u64;
        let mut step_ms: Vec<f64> = Vec::new();
        let mut occupancy_acc = 0.0;
        let mut sim_cycles: u64 = 0;
        let arch = ArchConfig::default();
        let mut iter_end_ms: Vec<f64> = Vec::new();
        let mut batch_widths: Vec<f64> = Vec::new();
        let mut queue_depths: Vec<f64> = Vec::new();
        let mut weight_passes: u64 = 0;
        let mut adaptive_shrinks: u64 = 0;

        // overload layer: admission policy + the step-time estimate its
        // deadline proof and Retry-After hints draw from
        let policy = AdmissionPolicy::new(self.cfg.max_queue_depth);
        let mut est = StepEstimate::default();
        ctl.status.set_queue_cap(self.cfg.max_queue_depth);
        let mut draining = false;
        let mut drain_deadline_ms = f64::INFINITY;
        let mut deadline_rejected: u64 = 0;
        let mut idle_parks: u64 = 0;
        let mut burst_seq = 0u64;

        // 0 = unbounded: a whole remaining prompt in one chunked step
        let max_prefill = if self.cfg.prefill_chunk == 0 {
            usize::MAX
        } else {
            self.cfg.prefill_chunk
        };

        let faults = self.cfg.faults.as_ref().filter(|p| !p.is_empty());
        loop {
            let now_ms = t0.elapsed().as_secs_f64() * 1e3;
            // shutdown latch: admission closes now — everything already
            // queued is shed, running lanes get `drain_ms` to finish
            if !draining && ctl.gate.shutdown_requested() {
                draining = true;
                ctl.status.set_draining();
                drain_deadline_ms = now_ms + self.cfg.drain_ms as f64;
                batcher.shed_queue(iteration);
            }
            // eventcount snapshot BEFORE the intake drain: a submission
            // that lands after the drain bumps the gate past this value,
            // so the park below returns immediately instead of sleeping
            // through it (no lost wakeups — loom_engine.rs checks the
            // protocol)
            let gate_seq = ctl.gate.seq();
            // the gate's intake-closed latch is set by the last
            // ServeHandle drop *before* its channel sender disconnects:
            // observing it before the drain means the drain sees every
            // submission that will ever arrive
            let closed_before_drain = ctl.gate.intake_closed();
            // live intake: pull every submission that has arrived on the
            // channel since the last step — this is what lets requests
            // join mid-flight
            intake.drain(&rx, now_ms);
            if closed_before_drain {
                intake.open = false;
            }
            // burst fault: slam the admission path with synthetic
            // requests this iteration (they flow through the same
            // arrival gating and shedding as real traffic; ids live in a
            // reserved high range so they never collide with real ones)
            if let Some(plan) = faults {
                if let Some(n) = plan.fire_burst(iteration) {
                    let count = if n == 0 { 4 * lanes } else { n };
                    for _ in 0..count {
                        let id = (1u64 << 40) | burst_seq;
                        burst_seq += 1;
                        let prompt: Vec<u32> =
                            (0..4).map(|j| ((burst_seq as usize + j) % vocab) as u32).collect();
                        intake.pending.push(
                            Request::new(id, prompt).gen_len(3).arrival_ms(now_ms as u64),
                        );
                    }
                }
            }
            // arrival gating + admission control: move every due request
            // (receipt order) through the shedding policy into the
            // admission queue. Oversized requests are rejected and their
            // streams closed with `Rejected`; a draining engine sheds
            // everything, due or not — no new work after shutdown.
            let mut i = 0;
            while i < intake.pending.len() {
                let due = intake.pending[i].arrival_ms as f64 <= now_ms;
                if !due && !draining {
                    i += 1;
                    continue;
                }
                let r = intake.pending.remove(i);
                if draining {
                    batcher.shed(r, iteration);
                    continue;
                }
                match policy.decide(&r, batcher.queue_len(), now_ms, &est) {
                    AdmissionDecision::Admit => {
                        if let Err(rejected) = batcher.submit(r) {
                            // dropped by design, but never silently: the
                            // batcher counted it, and a streaming
                            // submitter is told directly
                            if let Some(sink) = intake.sinks.remove(&rejected.id) {
                                let _ =
                                    sink.tx.try_send(TokenEvent::Done(SessionOutcome::Rejected));
                            }
                        }
                    }
                    AdmissionDecision::Shed { retry_after_ms } => {
                        // tail-drop keeps oldest-first fairness: queued
                        // requests hold their FIFO slots, the newcomer
                        // backs off (`Retry-After` rides the status
                        // block to the front door)
                        ctl.status.record_shed(retry_after_ms);
                        batcher.shed(r, iteration);
                    }
                    AdmissionDecision::DeadlineUnmeetable => {
                        deadline_rejected += 1;
                        batcher.reject_deadline(r, iteration);
                    }
                }
            }
            // deadline pass before admission: an expired queued request
            // must not take a lane, and an expired running lane's KV
            // blocks are reclaimed in time for this same iteration's
            // admissions
            for i in batcher.expire_deadlines(now_ms, iteration) {
                if states[i].pos != 0 || states[i].kv_blocks_in_use() > 0 {
                    states[i].reset_for_reuse();
                }
            }
            // client-cancellation pass: lanes whose client vanished or
            // stalled (observed through `try_send`, or injected by
            // `disconnect@`/`slowclient@` faults) retire as `Cancelled`
            // at this iteration boundary — KV blocks reclaimed before
            // this iteration's admissions, co-batched survivors
            // untouched (prop_cancel.rs asserts bit-exactness)
            for i in 0..lanes {
                let Some(kind) = batcher.lane_session(i).and_then(|s| {
                    let sink = intake.sinks.get(&s.request.id)?;
                    if sink.client_gone {
                        Some(CancelKind::Disconnect)
                    } else if sink.slow {
                        Some(CancelKind::SlowClient)
                    } else {
                        None
                    }
                }) else {
                    continue;
                };
                batcher.cancel_lane(i, iteration, kind);
                if states[i].pos != 0 || states[i].kv_blocks_in_use() > 0 {
                    states[i].reset_for_reuse();
                }
            }
            // drain bound: shutdown may not wait forever — lanes still
            // running past the bound are cancelled, blocks reclaimed,
            // and the engine exits through the normal audit path
            if draining && now_ms >= drain_deadline_ms && !batcher.is_drained() {
                for i in 0..lanes {
                    if batcher.cancel_lane(i, iteration, CancelKind::Drain).is_some()
                        && (states[i].pos != 0 || states[i].kv_blocks_in_use() > 0)
                    {
                        states[i].reset_for_reuse();
                    }
                }
                batcher.shed_queue(iteration);
            }
            batcher.admit(iteration);
            notify_finished(&batcher.finished, &mut finished_seen, &mut intake.sinks);
            ctl.status.set_depths(batcher.queue_len(), batcher.active());
            if batcher.is_drained() {
                if draining {
                    break;
                }
                if intake.pending.is_empty() && !intake.open {
                    break;
                }
                // idle: nothing on a lane. Park on the gate — a
                // submission, intake close, or shutdown notifies it —
                // bounded by the gap to the earliest scheduled arrival
                // when one is pending (correctness never depends on the
                // timeout; it only honors `arrival_ms` schedules).
                let timeout = intake
                    .pending
                    .iter()
                    .map(|r| r.arrival_ms)
                    .min()
                    .map(|t| ((t as f64 - now_ms).max(0.0) as u64).saturating_add(1));
                idle_parks += 1;
                ctl.gate.park(gate_seq, timeout);
                continue;
            }
            queue_depths.push(batcher.queue_len() as f64);

            // adaptive prefill co-scheduling: with live decode lanes,
            // shrink the chunk so a prefill lane cannot stretch the
            // whole batch step (wall time is the max over lanes)
            let step_prefill = if self.cfg.adaptive_prefill && self.cfg.prefill_chunk > 0 {
                let mut n_decode = 0usize;
                let mut n_prefill = 0usize;
                for i in 0..lanes {
                    match batcher.lane_session(i).map(|s| s.phase()) {
                        Some(SessionPhase::Decode) => n_decode += 1,
                        Some(SessionPhase::Prefill) => n_prefill += 1,
                        _ => {}
                    }
                }
                if n_decode > 0 && n_prefill > 0 {
                    let shrunk = (self.cfg.prefill_chunk / (1 + n_decode)).max(1);
                    if shrunk < max_prefill {
                        adaptive_shrinks += 1;
                    }
                    shrunk
                } else {
                    max_prefill
                }
            } else {
                max_prefill
            };

            let chunks = batcher.gather_chunks(step_prefill);
            let mut fed: Vec<usize> = chunks.iter().map(|c| c.tokens.len()).collect();
            let was_active: Vec<bool> = chunks.iter().map(|c| c.active).collect();
            let pos_v: Vec<usize> = chunks.iter().map(|c| c.pos).collect();
            // lane → request id and tokens-generated-so-far, captured
            // before the chunk borrows end (token streaming needs them
            // after the step)
            let req_ids: Vec<u64> = chunks.iter().map(|c| c.request_id).collect();
            let gen_before: Vec<usize> = chunks.iter().map(|c| c.generated).collect();
            occupancy_acc += batcher.occupancy();

            // lanes starting a fresh session restart their decode state
            // BEFORE the capacity precheck, so a recycled lane's old
            // blocks are back on the free list when grants are computed
            for (i, st) in states.iter_mut().enumerate() {
                if was_active[i] && pos_v[i] == 0 && st.pos != 0 {
                    st.reset_for_reuse();
                }
            }

            // KV-capacity precheck: grant block growth oldest-lane-first
            // from the pool's free list. A lane whose growth cannot be
            // granted stalls (`fed = 0`, no progress this iteration)
            // instead of panicking the pool mid-step; it retries every
            // iteration as retirements return blocks. An armed `oom@`
            // fault makes the free list look empty, forcing this path
            // deterministically.
            let oom_armed = faults.is_some_and(|p| p.oom_armed(iteration));
            let mut free = if oom_armed { 0 } else { kv_pool.free_blocks() };
            let mut order: Vec<usize> = (0..lanes).filter(|&i| was_active[i]).collect();
            order.sort_by_key(|&i| {
                (batcher.lane_session(i).map_or(u64::MAX, |s| s.admitted_at), i)
            });
            for &i in &order {
                let need = states[i].kv_blocks_needed(pos_v[i] + fed[i]);
                if need <= free {
                    free -= need;
                } else {
                    fed[i] = 0;
                }
            }
            if !order.is_empty() && order.iter().all(|&i| fed[i] == 0) {
                // no lane can take a step: preempt the youngest-admitted
                // lane — discard its progress, return its KV blocks,
                // requeue its request (bounded retries) — and rerun the
                // scheduler with the freed capacity
                if let Some(&victim) = order.last() {
                    drop(chunks);
                    states[victim].reset_for_reuse();
                    batcher.preempt_lane(victim, iteration, self.cfg.max_requeues);
                    notify_finished(&batcher.finished, &mut finished_seen, &mut intake.sinks);
                    if oom_armed {
                        if let Some(p) = faults {
                            p.oom_fired(iteration);
                        }
                    }
                    iteration += 1;
                    if self.cfg.max_iterations > 0 && iteration >= self.cfg.max_iterations {
                        break;
                    }
                    continue;
                }
            }
            let sampling: Vec<bool> =
                (0..lanes).map(|i| fed[i] > 0 && chunks[i].samples).collect();

            // per-lane fault triggers: a plan entry aimed at (request,
            // step) fires on the sampling chunk for that step
            let mut inject_panic = vec![false; lanes];
            if let Some(plan) = faults {
                for i in 0..lanes {
                    if !sampling[i] {
                        continue;
                    }
                    match plan.fire_lane_fault(chunks[i].request_id, chunks[i].generated) {
                        Some(FaultKind::LanePanic) => inject_panic[i] = true,
                        Some(FaultKind::NanActivations) => {
                            // poison the f32 KV rows this step attends
                            // over — surfaces as non-finite logits below
                            states[i].poison_kv_nan();
                        }
                        None => {}
                    }
                }
            }

            // partition the progressing lanes: single-token sampling
            // chunks are decode-phase and batch into ONE shared-weight
            // step; multi-token or non-sampling chunks (prefill) run per
            // lane. B batched lanes stream the weight set once, not B.
            let is_batched = |i: usize| fed[i] == 1 && chunks[i].samples;
            let n_batched = (0..lanes).filter(|&i| is_batched(i)).count();
            let n_prefill = (0..lanes).filter(|&i| fed[i] > 0).count() - n_batched;
            // contained per-lane faults from this iteration's step
            let mut lane_faults: Vec<Option<String>> = vec![None; lanes];

            let ts = Instant::now();
            // 1) prefill lanes: chunked prefill through the fused causal
            //    sweep, one persistent-pool task per lane (logits only
            //    when the chunk ends on a sampling position)
            if n_prefill > 0 {
                let mut tasks: Vec<PrefillTask> = states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                    .filter(|(i, _)| fed[*i] > 0 && !is_batched(*i))
                    .map(|(i, (st, out))| PrefillTask {
                        lane: i,
                        st,
                        tokens: chunks[i].tokens,
                        samples: chunks[i].samples,
                        out,
                        inject_panic: inject_panic[i],
                        fault: None,
                    })
                    .collect();
                let run_one = |t: &mut PrefillTask<'_>| {
                    // containment: a panic inside one lane's chunk
                    // (injected or organic) faults that lane only — the
                    // worker running it survives, co-scheduled lanes
                    // never notice
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        assert!(!t.inject_panic, "injected fault: lane panic during prefill");
                        let out = if t.samples { Some(&mut t.out[..]) } else { None };
                        model.prefill_into(t.st, t.tokens, mode, out);
                    }));
                    if let Err(cause) = r {
                        t.fault = Some(panic_message(&*cause));
                    }
                };
                match &worker_pool {
                    Some(p) if tasks.len() > 1 => {
                        let ptr = SharedMut::new(tasks.as_mut_ptr());
                        p.run(tasks.len(), |i| {
                            // SAFETY: task indices are distinct, so each
                            // task is this index's only reference
                            run_one(unsafe { &mut *ptr.get().add(i) });
                        });
                    }
                    _ => {
                        for t in tasks.iter_mut() {
                            run_one(t);
                        }
                    }
                }
                for t in &tasks {
                    if let Some(msg) = &t.fault {
                        lane_faults[t.lane] = Some(msg.clone());
                    }
                }
            }
            // 2) decode lanes: one batched step, weights streamed once
            //    for the whole batch; a lone lane runs the inline solo
            //    path (operator splitting cannot beat it at width 1)
            if n_batched > 0 {
                let batched_idx: Vec<usize> = (0..lanes).filter(|&i| is_batched(i)).collect();
                let mut blanes: Vec<BatchLane> = states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                    .filter(|(i, _)| is_batched(*i))
                    .map(|(i, (st, out))| BatchLane {
                        state: st,
                        // u32::MAX is out of range for every vocab: an
                        // injected panic rides the step's own token
                        // validation, like real poisoned input would
                        token: if inject_panic[i] {
                            u32::MAX
                        } else {
                            chunks[i].tokens[0]
                        },
                        logits: out,
                    })
                    .collect();
                if let [lane] = &mut blanes[..] {
                    // a lone decode lane takes the solo step verbatim —
                    // no batch-scratch gather/scatter, no pool — behind
                    // the same per-lane containment as the batched path
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        model.decode_step_into(lane.state, lane.token, mode, lane.logits);
                    }));
                    if let Err(cause) = r {
                        lane_faults[batched_idx[0]] = Some(panic_message(&*cause));
                    }
                } else {
                    for f in model.try_decode_steps_into(
                        &mut blanes,
                        mode,
                        &mut batch_scratch,
                        worker_pool.as_ref(),
                    ) {
                        lane_faults[batched_idx[f.lane]] = Some(f.message);
                    }
                }
            }
            let this_step_ms = ts.elapsed().as_secs_f64() * 1e3;
            step_ms.push(this_step_ms);
            // feed the admission policy's step-time estimate (deadline
            // lower bound + Retry-After sizing)
            est.record(this_step_ms);

            // weight-streaming accounting: the batched decode group pays
            // one layer-stack weight pass regardless of its width; a
            // prefill lane pays one per chunk token (prefill_into runs
            // the per-token QKV/O/MLP GEMVs for every token it feeds)
            let prefill_passes: u64 = (0..lanes)
                .filter(|&i| fed[i] > 0 && !is_batched(i))
                .map(|i| fed[i] as u64)
                .sum();
            weight_passes += prefill_passes + u64::from(n_batched > 0);
            if n_batched > 0 {
                batch_widths.push(n_batched as f64);
            }

            // simulated accelerator cost: a chunked iteration is billed
            // one simulated decode step per consumed token position —
            // lanes run in lockstep, so the batch pays the longest chunk
            // at the largest live context, token by token. With fed == 1
            // everywhere this reduces exactly to the old
            // one-simulate_token-per-iteration accounting.
            let max_fed = (0..lanes)
                .filter(|&i| fed[i] > 0)
                .map(|i| fed[i])
                .max()
                .unwrap_or(1);
            let base_ctx = (0..lanes)
                .filter(|&i| fed[i] > 0)
                .map(|i| pos_v[i])
                .max()
                .unwrap_or(0);
            for k in 1..=max_fed {
                let sim = layer_sched::simulate_token(&arch, &self.cfg.sim_model, base_ctx + k);
                sim_cycles += sim.total_cycles;
            }

            // fault retirement: a contained lane panic fails *that*
            // request only — its KV blocks go back to the pool, the lane
            // is recycled for the next admission, and every co-batched
            // lane's output this iteration is bit-exact (the fault
            // integration tests assert this)
            drop(chunks);
            for i in 0..lanes {
                let Some(msg) = lane_faults[i].take() else {
                    continue;
                };
                fed[i] = 0;
                batcher.fail_lane(i, iteration, &msg);
                states[i].reset_for_reuse();
            }
            // NaN firewall: a lane whose logits went non-finite (e.g.
            // poisoned activations) fails per-request instead of
            // emitting garbage tokens for the rest of its generation
            for i in 0..lanes {
                if fed[i] > 0
                    && sampling[i]
                    && logits[i * vocab..(i + 1) * vocab]
                        .iter()
                        .any(|v| !v.is_finite())
                {
                    fed[i] = 0;
                    batcher.fail_lane(i, iteration, "non-finite logits");
                    states[i].reset_for_reuse();
                }
            }

            // greedy sample — only for lanes whose chunk ended on a
            // sampling position; idle, stalled, and faulted lanes and
            // mid-prompt prefill chunks skip the argmax entirely (their
            // logits are stale or were never computed)
            let samples: Vec<u32> = (0..lanes)
                .map(|i| {
                    if fed[i] > 0 && sampling[i] {
                        argmax(&logits[i * vocab..(i + 1) * vocab]) as u32
                    } else {
                        0
                    }
                })
                .collect();
            // token streaming: each freshly sampled position goes out on
            // its request's event stream. A requeued request re-decodes
            // already-streamed positions bit-identically — the per-sink
            // high-water mark keeps them from being re-sent. Sends are
            // `try_send` on a bounded channel: `Full` marks the client
            // slow, `Disconnected` marks it gone, and either cancels the
            // lane at the next iteration boundary instead of blocking
            // the whole batch behind one client.
            for i in 0..lanes {
                if fed[i] == 0 || !sampling[i] {
                    continue;
                }
                if let Some(sink) = intake.sinks.get_mut(&req_ids[i]) {
                    if let Some(plan) = faults {
                        // injected client behavior, checked at the same
                        // boundary the organic signals surface on:
                        // disconnect after `streamed` tokens, or a stall
                        // from the first token
                        if plan.fire_disconnect(req_ids[i], sink.streamed) {
                            sink.client_gone = true;
                        }
                        if plan.fire_slowclient(req_ids[i]) {
                            sink.slow = true;
                        }
                    }
                    if sink.client_gone || sink.slow {
                        continue;
                    }
                    if gen_before[i] == sink.streamed {
                        match sink.tx.try_send(TokenEvent::Token(samples[i])) {
                            Ok(()) => {
                                sink.streamed += 1;
                                if let Some(plan) = faults {
                                    if plan.fire_disconnect(req_ids[i], sink.streamed) {
                                        sink.client_gone = true;
                                    }
                                }
                            }
                            Err(TrySendError::Full(_)) => sink.slow = true,
                            Err(TrySendError::Disconnected(_)) => sink.client_gone = true,
                        }
                    }
                }
            }
            let retired = batcher.scatter_chunk_outputs(&fed, &samples, iteration);
            if !retired.is_empty() {
                // reclaim at retirement, not at the lane's next admission:
                // an idle lane must not pin a dead sequence's blocks while
                // other lanes grow (a lane inactive after scatter has no
                // session, so its blocks are unreachable)
                let (_, _, still_active) = batcher.gather_inputs();
                for (i, st) in states.iter_mut().enumerate() {
                    if was_active[i] && !still_active[i] && st.pos != 0 {
                        st.reset_for_reuse();
                    }
                }
            }
            notify_finished(&batcher.finished, &mut finished_seen, &mut intake.sinks);
            iter_end_ms.push(t0.elapsed().as_secs_f64() * 1e3);

            iteration += 1;
            if self.cfg.max_iterations > 0 && iteration >= self.cfg.max_iterations {
                break;
            }
        }

        // retire the lane states: every block returns to the pool (the
        // Drop impl covers panicking paths; this makes it explicit and
        // lets callers assert full reclamation on the returned pool)
        drop(states);
        debug_assert_eq!(kv_pool.free_blocks(), kv_pool.total_blocks());
        // a `max_iterations` exit can leave live sessions behind; their
        // sinks drop here, which closes the streams — PendingRequest
        // maps that to a Failed outcome on the caller side
        notify_finished(&batcher.finished, &mut finished_seen, &mut intake.sinks);
        drop(intake.sinks);

        let wall_s = t0.elapsed().as_secs_f64();
        // admission accounting must reach the metrics: a rejected
        // (oversized) request is dropped by design, never silently
        let (requests_admitted, requests_rejected) = batcher.counters();
        let fc = batcher.fault_counters();
        let sessions = batcher.finished;
        let total_tokens: usize = sessions.iter().map(|s| s.generated.len()).sum();
        let at_ms = |it: u64| -> f64 {
            iter_end_ms
                .get(it as usize)
                .copied()
                .unwrap_or(wall_s * 1e3)
        };
        let latencies: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.finished_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();
        let ttfts: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.first_token_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();
        // time-per-output-token: steady-state decode cadence, first
        // token excluded (that is TTFT's job)
        let tpots: Vec<f64> = sessions
            .iter()
            .filter_map(|s| {
                let (first, last) = (s.first_token_at?, s.finished_at?);
                (s.generated.len() >= 2)
                    .then(|| (at_ms(last) - at_ms(first)) / (s.generated.len() - 1) as f64)
            })
            .collect();
        // time each request waited between reaching the engine (or its
        // nominal arrival, whichever is later) and taking a lane
        let queue_waits: Vec<f64> = sessions
            .iter()
            .map(|s| {
                let submitted = intake
                    .submit_ms
                    .get(&s.request.id)
                    .copied()
                    .unwrap_or(0.0)
                    .max(s.request.arrival_ms as f64);
                (at_ms(s.admitted_at) - submitted).max(0.0)
            })
            .collect();

        let zero = Percentiles::ZERO;
        let sim_ms = arch.cycles_to_ms(sim_cycles);
        let metrics = ServeMetrics {
            requests: sessions.len(),
            requests_admitted,
            requests_rejected,
            requests_failed: fc.failed,
            preemptions: fc.preemptions,
            requeues: fc.requeues,
            deadline_expired: fc.deadline_expired,
            requests_cancelled: fc.cancelled,
            requests_shed: fc.shed,
            slow_client_cancels: fc.slow_client,
            drain_cancels: fc.drain_cancelled,
            deadline_rejected,
            idle_parks,
            total_tokens_generated: total_tokens,
            iterations: iteration,
            wall_s,
            step_ms: Percentiles::compute(&step_ms).unwrap_or(zero),
            request_latency_ms: Percentiles::compute(&latencies).unwrap_or(zero),
            ttft_ms: Percentiles::compute(&ttfts).unwrap_or(zero),
            tpot_ms: Percentiles::compute(&tpots).unwrap_or(zero),
            time_in_queue_ms: Percentiles::compute(&queue_waits).unwrap_or(zero),
            queue_depth: Percentiles::compute(&queue_depths).unwrap_or(zero),
            adaptive_prefill_shrinks: adaptive_shrinks,
            mean_occupancy: if iteration > 0 {
                occupancy_acc / iteration as f64
            } else {
                0.0
            },
            batch_width: Percentiles::compute(&batch_widths).unwrap_or(zero),
            weight_passes,
            weight_passes_per_step: if iteration > 0 {
                weight_passes as f64 / iteration as f64
            } else {
                0.0
            },
            tokens_per_s: if wall_s > 0.0 {
                total_tokens as f64 / wall_s
            } else {
                0.0
            },
            simulated_accel_ms: sim_ms,
            simulated_tokens_per_s: if sim_ms > 0.0 {
                total_tokens as f64 / (sim_ms / 1e3)
            } else {
                0.0
            },
        };
        CpuServeReport {
            sessions,
            metrics,
            kv_pool,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_at_build_time() {
        assert!(ServeConfig::builder().build().is_ok(), "defaults are valid");
        let err = ServeConfig::builder().lanes(0).build().unwrap_err();
        assert!(err.contains("lanes"), "{err}");
        let err = ServeConfig::builder().kv_block_len(0).build().unwrap_err();
        assert!(err.contains("kv_block_len"), "{err}");
        let cfg = ServeConfig::builder()
            .lanes(2)
            .mode(NumericsMode::Accelerator)
            .prefill_chunk(0)
            .adaptive_prefill(true)
            .workers(1)
            .max_requeues(7)
            .build()
            .expect("valid config");
        assert_eq!(cfg.lanes, 2);
        assert_eq!(cfg.mode, NumericsMode::Accelerator);
        assert_eq!(cfg.prefill_chunk, 0);
        assert!(cfg.adaptive_prefill);
        assert_eq!(cfg.max_requeues, 7);
    }
}
