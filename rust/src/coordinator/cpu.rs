//! CPU batch serving over the pure-Rust tiny model — the default-feature
//! serving path (no PJRT required).
//!
//! Same continuous-batching shape as the PJRT [`super::server`]: queue →
//! [`super::batcher::Batcher`] → one batch step → greedy sample → retire.
//! The batch step fans the active lanes out across OS threads with
//! `std::thread::scope`; each lane owns its [`DecodeState`] (KV caches +
//! [`crate::kernels::DecodeScratch`]), so a steady-state lane step
//! performs zero heap allocation and lanes never contend on memory.
//! Grouped-query models serve unchanged: each lane's caches are sized
//! `n_kv_heads * d_head` per token by [`TinyModel::new_state`], so a GQA
//! model cuts per-lane KV memory (and streamed KV bytes per step) by the
//! group factor. Recycled lanes restart at position 0 via
//! [`DecodeState::reset`] — caches are reused, not re-allocated.

use super::batcher::Batcher;
use super::metrics::{Percentiles, ServeMetrics};
use super::session::Session;
use crate::model::tiny::{argmax, DecodeState};
use crate::model::{LlmConfig, NumericsMode, Request, TinyModel};
use crate::sim::{layer_sched, ArchConfig};
use std::collections::VecDeque;
use std::time::Instant;

/// CPU serving configuration.
#[derive(Debug, Clone)]
pub struct CpuServeOptions {
    /// Number of decode lanes (threads at full occupancy).
    pub lanes: usize,
    /// Numerics mode every lane decodes in.
    pub mode: NumericsMode,
    /// Safety cap on batch iterations (0 = unlimited).
    pub max_iterations: u64,
    /// Model config used for the simulated-accelerator metrics.
    pub sim_model: LlmConfig,
}

impl Default for CpuServeOptions {
    fn default() -> Self {
        CpuServeOptions {
            lanes: 4,
            mode: NumericsMode::DesktopF32,
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
        }
    }
}

/// Result of a CPU serving run.
pub struct CpuServeReport {
    pub sessions: Vec<Session>,
    pub metrics: ServeMetrics,
}

/// The CPU decode server.
pub struct CpuServer<'m> {
    model: &'m TinyModel,
    opts: CpuServeOptions,
}

impl<'m> CpuServer<'m> {
    pub fn new(model: &'m TinyModel, opts: CpuServeOptions) -> Self {
        assert!(opts.lanes >= 1, "need at least one lane");
        assert!(
            model.n_kv_heads >= 1 && model.n_heads % model.n_kv_heads == 0,
            "model GQA shape invalid: {} query heads over {} KV heads",
            model.n_heads,
            model.n_kv_heads
        );
        CpuServer { model, opts }
    }

    /// Serve a request stream to completion (arrival times are honoured in
    /// iteration order, like the PJRT server).
    pub fn serve(&self, requests: Vec<Request>) -> CpuServeReport {
        let lanes = self.opts.lanes;
        let model = self.model;
        let mode = self.opts.mode;
        let vocab = model.vocab;
        let mut batcher = Batcher::new(lanes, model.n_ctx);
        let mut states: Vec<DecodeState> = (0..lanes).map(|_| model.new_state()).collect();
        let mut logits = vec![0.0f32; lanes * vocab];

        let mut pending: VecDeque<Request> = requests.into();
        let t0 = Instant::now();
        let mut iteration = 0u64;
        let mut step_ms: Vec<f64> = Vec::new();
        let mut occupancy_acc = 0.0;
        let mut sim_cycles: u64 = 0;
        let arch = ArchConfig::default();
        let mut iter_end_ms: Vec<f64> = Vec::new();

        loop {
            // admit every request whose arrival time has passed
            let now_ms = t0.elapsed().as_secs_f64() * 1e3;
            while let Some(r) = pending.front() {
                if r.arrival_ms as f64 <= now_ms {
                    let r = pending.pop_front().unwrap();
                    // oversized requests are rejected by the batcher; drop
                    let _ = batcher.submit(r);
                } else {
                    break;
                }
            }
            batcher.admit(iteration);
            if batcher.is_drained() {
                if pending.is_empty() {
                    break;
                }
                // idle until the next arrival
                std::thread::sleep(std::time::Duration::from_micros(200));
                continue;
            }

            let (tokens, positions, active) = batcher.gather_inputs();
            occupancy_acc += batcher.occupancy();

            // lanes starting a fresh session restart their decode state
            for (i, st) in states.iter_mut().enumerate() {
                if active[i] && positions[i] == 0 && st.pos != 0 {
                    st.reset();
                }
            }

            // fused batch step: one thread per active lane; a lone lane
            // runs inline to skip the spawn overhead
            let ts = Instant::now();
            let n_active = active.iter().filter(|a| **a).count();
            if n_active <= 1 {
                for (i, (st, out)) in states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                {
                    if active[i] {
                        model.decode_step_into(st, tokens[i] as u32, mode, out);
                    }
                }
            } else {
                std::thread::scope(|scope| {
                    for (i, (st, out)) in states
                        .iter_mut()
                        .zip(logits.chunks_mut(vocab))
                        .enumerate()
                    {
                        if !active[i] {
                            continue;
                        }
                        let tok = tokens[i] as u32;
                        scope.spawn(move || {
                            model.decode_step_into(st, tok, mode, out);
                        });
                    }
                });
            }
            step_ms.push(ts.elapsed().as_secs_f64() * 1e3);

            // simulated accelerator cost for this step
            let max_ctx = positions
                .iter()
                .zip(&active)
                .filter(|(_, a)| **a)
                .map(|(p, _)| *p as usize + 1)
                .max()
                .unwrap_or(1);
            sim_cycles +=
                layer_sched::simulate_token(&arch, &self.opts.sim_model, max_ctx).total_cycles;

            // greedy sample per lane
            let samples: Vec<u32> = (0..lanes)
                .map(|i| argmax(&logits[i * vocab..(i + 1) * vocab]) as u32)
                .collect();
            batcher.scatter_outputs(&samples, iteration);
            iter_end_ms.push(t0.elapsed().as_secs_f64() * 1e3);

            iteration += 1;
            if self.opts.max_iterations > 0 && iteration >= self.opts.max_iterations {
                break;
            }
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let sessions = batcher.finished;
        let total_tokens: usize = sessions.iter().map(|s| s.generated.len()).sum();
        let at_ms = |it: u64| -> f64 {
            iter_end_ms
                .get(it as usize)
                .copied()
                .unwrap_or(wall_s * 1e3)
        };
        let latencies: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.finished_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();
        let ttfts: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.first_token_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();

        let zero = Percentiles {
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
            mean: 0.0,
            max: 0.0,
        };
        let sim_ms = arch.cycles_to_ms(sim_cycles);
        let metrics = ServeMetrics {
            requests: sessions.len(),
            total_tokens_generated: total_tokens,
            iterations: iteration,
            wall_s,
            step_ms: Percentiles::compute(&step_ms).unwrap_or(zero),
            request_latency_ms: Percentiles::compute(&latencies).unwrap_or(zero),
            ttft_ms: Percentiles::compute(&ttfts).unwrap_or(zero),
            mean_occupancy: if iteration > 0 {
                occupancy_acc / iteration as f64
            } else {
                0.0
            },
            tokens_per_s: if wall_s > 0.0 {
                total_tokens as f64 / wall_s
            } else {
                0.0
            },
            simulated_accel_ms: sim_ms,
            simulated_tokens_per_s: if sim_ms > 0.0 {
                total_tokens as f64 / (sim_ms / 1e3)
            } else {
                0.0
            },
        };
        CpuServeReport { sessions, metrics }
    }
}
