//! Symmetric INT8 activation quantization (the SFU's FXP32/INT8 cast).

/// An INT8-quantized vector with its dequantization scale.
#[derive(Debug, Clone)]
pub struct QuantizedVec {
    pub data: Vec<i8>,
    pub scale: f32,
}

impl QuantizedVec {
    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Vec<f32> {
        self.data.iter().map(|&q| q as f32 * self.scale).collect()
    }
}

/// Symmetric per-tensor INT8 quantization: `scale = max|x| / 127`,
/// round-to-nearest, clamp to ±127. Matches `ref.quantize_int8`.
pub fn quantize_int8(x: &[f32]) -> QuantizedVec {
    let mut data = vec![0i8; x.len()];
    let scale = quantize_int8_into(x, &mut data);
    QuantizedVec { data, scale }
}

/// [`quantize_int8`] into a caller-owned buffer (no allocation); returns
/// the dequantization scale. Bit-identical to the allocating variant.
pub fn quantize_int8_into(x: &[f32], out: &mut [i8]) -> f32 {
    assert_eq!(out.len(), x.len());
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let scale = amax / 127.0;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let q = quantize_int8(&x);
        let back = q.dequantize();
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= q.scale / 2.0 + 1e-7);
        }
    }

    #[test]
    fn full_range_used() {
        let x = vec![-4.0f32, 0.0, 4.0];
        let q = quantize_int8(&x);
        assert_eq!(q.data, vec![-127, 0, 127]);
    }

    #[test]
    fn zero_vector_safe() {
        let q = quantize_int8(&[0.0, 0.0]);
        assert_eq!(q.data, vec![0, 0]);
        assert!(q.scale > 0.0);
    }

    #[test]
    fn values_in_range() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 100.0).collect();
        let q = quantize_int8(&x);
        assert!(q.data.iter().all(|&v| (-127..=127).contains(&(v as i32))));
    }
}
