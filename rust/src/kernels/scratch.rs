//! Caller-owned scratch for one decode step of the tiny model.
//!
//! Every intermediate buffer a [`crate::model::TinyModel::decode_step_into`]
//! call needs is pre-allocated here once per sequence, so a steady-state
//! decode step performs **zero heap allocation** on the attention path
//! (asserted by `tests/alloc_hotpath.rs` with a counting allocator) —
//! including under GQA/MQA shapes, where the K/V projection buffers and
//! the packed multi-head SwiftKV states shrink to `n_kv_heads · d_head`
//! per token. The SwiftKV states ride along and are `reset()` — not
//! re-allocated — once per layer.

use super::fxp_mha::FxpMhaSwiftKv;
use super::mha::MhaSwiftKv;
use crate::fxp::Fxp32;

/// Pre-allocated intermediates for one decode step.
#[derive(Debug, Clone)]
pub struct DecodeScratch {
    /// Residual stream, `[d_model]`.
    pub x: Vec<f32>,
    /// RMS-normed activation, `[d_model]`.
    pub xn: Vec<f32>,
    /// Q projection, `[d_model]`.
    pub q: Vec<f32>,
    /// K/V projections, `[n_kv_heads * d_head]` each (GQA: ≤ d_model).
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Position-encoded query (all query heads), `[d_model]`.
    pub q_rot: Vec<f32>,
    /// Fused attention output, `[d_model]`.
    pub attn_out: Vec<f32>,
    /// Output projection, `[d_model]`.
    pub o: Vec<f32>,
    /// MLP intermediates, `[d_ffn]` each.
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    pub act: Vec<f32>,
    /// MLP down projection, `[d_model]`.
    pub down: Vec<f32>,
    /// INT8 activation buffer for the W4A8 GEMVs, `[max(d_model, d_ffn)]`.
    pub qi8: Vec<i8>,
    /// Q15.17 quantized query for the accelerator datapath, `[d_model]`.
    pub q_fxp: Vec<Fxp32>,
    /// Q15.17 fused attention output, `[d_model]`.
    pub attn_fxp: Vec<Fxp32>,
    /// Fused multi-head f32 SwiftKV state (desktop numerics).
    pub mha: MhaSwiftKv,
    /// Fused multi-head Q15.17 SwiftKV state (accelerator numerics).
    pub fxp_mha: FxpMhaSwiftKv,
    // --- chunked-prefill buffers, sized for `chunk_cap` tokens by
    // `ensure_chunk` (empty until the first prefill; growth allocates,
    // steady-state prefill steps at or below the capacity do not) ------
    /// Residual streams of the chunk tokens, `[chunk_cap, d_model]`.
    pub xs: Vec<f32>,
    /// Position-encoded queries of the chunk tokens, `[chunk_cap, d_model]`.
    pub q_rots: Vec<f32>,
    /// Fused attention outputs of the chunk tokens, `[chunk_cap, d_model]`.
    pub attn_outs: Vec<f32>,
    /// Per-chunk-token RoPE caches, `[chunk_cap, d_head / 2]` each.
    pub rope_cos: Vec<f32>,
    pub rope_sin: Vec<f32>,
    /// Q15.17 chunk queries / attention outputs, `[chunk_cap, d_model]`.
    pub q_fxps: Vec<Fxp32>,
    pub attn_fxps: Vec<Fxp32>,
    /// Chunk tokens the prefill buffers are currently sized for.
    chunk_cap: usize,
    /// Head dimension (sizes the per-token RoPE cache rows).
    d_head: usize,
}

impl DecodeScratch {
    /// Allocate all buffers for a model shape. `d_model = n_heads · d_head`;
    /// the KV-side buffers are `n_kv_heads · d_head` wide
    /// (`n_kv_heads == n_heads` for plain MHA, `1` for MQA).
    pub fn new(n_heads: usize, n_kv_heads: usize, d_head: usize, d_ffn: usize) -> Self {
        assert!(
            n_kv_heads > 0 && n_heads % n_kv_heads == 0,
            "n_heads must be a multiple of n_kv_heads"
        );
        let d_model = n_heads * d_head;
        let d_kv = n_kv_heads * d_head;
        DecodeScratch {
            x: vec![0.0; d_model],
            xn: vec![0.0; d_model],
            q: vec![0.0; d_model],
            k: vec![0.0; d_kv],
            v: vec![0.0; d_kv],
            q_rot: vec![0.0; d_model],
            attn_out: vec![0.0; d_model],
            o: vec![0.0; d_model],
            gate: vec![0.0; d_ffn],
            up: vec![0.0; d_ffn],
            act: vec![0.0; d_ffn],
            down: vec![0.0; d_model],
            qi8: vec![0; d_model.max(d_ffn)],
            q_fxp: vec![Fxp32::ZERO; d_model],
            attn_fxp: vec![Fxp32::ZERO; d_model],
            mha: MhaSwiftKv::new_grouped(n_heads, n_kv_heads, d_head),
            fxp_mha: FxpMhaSwiftKv::new_grouped(n_heads, n_kv_heads, d_head),
            xs: Vec::new(),
            q_rots: Vec::new(),
            attn_outs: Vec::new(),
            rope_cos: Vec::new(),
            rope_sin: Vec::new(),
            q_fxps: Vec::new(),
            attn_fxps: Vec::new(),
            chunk_cap: 0,
            d_head,
        }
    }

    /// Grow the chunked-prefill buffers to hold at least `chunk` tokens.
    /// Allocates only when the capacity actually grows — the warm-up
    /// allocation of the chunked-prefill path; prefill steps at or below
    /// the capacity stay heap-free (`tests/alloc_hotpath.rs`).
    pub fn ensure_chunk(&mut self, chunk: usize) {
        if chunk <= self.chunk_cap {
            return;
        }
        let d_model = self.d_model();
        let d_half = self.d_head / 2;
        self.xs.resize(chunk * d_model, 0.0);
        self.q_rots.resize(chunk * d_model, 0.0);
        self.attn_outs.resize(chunk * d_model, 0.0);
        self.rope_cos.resize(chunk * d_half, 0.0);
        self.rope_sin.resize(chunk * d_half, 0.0);
        self.q_fxps.resize(chunk * d_model, Fxp32::ZERO);
        self.attn_fxps.resize(chunk * d_model, Fxp32::ZERO);
        self.chunk_cap = chunk;
    }

    /// Chunk tokens the prefill buffers currently hold
    /// (0 before the first [`DecodeScratch::ensure_chunk`]).
    pub fn chunk_capacity(&self) -> usize {
        self.chunk_cap
    }

    /// Model width the scratch was sized for.
    pub fn d_model(&self) -> usize {
        self.x.len()
    }

    /// KV projection width the scratch was sized for
    /// (`n_kv_heads · d_head`).
    pub fn d_kv(&self) -> usize {
        self.k.len()
    }
}

/// Batch-width scratch for one **batched** decode step
/// ([`crate::model::TinyModel::decode_steps_into`]): the gathered INT8
/// activation rows and the batched GEMM outputs that all lanes share.
///
/// Per-lane intermediates (residual streams, RoPE'd queries, attention
/// outputs, the fused SwiftKV states) stay in each lane's
/// [`DecodeScratch`]; this struct holds only what the shared weight
/// passes consume and produce, laid out row-major `[batch, width]` so
/// one GEMM call covers every lane. Buffers are empty until the first
/// [`BatchScratch::ensure_batch`] and grow monotonically to the
/// high-water batch width — steady-state batched steps at or below the
/// capacity perform zero heap allocation (`tests/alloc_hotpath.rs`).
#[derive(Debug)]
pub struct BatchScratch {
    /// INT8 activation rows for the `d_model`-wide GEMM inputs,
    /// `[cap, d_model]`.
    pub qi8: Vec<i8>,
    /// INT8 activation rows for the down-projection input,
    /// `[cap, d_ffn]`.
    pub qi8_ffn: Vec<i8>,
    /// Per-lane activation quantization scales, `[cap]`.
    pub scales: Vec<f32>,
    /// Batched Q projection, `[cap, d_model]`.
    pub q: Vec<f32>,
    /// Batched K/V projections, `[cap, n_kv_heads * d_head]` each.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Batched O and down projections (reused for both), `[cap, d_model]`.
    pub o: Vec<f32>,
    /// Batched MLP gate/up projections, `[cap, d_ffn]` each.
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    /// Batched logits, `[cap, vocab]`, scattered to the lanes' buffers.
    pub logits: Vec<f32>,
    /// Per-lane fault flags for
    /// [`crate::model::TinyModel::try_decode_steps_into`]: a lane whose
    /// per-lane phase panicked is marked here and skipped by every later
    /// phase of the step (the shared GEMMs are row-independent, so the
    /// surviving lanes' outputs stay bit-identical). Atomic because the
    /// attention phase runs one task per lane across the worker pool.
    /// Pre-allocated alongside the buffers so the no-fault steady state
    /// stays allocation-free.
    pub faulted: Vec<std::sync::atomic::AtomicBool>,
    /// Lanes the buffers are currently sized for.
    cap: usize,
    d_model: usize,
    d_kv: usize,
    d_ffn: usize,
    vocab: usize,
}

impl BatchScratch {
    /// Empty scratch for a model shape (`d_model = n_heads · d_head`,
    /// KV rows `n_kv_heads · d_head` wide). Nothing is allocated until
    /// the first [`BatchScratch::ensure_batch`].
    pub fn new(
        n_heads: usize,
        n_kv_heads: usize,
        d_head: usize,
        d_ffn: usize,
        vocab: usize,
    ) -> Self {
        assert!(
            n_kv_heads > 0 && n_heads % n_kv_heads == 0,
            "n_heads must be a multiple of n_kv_heads"
        );
        BatchScratch {
            qi8: Vec::new(),
            qi8_ffn: Vec::new(),
            scales: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            o: Vec::new(),
            gate: Vec::new(),
            up: Vec::new(),
            logits: Vec::new(),
            faulted: Vec::new(),
            cap: 0,
            d_model: n_heads * d_head,
            d_kv: n_kv_heads * d_head,
            d_ffn,
            vocab,
        }
    }

    /// Grow every buffer to hold at least `batch` lanes. Allocates only
    /// when the capacity actually grows; smaller batches reuse the
    /// existing buffers untouched.
    pub fn ensure_batch(&mut self, batch: usize) {
        if batch <= self.cap {
            return;
        }
        self.qi8.resize(batch * self.d_model, 0);
        self.qi8_ffn.resize(batch * self.d_ffn, 0);
        self.scales.resize(batch, 0.0);
        self.q.resize(batch * self.d_model, 0.0);
        self.k.resize(batch * self.d_kv, 0.0);
        self.v.resize(batch * self.d_kv, 0.0);
        self.o.resize(batch * self.d_model, 0.0);
        self.gate.resize(batch * self.d_ffn, 0.0);
        self.up.resize(batch * self.d_ffn, 0.0);
        self.logits.resize(batch * self.vocab, 0.0);
        self.faulted
            .resize_with(batch, || std::sync::atomic::AtomicBool::new(false));
        self.cap = batch;
    }

    /// Lanes the buffers currently hold (0 before the first
    /// [`BatchScratch::ensure_batch`]).
    pub fn batch_capacity(&self) -> usize {
        self.cap
    }

    /// Model width the scratch was sized for.
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// KV projection width the scratch was sized for.
    pub fn d_kv(&self) -> usize {
        self.d_kv
    }

    /// MLP width the scratch was sized for.
    pub fn d_ffn(&self) -> usize {
        self.d_ffn
    }

    /// Vocabulary width the scratch was sized for.
    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_shape() {
        let s = DecodeScratch::new(4, 4, 8, 128);
        assert_eq!(s.d_model(), 32);
        assert_eq!(s.d_kv(), 32);
        assert_eq!(s.gate.len(), 128);
        assert_eq!(s.qi8.len(), 128);
        assert_eq!(s.mha.row_width(), 32);
        assert_eq!(s.fxp_mha.row_width(), 32);
    }

    #[test]
    fn gqa_shrinks_kv_buffers() {
        let s = DecodeScratch::new(8, 2, 16, 64);
        assert_eq!(s.d_model(), 128);
        assert_eq!(s.d_kv(), 32);
        assert_eq!(s.k.len(), 32);
        assert_eq!(s.v.len(), 32);
        assert_eq!(s.q.len(), 128);
        assert_eq!(s.mha.row_width(), 32);
        assert_eq!(s.mha.q_width(), 128);
        assert_eq!(s.fxp_mha.row_width(), 32);
        assert_eq!(s.fxp_mha.group(), 4);
    }

    #[test]
    #[should_panic(expected = "multiple of n_kv_heads")]
    fn indivisible_group_panics() {
        let _ = DecodeScratch::new(6, 4, 8, 32);
    }

    #[test]
    fn ensure_chunk_grows_once_and_never_shrinks() {
        let mut s = DecodeScratch::new(4, 2, 8, 64);
        assert_eq!(s.chunk_capacity(), 0);
        assert!(s.xs.is_empty());
        s.ensure_chunk(5);
        assert_eq!(s.chunk_capacity(), 5);
        assert_eq!(s.xs.len(), 5 * 32);
        assert_eq!(s.q_rots.len(), 5 * 32);
        assert_eq!(s.attn_outs.len(), 5 * 32);
        assert_eq!(s.rope_cos.len(), 5 * 4);
        assert_eq!(s.rope_sin.len(), 5 * 4);
        assert_eq!(s.q_fxps.len(), 5 * 32);
        assert_eq!(s.attn_fxps.len(), 5 * 32);
        // smaller requests keep the existing buffers
        s.ensure_chunk(2);
        assert_eq!(s.chunk_capacity(), 5);
        assert_eq!(s.xs.len(), 5 * 32);
        s.ensure_chunk(8);
        assert_eq!(s.chunk_capacity(), 8);
        assert_eq!(s.xs.len(), 8 * 32);
    }

    #[test]
    fn batch_scratch_grows_once_and_never_shrinks() {
        // 4 query heads over 2 KV heads, d_head 8, d_ffn 64, vocab 96
        let mut s = BatchScratch::new(4, 2, 8, 64, 96);
        assert_eq!(s.batch_capacity(), 0);
        assert_eq!((s.d_model(), s.d_kv(), s.d_ffn(), s.vocab()), (32, 16, 64, 96));
        assert!(s.qi8.is_empty() && s.logits.is_empty());
        s.ensure_batch(3);
        assert_eq!(s.batch_capacity(), 3);
        assert_eq!(s.qi8.len(), 3 * 32);
        assert_eq!(s.qi8_ffn.len(), 3 * 64);
        assert_eq!(s.scales.len(), 3);
        assert_eq!(s.q.len(), 3 * 32);
        assert_eq!(s.k.len(), 3 * 16);
        assert_eq!(s.v.len(), 3 * 16);
        assert_eq!(s.o.len(), 3 * 32);
        assert_eq!(s.gate.len(), 3 * 64);
        assert_eq!(s.up.len(), 3 * 64);
        assert_eq!(s.logits.len(), 3 * 96);
        // smaller batches reuse the buffers; larger ones grow them
        s.ensure_batch(2);
        assert_eq!(s.batch_capacity(), 3);
        s.ensure_batch(8);
        assert_eq!(s.batch_capacity(), 8);
        assert_eq!(s.logits.len(), 8 * 96);
    }

    #[test]
    #[should_panic(expected = "multiple of n_kv_heads")]
    fn batch_scratch_rejects_indivisible_group() {
        let _ = BatchScratch::new(6, 4, 8, 32, 16);
    }
}
