//! Property tests: chunked prefill (`TinyModel::prefill_into` batching
//! prompt tokens through the fused causal chunk sweeps) versus the
//! per-token decode path, swept over GQA/MQA/MHA shapes, KV block
//! lengths {1, 3, 16} (so chunks routinely straddle paged block
//! boundaries), and chunk lengths {1, 3, block_len, whole-prompt}.
//!
//! The chunked path issues every per-token op in the same order as
//! `decode_step_into`, so the bar is strict: `DesktopF32` logits must
//! match the per-token path within 1e-5 relative at every chunk
//! boundary, and `Accelerator` (Q15.17) logits must be **bit-exact**.

use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::{BlockPool, BlockTable, FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::model::{NumericsMode, TinyModel};
use swiftkv::util::{prop, Rng};

/// (n_heads, n_kv_heads) over d_model 32: MHA, GQA groups, MQA.
const SHAPES: [(usize, usize); 4] = [(4, 4), (4, 2), (4, 1), (8, 2)];
/// KV block lengths: degenerate, odd (ragged blocks), default.
const BLOCK_LENS: [usize; 3] = [1, 3, 16];
const N_CTX: usize = 32;

struct PrefillCase {
    model: TinyModel,
    block_len: usize,
    prompt: Vec<u32>,
}

impl PrefillCase {
    fn random(rng: &mut Rng) -> PrefillCase {
        let (h, hkv) = SHAPES[rng.gen_range(0, SHAPES.len())];
        let block_len = BLOCK_LENS[rng.gen_range(0, BLOCK_LENS.len())];
        let vocab = 64usize;
        let model = TinyModel::synthetic(
            rng.gen_range(0, 1 << 20) as u64,
            vocab,
            32,
            h,
            hkv,
            2,
            64,
            N_CTX,
        );
        let prompt_len = rng.gen_range(2, 25);
        let prompt = (0..prompt_len)
            .map(|_| rng.gen_range(0, vocab) as u32)
            .collect();
        PrefillCase {
            model,
            block_len,
            prompt,
        }
    }

    /// The chunk lengths the issue sweeps: 1 (per-token through the
    /// chunk path), 3 (straddles odd block boundaries), the KV block
    /// length, and the whole prompt in one chunk.
    fn chunk_lens(&self) -> Vec<usize> {
        let mut lens = vec![1, 3, self.block_len, self.prompt.len()];
        lens.sort_unstable();
        lens.dedup();
        lens
    }

    /// Per-position logits of the per-token reference path.
    fn reference_logits(&self, mode: NumericsMode) -> Vec<Vec<f32>> {
        let pool = self
            .model
            .new_pool(self.model.blocks_per_seq(self.block_len), self.block_len);
        let mut st = self.model.new_state_in(pool);
        self.prompt
            .iter()
            .map(|&t| self.model.decode_step(&mut st, t, mode))
            .collect()
    }

    /// Feed the prompt in chunks of at most `chunk_len`, collecting the
    /// logits `prefill_into` reports at every chunk's final token.
    fn chunked_logits(&self, chunk_len: usize, mode: NumericsMode) -> Vec<(usize, Vec<f32>)> {
        let pool = self
            .model
            .new_pool(self.model.blocks_per_seq(self.block_len), self.block_len);
        let mut st = self.model.new_state_in(pool);
        let mut out = Vec::new();
        let mut logits = vec![0.0f32; self.model.vocab];
        let mut at = 0usize;
        while at < self.prompt.len() {
            let end = self.prompt.len().min(at + chunk_len);
            self.model
                .prefill_into(&mut st, &self.prompt[at..end], mode, Some(&mut logits[..]));
            out.push((end - 1, logits.clone()));
            at = end;
        }
        assert_eq!(st.pos, self.prompt.len());
        out
    }
}

#[test]
fn prop_chunked_prefill_matches_per_token_f32() {
    prop::check("chunked prefill == per-token (DesktopF32, 1e-5)", 10, |rng, _| {
        let case = PrefillCase::random(rng);
        let reference = case.reference_logits(NumericsMode::DesktopF32);
        for chunk_len in case.chunk_lens() {
            for (tok, got) in case.chunked_logits(chunk_len, NumericsMode::DesktopF32) {
                let want = &reference[tok];
                for (i, (a, b)) in got.iter().zip(want).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                        "prompt_len={} chunk={chunk_len} bl={} token {tok} logit {i}: {a} vs {b}",
                        case.prompt.len(),
                        case.block_len
                    );
                }
            }
        }
    });
}

#[test]
fn prop_chunked_prefill_bit_exact_accelerator() {
    prop::check("chunked prefill == per-token (Q15.17, bit-exact)", 8, |rng, _| {
        let case = PrefillCase::random(rng);
        let reference = case.reference_logits(NumericsMode::Accelerator);
        for chunk_len in case.chunk_lens() {
            for (tok, got) in case.chunked_logits(chunk_len, NumericsMode::Accelerator) {
                assert_eq!(
                    &got,
                    &reference[tok],
                    "prompt_len={} chunk={chunk_len} bl={} token {tok}: accelerator \
                     logits must be bit-exact vs the per-token path",
                    case.prompt.len(),
                    case.block_len
                );
            }
        }
    });
}

#[test]
fn prop_decode_after_chunked_prefill_matches_pure_decode() {
    // the state a chunked prefill leaves behind (KV rows, Q15.17 mirror,
    // RoPE recurrence, fxp_rows) must be indistinguishable from the
    // per-token path's: generation after it stays identical
    prop::check("decode after chunked prefill == pure decode", 8, |rng, _| {
        let case = PrefillCase::random(rng);
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let pool = case
                .model
                .new_pool(case.model.blocks_per_seq(case.block_len), case.block_len);
            let mut ref_st = case.model.new_state_in(pool);
            let mut want = vec![0.0f32; case.model.vocab];
            for &t in &case.prompt {
                case.model
                    .decode_step_into(&mut ref_st, t, mode, &mut want);
            }
            let next = (case.prompt[0] + 1) % case.model.vocab as u32;
            let want_next = case.model.decode_step(&mut ref_st, next, mode);

            let pool = case
                .model
                .new_pool(case.model.blocks_per_seq(case.block_len), case.block_len);
            let mut st = case.model.new_state_in(pool);
            case.model.prefill_into(&mut st, &case.prompt, mode, None);
            let got_next = case.model.decode_step(&mut st, next, mode);
            assert_eq!(
                got_next, want_next,
                "{mode:?} prompt_len={} bl={}: decode diverged after chunked prefill",
                case.prompt.len(),
                case.block_len
            );
        }
    });
}

#[test]
fn prop_kernel_chunk_sweep_matches_per_query_sweeps() {
    // kernel-level: the causal chunk sweep must equal one-shot per-query
    // sweeps on both numerics, contiguous and paged
    prop::check("attend_chunk == per-query attend", 25, |rng, _| {
        let (h, hkv) = SHAPES[rng.gen_range(0, SHAPES.len())];
        let d = [4usize, 8, 16][rng.gen_range(0, 3)];
        let start = rng.gen_range(0, 9);
        let chunk = rng.gen_range(1, 9);
        let block_len = BLOCK_LENS[rng.gen_range(0, BLOCK_LENS.len())];
        let row = hkv * d;
        let len = start + chunk;
        let scale = 1.0 / (d as f32).sqrt();
        let qs = rng.uniform_vec(chunk * h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);

        let pool = BlockPool::new(len.div_ceil(block_len), block_len, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&k[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&v[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }

        // f32: per-query one-shot reference, contiguous chunk, paged chunk
        let mut reference = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut want = vec![0.0f32; chunk * h * d];
        for j in 0..chunk {
            let (qj, oj) = (j * h * d, (j + 1) * h * d);
            let out = &mut want[qj..oj];
            reference.attend(&qs[qj..oj], &k, &v, start + j + 1, scale, out);
        }
        let mut chunked = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut got = vec![0.0f32; chunk * h * d];
        chunked.attend_chunk(&qs, &k, &v, start, chunk, scale, &mut got);
        assert_eq!(got, want, "h={h} hkv={hkv} d={d} start={start} chunk={chunk}");
        let mut paged = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut got_paged = vec![0.0f32; chunk * h * d];
        paged.attend_chunk_paged(&qs, &table, start, chunk, scale, &mut got_paged);
        assert_eq!(got_paged, want, "paged chunk sweep diverged (bl={block_len})");

        // Q15.17: bit-exact on raw bits
        let lut = Exp2Lut::new();
        let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&qs);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);
        let mut freference = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut fwant = vec![Fxp32::ZERO; chunk * h * d];
        for j in 0..chunk {
            let (qj, oj) = (j * h * d, (j + 1) * h * d);
            let out = &mut fwant[qj..oj];
            freference.attend(&lut, &qq[qj..oj], &kq, &vq, start + j + 1, fscale, out);
        }
        let mut fchunked = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut fgot = vec![Fxp32::ZERO; chunk * h * d];
        fchunked.attend_chunk(&lut, &qq, &kq, &vq, start, chunk, fscale, &mut fgot);
        let mut fpaged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut fgot_paged = vec![Fxp32::ZERO; chunk * h * d];
        fpaged.attend_chunk_paged(&lut, &qq, &table, start, chunk, fscale, &mut fgot_paged);
        for (i, ((a, b), c)) in fgot.iter().zip(&fwant).zip(&fgot_paged).enumerate() {
            assert_eq!(a.raw(), b.raw(), "fxp chunk flat-dim {i} diverged");
            assert_eq!(c.raw(), b.raw(), "fxp paged chunk flat-dim {i} diverged");
        }
        table.release_into(&pool);
    });
}
