//! Fused multi-head SwiftKV decode state in the accelerator's FXP32
//! (Q15.17) arithmetic — the multi-head datapath of Fig. 5, grouped-query
//! aware.
//!
//! Same interleaved token-major layout and API as [`super::mha::MhaSwiftKv`]
//! (KV rows are `n_kv_heads · d` wide; queries/outputs `n_heads · d`), but
//! every operation is the bit-exact Q15.17 model: wide-accumulator
//! dot products on the MAC array ([`crate::fxp::vector::dot`]), the
//! shift + 5-bit-LUT exponential of Eqs. (9)–(10), and saturating AXPY
//! updates. Because integer addition is associative and all per-head
//! operations are issued in the same order as the per-head
//! [`crate::attention::fxp_swiftkv::FxpSwiftKvState`], the fused sweep is
//! **bit-for-bit identical** to running each query head separately against
//! its shared KV head — the property `tests/prop_mha_fused.rs` and
//! `tests/prop_gqa_fused.rs` assert on raw bits. The Q15.17 dot/AXPY
//! inner loops dispatch through [`super::isa`]; every table implements
//! them bit-exactly (`tests/prop_simd_dispatch.rs`), so the raw-bits
//! property holds under any `SWIFTKV_ISA` setting.
//!
//! lint: hotpath

use crate::fxp::{vector, Exp2Lut, Fxp32};

/// Packed multi-head Q15.17 SwiftKV recurrence state (GQA-aware).
#[derive(Debug, Clone)]
pub struct FxpMhaSwiftKv {
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    mu: Vec<Fxp32>,
    z: Vec<Fxp32>,
    /// Unnormalized output, `[n_heads * d]`, head-major.
    y: Vec<Fxp32>,
    consumed: usize,
}

impl FxpMhaSwiftKv {
    /// Fresh multi-head-attention state (`n_kv_heads == n_heads`) for
    /// `n_heads` heads of dimension `d`.
    pub fn new(n_heads: usize, d: usize) -> Self {
        Self::new_grouped(n_heads, n_heads, d)
    }

    /// Fresh grouped-query state: `n_heads` query heads sharing
    /// `n_kv_heads` KV heads (`n_heads % n_kv_heads == 0`).
    pub fn new_grouped(n_heads: usize, n_kv_heads: usize, d: usize) -> Self {
        assert!(n_heads > 0 && n_kv_heads > 0 && d > 0, "empty state");
        assert!(
            n_heads % n_kv_heads == 0,
            "n_heads ({n_heads}) must be a multiple of n_kv_heads ({n_kv_heads})"
        );
        FxpMhaSwiftKv {
            n_heads,
            n_kv_heads,
            d,
            // lint: allow(hotpath) — one-time constructor allocation; the
            // decode loop reuses the state via reset().
            mu: vec![Fxp32::MIN; n_heads],
            z: vec![Fxp32::ZERO; n_heads],
            y: vec![Fxp32::ZERO; n_heads * d],
            consumed: 0,
        }
    }

    /// Reset for a new query without releasing the buffers.
    #[inline]
    pub fn reset(&mut self) {
        self.consumed = 0;
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_kv_heads(&self) -> usize {
        self.n_kv_heads
    }

    /// Query heads per KV head (`1` for MHA, `n_heads` for MQA).
    #[inline]
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Width of one interleaved KV cache row (`n_kv_heads · d`).
    #[inline]
    pub fn row_width(&self) -> usize {
        self.n_kv_heads * self.d
    }

    /// Width of the packed query / output rows (`n_heads · d`).
    #[inline]
    pub fn q_width(&self) -> usize {
        self.n_heads * self.d
    }

    /// Consume one interleaved `(k_t, v_t)` row, advancing every query
    /// head — Eqs. (5)–(7) in Q15.17 with the LUT exponential. Each
    /// KV-head slice is loaded once and feeds its whole group.
    #[inline]
    pub fn update_token(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k_t: &[Fxp32],
        v_t: &[Fxp32],
        scale: Fxp32,
    ) {
        let d = self.d;
        let group = self.group();
        debug_assert_eq!(q.len(), self.n_heads * d);
        debug_assert_eq!(k_t.len(), self.n_kv_heads * d);
        debug_assert_eq!(v_t.len(), self.n_kv_heads * d);
        if self.consumed == 0 {
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = vector::dot(&q[o..o + d], kh).sat_mul(scale);
                    self.mu[head] = s;
                    self.z[head] = Fxp32::ONE;
                    self.y[o..o + d].copy_from_slice(vh);
                }
            }
        } else {
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = vector::dot(&q[o..o + d], kh).sat_mul(scale);
                    let yh = &mut self.y[o..o + d];
                    if s <= self.mu[head] {
                        // β = exp(s − μ) ∈ (0, 1]
                        let beta = lut.exp_neg(s.sat_sub(self.mu[head]));
                        self.z[head] = self.z[head].sat_add(beta);
                        vector::axpy_inplace(beta, yh, vh);
                    } else {
                        // α = exp(μ − s) ∈ (0, 1)
                        let alpha = lut.exp_neg(self.mu[head].sat_sub(s));
                        self.z[head] = alpha.sat_mul(self.z[head]).sat_add(Fxp32::ONE);
                        vector::scale_axpy_inplace(alpha, yh, vh);
                        self.mu[head] = s;
                    }
                }
            }
        }
        self.consumed += 1;
    }

    /// Extend over cache rows `[from, to)` of a token-major interleaved
    /// Q15.17 cache (`k`/`v` are `[len, n_kv_heads * d]` row-major).
    #[allow(clippy::too_many_arguments)]
    pub fn extend(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k: &[Fxp32],
        v: &[Fxp32],
        from: usize,
        to: usize,
        scale: Fxp32,
    ) {
        let row = self.row_width();
        assert!(k.len() >= to * row, "k cache too short");
        assert!(v.len() >= to * row, "v cache too short");
        for t in from..to {
            self.update_token(
                lut,
                q,
                &k[t * row..(t + 1) * row],
                &v[t * row..(t + 1) * row],
                scale,
            );
        }
    }

    /// Extend over token positions `[from, to)` of a block-gathered
    /// paged Q15.17 mirror ([`super::paged::BlockTable`]). Because the
    /// rows reach [`FxpMhaSwiftKv::update_token`] in the same order with
    /// the same per-head op sequence as [`FxpMhaSwiftKv::extend`], the
    /// paged sweep is **bit-exact** versus the contiguous one.
    #[allow(clippy::too_many_arguments)]
    pub fn extend_paged(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        table: &super::paged::BlockTable,
        from: usize,
        to: usize,
        scale: Fxp32,
    ) {
        assert_eq!(table.row_width(), self.row_width(), "table row width mismatch");
        assert!(table.capacity_tokens() >= to, "block table too short");
        for t in from..to {
            self.update_token(lut, q, table.kq_row(t), table.vq_row(t), scale);
        }
    }

    /// Causal multi-token Q15.17 sweep over a contiguous cache — the
    /// accelerator half of chunked prefill. Query row `j` of `qs`
    /// (`[chunk, n_heads * d]`) sits at token position `start + j` and
    /// attends over cache rows `[0, start + j + 1)` through the same
    /// reset → [`FxpMhaSwiftKv::extend`] → finalize pipeline as the
    /// single-token decode path, so the chunked sweep is **bit-exact**
    /// versus feeding the tokens one step at a time.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_chunk(
        &mut self,
        lut: &Exp2Lut,
        qs: &[Fxp32],
        k: &[Fxp32],
        v: &[Fxp32],
        start: usize,
        chunk: usize,
        scale: Fxp32,
        outs: &mut [Fxp32],
    ) {
        let qw = self.q_width();
        assert_eq!(qs.len(), chunk * qw, "qs must hold chunk packed query rows");
        assert_eq!(outs.len(), chunk * qw, "outs must hold chunk packed output rows");
        for j in 0..chunk {
            self.reset();
            self.extend(lut, &qs[j * qw..(j + 1) * qw], k, v, 0, start + j + 1, scale);
            self.finalize_into(&mut outs[j * qw..(j + 1) * qw]);
        }
    }

    /// [`FxpMhaSwiftKv::attend_chunk`] over a block-gathered paged
    /// Q15.17 mirror — the chunked-prefill sweep of the serving path,
    /// bit-exact versus both the contiguous chunk sweep and the
    /// per-token decode path over equal rows.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_chunk_paged(
        &mut self,
        lut: &Exp2Lut,
        qs: &[Fxp32],
        table: &super::paged::BlockTable,
        start: usize,
        chunk: usize,
        scale: Fxp32,
        outs: &mut [Fxp32],
    ) {
        let qw = self.q_width();
        assert_eq!(qs.len(), chunk * qw, "qs must hold chunk packed query rows");
        assert_eq!(outs.len(), chunk * qw, "outs must hold chunk packed output rows");
        assert!(table.capacity_tokens() >= start + chunk, "block table too short");
        for j in 0..chunk {
            self.reset();
            self.extend_paged(lut, &qs[j * qw..(j + 1) * qw], table, 0, start + j + 1, scale);
            self.finalize_into(&mut outs[j * qw..(j + 1) * qw]);
        }
    }

    /// Eq. (8) on the divide unit, into a caller-owned buffer.
    pub fn finalize_into(&self, out: &mut [Fxp32]) {
        assert!(self.consumed > 0, "finalize before any token");
        assert_eq!(out.len(), self.n_heads * self.d);
        for head in 0..self.n_heads {
            let o = head * self.d;
            let z = self.z[head];
            for (dst, &y) in out[o..o + self.d].iter_mut().zip(&self.y[o..o + self.d]) {
                *dst = y.sat_div(z);
            }
        }
    }

    /// One-shot fused attention over `len` interleaved cache rows.
    #[allow(clippy::too_many_arguments)]
    pub fn attend(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k: &[Fxp32],
        v: &[Fxp32],
        len: usize,
        scale: Fxp32,
        out: &mut [Fxp32],
    ) {
        self.reset();
        self.extend(lut, q, k, v, 0, len, scale);
        self.finalize_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
    use crate::kernels::gather_head;
    use crate::util::Rng;

    #[test]
    fn fused_bit_exact_vs_per_head() {
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(21);
        let (h, d, len) = (4usize, 16usize, 48usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);

        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);
        let mut mha = FxpMhaSwiftKv::new(h, d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            let kh = gather_head(&k, head, h, d, len);
            let vh = gather_head(&v, head, h, d, len);
            let p = FxpHeadProblem::quantize(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(a.raw(), b.raw(), "head {head} dim {i} diverged");
            }
        }
    }

    #[test]
    fn grouped_bit_exact_vs_per_head_over_shared_kv() {
        // GQA: every query head must be bit-identical to the per-head
        // Q15.17 reference run on its shared KV head's cache.
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(23);
        let (h, hkv, d, len) = (8usize, 2usize, 16usize, 32usize);
        let group = h / hkv;
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * hkv * d, 1.0);
        let v = rng.uniform_vec(len * hkv * d, 1.0);

        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);
        let mut mha = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        assert_eq!(mha.row_width(), hkv * d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            let kv = head / group;
            let kh = gather_head(&k, kv, hkv, d, len);
            let vh = gather_head(&v, kv, hkv, d, len);
            let p = FxpHeadProblem::quantize(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(a.raw(), b.raw(), "head {head} dim {i} diverged");
            }
        }
    }

    #[test]
    fn paged_extend_bit_exact_vs_contiguous() {
        use crate::kernels::paged::{BlockPool, BlockTable};
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(24);
        let (h, hkv, d, len) = (4usize, 2usize, 8usize, 10usize);
        let row = hkv * d;
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);

        // block_len 4 → ragged last block (10 = 2·4 + 2); mirror filled
        // through the same quantize path as the contiguous reference
        let pool = BlockPool::new(3, 4, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&k[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&v[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }

        let mut contiguous = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![Fxp32::ZERO; h * d];
        contiguous.attend(&lut, &qq, &kq, &vq, len, scale, &mut a);

        let mut paged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&lut, &qq, &table, 0, 7, scale);
        paged.extend_paged(&lut, &qq, &table, 7, len, scale);
        let mut b = vec![Fxp32::ZERO; h * d];
        paged.finalize_into(&mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.raw(), y.raw(), "flat dim {i} diverged");
        }
        table.release_into(&pool);
    }

    #[test]
    fn chunk_sweep_bit_exact_vs_per_token_attend() {
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(25);
        let (h, hkv, d, start, chunk) = (4usize, 2usize, 8usize, 7usize, 4usize);
        let row = hkv * d;
        let len = start + chunk;
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qs = vector::quantize(&rng.uniform_vec(chunk * h * d, 1.0));
        let k = vector::quantize(&rng.uniform_vec(len * row, 1.0));
        let v = vector::quantize(&rng.uniform_vec(len * row, 1.0));

        let mut mha = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut outs = vec![Fxp32::ZERO; chunk * h * d];
        mha.attend_chunk(&lut, &qs, &k, &v, start, chunk, scale, &mut outs);

        let mut reference = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut want = vec![Fxp32::ZERO; h * d];
        for j in 0..chunk {
            reference.attend(
                &lut,
                &qs[j * h * d..(j + 1) * h * d],
                &k,
                &v,
                start + j + 1,
                scale,
                &mut want,
            );
            for (i, (a, b)) in outs[j * h * d..(j + 1) * h * d].iter().zip(&want).enumerate() {
                assert_eq!(a.raw(), b.raw(), "chunk query {j} dim {i} diverged");
            }
        }
    }

    #[test]
    fn chunk_sweep_paged_bit_exact_vs_contiguous() {
        use crate::kernels::paged::{BlockPool, BlockTable};
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(26);
        let (h, hkv, d, start, chunk) = (4usize, 1usize, 8usize, 3usize, 7usize);
        let row = hkv * d;
        let len = start + chunk;
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qsf = rng.uniform_vec(chunk * h * d, 1.0);
        let kf = rng.uniform_vec(len * row, 1.0);
        let vf = rng.uniform_vec(len * row, 1.0);
        let qs = vector::quantize(&qsf);
        let k = vector::quantize(&kf);
        let v = vector::quantize(&vf);

        // block_len 3 → ragged last block (10 = 3·3 + 1)
        let pool = BlockPool::new(4, 3, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&kf[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&vf[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }

        let mut contiguous = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![Fxp32::ZERO; chunk * h * d];
        contiguous.attend_chunk(&lut, &qs, &k, &v, start, chunk, scale, &mut a);

        let mut paged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut b = vec![Fxp32::ZERO; chunk * h * d];
        paged.attend_chunk_paged(&lut, &qs, &table, start, chunk, scale, &mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.raw(), y.raw(), "flat dim {i} diverged");
        }
        table.release_into(&pool);
    }

    #[test]
    fn deterministic_across_reset() {
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(22);
        let (h, d, len) = (2usize, 8usize, 20usize);
        let qq = vector::quantize(&rng.uniform_vec(h * d, 1.0));
        let kq = vector::quantize(&rng.uniform_vec(len * h * d, 1.0));
        let vq = vector::quantize(&rng.uniform_vec(len * h * d, 1.0));
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let mut mha = FxpMhaSwiftKv::new(h, d);
        let mut a = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut a);
        let mut b = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut b);
        assert_eq!(
            a.iter().map(|x| x.raw()).collect::<Vec<_>>(),
            b.iter().map(|x| x.raw()).collect::<Vec<_>>()
        );
    }
}
