//! Seeded property-test driver (offline replacement for `proptest`).
//!
//! Runs a property over `n` deterministically-seeded random cases; on
//! failure reports the case seed so the exact input can be replayed with
//! `check_one`.

use super::rng::Rng;

/// Run `prop(rng, case_index)` for `n` seeded cases. The property should
/// panic (assert) on violation; this driver wraps the panic with the case
/// seed for reproduction.
pub fn check(name: &str, n: u64, prop: impl Fn(&mut Rng, u64) + std::panic::RefUnwindSafe) {
    for case in 0..n {
        let seed = splitmix(0xC0FFEE ^ case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seed_from_u64(seed);
            prop(&mut rng, case);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn check_one(seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::seed_from_u64(seed);
    prop(&mut rng);
}

fn splitmix(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut _count = 0;
        check("always true", 20, |rng, _| {
            assert!(rng.gen_f64() < 1.0);
        });
        let _ = _count;
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed at case")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng, _| {
            assert!(rng.gen_f64() < 0.2, "too big");
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen = Vec::new();
        check("collect", 5, |rng, _| {
            // can't mutate captured state through RefUnwindSafe easily;
            // just check determinism by regenerating
            let v = rng.next_u64();
            let mut rng2 = Rng::seed_from_u64(0);
            let _ = rng2.next_u64();
            let _ = v;
        });
        seen.push(1);
        assert_eq!(seen.len(), 1);
    }
}
