//! The redesigned submission API: submit → per-request token stream →
//! final [`SessionOutcome`] — plus the engine-side wake/shutdown gate.
//!
//! A [`ServeHandle`] is the only way work enters a running continuous
//! engine ([`super::cpu::CpuServer::serve_continuous`]): callers submit
//! a [`crate::model::Request`] and get back a [`PendingRequest`] — a
//! per-request stream of [`TokenEvent`]s that ends with the request's
//! final outcome. The handle is cheap to clone (one clone per HTTP
//! connection thread, one per load-generator worker); dropping every
//! clone closes the engine's intake, which lets it drain and retire.
//! [`ServeHandle::request_shutdown`] asks the engine to stop admitting
//! and drain under its wall-clock bound, and
//! [`ServeHandle::status`] exposes the live queue-depth / draining
//! snapshot the HTTP front door serves from `/healthz`.
//!
//! The engine stays runtime-agnostic behind this surface: events ride
//! bounded `std::sync::mpsc::sync_channel`s (so a stalled consumer
//! back-pressures into slow-client cancellation instead of unbounded
//! buffering), and wakeups ride [`EngineGate`] — an eventcount built on
//! [`crate::kernels::sync`] so the loom tier can model-check the
//! park/wake/shutdown protocol.

use super::session::SessionOutcome;
use crate::kernels::sync::{self, Condvar, Mutex};
use crate::model::Request;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::sync::PoisonError;

/// One event on a request's output stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenEvent {
    /// One generated token, in generation order. Tokens are emitted as
    /// they are sampled; a preempted-and-requeued request re-decodes
    /// bit-identically, so already-streamed positions are never re-sent.
    Token(u32),
    /// The request retired with this outcome. Always the stream's last
    /// event (when the engine survives long enough to send it).
    Done(SessionOutcome),
}

/// One unit of work on the engine's intake channel: the request plus
/// (for streaming submitters) the sender half of its event stream.
pub(crate) struct Submission {
    pub(crate) request: Request,
    pub(crate) events: Option<SyncSender<TokenEvent>>,
}

/// Why a submission failed to enter the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The engine's intake is gone — the serving loop has exited (hit
    /// `max_iterations`, or the scope is shutting down).
    EngineClosed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EngineClosed => write!(f, "engine closed: serving loop has exited"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// State guarded by the gate's mutex. `seq` is an eventcount: it
/// advances on every wake-worthy event (submission, intake close,
/// shutdown), and a parker only sleeps while the sequence it snapshot
/// before its last intake drain is still current.
struct GateState {
    seq: u64,
    shutdown: bool,
    intake_closed: bool,
}

/// Eventcount-style park/wake gate between submitters and the engine.
///
/// Protocol (model-checked by `rust/tests/loom_engine.rs`):
/// 1. submitter: enqueue work (mpsc send / flag store), then
///    [`EngineGate::notify`] — bump `seq` *under the lock*, notify_all.
/// 2. engine: `seen = gate.seq()`, then drain the intake, then
///    `gate.park(seen, ..)` — the park re-checks `seq` under the same
///    lock, so a notify between the snapshot and the park is never
///    lost (the wait never starts).
///
/// `intake_closed` / `shutdown` are latched under the lock before the
/// notify so a parked engine observes them on wake without racing the
/// mpsc disconnect.
pub struct EngineGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl Default for EngineGate {
    fn default() -> Self {
        EngineGate::new()
    }
}

impl EngineGate {
    pub fn new() -> EngineGate {
        EngineGate {
            state: Mutex::new(GateState {
                seq: 0,
                shutdown: false,
                intake_closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> sync::MutexGuard<'_, GateState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Current eventcount. Snapshot this *before* draining the intake.
    pub fn seq(&self) -> u64 {
        self.lock().seq
    }

    /// Something arrived: advance the eventcount and wake the engine.
    pub fn notify(&self) {
        let mut g = self.lock();
        g.seq = g.seq.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    /// Latch "no more submissions will ever arrive" and wake the engine.
    pub fn close_intake(&self) {
        let mut g = self.lock();
        g.intake_closed = true;
        g.seq = g.seq.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    /// Latch a shutdown request and wake the engine.
    pub fn request_shutdown(&self) {
        let mut g = self.lock();
        g.shutdown = true;
        g.seq = g.seq.wrapping_add(1);
        drop(g);
        self.cv.notify_all();
    }

    pub fn shutdown_requested(&self) -> bool {
        self.lock().shutdown
    }

    pub fn intake_closed(&self) -> bool {
        self.lock().intake_closed
    }

    /// Park until the eventcount moves past `seen`, shutdown or
    /// intake-close latches, or (std builds only) `timeout_ms` elapses.
    /// Returns immediately if any of those already hold.
    pub fn park(&self, seen: u64, timeout_ms: Option<u64>) {
        let mut g = self.lock();
        while g.seq == seen && !g.shutdown && !g.intake_closed {
            g = sync::wait_ms(&self.cv, g, timeout_ms);
            if timeout_ms.is_some() {
                // Timed park: one wait is the bound; the engine re-runs
                // its arrival-gating pass on wake regardless of cause.
                break;
            }
        }
    }
}

/// Live engine state the front door reads without touching the engine
/// thread: plain `std` atomics (never under loom — `/healthz` is not
/// part of the model-checked protocol; the gate is).
#[derive(Debug, Default)]
pub struct EngineStatus {
    draining: AtomicBool,
    queue_depth: AtomicUsize,
    active_lanes: AtomicUsize,
    queue_cap: AtomicUsize,
    shed_total: AtomicU64,
    retry_after_ms: AtomicU64,
}

impl EngineStatus {
    pub(crate) fn set_draining(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub(crate) fn set_depths(&self, queue_depth: usize, active_lanes: usize) {
        self.queue_depth.store(queue_depth, Ordering::Release);
        self.active_lanes.store(active_lanes, Ordering::Release);
    }

    pub(crate) fn set_queue_cap(&self, cap: usize) {
        self.queue_cap.store(cap, Ordering::Release);
    }

    pub(crate) fn record_shed(&self, retry_after_ms: u64) {
        self.shed_total.fetch_add(1, Ordering::AcqRel);
        self.retry_after_ms.store(retry_after_ms, Ordering::Release);
    }

    /// True once shutdown was requested: admission is closed and the
    /// engine is draining (or cancelling) its remaining lanes.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// True while the admission queue sits at its configured cap — new
    /// submissions are being shed.
    pub fn is_overloaded(&self) -> bool {
        let cap = self.queue_cap.load(Ordering::Acquire);
        cap > 0 && self.queue_depth.load(Ordering::Acquire) >= cap
    }

    /// Admission-queue depth as of the engine's last iteration.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Acquire)
    }

    /// Lanes actively decoding as of the engine's last iteration.
    pub fn active_lanes(&self) -> usize {
        self.active_lanes.load(Ordering::Acquire)
    }

    /// Total requests shed by admission control so far.
    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Acquire)
    }

    /// The engine's most recent `Retry-After` hint, in milliseconds.
    pub fn retry_after_ms(&self) -> u64 {
        self.retry_after_ms.load(Ordering::Acquire)
    }
}

/// Engine-side control block shared between every [`ServeHandle`]
/// clone and the engine loop.
pub(crate) struct EngineCtl {
    pub(crate) gate: EngineGate,
    pub(crate) status: EngineStatus,
    /// Capacity of each request's bounded event stream. A full buffer
    /// marks the client slow; the engine cancels the lane rather than
    /// block or buffer unboundedly.
    pub(crate) event_buffer: usize,
}

impl EngineCtl {
    pub(crate) fn new(event_buffer: usize) -> Arc<EngineCtl> {
        Arc::new(EngineCtl {
            gate: EngineGate::new(),
            status: EngineStatus::default(),
            event_buffer: event_buffer.max(1),
        })
    }
}

/// Shared core behind every [`ServeHandle`] clone. Dropping the last
/// clone latches intake-close on the gate *before* the mpsc sender
/// disconnects (field order: `tx` drops first, but the gate latch in
/// `Drop::drop` runs before either field drops), so a parked engine
/// always wakes and always sees every buffered submission.
struct HandleShared {
    tx: Sender<Submission>,
    ctl: Arc<EngineCtl>,
}

impl Drop for HandleShared {
    fn drop(&mut self) {
        self.ctl.gate.close_intake();
    }
}

/// Submission handle onto a running continuous engine. Clone freely —
/// every clone feeds the same lane array; the engine's intake closes
/// when the last clone drops.
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<HandleShared>,
}

impl ServeHandle {
    pub(crate) fn new(tx: Sender<Submission>, ctl: Arc<EngineCtl>) -> ServeHandle {
        ServeHandle {
            shared: Arc::new(HandleShared { tx, ctl }),
        }
    }

    /// Submit a request and stream its output. The request joins the
    /// admission queue mid-flight — it takes a lane as soon as its
    /// `arrival_ms` has passed and a lane is free, with no drain
    /// barrier. Oversized requests are not an error here: their stream
    /// reports [`SessionOutcome::Rejected`] as its only event; shed
    /// requests report [`SessionOutcome::Shed`].
    ///
    /// Dropping the returned [`PendingRequest`] is cancellation: the
    /// engine notices the dead stream at its next iteration boundary,
    /// retires the lane as [`SessionOutcome::Cancelled`], and reclaims
    /// its KV blocks.
    pub fn submit(&self, request: Request) -> Result<PendingRequest, SubmitError> {
        let id = request.id;
        let (etx, erx) = std::sync::mpsc::sync_channel(self.shared.ctl.event_buffer);
        self.shared
            .tx
            .send(Submission {
                request,
                events: Some(etx),
            })
            .map_err(|_| SubmitError::EngineClosed)?;
        self.shared.ctl.gate.notify();
        Ok(PendingRequest { id, rx: erx })
    }

    /// Submit without an event stream: the request's tokens and outcome
    /// are only observable through the engine's final
    /// [`super::cpu::CpuServeReport`] (the offline path).
    pub fn submit_nowait(&self, request: Request) -> Result<(), SubmitError> {
        self.shared
            .tx
            .send(Submission {
                request,
                events: None,
            })
            .map_err(|_| SubmitError::EngineClosed)?;
        self.shared.ctl.gate.notify();
        Ok(())
    }

    /// Ask the engine to shut down gracefully: admission closes
    /// immediately (queued requests are shed), running lanes drain
    /// within the engine's `drain_ms` bound, then the engine retires
    /// with its pool-leak audit. Idempotent; returns immediately —
    /// observe completion through the engine's report or join.
    pub fn request_shutdown(&self) {
        self.shared.ctl.status.set_draining();
        self.shared.ctl.gate.request_shutdown();
    }

    /// Live engine status: queue depth, active lanes, draining /
    /// overloaded flags. This is what `/healthz` serves.
    pub fn status(&self) -> &EngineStatus {
        &self.shared.ctl.status
    }
}

/// The receiving half of one submitted request: a blocking stream of
/// [`TokenEvent`]s ending in [`TokenEvent::Done`]. Dropping it cancels
/// the request at the engine's next iteration boundary.
pub struct PendingRequest {
    id: u64,
    rx: Receiver<TokenEvent>,
}

impl PendingRequest {
    /// The submitted request's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the stream is over (after
    /// `Done`, or if the engine died without retiring the request).
    pub fn next_event(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Block until the request retires, collecting its tokens. An
    /// engine that exits without retiring the request (e.g. a
    /// `max_iterations` cap) yields a `Failed` outcome rather than a
    /// hang or a panic.
    pub fn wait(self) -> FinishedRequest {
        let mut tokens = Vec::new();
        loop {
            match self.rx.recv() {
                Ok(TokenEvent::Token(t)) => tokens.push(t),
                Ok(TokenEvent::Done(outcome)) => {
                    return FinishedRequest {
                        id: self.id,
                        tokens,
                        outcome,
                    }
                }
                Err(_) => {
                    return FinishedRequest {
                        id: self.id,
                        tokens,
                        outcome: SessionOutcome::Failed(
                            "engine terminated before the request finished".to_string(),
                        ),
                    }
                }
            }
        }
    }
}

/// A retired request as seen through the submission API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinishedRequest {
    pub id: u64,
    /// Every token streamed before retirement (the full generation for
    /// `Completed`, a prefix for failures).
    pub tokens: Vec<u32>,
    pub outcome: SessionOutcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_handle() -> (ServeHandle, Receiver<Submission>, Arc<EngineCtl>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let ctl = EngineCtl::new(256);
        (ServeHandle::new(tx, ctl.clone()), rx, ctl)
    }

    #[test]
    fn wait_collects_tokens_then_outcome() {
        let (handle, rx, _ctl) = test_handle();
        let pending = handle
            .submit(Request::new(7, vec![1, 2]).gen_len(3))
            .expect("intake open");
        assert_eq!(pending.id(), 7);
        // play the engine side
        let sub = rx.recv().expect("submission arrives");
        assert_eq!(sub.request.id, 7);
        let events = sub.events.expect("streaming submission carries a sink");
        for t in [10u32, 11, 12] {
            events.send(TokenEvent::Token(t)).expect("receiver alive");
        }
        events
            .send(TokenEvent::Done(SessionOutcome::Completed))
            .expect("receiver alive");
        let fin = pending.wait();
        assert_eq!(fin.tokens, vec![10, 11, 12]);
        assert!(fin.outcome.is_completed());
    }

    #[test]
    fn engine_death_maps_to_failed_outcome() {
        let (handle, rx, _ctl) = test_handle();
        let pending = handle.submit(Request::new(0, vec![1])).expect("intake open");
        let sub = rx.recv().expect("submission arrives");
        let events = sub.events.expect("sink");
        events.send(TokenEvent::Token(5)).expect("receiver alive");
        drop(events); // engine dies without sending Done
        let fin = pending.wait();
        assert_eq!(fin.tokens, vec![5]);
        assert!(
            matches!(&fin.outcome, SessionOutcome::Failed(m) if m.contains("engine terminated")),
            "got {:?}",
            fin.outcome
        );
    }

    #[test]
    fn submit_after_engine_exit_errors() {
        let (handle, rx, _ctl) = test_handle();
        drop(rx);
        assert_eq!(
            handle.submit(Request::new(0, vec![1])).err(),
            Some(SubmitError::EngineClosed)
        );
        assert_eq!(
            handle.submit_nowait(Request::new(1, vec![1])),
            Err(SubmitError::EngineClosed)
        );
    }

    #[test]
    fn submit_notifies_gate_and_drop_closes_intake() {
        let (handle, _rx, ctl) = test_handle();
        let seq0 = ctl.gate.seq();
        handle.submit_nowait(Request::new(0, vec![1])).expect("open");
        assert!(ctl.gate.seq() != seq0, "submit must bump the eventcount");
        assert!(!ctl.gate.intake_closed());
        let clone = handle.clone();
        drop(handle);
        assert!(
            !ctl.gate.intake_closed(),
            "intake stays open while a clone lives"
        );
        drop(clone);
        assert!(ctl.gate.intake_closed(), "last drop latches intake-close");
    }

    #[test]
    fn shutdown_latches_and_park_returns_immediately() {
        let (handle, _rx, ctl) = test_handle();
        assert!(!handle.status().is_draining());
        handle.request_shutdown();
        assert!(handle.status().is_draining());
        assert!(ctl.gate.shutdown_requested());
        // park with a stale seq must not block once shutdown latched
        ctl.gate.park(ctl.gate.seq(), None);
    }

    #[test]
    fn status_overload_flag_tracks_cap_and_depth() {
        let status = EngineStatus::default();
        assert!(!status.is_overloaded(), "uncapped queue never overloads");
        status.set_queue_cap(2);
        status.set_depths(1, 0);
        assert!(!status.is_overloaded());
        status.set_depths(2, 0);
        assert!(status.is_overloaded());
        status.record_shed(120);
        assert_eq!(status.shed_total(), 1);
        assert_eq!(status.retry_after_ms(), 120);
    }
}
