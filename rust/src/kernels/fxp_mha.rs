//! Fused multi-head SwiftKV decode state in the accelerator's FXP32
//! (Q15.17) arithmetic — the multi-head datapath of Fig. 5, grouped-query
//! aware.
//!
//! Same interleaved token-major layout and API as [`super::mha::MhaSwiftKv`]
//! (KV rows are `n_kv_heads · d` wide; queries/outputs `n_heads · d`), but
//! every operation is the bit-exact Q15.17 model: wide-accumulator
//! dot products on the MAC array ([`crate::fxp::vector::dot`]), the
//! shift + 5-bit-LUT exponential of Eqs. (9)–(10), and saturating AXPY
//! updates. Because integer addition is associative and all per-head
//! operations are issued in the same order as the per-head
//! [`crate::attention::fxp_swiftkv::FxpSwiftKvState`], the fused sweep is
//! **bit-for-bit identical** to running each query head separately against
//! its shared KV head — the property `tests/prop_mha_fused.rs` and
//! `tests/prop_gqa_fused.rs` assert on raw bits.

use crate::fxp::{vector, Exp2Lut, Fxp32};

/// Packed multi-head Q15.17 SwiftKV recurrence state (GQA-aware).
#[derive(Debug, Clone)]
pub struct FxpMhaSwiftKv {
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    mu: Vec<Fxp32>,
    z: Vec<Fxp32>,
    /// Unnormalized output, `[n_heads * d]`, head-major.
    y: Vec<Fxp32>,
    consumed: usize,
}

impl FxpMhaSwiftKv {
    /// Fresh multi-head-attention state (`n_kv_heads == n_heads`) for
    /// `n_heads` heads of dimension `d`.
    pub fn new(n_heads: usize, d: usize) -> Self {
        Self::new_grouped(n_heads, n_heads, d)
    }

    /// Fresh grouped-query state: `n_heads` query heads sharing
    /// `n_kv_heads` KV heads (`n_heads % n_kv_heads == 0`).
    pub fn new_grouped(n_heads: usize, n_kv_heads: usize, d: usize) -> Self {
        assert!(n_heads > 0 && n_kv_heads > 0 && d > 0, "empty state");
        assert!(
            n_heads % n_kv_heads == 0,
            "n_heads ({n_heads}) must be a multiple of n_kv_heads ({n_kv_heads})"
        );
        FxpMhaSwiftKv {
            n_heads,
            n_kv_heads,
            d,
            mu: vec![Fxp32::MIN; n_heads],
            z: vec![Fxp32::ZERO; n_heads],
            y: vec![Fxp32::ZERO; n_heads * d],
            consumed: 0,
        }
    }

    /// Reset for a new query without releasing the buffers.
    #[inline]
    pub fn reset(&mut self) {
        self.consumed = 0;
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_kv_heads(&self) -> usize {
        self.n_kv_heads
    }

    /// Query heads per KV head (`1` for MHA, `n_heads` for MQA).
    #[inline]
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Width of one interleaved KV cache row (`n_kv_heads · d`).
    #[inline]
    pub fn row_width(&self) -> usize {
        self.n_kv_heads * self.d
    }

    /// Width of the packed query / output rows (`n_heads · d`).
    #[inline]
    pub fn q_width(&self) -> usize {
        self.n_heads * self.d
    }

    /// Consume one interleaved `(k_t, v_t)` row, advancing every query
    /// head — Eqs. (5)–(7) in Q15.17 with the LUT exponential. Each
    /// KV-head slice is loaded once and feeds its whole group.
    #[inline]
    pub fn update_token(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k_t: &[Fxp32],
        v_t: &[Fxp32],
        scale: Fxp32,
    ) {
        let d = self.d;
        let group = self.group();
        debug_assert_eq!(q.len(), self.n_heads * d);
        debug_assert_eq!(k_t.len(), self.n_kv_heads * d);
        debug_assert_eq!(v_t.len(), self.n_kv_heads * d);
        if self.consumed == 0 {
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = vector::dot(&q[o..o + d], kh).sat_mul(scale);
                    self.mu[head] = s;
                    self.z[head] = Fxp32::ONE;
                    self.y[o..o + d].copy_from_slice(vh);
                }
            }
        } else {
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = vector::dot(&q[o..o + d], kh).sat_mul(scale);
                    let yh = &mut self.y[o..o + d];
                    if s <= self.mu[head] {
                        // β = exp(s − μ) ∈ (0, 1]
                        let beta = lut.exp_neg(s.sat_sub(self.mu[head]));
                        self.z[head] = self.z[head].sat_add(beta);
                        vector::axpy_inplace(beta, yh, vh);
                    } else {
                        // α = exp(μ − s) ∈ (0, 1)
                        let alpha = lut.exp_neg(self.mu[head].sat_sub(s));
                        self.z[head] = alpha.sat_mul(self.z[head]).sat_add(Fxp32::ONE);
                        vector::scale_axpy_inplace(alpha, yh, vh);
                        self.mu[head] = s;
                    }
                }
            }
        }
        self.consumed += 1;
    }

    /// Extend over cache rows `[from, to)` of a token-major interleaved
    /// Q15.17 cache (`k`/`v` are `[len, n_kv_heads * d]` row-major).
    #[allow(clippy::too_many_arguments)]
    pub fn extend(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k: &[Fxp32],
        v: &[Fxp32],
        from: usize,
        to: usize,
        scale: Fxp32,
    ) {
        let row = self.row_width();
        assert!(k.len() >= to * row, "k cache too short");
        assert!(v.len() >= to * row, "v cache too short");
        for t in from..to {
            self.update_token(
                lut,
                q,
                &k[t * row..(t + 1) * row],
                &v[t * row..(t + 1) * row],
                scale,
            );
        }
    }

    /// Extend over token positions `[from, to)` of a block-gathered
    /// paged Q15.17 mirror ([`super::paged::BlockTable`]). Because the
    /// rows reach [`FxpMhaSwiftKv::update_token`] in the same order with
    /// the same per-head op sequence as [`FxpMhaSwiftKv::extend`], the
    /// paged sweep is **bit-exact** versus the contiguous one.
    #[allow(clippy::too_many_arguments)]
    pub fn extend_paged(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        table: &super::paged::BlockTable,
        from: usize,
        to: usize,
        scale: Fxp32,
    ) {
        assert_eq!(table.row_width(), self.row_width(), "table row width mismatch");
        assert!(table.capacity_tokens() >= to, "block table too short");
        for t in from..to {
            self.update_token(lut, q, table.kq_row(t), table.vq_row(t), scale);
        }
    }

    /// Eq. (8) on the divide unit, into a caller-owned buffer.
    pub fn finalize_into(&self, out: &mut [Fxp32]) {
        assert!(self.consumed > 0, "finalize before any token");
        assert_eq!(out.len(), self.n_heads * self.d);
        for head in 0..self.n_heads {
            let o = head * self.d;
            let z = self.z[head];
            for (dst, &y) in out[o..o + self.d].iter_mut().zip(&self.y[o..o + self.d]) {
                *dst = y.sat_div(z);
            }
        }
    }

    /// One-shot fused attention over `len` interleaved cache rows.
    #[allow(clippy::too_many_arguments)]
    pub fn attend(
        &mut self,
        lut: &Exp2Lut,
        q: &[Fxp32],
        k: &[Fxp32],
        v: &[Fxp32],
        len: usize,
        scale: Fxp32,
        out: &mut [Fxp32],
    ) {
        self.reset();
        self.extend(lut, q, k, v, 0, len, scale);
        self.finalize_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
    use crate::kernels::gather_head;
    use crate::util::Rng;

    #[test]
    fn fused_bit_exact_vs_per_head() {
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(21);
        let (h, d, len) = (4usize, 16usize, 48usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);

        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);
        let mut mha = FxpMhaSwiftKv::new(h, d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            let kh = gather_head(&k, head, h, d, len);
            let vh = gather_head(&v, head, h, d, len);
            let p = FxpHeadProblem::quantize(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(a.raw(), b.raw(), "head {head} dim {i} diverged");
            }
        }
    }

    #[test]
    fn grouped_bit_exact_vs_per_head_over_shared_kv() {
        // GQA: every query head must be bit-identical to the per-head
        // Q15.17 reference run on its shared KV head's cache.
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(23);
        let (h, hkv, d, len) = (8usize, 2usize, 16usize, 32usize);
        let group = h / hkv;
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * hkv * d, 1.0);
        let v = rng.uniform_vec(len * hkv * d, 1.0);

        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);
        let mut mha = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        assert_eq!(mha.row_width(), hkv * d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            let kv = head / group;
            let kh = gather_head(&k, kv, hkv, d, len);
            let vh = gather_head(&v, kv, hkv, d, len);
            let p = FxpHeadProblem::quantize(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(a.raw(), b.raw(), "head {head} dim {i} diverged");
            }
        }
    }

    #[test]
    fn paged_extend_bit_exact_vs_contiguous() {
        use crate::kernels::paged::{BlockPool, BlockTable};
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(24);
        let (h, hkv, d, len) = (4usize, 2usize, 8usize, 10usize);
        let row = hkv * d;
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);
        let qq = vector::quantize(&q);
        let kq = vector::quantize(&k);
        let vq = vector::quantize(&v);

        // block_len 4 → ragged last block (10 = 2·4 + 2); mirror filled
        // through the same quantize path as the contiguous reference
        let pool = BlockPool::new(3, 4, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&k[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&v[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }

        let mut contiguous = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![Fxp32::ZERO; h * d];
        contiguous.attend(&lut, &qq, &kq, &vq, len, scale, &mut a);

        let mut paged = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&lut, &qq, &table, 0, 7, scale);
        paged.extend_paged(&lut, &qq, &table, 7, len, scale);
        let mut b = vec![Fxp32::ZERO; h * d];
        paged.finalize_into(&mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.raw(), y.raw(), "flat dim {i} diverged");
        }
        table.release_into(&pool);
    }

    #[test]
    fn deterministic_across_reset() {
        let lut = Exp2Lut::new();
        let mut rng = Rng::seed_from_u64(22);
        let (h, d, len) = (2usize, 8usize, 20usize);
        let qq = vector::quantize(&rng.uniform_vec(h * d, 1.0));
        let kq = vector::quantize(&rng.uniform_vec(len * h * d, 1.0));
        let vq = vector::quantize(&rng.uniform_vec(len * h * d, 1.0));
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let mut mha = FxpMhaSwiftKv::new(h, d);
        let mut a = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut a);
        let mut b = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut b);
        assert_eq!(
            a.iter().map(|x| x.raw()).collect::<Vec<_>>(),
            b.iter().map(|x| x.raw()).collect::<Vec<_>>()
        );
    }
}
