//! Bit-exact FXP32 model of the SwiftKV core datapath (Fig. 3).
//!
//! The same per-token recurrence as [`super::swiftkv`], but in the
//! accelerator's arithmetic: Q15.17 fixed point everywhere, exponentials
//! through the shift + 5-bit-LUT unit of Eqs. (9)–(10), the dot product on
//! the wide-accumulator MAC array, and the final normalization as one
//! reciprocal-free divide sweep. This is the numerics the Table I
//! experiment compares against desktop f32.

use crate::fxp::{vector, Exp2Lut, Fxp32};

/// Q15.17 state of the SwiftKV core update part.
#[derive(Debug, Clone)]
pub struct FxpSwiftKvState {
    pub mu: Fxp32,
    pub z: Fxp32,
    pub y: Vec<Fxp32>,
    pub consumed: usize,
}

impl FxpSwiftKvState {
    pub fn new(d: usize) -> Self {
        FxpSwiftKvState {
            mu: Fxp32::MIN, // stands in for −∞; replaced on first token
            z: Fxp32::ZERO,
            y: vec![Fxp32::ZERO; d],
            consumed: 0,
        }
    }

    /// One per-token update, Eqs. (6)/(7), in Q15.17 with the LUT exp.
    #[inline]
    pub fn update(&mut self, lut: &Exp2Lut, s_t: Fxp32, v_t: &[Fxp32]) {
        debug_assert_eq!(v_t.len(), self.y.len());
        if self.consumed == 0 {
            self.mu = s_t;
            self.z = Fxp32::ONE;
            self.y.copy_from_slice(v_t);
        } else if s_t <= self.mu {
            // β = exp(s_t − μ) ∈ (0, 1]
            let beta = lut.exp_neg(s_t.sat_sub(self.mu));
            self.z = self.z.sat_add(beta);
            vector::axpy_inplace(beta, &mut self.y, v_t);
        } else {
            // α = exp(μ − s_t) ∈ (0, 1)
            let alpha = lut.exp_neg(self.mu.sat_sub(s_t));
            self.z = alpha.sat_mul(self.z).sat_add(Fxp32::ONE);
            vector::scale_axpy_inplace(alpha, &mut self.y, v_t);
            self.mu = s_t;
        }
        self.consumed += 1;
    }

    /// Eq. (8): one-time normalization on the divide unit.
    pub fn finalize(&self) -> Vec<Fxp32> {
        let mut out = vec![Fxp32::ZERO; self.y.len()];
        self.finalize_into(&mut out);
        out
    }

    /// Eq. (8) into a caller-owned buffer (no allocation); bit-identical
    /// to [`Self::finalize`].
    pub fn finalize_into(&self, out: &mut [Fxp32]) {
        assert!(self.consumed > 0);
        assert_eq!(out.len(), self.y.len());
        for (o, &y) in out.iter_mut().zip(&self.y) {
            *o = y.sat_div(self.z);
        }
    }
}

/// A head problem already quantized to the accelerator's formats.
pub struct FxpHeadProblem {
    pub q: Vec<Fxp32>,
    pub k: Vec<Fxp32>,
    pub v: Vec<Fxp32>,
    pub d: usize,
    pub len: usize,
    /// 1/√d, quantized once (the hardware folds it into the dot product).
    pub scale: Fxp32,
}

impl FxpHeadProblem {
    /// Quantize an f32 problem (SFU FXP32 cast of Fig. 5(c)).
    pub fn quantize(q: &[f32], k: &[f32], v: &[f32], d: usize, len: usize) -> Self {
        assert_eq!(q.len(), d);
        assert!(k.len() >= len * d && v.len() >= len * d);
        FxpHeadProblem {
            q: vector::quantize(q),
            k: vector::quantize(&k[..len * d]),
            v: vector::quantize(&v[..len * d]),
            d,
            len,
            scale: Fxp32::from_f64(1.0 / (d as f64).sqrt()),
        }
    }

    #[inline]
    pub fn key(&self, t: usize) -> &[Fxp32] {
        &self.k[t * self.d..(t + 1) * self.d]
    }

    #[inline]
    pub fn value(&self, t: usize) -> &[Fxp32] {
        &self.v[t * self.d..(t + 1) * self.d]
    }
}

/// Single-pass FXP32 attention; returns the Q15.17 output vector.
pub fn attend_fxp(lut: &Exp2Lut, p: &FxpHeadProblem) -> Vec<Fxp32> {
    let mut st = FxpSwiftKvState::new(p.d);
    for t in 0..p.len {
        // Eq. (5) on the MAC array: wide-accumulator dot, then scale
        let s_t = vector::dot(&p.q, p.key(t)).sat_mul(p.scale);
        st.update(lut, s_t, p.value(t));
    }
    st.finalize()
}

/// Convenience wrapper: quantize an f32 problem, run the FXP32 datapath,
/// dequantize the result (what the SFU hands back to the GEMV pipeline).
pub fn attend(lut: &Exp2Lut, q: &[f32], k: &[f32], v: &[f32], d: usize, len: usize) -> Vec<f32> {
    let p = FxpHeadProblem::quantize(q, k, v, d, len);
    vector::dequantize(&attend_fxp(lut, &p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::ProblemData;
    use crate::attention::{native, HeadProblem};

    /// The paper's headline numeric claim: FXP32 attention error < 1e-5…
    /// measured against f32 on inputs in the typical attention range.
    /// (Strictly the claim is about arithmetic resolution, 2^-17 ≈ 7.6e-6;
    /// end-to-end we allow small accumulation on top.)
    #[test]
    fn fxp_attention_close_to_f32() {
        let lut = Exp2Lut::new();
        for seed in 0..6 {
            let data = ProblemData::random(seed, 32, 128, 1.0);
            let p = HeadProblem::new(&data.q, &data.k, &data.v, data.d, data.len);
            let want = native::attend(&p);
            let got = attend(&lut, &data.q, &data.k, &data.v, data.d, data.len);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() < 5e-4,
                    "seed {seed} dim {i}: fxp {g} vs f32 {w}"
                );
            }
        }
    }

    #[test]
    fn precision_better_than_1e5_on_recurrence_state() {
        // Drive both datapaths with *identical* scores/values so the only
        // difference is Q15.17 + LUT-exp arithmetic; the per-step state
        // error must stay below 1e-5 · O(1) (the paper's §III claim).
        let lut = Exp2Lut::new();
        let data = ProblemData::random(3, 16, 256, 1.0);
        let p = HeadProblem::new(&data.q, &data.k, &data.v, data.d, data.len);
        let scale = p.scale();

        let mut f_st = crate::attention::swiftkv::SwiftKvState::new(p.d);
        let mut x_st = FxpSwiftKvState::new(p.d);
        let qq = vector::quantize(p.q);
        for t in 0..p.len {
            let s_f = crate::attention::dot_f32(p.q, p.key(t)) * scale;
            f_st.update(s_f, p.value(t));
            let kq = vector::quantize(p.key(t));
            let vq = vector::quantize(p.value(t));
            let s_x = vector::dot(&qq, &kq).sat_mul(Fxp32::from_f64(scale as f64));
            x_st.update(&lut, s_x, &vq);
            assert!(
                (x_st.z.to_f32() - f_st.z).abs() / f_st.z.max(1.0) < 1e-3,
                "Z diverged at t={t}"
            );
        }
        let out_f = f_st.finalize();
        let out_x = vector::dequantize(&x_st.finalize());
        for (g, w) in out_x.iter().zip(&out_f) {
            assert!((g - w).abs() < 5e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn z_bounded_by_token_count() {
        let lut = Exp2Lut::new();
        let data = ProblemData::random(8, 8, 300, 4.0);
        let p = FxpHeadProblem::quantize(&data.q, &data.k, &data.v, data.d, data.len);
        let mut st = FxpSwiftKvState::new(p.d);
        for t in 0..p.len {
            let s = vector::dot(&p.q, p.key(t)).sat_mul(p.scale);
            st.update(&lut, s, p.value(t));
            assert!(st.z.raw() > 0);
            assert!(st.z.to_f64() <= (t + 1) as f64 + 1e-3);
        }
    }

    #[test]
    fn deterministic_bit_exact() {
        let lut = Exp2Lut::new();
        let data = ProblemData::random(11, 16, 64, 1.0);
        let p = FxpHeadProblem::quantize(&data.q, &data.k, &data.v, data.d, data.len);
        let a: Vec<i32> = attend_fxp(&lut, &p).iter().map(|x| x.raw()).collect();
        let b: Vec<i32> = attend_fxp(&lut, &p).iter().map(|x| x.raw()).collect();
        assert_eq!(a, b);
    }
}
