//! Per-token operation counting — the basis of the paper's GOPS numbers.
//!
//! §V: "for LLaMA2-7B, with a context length of 512, the number of
//! operations required to generate a single token is 13.5 GOP", i.e.
//! 2 ops (MAC = mul+add) per weight parameter plus the attention
//! `qKᵀ`/`PV` work over the live context.

use super::config::LlmConfig;

/// Operation/byte cost of generating one token.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenCost {
    /// GEMV multiply-adds ×2 (weight ops).
    pub weight_ops: u64,
    /// Attention qKᵀ + PV multiply-adds ×2 across heads/layers.
    pub attention_ops: u64,
    /// Weight bytes streamed from HBM (W4 packed + scales).
    pub weight_bytes: u64,
    /// KV-cache bytes read.
    pub kv_bytes: u64,
}

impl TokenCost {
    /// Cost of one decode step at context length `n`.
    pub fn of(cfg: &LlmConfig, n: usize) -> TokenCost {
        let d = cfg.d_model as u64;
        let ffn = cfg.d_ffn as u64;
        let kv_dim = (cfg.n_kv_heads * cfg.d_head) as u64;
        let l = cfg.n_layers as u64;

        let mut mat_ops = 0u64;
        mat_ops += 2 * (d * d + 2 * d * kv_dim + d * d) * l; // QKVO
        mat_ops += if cfg.gated_mlp {
            2 * (2 * d * ffn + ffn * d) * l
        } else {
            2 * (d * ffn + ffn * d) * l
        };
        mat_ops += 2 * d * cfg.vocab as u64; // lm head

        // per layer: qKᵀ (n·d_head MACs per head) + PV (same) over n tokens
        let attn = 2 * 2 * (cfg.n_heads as u64) * (cfg.d_head as u64) * n as u64 * l;

        TokenCost {
            weight_ops: mat_ops,
            attention_ops: attn,
            weight_bytes: cfg.weight_bytes_w4(),
            kv_bytes: cfg.kv_read_bytes(n),
        }
    }

    /// Total GOP per token (the paper's 13.5 figure for LLaMA2-7B @512).
    pub fn total_gop(&self) -> f64 {
        (self.weight_ops + self.attention_ops) as f64 / 1e9
    }

    /// Throughput in GOPS for a given per-token latency.
    pub fn gops_at(&self, token_latency_s: f64) -> f64 {
        self.total_gop() / token_latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_matches_paper_13_5_gop() {
        let cost = TokenCost::of(&LlmConfig::llama2_7b(), 512);
        let gop = cost.total_gop();
        assert!(
            (gop - 13.5).abs() < 0.7,
            "paper: 13.5 GOP/token, model: {gop:.2}"
        );
    }

    #[test]
    fn paper_throughput_composition() {
        // §V: 13.5 GOP × 81.5 token/s ≈ 1100.3 GOPS
        let cost = TokenCost::of(&LlmConfig::llama2_7b(), 512);
        let gops = cost.gops_at(1.0 / 81.5);
        assert!((gops - 1100.3).abs() < 60.0, "GOPS = {gops:.1}");
    }

    #[test]
    fn attention_ops_linear_in_context() {
        let cfg = LlmConfig::llama2_7b();
        let a = TokenCost::of(&cfg, 256).attention_ops;
        let b = TokenCost::of(&cfg, 512).attention_ops;
        assert_eq!(2 * a, b);
    }

    #[test]
    fn weight_ops_independent_of_context() {
        let cfg = LlmConfig::chatglm_6b();
        assert_eq!(
            TokenCost::of(&cfg, 64).weight_ops,
            TokenCost::of(&cfg, 4096).weight_ops
        );
    }

    #[test]
    fn weight_ops_track_param_count() {
        for cfg in LlmConfig::paper_models() {
            let cost = TokenCost::of(&cfg, 1);
            let ratio = cost.weight_ops as f64 / (2.0 * cfg.params() as f64);
            // embeddings/norms don't contribute GEMV ops → slightly < 1
            assert!((0.9..=1.02).contains(&ratio), "{}: {ratio}", cfg.name);
        }
    }
}
