//! Bench: the L3 hot paths (§Perf targets).
//!
//! - the FXP32 per-token SwiftKV update (the SKV-core inner loop),
//! - the f32 per-token update,
//! - W4A8 GEMV (the tiny model's dominant op),
//! - one full tiny-model decode step (both numerics modes),
//! - one PJRT engine decode step (batch 1/8) when artifacts exist.

use swiftkv::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
use swiftkv::attention::{swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::fxp::Exp2Lut;
use swiftkv::model::{NumericsMode, TinyModel, WeightStore};
use swiftkv::quant::{quantize_int8, Int4Matrix, QuantLinear};
use swiftkv::runtime::{artifacts_available, default_artifacts_dir, Engine};
use swiftkv::util::bench::Bencher;
use swiftkv::util::Rng;

fn main() {
    let mut b = Bencher::new(200, 1000);
    let mut rng = Rng::seed_from_u64(5);

    // FXP32 SwiftKV scan — the SKV core inner loop
    let (d, n) = (128usize, 512usize);
    let q = rng.uniform_vec(d, 1.0);
    let k = rng.uniform_vec(n * d, 1.0);
    let v = rng.uniform_vec(n * d, 1.0);
    let lut = Exp2Lut::new();
    let fp = FxpHeadProblem::quantize(&q, &k, &v, d, n);
    b.bench("hot/fxp_swiftkv_scan n=512 d=128", || attend_fxp(&lut, &fp));
    let p = HeadProblem::new(&q, &k, &v, d, n);
    b.bench("hot/f32_swiftkv_scan n=512 d=128", || swiftkv_attn::attend(&p));

    // W4A8 GEMV 256→768 (tiny model's widest projection)
    let w = rng.uniform_vec(256 * 768, 0.5);
    let lin = QuantLinear::new(Int4Matrix::quantize(&w, 256, 768));
    let x = rng.uniform_vec(256, 1.0);
    b.bench("hot/gemv_w4a8 256x768", || lin.forward(&x));
    let xq = quantize_int8(&x);
    b.bench("hot/gemv_w4a8 256x768 (prequant)", || {
        swiftkv::quant::gemv_w4a8(&xq, &lin.weight)
    });

    if artifacts_available() {
        let ws = WeightStore::load(&default_artifacts_dir()).unwrap();
        let tm = TinyModel::load(&ws).unwrap();
        let mut st = tm.new_state();
        let mut i = 0u32;
        b.bench("hot/tiny_decode_step rust-desktop", || {
            if st.pos >= tm.n_ctx {
                st = tm.new_state();
            }
            i = (i + 1) % 512;
            tm.decode_step(&mut st, i, NumericsMode::DesktopF32)
        });
        let mut st2 = tm.new_state();
        b.bench("hot/tiny_decode_step rust-accel", || {
            if st2.pos >= tm.n_ctx {
                st2 = tm.new_state();
            }
            i = (i + 1) % 512;
            tm.decode_step(&mut st2, i, NumericsMode::Accelerator)
        });

        let eng = Engine::load(&default_artifacts_dir()).unwrap();
        for batch in [1usize, 8] {
            let mut bs = eng.new_state(batch).unwrap();
            let tokens = vec![7i32; batch];
            let mut pos = 0i32;
            b.bench(&format!("hot/pjrt_decode_step b{batch}"), || {
                if pos as usize >= eng.manifest.n_ctx {
                    bs = eng.new_state(batch).unwrap();
                    pos = 0;
                }
                let out = eng
                    .decode_step(&mut bs, &tokens, &vec![pos; batch])
                    .unwrap();
                pos += 1;
                out
            });
        }
    } else {
        println!("(artifacts not built — PJRT benches skipped)");
    }
}
