//! Fused multi-head decode kernels — the software hot-path substrate.
//!
//! The paper's SwiftKV-MHA accelerator derives its 13.48× attention
//! latency reduction from a *fused* schedule (§IV, Fig. 5): every
//! `(k_t, v_t)` cache row is streamed exactly once and feeds all heads in
//! a uniform pipeline; no per-head re-scan, no intermediate buffers. This
//! module is the same restructuring applied to the Rust model:
//!
//! - [`simd`] — `chunks_exact`-based multi-accumulator `dot`/`axpy`/
//!   `scale_axpy` primitives (the 4-lane trick of `quant::gemv`,
//!   generalized),
//! - [`mha::MhaSwiftKv`] — all heads' `(μ, Z, Y)` state packed
//!   contiguously, advanced per interleaved cache row in a single sweep
//!   (f32 numerics),
//! - [`fxp_mha::FxpMhaSwiftKv`] — the same fused sweep in the
//!   accelerator's Q15.17 + LUT-exp arithmetic, bit-exact vs. the
//!   per-head [`crate::attention::fxp_swiftkv`] datapath,
//! - [`scratch::DecodeScratch`] — caller-owned buffers making a
//!   steady-state [`crate::model::TinyModel`] decode step allocation-free.
//!
//! The non-allocating `_into` companions on the quant side
//! ([`crate::quant::gemv_w4a8_into`], [`crate::quant::quantize_int8_into`],
//! [`crate::quant::QuantLinear::forward_into`]) are re-exported here so
//! the whole fused-kernel surface is reachable from one path.

pub mod fxp_mha;
pub mod mha;
pub mod scratch;
pub mod simd;

pub use crate::quant::{gemv_w4a8_into, quantize_int8_into};
pub use fxp_mha::FxpMhaSwiftKv;
pub use mha::MhaSwiftKv;
pub use scratch::DecodeScratch;
pub use simd::{axpy, dot, scale, scale_axpy};

/// Gather one head of a token-major interleaved cache
/// (`[len][n_heads * d]`) into a contiguous head-major `[len, d]`
/// buffer — the layout the per-head [`crate::attention`] paths consume.
/// Used by the fused-vs-per-head equivalence tests and for layout
/// debugging.
pub fn gather_head(cache: &[f32], head: usize, n_heads: usize, d: usize, len: usize) -> Vec<f32> {
    assert!(head < n_heads, "head out of range");
    assert!(cache.len() >= len * n_heads * d, "cache too short");
    let mut out = Vec::with_capacity(len * d);
    for t in 0..len {
        let at = (t * n_heads + head) * d;
        out.extend_from_slice(&cache[at..at + d]);
    }
    out
}
