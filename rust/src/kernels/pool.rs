//! Persistent worker pool for operator-level parallelism on the serving
//! hot path.
//!
//! The CPU batch server used to fan lanes out with one
//! `std::thread::scope` spawn per iteration — thread creation and
//! teardown on every engine step, and no way to parallelize *inside* an
//! operator. This pool keeps its workers alive for the whole serving
//! run and hands them index-addressed task batches: a batched GEMM
//! splits its output columns across workers, the per-lane attention
//! phase splits lanes across workers, and between jobs the workers spin
//! briefly then park on a condvar. Dispatch performs **zero heap
//! allocation** (a raw closure pointer plus atomics), so pooled steps
//! keep the hot path's allocation-free guarantee.
//!
//! Scheduling is dynamic (workers pull task indices from a shared
//! atomic counter) but the tasks themselves write disjoint data, so
//! results never depend on which worker ran what —
//! `tests/prop_batched_decode.rs` asserts pooled and serial batched
//! decode steps are bit-identical.
//!
//! All synchronization goes through the [`super::sync`] alias layer, so
//! a `--cfg loom` build swaps in the [`crate::util::mc`] model checker
//! and `tests/loom_pool.rs` explores the epoch publication / park /
//! wake / panic protocol across thread interleavings.

use super::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use super::sync::{hint, thread, Arc, Condvar, Mutex};
use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

/// Spins a waiting worker performs before parking on the condvar. Sized
/// to cover the few-microsecond gaps between the pooled operators of one
/// decode step, so a step's jobs rarely pay a futex round trip.
#[cfg(not(loom))]
const SPIN_LIMIT: u32 = 8_192;
/// Under the model checker every spin iteration is a scheduling point;
/// park almost immediately so the DFS explores the condvar protocol
/// instead of enumerating pointless spin interleavings.
#[cfg(loom)]
const SPIN_LIMIT: u32 = 1;

/// A raw mutable pointer that may cross worker threads.
///
/// This wrapper only exists to carry a `*mut T` through the
/// `Send + Sync` bounds of [`WorkerPool::run`] closures; it never
/// dereferences the pointer itself. The aliasing contract is the
/// caller's: concurrent tasks must touch **disjoint** data behind the
/// pointer (e.g. task `i` writes only element `i`), and the pointee
/// must outlive the `run` call. Every dereference of [`SharedMut::get`]
/// therefore sits in caller `unsafe` with its own `// SAFETY:`
/// justification.
///
/// `T: Send` is required for the `Send`/`Sync` impls, so values whose
/// ownership must stay on one thread cannot be smuggled across workers:
///
/// ```compile_fail,E0277
/// use swiftkv::kernels::SharedMut;
/// fn cross_thread(p: SharedMut<std::rc::Rc<u32>>) {
///     // Rc is !Send, so SharedMut<Rc<_>> must not cross threads
///     std::thread::spawn(move || {
///         let _ = p;
///     });
/// }
/// ```
#[derive(Debug)]
pub struct SharedMut<T> {
    ptr: *mut T,
}

impl<T> Clone for SharedMut<T> {
    fn clone(&self) -> SharedMut<T> {
        *self
    }
}

impl<T> Copy for SharedMut<T> {}

impl<T> SharedMut<T> {
    /// Wrap a raw pointer for cross-worker task dispatch. Creating the
    /// wrapper is safe — the obligations (disjoint concurrent access,
    /// pointee outlives the job) bind at each `unsafe` dereference of
    /// [`SharedMut::get`].
    pub fn new(ptr: *mut T) -> SharedMut<T> {
        SharedMut { ptr }
    }

    /// The wrapped pointer. Dereferencing it is `unsafe`; see the type
    /// docs for the contract the caller must uphold.
    pub fn get(&self) -> *mut T {
        self.ptr
    }
}

// SAFETY: the wrapper carries the pointer only; all access happens in
// caller `unsafe` under the disjointness contract in the type docs.
// `T: Send` ensures access to the pointee may move to another thread.
unsafe impl<T: Send> Send for SharedMut<T> {}
// SAFETY: as above — `&SharedMut<T>` exposes nothing beyond the raw
// pointer value, and dereferences are the caller's obligation.
unsafe impl<T: Send> Sync for SharedMut<T> {}

/// Type-erased job: a caller-stack closure plus its task count. Valid
/// only while the submitting [`WorkerPool::run`] call is on the stack —
/// `run` does not return until every worker has checked out of the job.
#[derive(Clone, Copy)]
struct RawJob {
    call: unsafe fn(*const (), usize),
    data: *const (),
    tasks: usize,
}

/// # Safety
/// `data` must point to a live `F` (the closure submitted by the
/// current [`WorkerPool::run`] call) for the whole duration of the
/// call; `run` guarantees this by not returning until every worker has
/// checked out of the job's epoch.
unsafe fn invoke<F: Fn(usize) + Sync>(data: *const (), idx: usize) {
    // SAFETY: per the function contract, `data` is the submitter's `F`,
    // alive and shared (`&F`) for the duration of the job.
    unsafe { (*(data as *const F))(idx) }
}

/// # Safety
/// Trivially safe (touches nothing); `unsafe fn` only to match the
/// [`RawJob::call`] signature for the idle placeholder job.
unsafe fn invoke_nothing(_data: *const (), _idx: usize) {}

struct Shared {
    /// Bumped (under `sleep`'s mutex) to publish a new job; workers spin
    /// on it between jobs.
    epoch: AtomicU64,
    shutdown: AtomicBool,
    /// The current job; written by the submitter before the epoch bump,
    /// read by workers after observing it (Release/Acquire pairing).
    job: UnsafeCell<RawJob>,
    /// Next task index to claim (dynamic scheduling).
    next: AtomicUsize,
    /// Workers that finished the current epoch.
    done: AtomicUsize,
    /// Any task of the current epoch panicked on a worker.
    panicked: AtomicBool,
    /// Reentrancy guard: `run` must never be called from inside a task.
    in_run: AtomicBool,
    /// Count of workers parked on `start` (guarded by the mutex so a
    /// worker deciding to park cannot miss a publication).
    sleep: Mutex<usize>,
    start: Condvar,
}

// SAFETY: `job` is only written while every worker is quiescent (the
// previous `run` waited for all of them) and read after an Acquire load
// of `epoch` that the publishing Release bump synchronizes with.
unsafe impl Send for Shared {}
// SAFETY: as above — the epoch protocol serializes all `job` access.
unsafe impl Sync for Shared {}

/// A fixed set of persistent worker threads executing index-addressed
/// task batches. See the module docs for the intended use.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
}

// The pool is panic-robust by design: task panics are caught on the
// workers and re-raised on the submitter, leaving the pool reusable
// (tested below) — so observing it across an unwind boundary is fine.
// (The `UnsafeCell` job slot would otherwise opt it out of the auto
// traits and poison every closure capturing a pool reference.)
impl std::panic::RefUnwindSafe for WorkerPool {}
impl std::panic::UnwindSafe for WorkerPool {}

impl WorkerPool {
    /// Spawn `workers` background threads. `0` is valid: every
    /// [`WorkerPool::run`] then executes inline on the caller.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            job: UnsafeCell::new(RawJob {
                call: invoke_nothing,
                data: std::ptr::null(),
                tasks: 0,
            }),
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            in_run: AtomicBool::new(false),
            sleep: Mutex::new(0),
            start: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = shared.clone();
                thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Background workers in the pool (the submitting thread also runs
    /// tasks, so total parallelism is `workers() + 1`).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Threads that execute a job: the workers plus the submitter.
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Execute `f(0), f(1), …, f(tasks - 1)` across the pool (the
    /// calling thread participates) and return once all of them
    /// finished. Task indices are claimed dynamically; the closure must
    /// make concurrent calls with distinct indices safe (write disjoint
    /// data). Panics if any task panicked. Must not be called from
    /// inside a task of the same pool.
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if tasks == 0 {
            return;
        }
        let workers = self.handles.len();
        if workers == 0 || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        assert!(
            !self.shared.in_run.swap(true, Ordering::Acquire),
            "WorkerPool::run called from inside one of its own tasks"
        );
        // SAFETY: every worker is quiescent (the previous `run` waited
        // for all of them to check out and bumped `done`; workers only
        // read `job` after observing a new epoch), so this write cannot
        // race; the Release epoch bump below publishes it.
        unsafe {
            *self.shared.job.get() = RawJob {
                call: invoke::<F>,
                data: &f as *const F as *const (),
                tasks,
            };
        }
        self.shared.next.store(0, Ordering::Relaxed);
        self.shared.done.store(0, Ordering::Relaxed);
        self.shared.panicked.store(false, Ordering::Relaxed);
        {
            // poison-recovering: the sections guarding this counter
            // never run user code, but a fault-containing server must
            // not let a poisoned sleep count wedge the whole pool
            let sleepers = self
                .shared
                .sleep
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            self.shared.epoch.fetch_add(1, Ordering::Release);
            if *sleepers > 0 {
                self.shared.start.notify_all();
            }
        }
        // the submitter pulls tasks like any worker
        let mine = catch_unwind(AssertUnwindSafe(|| loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            f(i);
        }));
        // wait for every worker to check out of this epoch — only then
        // is `f` (on our stack) safe to drop or unwind past
        let mut spins = 0u32;
        while self.shared.done.load(Ordering::Acquire) < workers {
            spins = spins.saturating_add(1);
            if spins < SPIN_LIMIT {
                hint::spin_loop();
            } else {
                thread::yield_now();
            }
        }
        self.shared.in_run.store(false, Ordering::Release);
        if let Err(payload) = mine {
            resume_unwind(payload);
        }
        if self.shared.panicked.load(Ordering::Relaxed) {
            panic!("a WorkerPool task panicked on a worker thread");
        }
    }

    /// Test hook for the poisoned-lock recovery paths: panic a throwaway
    /// thread while it holds the `sleep` mutex, leaving the lock
    /// poisoned. Production code never panics inside these critical
    /// sections; `tests/poisoned_locks.rs` uses this to assert the
    /// `into_inner` recovery keeps the pool serving.
    #[doc(hidden)]
    #[cfg(not(loom))]
    pub fn poison_sleep_mutex_for_tests(&self) {
        let shared = &self.shared;
        std::thread::scope(|s| {
            let handle = s.spawn(|| {
                let _guard = shared.sleep.lock().unwrap_or_else(|e| e.into_inner());
                panic!("deliberately poisoning the WorkerPool sleep mutex");
            });
            assert!(handle.join().is_err(), "the poisoning thread must panic");
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        {
            let sleepers = self
                .shared
                .sleep
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            self.shared.epoch.fetch_add(1, Ordering::Release);
            if *sleepers > 0 {
                self.shared.start.notify_all();
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        // wait for the next epoch: spin briefly, then park
        let mut spins = 0u32;
        loop {
            let e = shared.epoch.load(Ordering::Acquire);
            if e != seen {
                seen = e;
                break;
            }
            spins = spins.saturating_add(1);
            if spins < SPIN_LIMIT {
                hint::spin_loop();
            } else {
                let mut sleepers = shared.sleep.lock().unwrap_or_else(|e| e.into_inner());
                // re-check under the mutex: the publisher bumps the
                // epoch while holding it, so this cannot race
                while shared.epoch.load(Ordering::Acquire) == seen {
                    *sleepers += 1;
                    sleepers = shared
                        .start
                        .wait(sleepers)
                        .unwrap_or_else(|e| e.into_inner());
                    *sleepers -= 1;
                }
                spins = 0;
            }
        }
        if shared.shutdown.load(Ordering::Relaxed) {
            return;
        }
        // SAFETY: the epoch Acquire load above synchronizes with the
        // publishing Release bump, making the job slot write visible;
        // the submitter keeps the closure alive until `done` says every
        // worker finished.
        let job = unsafe { *shared.job.get() };
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.tasks {
                break;
            }
            // SAFETY: `job.data` is the submitter's closure, alive until
            // every worker checks out (see the job-slot SAFETY above).
            unsafe { (job.call)(job.data, i) };
        }));
        if result.is_err() {
            shared.panicked.store(true, Ordering::Relaxed);
        }
        shared.done.fetch_add(1, Ordering::Release);
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        for tasks in [1usize, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU32> = (0..tasks).map(|_| AtomicU32::new(0)).collect();
            pool.run(tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "{tasks} tasks: some index ran zero or multiple times"
            );
        }
    }

    #[test]
    fn tasks_write_disjoint_slices_through_shared_mut() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0u64; 257];
        let ptr = SharedMut::new(out.as_mut_ptr());
        pool.run(out.len(), |i| {
            // SAFETY: one task per index — each write lands in its own
            // element, and `out` outlives the `run` call
            unsafe { ptr.get().add(i).write(i as u64 * 3 + 1) };
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3 + 1);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        // jobs spaced by sleeps long enough to park the workers — the
        // wakeup path must not lose a job
        let pool = WorkerPool::new(2);
        let counter = AtomicU32::new(0);
        for round in 0..50u32 {
            pool.run(5, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 5);
            if round % 16 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 0);
        assert_eq!(pool.parallelism(), 1);
        let counter = AtomicU32::new(0);
        pool.run(9, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn worker_panic_propagates_to_the_submitter() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 40 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "task panic must fail the run");
        // and the pool must still work afterwards
        let counter = AtomicU32::new(0);
        pool.run(8, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = WorkerPool::new(3);
        let xs: Vec<u64> = (0..10_000).collect();
        let partials: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        let parts = partials.len();
        pool.run(parts, |t| {
            let lo = xs.len() * t / parts;
            let hi = xs.len() * (t + 1) / parts;
            let s: u64 = xs[lo..hi].iter().sum();
            partials[t].store(s, Ordering::Relaxed);
        });
        let total: u64 = partials.iter().map(|p| p.load(Ordering::Relaxed)).sum();
        assert_eq!(total, xs.iter().sum::<u64>());
    }

    #[test]
    fn poisoned_sleep_mutex_does_not_wedge_the_pool() {
        let pool = WorkerPool::new(2);
        pool.poison_sleep_mutex_for_tests();
        let counter = AtomicU32::new(0);
        pool.run(16, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }
}
