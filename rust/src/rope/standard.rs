//! Direct RoPE (Eqs. 1–3) — the reference the incremental unit is
//! validated against.

/// Angular frequencies `ω_i = base^{−2(i−1)/d}`, `i = 1..d/2` (Eq. 1).
pub fn rope_freqs(d: usize, base: f64) -> Vec<f64> {
    assert!(d % 2 == 0, "head dim must be even");
    (0..d / 2)
        .map(|i| base.powf(-2.0 * i as f64 / d as f64))
        .collect()
}

/// Direct `RoPE(x, m)` (Eq. 3): rotate each consecutive channel pair by
/// `mθ_i`, computing the trig directly (the "hardware-expensive" path the
/// paper avoids at decode time).
pub fn rope_standard(x: &[f32], m: u64, base: f64) -> Vec<f32> {
    let d = x.len();
    let freqs = rope_freqs(d, base);
    let mut out = vec![0.0f32; d];
    for (i, &w) in freqs.iter().enumerate() {
        let theta = m as f64 * w;
        let (sin, cos) = theta.sin_cos();
        let (c, s) = (cos as f32, sin as f32);
        let (x0, x1) = (x[2 * i], x[2 * i + 1]);
        out[2 * i] = x0 * c - x1 * s;
        out[2 * i + 1] = x0 * s + x1 * c;
    }
    out
}

/// Rotate channel pairs with pre-computed `(cos, sin)` tables — the
/// rotation half of the incremental unit (Eq. 11's multiply network).
pub fn rope_apply_cached(x: &[f32], cos: &[f32], sin: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rope_apply_cached_into(x, cos, sin, &mut out);
    out
}

/// [`rope_apply_cached`] into a caller-owned buffer (no allocation). The
/// decode hot path rotates the new token's q/k directly into scratch and
/// the KV cache row with this.
pub fn rope_apply_cached_into(x: &[f32], cos: &[f32], sin: &[f32], out: &mut [f32]) {
    let d = x.len();
    assert_eq!(out.len(), d);
    assert_eq!(cos.len(), d / 2);
    assert_eq!(sin.len(), d / 2);
    for i in 0..d / 2 {
        let (x0, x1) = (x[2 * i], x[2 * i + 1]);
        out[2 * i] = x0 * cos[i] - x1 * sin[i];
        out[2 * i + 1] = x0 * sin[i] + x1 * cos[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_zero_is_identity() {
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        assert_eq!(rope_standard(&x, 0, 10000.0), x);
    }

    #[test]
    fn rotation_preserves_pair_norms() {
        let x: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let y = rope_standard(&x, 1234, 10000.0);
        for i in 0..16 {
            let nx = x[2 * i].hypot(x[2 * i + 1]);
            let ny = y[2 * i].hypot(y[2 * i + 1]);
            assert!((nx - ny).abs() < 1e-5);
        }
    }

    #[test]
    fn relative_position_property() {
        // ⟨RoPE(q,m), RoPE(k,n)⟩ depends only on m−n (RoPE's raison d'être)
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).sin()).collect();
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let d1 = dot(&rope_standard(&q, 100, 10000.0), &rope_standard(&k, 90, 10000.0));
        let d2 = dot(&rope_standard(&q, 20, 10000.0), &rope_standard(&k, 10, 10000.0));
        assert!((d1 - d2).abs() < 1e-3, "{d1} vs {d2}");
    }

    #[test]
    fn cached_apply_matches_direct() {
        let d = 16;
        let m = 77u64;
        let freqs = rope_freqs(d, 10000.0);
        let cos: Vec<f32> = freqs.iter().map(|w| ((m as f64) * w).cos() as f32).collect();
        let sin: Vec<f32> = freqs.iter().map(|w| ((m as f64) * w).sin() as f32).collect();
        let x: Vec<f32> = (0..d).map(|i| i as f32 * 0.25 - 2.0).collect();
        let a = rope_apply_cached(&x, &cos, &sin);
        let b = rope_standard(&x, m, 10000.0);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic]
    fn odd_dim_rejected() {
        rope_freqs(7, 10000.0);
    }
}
