//! Design-choice ablation: why the paper's exp unit uses a **5-bit** LUT
//! with linear interpolation (Eqs. 9–10).
//!
//! Sweeps the LUT index width and reports the worst-case relative error of
//! `2^f` over (−1, 0]. The FXP32 (Q15.17) datapath resolves 2⁻¹⁷ ≈ 7.6e-6,
//! and the paper claims "precision better than 10⁻⁵": 5 bits is the
//! smallest table whose interpolation error (5.9e-5, i.e. 0.00586 %)
//! keeps the *weighted-value* error below that target, while 4 bits
//! overshoots 4× and 6 bits doubles the ROM for error already below the
//! datapath's own quantization floor.
//!
//! ```sh
//! cargo run --release --example ablation_lut
//! ```

use swiftkv::fxp::exp2lut::lut_ablation_error;
use swiftkv::fxp::Exp2Lut;

fn main() {
    println!("exp-LUT width ablation (secant interpolation over (-1, 0]):\n");
    println!("{:>6} {:>9} {:>16} {:>14}", "bits", "entries", "max rel err", "err (%)");
    for bits in 2..=8 {
        let err = lut_ablation_error(bits);
        let marker = if bits == 5 { "  ← paper (Eq. 10)" } else { "" };
        println!(
            "{:>6} {:>9} {:>16.3e} {:>13.5}%{}",
            bits,
            1u32 << bits,
            err,
            err * 100.0,
            marker
        );
    }
    let hw = Exp2Lut::new().max_relative_error();
    println!(
        "\nbit-exact Q15.17 implementation of the 5-bit unit: {:.5} % \
         (paper reports 0.00586 %)",
        hw * 100.0
    );
    println!(
        "analytic bound (ln2/2^bits)^2/8 at 5 bits: {:.5} %",
        (std::f64::consts::LN_2 / 32.0).powi(2) / 8.0 * 100.0
    );
}
