//! Bench: regenerate Fig. 7(a)/(b) and measure the *software* cost of each
//! attention algorithm (the cycle model prices the hardware; this bench
//! also times the actual Rust implementations to validate relative order).

use swiftkv::attention::{flash, native, online, swiftkv as swiftkv_attn};
use swiftkv::report;
use swiftkv::sim::ArchConfig;
use swiftkv::util::bench::Bencher;
use swiftkv::util::Rng;

fn main() {
    let arch = ArchConfig::default();
    println!("{}", report::fig7a(&arch));
    println!("{}", report::fig7b(&arch));

    // software-side timing of the same algorithms (Rust implementations)
    let (d, n) = (128usize, 512usize);
    let mut rng = Rng::seed_from_u64(3);
    let q = rng.uniform_vec(d, 1.0);
    let k = rng.uniform_vec(n * d, 1.0);
    let v = rng.uniform_vec(n * d, 1.0);
    let p = swiftkv::attention::HeadProblem::new(&q, &k, &v, d, n);

    let mut b = Bencher::new(200, 800);
    b.bench("attention/native (sw, n=512, d=128)", || native::attend(&p));
    b.bench("attention/online (sw)", || online::attend(&p));
    b.bench("attention/flash32 (sw)", || flash::attend(&p, 32));
    b.bench("attention/swiftkv (sw)", || swiftkv_attn::attend(&p));

    // FXP32 datapath
    let lut = swiftkv::fxp::Exp2Lut::new();
    let fp = swiftkv::attention::fxp_swiftkv::FxpHeadProblem::quantize(&q, &k, &v, d, n);
    b.bench("attention/swiftkv-fxp32 (bit-exact)", || {
        swiftkv::attention::fxp_swiftkv::attend_fxp(&lut, &fp)
    });
}
