"""L2 model tests: decode-step semantics on a reduced TinyConfig."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.TinyConfig(n_layers=2, n_ctx=64, vocab=64, d_model=64, n_heads=2,
                   d_head=32, d_ffn=128, block_k=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def step(params, tokens, pos, state):
    return M.decode_step(params, CFG, jnp.asarray(tokens, jnp.int32),
                         jnp.asarray(pos, jnp.int32), *state)


def test_decode_step_shapes(params):
    state = M.init_state(CFG, 3)
    logits, kc, vc, cos, sin = step(params, [1, 2, 3], [0, 0, 0], state)
    assert logits.shape == (3, CFG.vocab)
    assert kc.shape == (3, CFG.n_layers, CFG.n_heads, CFG.n_ctx, CFG.d_head)
    assert cos.shape == (3, CFG.d_head // 2)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cache_written_at_position(params):
    state = M.init_state(CFG, 1)
    _, kc, vc, *_ = step(params, [5], [0], state)
    # row 0 of every layer/head must be non-zero, the rest untouched (zero)
    assert float(jnp.max(jnp.abs(kc[0, :, :, 0, :]))) > 0
    assert float(jnp.max(jnp.abs(kc[0, :, :, 1:, :]))) == 0
    assert float(jnp.max(jnp.abs(vc[0, :, :, 1:, :]))) == 0


def test_determinism(params):
    s1 = M.init_state(CFG, 2)
    s2 = M.init_state(CFG, 2)
    l1, *_ = step(params, [9, 4], [0, 0], s1)
    l2, *_ = step(params, [9, 4], [0, 0], s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_batch_consistency(params):
    """A sequence decoded alone equals the same sequence inside a batch."""
    state1 = M.init_state(CFG, 1)
    l_solo, kc1, vc1, c1, s1 = step(params, [7], [0], state1)
    state3 = M.init_state(CFG, 3)
    l_batch, *_ = step(params, [7, 11, 13], [0, 0, 0], state3)
    np.testing.assert_allclose(np.asarray(l_solo[0]), np.asarray(l_batch[0]),
                               rtol=1e-5, atol=1e-5)


def test_multi_step_positions_advance(params):
    state = M.init_state(CFG, 1)
    toks = [3, 1, 4, 1, 5]
    kc, vc, cos, sin = state
    for t, tok in enumerate(toks):
        logits, kc, vc, cos, sin = M.decode_step(
            params, CFG, jnp.asarray([tok], jnp.int32),
            jnp.asarray([t], jnp.int32), kc, vc, cos, sin)
    # all five cache rows populated, the sixth untouched
    assert float(jnp.max(jnp.abs(kc[0, 0, :, 4, :]))) > 0
    assert float(jnp.max(jnp.abs(kc[0, 0, :, 5:, :]))) == 0
    # rope state advanced to position 4: cos^2+sin^2 == 1 still
    np.testing.assert_allclose(np.asarray(cos**2 + sin**2),
                               np.ones_like(np.asarray(cos)), atol=1e-5)


def test_attention_inside_model_matches_oracle(params):
    """Extract one layer's cached K/V after several steps and check the
    model's attention output path against the native oracle."""
    state = M.init_state(CFG, 1)
    kc, vc, cos, sin = state
    for t, tok in enumerate([2, 3, 5, 7]):
        _, kc, vc, cos, sin = M.decode_step(
            params, CFG, jnp.asarray([tok], jnp.int32),
            jnp.asarray([t], jnp.int32), kc, vc, cos, sin)
    # re-run the kernel on the final cache vs the oracle
    from compile.kernels.swiftkv import swiftkv_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(CFG.n_heads, CFG.d_head)), jnp.float32)
    k_rows = kc[0, 0]
    v_rows = vc[0, 0]
    lens = jnp.full((CFG.n_heads,), 4, jnp.int32)
    got = swiftkv_attention(q, k_rows, v_rows, lens, block_k=CFG.block_k)
    want = ref.native_attention_rows(q, k_rows, v_rows, lens)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_greedy_generate_deterministic(params):
    out1 = M.greedy_generate(params, CFG, np.asarray([1, 2, 3]), steps=4)
    out2 = M.greedy_generate(params, CFG, np.asarray([1, 2, 3]), steps=4)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (4,)
    assert all(0 <= t < CFG.vocab for t in out1)


def test_param_specs_cover_params(params):
    specs = M.param_specs(CFG)
    assert set(n for n, _, _ in specs) == set(params.keys())
    for name, shape, dtype in specs:
        assert params[name].shape == tuple(shape), name
        assert str(params[name].dtype) == dtype, name
