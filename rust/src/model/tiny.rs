//! Pure-Rust forward pass of the tiny AOT model, in two numerics modes.
//!
//! - [`NumericsMode::DesktopF32`] — "desktop" arithmetic: f32 GEMV over
//!   dequantized W4A8 weights, f32 softmax attention. This is the
//!   reference side of the paper's Table I comparison ("desktop results
//!   using the same W4A8 precision").
//! - [`NumericsMode::Accelerator`] — the SwiftKV-MHA datapath: exact
//!   INT8×INT4 integer GEMV, FXP32 (Q15.17) single-pass attention with
//!   the 5-bit-LUT exponential, decoder-RoPE recurrence.
//!
//! Running both modes over the same token stream and comparing Top-k
//! logits reproduces Table I. The desktop mode additionally cross-checks
//! the PJRT runtime (same weights, same math → near-identical logits).

use super::weights::WeightStore;
use crate::attention::{fxp_swiftkv, native, HeadProblem};
use crate::fxp::Exp2Lut;
use crate::quant::{gemv_w4a8, quantize_int8, Int4Matrix, QuantLinear};
use crate::rope::RopeState;
use anyhow::{bail, Result};

/// Which datapath to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumericsMode {
    /// f32 GEMV on dequantized weights + f32 softmax attention.
    DesktopF32,
    /// Integer GEMV + FXP32 LUT-exp SwiftKV attention.
    Accelerator,
}

/// A W4A8 linear layer carried in both representations.
struct DualLinear {
    quant: QuantLinear,
    dequant: Vec<f32>, // row-major [din, dout]
    din: usize,
}

impl DualLinear {
    fn load(ws: &WeightStore, name: &str) -> Result<DualLinear> {
        let wq = ws.i8_vec(&format!("{name}.q"))?;
        let scales = ws.f32_vec(&format!("{name}.scale"))?;
        let shape = ws.shape(&format!("{name}.q"))?;
        if shape.len() != 2 {
            bail!("{name}: expected rank-2 weight");
        }
        let (din, dout) = (shape[0], shape[1]);
        let mat = Int4Matrix::from_quantized(&wq, scales.clone(), din, dout);
        let mut dequant = vec![0.0f32; din * dout];
        for i in 0..din {
            for j in 0..dout {
                dequant[i * dout + j] = wq[i * dout + j] as f32 * scales[j];
            }
        }
        let _ = dout;
        Ok(DualLinear {
            quant: QuantLinear::new(mat),
            dequant,
            din,
        })
    }

    fn forward(&self, x: &[f32], _mode: NumericsMode) -> Vec<f32> {
        assert_eq!(x.len(), self.din);
        // Both modes share the *exact* W4A8 integer GEMV (INT8×INT4→INT32
        // is exact on desktop hardware too — the paper compares "desktop
        // results using the same W4A8 precision"). The two modes therefore
        // differ ONLY in the attention datapath, which is precisely the
        // contribution Table I isolates.
        let xq = quantize_int8(x);
        gemv_w4a8(&xq, &self.quant.weight)
    }

    /// Dequantized f32 weight view (diagnostics / error analysis).
    #[allow(dead_code)]
    fn dequant_weights(&self) -> &[f32] {
        &self.dequant
    }
}

struct LayerWeights {
    attn_norm: Vec<f32>,
    wq: DualLinear,
    wk: DualLinear,
    wv: DualLinear,
    wo: DualLinear,
    mlp_norm: Vec<f32>,
    w_gate: DualLinear,
    w_up: DualLinear,
    w_down: DualLinear,
}

/// The tiny decoder with all weights resident.
pub struct TinyModel {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub n_ctx: usize,
    pub rope_base: f64,
    embedding: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
    lm_head: DualLinear,
    lut: Exp2Lut,
}

/// Mutable per-sequence decode state (KV caches + RoPE recurrence).
pub struct DecodeState {
    /// `[layer][head][pos][d_head]` flattened K cache.
    kc: Vec<f32>,
    vc: Vec<f32>,
    rope: RopeState,
    pub pos: usize,
    n_ctx: usize,
    n_heads: usize,
    d_head: usize,
}

impl DecodeState {
    fn idx(&self, l: usize, h: usize, t: usize) -> usize {
        ((l * self.n_heads + h) * self.n_ctx + t) * self.d_head
    }

    /// Contiguous `[n_ctx, d_head]` cache rows for (layer, head).
    fn head_cache(&self, l: usize, h: usize) -> std::ops::Range<usize> {
        let start = self.idx(l, h, 0);
        start..start + self.n_ctx * self.d_head
    }
}

impl TinyModel {
    /// Load from the artifact weight store.
    pub fn load(ws: &WeightStore) -> Result<TinyModel> {
        let m = &ws.manifest;
        let mut layers = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let p = format!("layer{l}");
            layers.push(LayerWeights {
                attn_norm: ws.f32_vec(&format!("{p}.attn_norm"))?,
                wq: DualLinear::load(ws, &format!("{p}.wq"))?,
                wk: DualLinear::load(ws, &format!("{p}.wk"))?,
                wv: DualLinear::load(ws, &format!("{p}.wv"))?,
                wo: DualLinear::load(ws, &format!("{p}.wo"))?,
                mlp_norm: ws.f32_vec(&format!("{p}.mlp_norm"))?,
                w_gate: DualLinear::load(ws, &format!("{p}.w_gate"))?,
                w_up: DualLinear::load(ws, &format!("{p}.w_up"))?,
                w_down: DualLinear::load(ws, &format!("{p}.w_down"))?,
            });
        }
        Ok(TinyModel {
            vocab: m.vocab,
            d_model: m.d_model,
            n_heads: m.n_heads,
            d_head: m.d_head,
            n_layers: m.n_layers,
            n_ctx: m.n_ctx,
            rope_base: m.rope_base,
            embedding: ws.f32_vec("embedding")?,
            layers,
            final_norm: ws.f32_vec("final_norm")?,
            lm_head: DualLinear::load(ws, "lm_head")?,
            lut: Exp2Lut::new(),
        })
    }

    /// Fresh decode state.
    pub fn new_state(&self) -> DecodeState {
        DecodeState {
            kc: vec![0.0; self.n_layers * self.n_heads * self.n_ctx * self.d_head],
            vc: vec![0.0; self.n_layers * self.n_heads * self.n_ctx * self.d_head],
            rope: RopeState::new(self.d_head, self.rope_base),
            pos: 0,
            n_ctx: self.n_ctx,
            n_heads: self.n_heads,
            d_head: self.d_head,
        }
    }

    /// One decode step: append `token` at the state's position, return
    /// logits over the vocabulary.
    pub fn decode_step(&self, st: &mut DecodeState, token: u32, mode: NumericsMode) -> Vec<f32> {
        assert!((token as usize) < self.vocab, "token out of range");
        assert!(st.pos < self.n_ctx, "context overflow");
        let d = self.d_model;
        let (h, dh) = (self.n_heads, self.d_head);

        let mut x = self.embedding[token as usize * d..(token as usize + 1) * d].to_vec();
        // advance the shared RoPE recurrence once per token
        st.rope.advance();
        let (cos, sin) = (st.rope.cos.clone(), st.rope.sin.clone());

        for (l, lw) in self.layers.iter().enumerate() {
            let xn = rms_norm(&x, &lw.attn_norm);
            let q = lw.wq.forward(&xn, mode);
            let k = lw.wk.forward(&xn, mode);
            let v = lw.wv.forward(&xn, mode);

            let mut attn_out = vec![0.0f32; d];
            for head in 0..h {
                let q_h = crate::rope::rope_apply_cached(&q[head * dh..(head + 1) * dh], &cos, &sin);
                let k_h = crate::rope::rope_apply_cached(&k[head * dh..(head + 1) * dh], &cos, &sin);
                // append to cache (already position-encoded)
                let at = st.idx(l, head, st.pos);
                st.kc[at..at + dh].copy_from_slice(&k_h);
                st.vc[at..at + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);

                let range = st.head_cache(l, head);
                let k_cache = &st.kc[range.clone()];
                let v_cache = &st.vc[range];
                let len = st.pos + 1;
                let out = match mode {
                    NumericsMode::DesktopF32 => {
                        let p = HeadProblem::new(&q_h, k_cache, v_cache, dh, len);
                        native::attend(&p)
                    }
                    NumericsMode::Accelerator => {
                        fxp_swiftkv::attend(&self.lut, &q_h, k_cache, v_cache, dh, len)
                    }
                };
                attn_out[head * dh..(head + 1) * dh].copy_from_slice(&out);
            }
            let o = lw.wo.forward(&attn_out, mode);
            for (xi, oi) in x.iter_mut().zip(&o) {
                *xi += oi;
            }

            let xn = rms_norm(&x, &lw.mlp_norm);
            let gate = lw.w_gate.forward(&xn, mode);
            let up = lw.w_up.forward(&xn, mode);
            let act: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lw.w_down.forward(&act, mode);
            for (xi, di) in x.iter_mut().zip(&down) {
                *xi += di;
            }
        }

        st.pos += 1;
        let xn = rms_norm(&x, &self.final_norm);
        self.lm_head.forward(&xn, mode)
    }

    /// Debug access to cache rows (cross-validation against the JAX side).
    pub fn debug_cache<'a>(
        &self,
        st: &'a DecodeState,
        l: usize,
        h: usize,
        t: usize,
    ) -> (&'a [f32], &'a [f32]) {
        let at = st.idx(l, h, t);
        (&st.kc[at..at + self.d_head], &st.vc[at..at + self.d_head])
    }

    /// Debug access to the RoPE recurrence values.
    pub fn debug_rope(st: &DecodeState) -> (&[f32], &[f32]) {
        (&st.rope.cos, &st.rope.sin)
    }

    /// Greedy generation: feed `prompt`, then generate `steps` tokens.
    pub fn generate(&self, prompt: &[u32], steps: usize, mode: NumericsMode) -> Vec<u32> {
        let mut st = self.new_state();
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_step(&mut st, t, mode);
        }
        let mut out = Vec::with_capacity(steps);
        for _ in 0..steps {
            let next = argmax(&logits) as u32;
            out.push(next);
            if st.pos >= self.n_ctx {
                break;
            }
            logits = self.decode_step(&mut st, next, mode);
        }
        out
    }
}

/// RMS normalization (SFU op).
pub fn rms_norm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let var = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + 1e-5).sqrt();
    x.iter().zip(g).map(|(v, w)| v * r * w).collect()
}

/// SiLU activation (SFU op).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Index of the maximum logit (greedy sampling).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Indices of the top-k logits, descending.
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::WeightStore;

    fn model() -> Option<TinyModel> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| TinyModel::load(&WeightStore::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn decode_produces_finite_logits_both_modes() {
        let Some(m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            let logits = m.decode_step(&mut st, 7, mode);
            assert_eq!(logits.len(), m.vocab);
            assert!(logits.iter().all(|x| x.is_finite()), "{mode:?}");
        }
    }

    #[test]
    fn modes_agree_on_top1_short_sequence() {
        let Some(m) = model() else {
            return;
        };
        let mut sd = m.new_state();
        let mut sa = m.new_state();
        for &t in &[1u32, 5, 9, 2] {
            let ld = m.decode_step(&mut sd, t, NumericsMode::DesktopF32);
            let la = m.decode_step(&mut sa, t, NumericsMode::Accelerator);
            assert_eq!(argmax(&ld), argmax(&la), "top-1 diverged at token {t}");
        }
    }

    #[test]
    fn generation_deterministic() {
        let Some(m) = model() else {
            return;
        };
        let a = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        let b = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        assert_eq!(a, b);
    }

    #[test]
    fn dump_intermediates_for_cross_check() {
        // printed with --nocapture; compared against the python dump in
        // the build log (manual diff aid, asserts only basic sanity)
        let Some(m) = model() else {
            return;
        };
        let mut st = m.new_state();
        for (i, &t) in [3u32, 141, 27].iter().enumerate() {
            let l = m.decode_step(&mut st, t, NumericsMode::DesktopF32);
            println!("step {i}: logits[:4] = {:?}, argmax = {}", &l[..4], argmax(&l));
        }
        let (cos, _sin) = TinyModel::debug_rope(&st);
        println!("cos[:4] {:?}", &cos[..4]);
        let (k0, _) = m.debug_cache(&st, 0, 0, 0);
        let (k1, v1) = m.debug_cache(&st, 0, 0, 1);
        println!("kc l0 h0 row0[:4] {:?}", &k0[..4]);
        println!("kc l0 h0 row1[:4] {:?}", &k1[..4]);
        println!("vc l0 h0 row1[:4] {:?}", &v1[..4]);
    }

    #[test]
    fn top_k_ordering() {
        let xs = vec![0.1f32, 3.0, -1.0, 2.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 0]);
        assert_eq!(argmax(&xs), 1);
    }

    #[test]
    fn rms_norm_unit_scale() {
        let x = vec![3.0f32, -3.0, 3.0, -3.0];
        let g = vec![1.0f32; 4];
        let y = rms_norm(&x, &g);
        for v in y {
            assert!((v.abs() - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0 / (1.0 + (-10.0f32).exp())).abs() < 1e-6);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
