//! Integration: the fault-tolerance layer of the CPU serving loop —
//! deterministic fault injection ([`FaultPlan`]) driving panic
//! containment, the NaN firewall, preemption/requeue under KV-pool
//! exhaustion, bounded retry, and wall-clock deadlines. The bar
//! everywhere: a fault fails *its* request only (co-batched lanes stay
//! bit-exact against a fault-free run), the shared block pool is fully
//! reclaimed, and the server always runs to completion.

use swiftkv::coordinator::{CpuServer, FaultPlan, ServeConfig, SessionOutcome};
use swiftkv::model::{LlmConfig, NumericsMode, Request, TinyModel, WorkloadGen, WorkloadSpec};

fn model() -> TinyModel {
    TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48)
}

fn req(id: u64, prompt: Vec<u32>, gen_len: usize) -> Request {
    Request::new(id, prompt).gen_len(gen_len)
}

fn opts(lanes: usize) -> ServeConfig {
    ServeConfig::builder()
        .lanes(lanes)
        .mode(NumericsMode::DesktopF32)
        .max_iterations(10_000)
        .sim_model(LlmConfig::llama2_7b())
        .build()
        .expect("test serve config is valid")
}

/// Pool fully reclaimed — the block-leak audit every fault run must pass.
fn assert_pool_reclaimed(report: &swiftkv::coordinator::CpuServeReport) {
    assert_eq!(
        report.kv_pool.free_blocks(),
        report.kv_pool.total_blocks(),
        "serve run leaked KV blocks"
    );
}

#[test]
fn injected_panic_fails_one_lane_others_bit_identical() {
    // 4 co-batched decode lanes; the lane serving request 1 panics on
    // its 3rd sample. Acceptance: exactly that request fails, the other
    // three finish bit-identical to a fault-free run, a queued 5th
    // request rides the recycled lane, and the pool drains to empty.
    let tm = model();
    let reqs = |n: usize| -> Vec<Request> {
        (0..n as u64).map(|i| req(i, vec![1 + i as u32], 8)).collect()
    };
    let clean = CpuServer::new(&tm, opts(4)).serve(reqs(5));
    assert!(clean.sessions.iter().all(|s| s.outcome.is_completed()));

    let mut o = opts(4);
    o.faults = Some(FaultPlan::parse("panic@r1:s2").expect("spec parses"));
    let report = CpuServer::new(&tm, o).serve(reqs(5));

    assert_eq!(report.sessions.len(), 5, "every request must be accounted for");
    assert_eq!(report.metrics.requests_failed, 1);
    assert_eq!(report.metrics.preemptions, 0);
    assert_eq!(report.metrics.deadline_expired, 0);

    let failed = report.sessions.iter().find(|s| s.request.id == 1).expect("session 1");
    match &failed.outcome {
        SessionOutcome::Failed(reason) => {
            assert!(
                reason.contains("token out of range"),
                "fault reason lost the panic payload: '{reason}'"
            );
        }
        other => panic!("request 1 must fail, got {other:?}"),
    }
    // the fault fired on the step sampling token 3: tokens 1–2 stand
    assert_eq!(failed.generated.len(), 2);
    let clean1 = clean.sessions.iter().find(|s| s.request.id == 1).expect("clean 1");
    assert_eq!(failed.generated, clean1.generated[..2]);

    // survivors and the recycled-lane rider: bit-identical to fault-free
    for id in [0u64, 2, 3, 4] {
        let got = report.sessions.iter().find(|s| s.request.id == id).expect("session");
        let want = clean.sessions.iter().find(|s| s.request.id == id).expect("clean");
        assert!(got.outcome.is_completed(), "request {id} must complete");
        assert_eq!(
            got.generated, want.generated,
            "request {id}: a contained fault in lane 1 perturbed another lane"
        );
    }
    assert_pool_reclaimed(&report);

    // the failure surfaces in the human-readable metrics table
    let table = report.metrics.format_table();
    assert!(table.contains("failed"), "{table}");
}

#[test]
fn injected_prefill_panic_is_contained() {
    // the fault fires on a multi-token final prefill chunk, so it rides
    // the per-lane prefill path (not the batched decode step)
    let tm = model();
    let mk = || {
        vec![
            req(0, (0..12).map(|t| (t * 3 + 1) % 64).collect(), 4),
            req(1, vec![2, 3], 6),
        ]
    };
    let clean = CpuServer::new(&tm, opts(2)).serve(mk());
    let mut o = opts(2);
    o.faults = Some(FaultPlan::parse("panic@r0:s0").expect("spec parses"));
    let report = CpuServer::new(&tm, o).serve(mk());

    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.metrics.requests_failed, 1);
    let failed = report.sessions.iter().find(|s| s.request.id == 0).expect("session 0");
    match &failed.outcome {
        SessionOutcome::Failed(reason) => {
            assert!(reason.contains("injected fault"), "'{reason}'");
        }
        other => panic!("request 0 must fail, got {other:?}"),
    }
    assert!(failed.generated.is_empty(), "the fault fired before the first sample");
    let got = report.sessions.iter().find(|s| s.request.id == 1).expect("session 1");
    let want = clean.sessions.iter().find(|s| s.request.id == 1).expect("clean 1");
    assert!(got.outcome.is_completed());
    assert_eq!(got.generated, want.generated, "co-scheduled prefill lane perturbed");
    assert_pool_reclaimed(&report);
}

#[test]
fn nan_poisoned_lane_fails_instead_of_emitting_garbage() {
    // poisoned KV rows drive one lane's logits non-finite; the firewall
    // must fail that request at the step, not argmax over NaN for the
    // rest of its generation
    let tm = model();
    let reqs = || -> Vec<Request> {
        (0..4u64).map(|i| req(i, vec![1 + i as u32], 8)).collect()
    };
    let clean = CpuServer::new(&tm, opts(4)).serve(reqs());
    let mut o = opts(4);
    o.faults = Some(FaultPlan::parse("nan@r2:s3").expect("spec parses"));
    let report = CpuServer::new(&tm, o).serve(reqs());

    assert_eq!(report.sessions.len(), 4);
    assert_eq!(report.metrics.requests_failed, 1);
    let failed = report.sessions.iter().find(|s| s.request.id == 2).expect("session 2");
    match &failed.outcome {
        SessionOutcome::Failed(reason) => {
            assert!(reason.contains("non-finite"), "'{reason}'");
        }
        other => panic!("request 2 must fail, got {other:?}"),
    }
    assert_eq!(failed.generated.len(), 3, "samples before the poison stand");
    for id in [0u64, 1, 3] {
        let got = report.sessions.iter().find(|s| s.request.id == id).expect("session");
        let want = clean.sessions.iter().find(|s| s.request.id == id).expect("clean");
        assert!(got.outcome.is_completed());
        assert_eq!(got.generated, want.generated, "request {id} perturbed by NaN lane");
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn forced_pool_exhaustion_preempts_requeues_and_completes() {
    // an armed oom@ fault empties the precheck's view of the free list
    // until every lane stalls on a block boundary; the youngest lane is
    // preempted, its request re-prefills from the queue, and both
    // requests still finish with exactly their fault-free outputs
    let tm = model();
    let mk = || vec![req(0, vec![3], 12), req(1, vec![5], 12)];
    let mut o = opts(2);
    o.kv_block_len = 4;
    o.faults = Some(FaultPlan::parse("oom@i1").expect("spec parses"));
    let report = CpuServer::new(&tm, o).serve(mk());

    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.metrics.preemptions, 1, "the armed oom must force one preemption");
    assert_eq!(report.metrics.requeues, 1);
    assert_eq!(report.metrics.requests_failed, 0);
    for s in &report.sessions {
        assert!(s.outcome.is_completed(), "request {} must survive preemption", s.request.id);
        let want = tm.generate(&s.request.prompt, s.request.gen_len, NumericsMode::DesktopF32);
        assert_eq!(
            s.generated, want,
            "request {}: re-prefill after preemption changed the output",
            s.request.id
        );
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn natural_pool_exhaustion_stalls_lanes_without_changing_outputs() {
    // no fault plan — a genuinely undersized pool (24 blocks vs the 32
    // both lanes would pin at full length) exercises the organic stall
    // path: growth grants go oldest-lane-first, short lanes wait, and
    // the numbers never change. The ample-pool run is the reference.
    let tm = model();
    let mk = || {
        (0..2u64)
            .map(|i| req(i, (0..8).map(|t| (t * 5 + i as u32 + 1) % 64).collect(), 24))
            .collect::<Vec<_>>()
    };
    let run = |pool_blocks: usize| {
        let mut o = opts(2);
        o.kv_block_len = 4;
        o.kv_pool_blocks = pool_blocks;
        CpuServer::new(&tm, o).serve(mk())
    };
    let tight = run(24);
    let ample = run(32);
    assert_eq!(tight.sessions.len(), 2);
    assert_eq!(tight.metrics.requests_failed, 0);
    // both lanes eventually stall on the same boundary (demand 32 > 24),
    // so the organic preempt-and-requeue path must have fired; the ample
    // pool never needs it
    assert!(tight.metrics.preemptions >= 1, "undersized pool never preempted");
    assert_eq!(tight.metrics.requeues, tight.metrics.preemptions);
    assert_eq!(ample.metrics.preemptions, 0);
    for s in &tight.sessions {
        assert!(s.outcome.is_completed());
        let want = &ample
            .sessions
            .iter()
            .find(|a| a.request.id == s.request.id)
            .expect("ample session")
            .generated;
        assert_eq!(
            &s.generated, want,
            "request {}: pool pressure changed the generated tokens",
            s.request.id
        );
    }
    assert_pool_reclaimed(&tight);
    assert_pool_reclaimed(&ample);
}

#[test]
fn exhausted_requeue_budget_retires_the_request_as_failed() {
    // max_requeues = 0: the first preemption immediately exhausts the
    // retry budget — bounded retry, no preemption livelock
    let tm = model();
    let mut o = opts(1);
    o.kv_block_len = 4;
    o.max_requeues = 0;
    o.faults = Some(FaultPlan::parse("oom@i1").expect("spec parses"));
    let report = CpuServer::new(&tm, o).serve(vec![req(0, vec![3], 12)]);

    assert_eq!(report.sessions.len(), 1);
    assert_eq!(report.metrics.preemptions, 1);
    assert_eq!(report.metrics.requeues, 0);
    assert_eq!(report.metrics.requests_failed, 1);
    match &report.sessions[0].outcome {
        SessionOutcome::Failed(reason) => {
            assert!(reason.contains("requeue budget"), "'{reason}'");
        }
        other => panic!("expected a retry-budget failure, got {other:?}"),
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn deadlines_cancel_running_and_queued_requests() {
    // a 1 ms deadline on a 250-token generation cannot be met: the
    // running lane is cancelled at an iteration boundary (KV blocks
    // reclaimed) and the queued request expires without ever taking the
    // lane. Large-context model so the run must outlast the deadline.
    let tm = TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 256);
    let mut running = req(0, vec![3, 4], 250);
    running.deadline_ms = 1;
    let mut queued = req(1, vec![5], 5);
    queued.deadline_ms = 1;
    let report = CpuServer::new(&tm, opts(1)).serve(vec![running, queued]);

    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.metrics.deadline_expired, 2);
    assert_eq!(report.metrics.requests_failed, 0);
    for s in &report.sessions {
        assert_eq!(
            s.outcome,
            SessionOutcome::DeadlineExpired,
            "request {} must expire",
            s.request.id
        );
        assert!(s.generated.len() < s.request.gen_len);
        assert!(s.finished_at.is_some(), "expired sessions must be stamped");
    }
    assert_pool_reclaimed(&report);
    // the counter also lands in the human-readable table
    assert!(report.metrics.format_table().contains("expired"), "metrics table");
}

#[test]
fn panicked_lane_slot_is_readmitted_to_a_queued_continuous_request() {
    // continuous submission path: 3 requests through 2 lanes, with the
    // lane serving request 1 panicking on its 3rd sample. Request 2 is
    // queued behind the full batch — the panic must free its lane slot
    // back to admission, the queued request must ride the recycled slot
    // to completion (bit-identical to solo decode), and only the faulted
    // request may fail.
    let tm = model();
    let mut o = opts(2);
    o.faults = Some(FaultPlan::parse("panic@r1:s2").expect("spec parses"));
    let server = CpuServer::new(&tm, o);
    let (report, finished) = server.serve_continuous(|handle| {
        let pending: Vec<_> = (0..3u64)
            .map(|i| {
                handle
                    .submit(req(i, vec![1 + i as u32], 8))
                    .expect("engine accepts while the handle is live")
            })
            .collect();
        pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
    });

    assert_eq!(finished.len(), 3);
    assert_eq!(report.metrics.requests_failed, 1);
    for fin in &finished {
        if fin.id == 1 {
            assert!(
                matches!(fin.outcome, SessionOutcome::Failed(_)),
                "the faulted request must fail, got {:?}",
                fin.outcome
            );
            // the fault fired on the step sampling token 3
            assert_eq!(fin.tokens.len(), 2, "samples before the panic stand");
        } else {
            assert!(fin.outcome.is_completed(), "request {} must complete", fin.id);
            let want = tm.generate(&[1 + fin.id as u32], 8, NumericsMode::DesktopF32);
            assert_eq!(
                fin.tokens, want,
                "request {}: the contained panic perturbed its stream",
                fin.id
            );
        }
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn seeded_fault_plans_never_crash_the_server() {
    // fuzz the whole layer: seeded plans (panics, NaN, forced oom on odd
    // seeds) against a real workload. Whatever fires, the server must
    // return with every request accounted for, completed requests
    // bit-identical to solo decode, and the pool drained. CI sweeps
    // extra seeds through SWIFTKV_FAULT_SEED.
    let tm = model();
    let mut seeds: Vec<u64> = vec![1, 2, 3, 5, 8];
    if let Ok(s) = std::env::var("SWIFTKV_FAULT_SEED") {
        if let Ok(s) = s.trim().parse::<u64>() {
            seeds.push(s);
        }
    }
    for seed in seeds {
        let reqs = WorkloadGen::new(WorkloadSpec {
            num_requests: 8,
            vocab: tm.vocab,
            prompt_len: (2, 6),
            gen_len: (3, 8),
            mean_gap_ms: 0.0,
            deadline_ms: 0,
            seed: 42,
        })
        .generate();
        let expect: Vec<(u64, Vec<u32>, usize)> = reqs
            .iter()
            .map(|r| (r.id, r.prompt.clone(), r.gen_len))
            .collect();
        let mut o = opts(4);
        o.kv_block_len = 4;
        o.faults = Some(FaultPlan::seeded(seed));
        let report = CpuServer::new(&tm, o).serve(reqs);

        assert_eq!(report.sessions.len(), 8, "seed {seed}: a request vanished");
        assert!(
            report.metrics.iterations < 10_000,
            "seed {seed}: the run did not converge"
        );
        for (id, prompt, gen_len) in &expect {
            let s = report
                .sessions
                .iter()
                .find(|s| s.request.id == *id)
                .expect("session");
            if s.outcome.is_completed() {
                let want = tm.generate(prompt, *gen_len, NumericsMode::DesktopF32);
                assert_eq!(
                    s.generated, want,
                    "seed {seed} request {id}: fault injection perturbed a completed request"
                );
            }
        }
        assert_pool_reclaimed(&report);
    }
}
