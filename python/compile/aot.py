"""AOT compile path: lower the L2 model + L1 kernels to HLO text.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:

- ``tiny_decode_b{B}.hlo.txt``   — one decode step per batch variant
- ``swiftkv_attn.hlo.txt``       — attention-only computation (quickstart)
- ``weights.bin``                — raw little-endian parameter blob
- ``manifest.json``              — config, artifact signatures, weight table

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.swiftkv import swiftkv_attention

BATCH_VARIANTS = (1, 2, 4, 8)
ATTN_ROWS = 8          # quickstart artifact: 8 head-rows
ATTN_CTX = 512
ATTN_DHEAD = 32
ALIGN = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constants as ``constant({...})``, which the 0.5.1 text
    parser silently reads as zeros (it cost us a debugging session: the
    RoPE cos/sin tables came back as 0 and every position-dependent value
    downstream was wrong).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    hlo = comp.as_hlo_module()
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return hlo.to_string(opts)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_decode(cfg: M.TinyConfig, params, batch: int) -> str:
    specs = M.param_specs(cfg)
    flat = [params[name] for name, _, _ in specs]
    args = [
        _spec((batch,), jnp.int32),                                     # tokens
        _spec((batch,), jnp.int32),                                     # pos
        _spec((batch, cfg.n_layers, cfg.n_kv_heads, cfg.n_ctx, cfg.d_head),
              jnp.float32),                                             # kc
        _spec((batch, cfg.n_layers, cfg.n_kv_heads, cfg.n_ctx, cfg.d_head),
              jnp.float32),                                             # vc
        _spec((batch, cfg.d_head // 2), jnp.float32),                   # cos
        _spec((batch, cfg.d_head // 2), jnp.float32),                   # sin
    ] + [_spec(p.shape, p.dtype) for p in flat]

    fn = functools.partial(M.decode_step_flat, cfg)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def lower_attention(rows: int, n_ctx: int, d_head: int) -> str:
    def fn(lens, q, k, v):
        return swiftkv_attention(q, k, v, lens, block_k=64)

    lowered = jax.jit(fn).lower(
        _spec((rows,), jnp.int32),
        _spec((rows, d_head), jnp.float32),
        _spec((rows, n_ctx, d_head), jnp.float32),
        _spec((rows, n_ctx, d_head), jnp.float32),
    )
    return to_hlo_text(lowered)


def model_manifest(cfg: M.TinyConfig, seed: int) -> dict:
    """The manifest's ``model`` section.

    ``n_kv_heads`` is emitted explicitly (not defaulted by the reader):
    the Rust loader validates the stored K/V projection widths against
    ``n_kv_heads * d_head``, so a grouped-query artifact that lies about
    its shape fails at load time, not mid-decode.
    """
    if cfg.n_kv_heads <= 0 or cfg.n_heads % cfg.n_kv_heads != 0:
        raise ValueError(
            f"n_heads ({cfg.n_heads}) must be a positive multiple of "
            f"n_kv_heads ({cfg.n_kv_heads})")
    return {
        "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
        "d_head": cfg.d_head,
        "n_layers": cfg.n_layers, "d_ffn": cfg.d_ffn,
        "n_ctx": cfg.n_ctx, "rope_base": cfg.rope_base,
        "block_k": cfg.block_k, "seed": seed,
    }


def dump_weights(params, specs, path: str):
    """weights.bin: little-endian arrays at 64-byte alignment, in
    signature order. Returns the manifest table."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for name, shape, dtype in specs:
            arr = np.asarray(params[name]).astype(dtype)
            assert arr.shape == tuple(shape), (name, arr.shape, shape)
            pad = (-offset) % ALIGN
            f.write(b"\0" * pad)
            offset += pad
            raw = arr.tobytes(order="C")
            f.write(raw)
            table.append({
                "name": name,
                "dtype": dtype,
                "shape": list(shape),
                "offset": offset,
                "nbytes": len(raw),
            })
            offset += len(raw)
    return table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--kv-heads", type=int, default=None, metavar="N",
        help="KV heads for the emitted model (GQA/MQA when < n_heads; "
             "must divide n_heads). Default: the config's n_kv_heads "
             "(MHA). The manifest's model.n_kv_heads and the wk/wv "
             "shapes in weights.bin both follow it, so the Rust "
             "TinyModel::load path exercises grouped shapes from real "
             "artifacts.")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.TinyConfig()
    if args.kv_heads is not None:
        cfg = dataclasses.replace(cfg, n_kv_heads=args.kv_heads)
    model_manifest(cfg, args.seed)  # validate the GQA shape up front
    params = M.init_params(cfg, seed=args.seed)
    specs = M.param_specs(cfg)

    artifacts = {}
    for b in BATCH_VARIANTS:
        name = f"tiny_decode_b{b}.hlo.txt"
        text = lower_decode(cfg, params, b)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars")
        artifacts[f"decode_b{b}"] = {
            "file": name,
            "batch": b,
            "inputs": ["tokens", "pos", "k_cache", "v_cache", "cos", "sin",
                       "*params"],
            "outputs": ["logits", "k_cache", "v_cache", "cos", "sin"],
        }

    attn_name = "swiftkv_attn.hlo.txt"
    text = lower_attention(ATTN_ROWS, ATTN_CTX, ATTN_DHEAD)
    with open(os.path.join(args.out_dir, attn_name), "w") as f:
        f.write(text)
    print(f"wrote {attn_name}: {len(text)} chars")
    artifacts["swiftkv_attn"] = {
        "file": attn_name,
        "rows": ATTN_ROWS, "n_ctx": ATTN_CTX, "d_head": ATTN_DHEAD,
        "inputs": ["lens", "q", "k", "v"],
        "outputs": ["attn"],
    }

    wpath = os.path.join(args.out_dir, "weights.bin")
    table = dump_weights(params, specs, wpath)
    print(f"wrote weights.bin: {sum(t['nbytes'] for t in table)} bytes, "
          f"{len(table)} arrays")

    manifest = {
        "model": model_manifest(cfg, args.seed),
        "batch_variants": list(BATCH_VARIANTS),
        "artifacts": artifacts,
        "weights": table,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
