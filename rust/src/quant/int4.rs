//! INT4 weight quantization with nibble packing.
//!
//! Weights are symmetric per-output-channel INT4 in `[-7, 7]` (Q4.0),
//! stored column-major packed two nibbles per byte — the layout each SKV
//! Processor's KV-Weight Memory streams to its 128 DSP lanes.

/// A packed INT4 weight matrix `[din, dout]` with per-column scales.
#[derive(Debug, Clone)]
pub struct Int4Matrix {
    /// Packed nibbles, column-major: column `j` occupies
    /// `packed[j * stride .. j * stride + din.div_ceil(2)]`.
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

impl Int4Matrix {
    /// Quantize a row-major f32 matrix `[din, dout]`.
    pub fn quantize(w: &[f32], din: usize, dout: usize) -> Self {
        assert_eq!(w.len(), din * dout);
        let (qcols, scales) = quantize_int4(w, din, dout);
        let stride = din.div_ceil(2);
        let mut packed = vec![0u8; stride * dout];
        for j in 0..dout {
            pack_int4(&qcols[j * din..(j + 1) * din], &mut packed[j * stride..(j + 1) * stride]);
        }
        Int4Matrix {
            packed,
            scales,
            din,
            dout,
        }
    }

    /// Build from pre-quantized int8-held int4 values (row-major `[din,
    /// dout]`, as stored in `weights.bin`) and per-column scales.
    pub fn from_quantized(wq: &[i8], scales: Vec<f32>, din: usize, dout: usize) -> Self {
        assert_eq!(wq.len(), din * dout);
        assert_eq!(scales.len(), dout);
        let stride = din.div_ceil(2);
        let mut packed = vec![0u8; stride * dout];
        let mut col = vec![0i8; din];
        for j in 0..dout {
            for i in 0..din {
                col[i] = wq[i * dout + j];
            }
            pack_int4(&col, &mut packed[j * stride..(j + 1) * stride]);
        }
        Int4Matrix {
            packed,
            scales,
            din,
            dout,
        }
    }

    /// Unpack column `j` into int8 lane values.
    pub fn column(&self, j: usize, out: &mut [i8]) {
        assert_eq!(out.len(), self.din);
        let stride = self.din.div_ceil(2);
        unpack_int4(&self.packed[j * stride..(j + 1) * stride], out);
    }

    /// Dequantized f32 copy (row-major) — test/diagnostic use.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.din * self.dout];
        let mut col = vec![0i8; self.din];
        for j in 0..self.dout {
            self.column(j, &mut col);
            for i in 0..self.din {
                out[i * self.dout + j] = col[i] as f32 * self.scales[j];
            }
        }
        out
    }

    /// Bytes of packed weight storage (HBM traffic accounting).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }
}

/// Symmetric per-output-channel INT4 quantization of a row-major matrix.
/// Returns column-major quantized values and per-column scales
/// (matches `ref.quantize_int4` up to layout).
pub fn quantize_int4(w: &[f32], din: usize, dout: usize) -> (Vec<i8>, Vec<f32>) {
    let mut q = vec![0i8; din * dout];
    let mut scales = vec![0.0f32; dout];
    for j in 0..dout {
        let amax = (0..din)
            .map(|i| w[i * dout + j].abs())
            .fold(0.0f32, f32::max)
            .max(1e-8);
        let scale = amax / 7.0;
        scales[j] = scale;
        for i in 0..din {
            q[j * din + i] = (w[i * dout + j] / scale).round().clamp(-7.0, 7.0) as i8;
        }
    }
    (q, scales)
}

/// Pack int4 values (in int8 lanes, range [-8, 7]) two per byte,
/// low nibble first.
pub fn pack_int4(vals: &[i8], out: &mut [u8]) {
    assert_eq!(out.len(), vals.len().div_ceil(2));
    for (b, pair) in out.iter_mut().zip(vals.chunks(2)) {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() > 1 {
            (pair[1] as u8) & 0x0F
        } else {
            0
        };
        *b = lo | (hi << 4);
    }
}

/// Unpack nibbles back to sign-extended int8 lane values.
pub fn unpack_int4(packed: &[u8], out: &mut [i8]) {
    for (i, o) in out.iter_mut().enumerate() {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        // sign-extend 4-bit two's complement
        *o = ((nib << 4) as i8) >> 4;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        let vals: Vec<i8> = (-8..8).collect();
        let mut packed = vec![0u8; 8];
        pack_int4(&vals, &mut packed);
        let mut back = vec![0i8; 16];
        unpack_int4(&packed, &mut back);
        assert_eq!(vals, back);
    }

    #[test]
    fn odd_length_pack() {
        let vals = vec![3i8, -2, 7];
        let mut packed = vec![0u8; 2];
        pack_int4(&vals, &mut packed);
        let mut back = vec![0i8; 3];
        unpack_int4(&packed, &mut back);
        assert_eq!(vals, back);
    }

    #[test]
    fn quantize_roundtrip_error() {
        let mut rng = Rng::seed_from_u64(0);
        let (din, dout) = (32, 16);
        let w: Vec<f32> = rng.uniform_vec(din * dout, 0.5);
        let m = Int4Matrix::quantize(&w, din, dout);
        let back = m.dequantize();
        for j in 0..dout {
            let half_step = m.scales[j] / 2.0;
            for i in 0..din {
                let (a, b) = (w[i * dout + j], back[i * dout + j]);
                assert!((a - b).abs() <= half_step + 1e-6, "({i},{j}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn from_quantized_matches_quantize() {
        let mut rng = Rng::seed_from_u64(7);
        let (din, dout) = (16, 8);
        let w: Vec<f32> = rng.uniform_vec(din * dout, 1.0);
        let a = Int4Matrix::quantize(&w, din, dout);
        // route through the row-major int8 representation
        let (qcols, scales) = quantize_int4(&w, din, dout);
        let mut row_major = vec![0i8; din * dout];
        for j in 0..dout {
            for i in 0..din {
                row_major[i * dout + j] = qcols[j * din + i];
            }
        }
        let b = Int4Matrix::from_quantized(&row_major, scales, din, dout);
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn packed_size_halves_storage() {
        let w = vec![0.5f32; 128 * 64];
        let m = Int4Matrix::quantize(&w, 128, 64);
        assert_eq!(m.packed.len(), 128 * 64 / 2);
    }
}
