//! Integration: the CPU serving loop end-to-end over the synthetic tiny
//! model — continuous batching, the operator-level batched decode step
//! (one shared weight pass per batch step) over the persistent worker
//! pool, lane recycling, and correctness of batched generation against
//! solo generation. Runs on the default feature set (no PJRT, no
//! artifacts).

use swiftkv::coordinator::{CpuServer, ServeConfig};
use swiftkv::model::{LlmConfig, NumericsMode, Request, TinyModel, WorkloadGen, WorkloadSpec};

fn model() -> TinyModel {
    TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48)
}

/// Grouped-query synthetic model: 4 query heads sharing 2 KV heads.
fn gqa_model() -> TinyModel {
    TinyModel::synthetic(7, 64, 32, 4, 2, 2, 64, 48)
}

fn opts(lanes: usize, mode: NumericsMode) -> ServeConfig {
    ServeConfig::builder()
        .lanes(lanes)
        .mode(mode)
        .max_iterations(10_000)
        .sim_model(LlmConfig::llama2_7b())
        .build()
        .expect("test serve config is valid")
}

#[test]
fn serves_a_workload_to_completion() {
    let tm = model();
    let reqs = WorkloadGen::new(WorkloadSpec {
        num_requests: 6,
        vocab: tm.vocab,
        prompt_len: (2, 6),
        gen_len: (3, 8),
        mean_gap_ms: 0.0,
        deadline_ms: 0,
        seed: 42,
    })
    .generate();
    let expect: Vec<(u64, usize)> = reqs.iter().map(|r| (r.id, r.gen_len)).collect();

    let report = CpuServer::new(&tm, opts(4, NumericsMode::DesktopF32)).serve(reqs);
    assert_eq!(report.sessions.len(), 6);
    for (id, gen_len) in expect {
        let s = report
            .sessions
            .iter()
            .find(|s| s.request.id == id)
            .expect("session missing");
        assert_eq!(s.generated.len(), gen_len, "request {id}");
        assert!(s.generated.iter().all(|&t| (t as usize) < tm.vocab));
    }
    assert!(report.metrics.total_tokens_generated > 0);
    assert!(report.metrics.tokens_per_s > 0.0);
    assert!(report.metrics.simulated_accel_ms > 0.0);
    assert!(report.metrics.mean_occupancy > 0.0);
}

#[test]
fn batched_serving_matches_solo_generation_both_modes() {
    let tm = model();
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![50, 7], vec![42, 42, 42, 42]];
    let gen_len = 6;

    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::new(i as u64, p.clone()).gen_len(gen_len))
            .collect();
        let report = CpuServer::new(&tm, opts(4, mode)).serve(reqs);

        for (i, p) in prompts.iter().enumerate() {
            let want = tm.generate(p, gen_len, mode);
            let got = &report
                .sessions
                .iter()
                .find(|s| s.request.id == i as u64)
                .unwrap()
                .generated;
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "{mode:?} request {i}: batched serving diverged from solo decode"
            );
        }
    }
}

#[test]
fn gqa_batched_serving_matches_solo_generation_both_modes() {
    // the whole serving stack — batcher, lane threads, recycled
    // DecodeStates with group-factor-shrunk KV caches — over a
    // grouped-query model, in both numerics modes
    let tm = gqa_model();
    assert_eq!(tm.n_kv_heads, 2);
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![50, 7], vec![42, 42, 42, 42], vec![9]];
    let gen_len = 6;

    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::new(i as u64, p.clone()).gen_len(gen_len))
            .collect();
        // llama3-8b sim config: the GQA shape the sim layer prices;
        // fewer lanes than requests → recycling under GQA
        let opts = ServeConfig::builder()
            .lanes(2)
            .mode(mode)
            .max_iterations(10_000)
            .sim_model(LlmConfig::llama3_8b())
            .build()
            .expect("test serve config is valid");
        let report = CpuServer::new(&tm, opts).serve(reqs);
        assert_eq!(report.sessions.len(), prompts.len());

        for (i, p) in prompts.iter().enumerate() {
            let want = tm.generate(p, gen_len, mode);
            let got = &report
                .sessions
                .iter()
                .find(|s| s.request.id == i as u64)
                .unwrap()
                .generated;
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "{mode:?} GQA request {i}: batched serving diverged from solo decode"
            );
        }
    }
}

#[test]
fn lane_recycling_more_requests_than_lanes() {
    let tm = model();
    // 5 requests through 2 lanes → at least one lane is recycled
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request::new(i, vec![(i as u32 * 31 + 5) % tm.vocab as u32]).gen_len(3))
        .collect();
    let report = CpuServer::new(&tm, opts(2, NumericsMode::DesktopF32)).serve(reqs);
    assert_eq!(report.sessions.len(), 5);
    for s in &report.sessions {
        assert_eq!(s.generated.len(), 3);
    }
    // recycled-lane results must equal fresh-lane results
    let solo = CpuServer::new(&tm, opts(2, NumericsMode::DesktopF32))
        .serve(vec![Request::new(99, vec![5]).gen_len(3)]);
    let first = report.sessions.iter().find(|s| s.request.id == 0).unwrap();
    assert_eq!(first.generated, solo.sessions[0].generated);
}

#[test]
fn lanes_share_one_pool_with_reclamation() {
    // Tiny blocks so every sequence spans several of them, and a pool
    // sized for just the two concurrent lanes' live sets (10 blocks ≪
    // the 48 of worst-case sizing): each 6-token sequence pins 2 blocks
    // per layer × 2 layers = 4 blocks, and the 7 requests through 2
    // lanes need 28 block-checkouts in total — without reclamation on
    // reset_for_reuse the pool would exhaust (and panic a lane) midway.
    let tm = model();
    let kv_block_len = 4;
    let lanes = 2;
    let kv_pool_blocks = 10;
    let opts = ServeConfig::builder()
        .lanes(lanes)
        .mode(NumericsMode::DesktopF32)
        .max_iterations(10_000)
        .sim_model(LlmConfig::llama2_7b())
        .kv_block_len(kv_block_len)
        .kv_pool_blocks(kv_pool_blocks)
        .build()
        .expect("test serve config is valid");
    let reqs: Vec<Request> = (0..7)
        .map(|i| Request::new(i, vec![(i as u32 * 17 + 3) % tm.vocab as u32]).gen_len(5))
        .collect();
    let report = CpuServer::new(&tm, opts).serve(reqs);
    assert_eq!(report.sessions.len(), 7);

    // the shared pool has the configured shape and is fully reclaimed
    let pool = &report.kv_pool;
    assert_eq!(pool.block_len(), kv_block_len);
    assert_eq!(pool.total_blocks(), kv_pool_blocks);
    assert_eq!(
        pool.free_blocks(),
        pool.total_blocks(),
        "retired lanes must return every block to the shared pool"
    );

    // paged, pool-shared serving still decodes exactly like solo decode
    for s in &report.sessions {
        let want = tm.generate(&s.request.prompt, s.request.gen_len, NumericsMode::DesktopF32);
        assert_eq!(
            s.generated, want,
            "request {}: pooled serving diverged from solo decode",
            s.request.id
        );
    }
}

#[test]
fn idle_lanes_release_blocks_at_retirement() {
    // Three short sequences retire and leave two lanes idle forever
    // (nothing left in the queue for them) while the fourth, long
    // request grows to 16 blocks. The pool (17) only covers that if
    // retired lanes release their blocks *at retirement* — lazily
    // holding them until the lane's next admission (which never comes
    // for the idle lanes) would pin 4 dead blocks and panic the long
    // lane with pool exhaustion at ~14 blocks.
    let tm = model();
    let opts = ServeConfig::builder()
        .lanes(3)
        .mode(NumericsMode::DesktopF32)
        .max_iterations(10_000)
        .sim_model(LlmConfig::llama2_7b())
        .kv_block_len(4)
        .kv_pool_blocks(17)
        .build()
        .expect("test serve config is valid");
    let mut reqs: Vec<Request> = (0..3)
        // 3 cache rows → 1 block per layer
        .map(|i| Request::new(i, vec![1 + i as u32]).gen_len(3))
        .collect();
    // 30 cache rows → 8 blocks per layer = 16 blocks
    reqs.push(Request::new(3, vec![9]).gen_len(30));
    let report = CpuServer::new(&tm, opts).serve(reqs);
    assert_eq!(report.sessions.len(), 4);
    let long = report.sessions.iter().find(|s| s.request.id == 3).unwrap();
    assert_eq!(long.generated.len(), 30);
    assert_eq!(report.kv_pool.free_blocks(), 17);
}

#[test]
fn undersized_pool_is_enough_for_short_sequences() {
    // The point of paging: a pool far smaller than lanes × n_ctx serves
    // short sequences fine. 2 lanes × 2 layers; prompts+gen stay ≤ 8
    // tokens = 2 blocks of 4 per layer, so 8 blocks cover both lanes —
    // versus 24 for the worst-case sizing (n_ctx 48, 12 blocks/lane).
    let tm = model();
    let opts = ServeConfig::builder()
        .lanes(2)
        .mode(NumericsMode::DesktopF32)
        .max_iterations(10_000)
        .sim_model(LlmConfig::llama2_7b())
        .kv_block_len(4)
        .kv_pool_blocks(8)
        .build()
        .expect("test serve config is valid");
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request::new(i, vec![1 + i as u32, 2]).gen_len(4))
        .collect();
    let report = CpuServer::new(&tm, opts).serve(reqs);
    assert_eq!(report.sessions.len(), 5);
    assert_eq!(report.kv_pool.total_blocks(), 8);
    assert_eq!(report.kv_pool.free_blocks(), 8);
    for s in &report.sessions {
        assert_eq!(s.generated.len(), 4);
    }
}

#[test]
fn rejected_requests_surface_in_metrics() {
    // n_ctx is 48: a request with prompt + gen_len > 48 is rejected at
    // submission. It is dropped by design — but the loop must count it,
    // and the metrics must surface both counters.
    let tm = model();
    let mut reqs: Vec<Request> = (0..3)
        .map(|i| Request::new(i, vec![1 + i as u32, 2]).gen_len(3))
        .collect();
    let long_prompt: Vec<u32> = (0..40).map(|t| t % tm.vocab as u32).collect();
    // 40 + 20 > 48 → rejected
    reqs.push(Request::new(99, long_prompt).gen_len(20));
    let report = CpuServer::new(&tm, opts(2, NumericsMode::DesktopF32)).serve(reqs);
    assert_eq!(report.metrics.requests_admitted, 3);
    assert_eq!(
        report.metrics.requests_rejected, 1,
        "the oversized request must be counted, not silently dropped"
    );
    assert_eq!(report.sessions.len(), 3);
    assert!(report.sessions.iter().all(|s| s.request.id != 99));
    // the counters also land in the human-readable table
    let table = report.metrics.format_table();
    assert!(table.contains("admitted / rejected"), "{table}");
}

#[test]
fn nothing_rejected_reports_zero() {
    let tm = model();
    let reqs = vec![Request::new(0, vec![3, 4]).gen_len(2)];
    let report = CpuServer::new(&tm, opts(1, NumericsMode::DesktopF32)).serve(reqs);
    assert_eq!(report.metrics.requests_admitted, 1);
    assert_eq!(report.metrics.requests_rejected, 0);
}

#[test]
fn prefill_chunk_lengths_do_not_change_outputs() {
    // the scheduling contract changed; the numbers must not — serving
    // with per-token prefill (chunk 1), odd chunks, the default, and
    // whole-prompt chunks (0) generates identical tokens, all equal to
    // solo generate
    let tm = model();
    let prompts: Vec<Vec<u32>> = vec![
        vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11],
        vec![50, 7],
        vec![9],
        vec![42; 14],
    ];
    let gen_len = 4;
    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        for prefill_chunk in [1usize, 3, 8, 0] {
            let reqs: Vec<Request> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| Request::new(i as u64, p.clone()).gen_len(gen_len))
                .collect();
            // fewer lanes than requests → recycling mid-stream
            let opts = ServeConfig::builder()
                .lanes(2)
                .mode(mode)
                .max_iterations(10_000)
                .sim_model(LlmConfig::llama2_7b())
                .prefill_chunk(prefill_chunk)
                .build()
                .expect("test serve config is valid");
            let report = CpuServer::new(&tm, opts).serve(reqs);
            assert_eq!(report.sessions.len(), prompts.len());
            for (i, p) in prompts.iter().enumerate() {
                let want = tm.generate(p, gen_len, mode);
                let got = &report
                    .sessions
                    .iter()
                    .find(|s| s.request.id == i as u64)
                    .unwrap()
                    .generated;
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "{mode:?} chunk={prefill_chunk} request {i}: chunked prefill \
                     changed the generated tokens"
                );
            }
        }
    }
}

#[test]
fn chunked_prefill_takes_fewer_iterations() {
    // one lane, one 16-token prompt: per-token prefill needs 16
    // iterations before the first sample; chunk 8 needs 2. Iteration
    // counts are deterministic (all requests arrive at t=0).
    let tm = model();
    let req = |id: u64| {
        let prompt: Vec<u32> = (0..16).map(|t| (t * 3 + 1) % tm.vocab as u32).collect();
        Request::new(id, prompt).gen_len(2)
    };
    let run = |prefill_chunk: usize| {
        let opts = ServeConfig::builder()
            .lanes(1)
            .mode(NumericsMode::DesktopF32)
            .max_iterations(10_000)
            .sim_model(LlmConfig::llama2_7b())
            .prefill_chunk(prefill_chunk)
            .build()
            .expect("test serve config is valid");
        CpuServer::new(&tm, opts).serve(vec![req(0)])
    };
    let per_token = run(1);
    let chunked = run(8);
    let whole = run(0);
    // same outputs…
    assert_eq!(
        per_token.sessions[0].generated,
        chunked.sessions[0].generated
    );
    assert_eq!(per_token.sessions[0].generated, whole.sessions[0].generated);
    // …in 16+1 vs 2+1 vs 1+1 engine iterations
    assert_eq!(per_token.metrics.iterations, 17);
    assert_eq!(chunked.metrics.iterations, 3);
    assert_eq!(whole.metrics.iterations, 2);
    // and the first token lands on an earlier iteration
    assert_eq!(per_token.sessions[0].first_token_at, Some(15));
    assert_eq!(chunked.sessions[0].first_token_at, Some(1));
    assert_eq!(whole.sessions[0].first_token_at, Some(0));
}

#[test]
fn decode_heavy_run_pays_one_weight_pass_per_step() {
    // 4 lanes × 1-token prompts: every iteration is a pure decode batch
    // of width 4, so the whole run must stream the weights exactly once
    // per iteration — the point of operator-level batching (B lanes
    // report 1 weight pass, not B)
    let tm = model();
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request::new(i, vec![(i as u32 * 9 + 1) % tm.vocab as u32]).gen_len(6))
        .collect();
    let report = CpuServer::new(&tm, opts(4, NumericsMode::DesktopF32)).serve(reqs);
    let m = &report.metrics;
    assert_eq!(
        m.weight_passes, m.iterations,
        "a decode-only run must pay exactly one weight pass per iteration"
    );
    assert!(
        (m.weight_passes_per_step - 1.0).abs() < 1e-9,
        "weight_passes_per_step = {}",
        m.weight_passes_per_step
    );
    // all 4 lanes decode together until the first retirements
    assert_eq!(m.batch_width.max, 4.0);
    assert!(m.batch_width.p50 >= 1.0);
    // and the counters land in the human-readable table
    let table = m.format_table();
    assert!(table.contains("weight passes / step"), "{table}");
    assert!(table.contains("decode batch width p50"), "{table}");
}

#[test]
fn prefill_lanes_pay_their_own_weight_passes() {
    // 2 lanes × 16-token prompts, chunk 8: prefill iterations run per
    // lane and stream the layer weights once per chunk *token* (the
    // per-token GEMVs of prefill_into), decode iterations batch into
    // one shared pass each
    let tm = model();
    let reqs: Vec<Request> = (0..2)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..16).map(|t| (t * 3 + i as u32) % tm.vocab as u32).collect();
            Request::new(i, prompt).gen_len(4)
        })
        .collect();
    let report = CpuServer::new(&tm, opts(2, NumericsMode::DesktopF32)).serve(reqs);
    let m = &report.metrics;
    // chunked prefill: iteration 0 feeds prompt[0..8), iteration 1
    // feeds prompt[8..16) and samples token 1, iterations 2–4 decode
    // tokens 2–4 as width-2 batches
    assert_eq!(m.iterations, 5);
    // 2 prefill iterations at 2 lanes × 8 chunk tokens + 3 batched
    // decode iterations at 1 shared pass
    assert_eq!(m.weight_passes, 2 * (2 * 8) + 3);
    assert_eq!(m.batch_width.max, 2.0);
}

#[test]
fn explicit_worker_counts_do_not_change_outputs() {
    // the worker pool is a scheduling choice, never a numerics one:
    // inline (1), tiny pool (2), and oversubscribed (6) runs must all
    // reproduce solo generation exactly
    let tm = gqa_model();
    let prompts: Vec<Vec<u32>> =
        vec![vec![1, 2, 3, 4, 5, 6, 7], vec![50, 7], vec![9; 12], vec![33]];
    let gen_len = 5;
    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        for workers in [1usize, 2, 6] {
            let reqs: Vec<Request> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| Request::new(i as u64, p.clone()).gen_len(gen_len))
                .collect();
            let opts = ServeConfig::builder()
                .lanes(3)
                .mode(mode)
                .max_iterations(10_000)
                .sim_model(LlmConfig::llama2_7b())
                .workers(workers)
                .build()
                .expect("test serve config is valid");
            let report = CpuServer::new(&tm, opts).serve(reqs);
            for (i, p) in prompts.iter().enumerate() {
                let want = tm.generate(p, gen_len, mode);
                let got = &report
                    .sessions
                    .iter()
                    .find(|s| s.request.id == i as u64)
                    .unwrap()
                    .generated;
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "{mode:?} workers={workers} request {i}: worker count changed the output"
                );
            }
        }
    }
}

#[test]
fn staggered_arrivals_all_served() {
    let tm = model();
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request::new(i, vec![10 + i as u32]).gen_len(2).arrival_ms(i * 20))
        .collect();
    let report = CpuServer::new(&tm, opts(2, NumericsMode::DesktopF32)).serve(reqs);
    assert_eq!(report.sessions.len(), 4);
    assert!(report.metrics.mean_occupancy > 0.0);
}

#[test]
fn single_lane_runs_inline() {
    // exercises the no-spawn fast path (n_active <= 1)
    let tm = model();
    let reqs = vec![Request::new(0, vec![3, 4]).gen_len(4)];
    let report = CpuServer::new(&tm, opts(1, NumericsMode::Accelerator)).serve(reqs);
    assert_eq!(report.sessions.len(), 1);
    assert_eq!(
        report.sessions[0].generated,
        tm.generate(&[3, 4], 4, NumericsMode::Accelerator)
    );
}
