//! Integration: the serving loop end-to-end over the PJRT engine —
//! continuous batching, lane recycling, and correctness of batched
//! generation against solo generation. Compiled only with the `pjrt`
//! feature; the default-build equivalents over the CPU backend live in
//! `integration_cpu_serve.rs`.
#![cfg(feature = "pjrt")]

use swiftkv::coordinator::{ServeOptions, Server};
use swiftkv::model::{
    LlmConfig, NumericsMode, Request, TinyModel, WeightStore, WorkloadGen, WorkloadSpec,
};
use swiftkv::runtime::{artifacts_available, default_artifacts_dir, Engine};

fn engine() -> Option<Engine> {
    artifacts_available().then(|| Engine::load(&default_artifacts_dir()).unwrap())
}

fn opts(batch: usize) -> ServeOptions {
    ServeOptions {
        batch: Some(batch),
        max_iterations: 10_000,
        sim_model: LlmConfig::llama2_7b(),
    }
}

#[test]
fn serves_a_workload_to_completion() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let reqs = WorkloadGen::new(WorkloadSpec {
        num_requests: 6,
        vocab: eng.manifest.vocab,
        prompt_len: (2, 6),
        gen_len: (3, 8),
        mean_gap_ms: 0.0,
        deadline_ms: 0,
        seed: 42,
    })
    .generate();
    let expect: Vec<(u64, usize)> = reqs.iter().map(|r| (r.id, r.gen_len)).collect();

    let report = Server::new(&eng, opts(4)).serve(reqs).unwrap();
    assert_eq!(report.sessions.len(), 6);
    for (id, gen_len) in expect {
        let s = report
            .sessions
            .iter()
            .find(|s| s.request.id == id)
            .expect("session missing");
        assert_eq!(s.generated.len(), gen_len, "request {id}");
        assert!(s.generated.iter().all(|&t| (t as usize) < eng.manifest.vocab));
    }
    assert!(report.metrics.total_tokens_generated > 0);
    assert!(report.metrics.tokens_per_s > 0.0);
    assert!(report.metrics.simulated_accel_ms > 0.0);
}

#[test]
fn batched_serving_matches_solo_generation() {
    let Some(eng) = engine() else {
        return;
    };
    // reference: pure-rust greedy generation (same weights/numerics family)
    let tm = TinyModel::load(&WeightStore::load(&default_artifacts_dir()).unwrap()).unwrap();
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![250, 7], vec![42, 42, 42, 42]];
    let gen_len = 6;

    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone()).gen_len(gen_len))
        .collect();
    let report = Server::new(&eng, opts(4)).serve(reqs).unwrap();

    for (i, p) in prompts.iter().enumerate() {
        let want = tm.generate(p, gen_len, NumericsMode::DesktopF32);
        let got = &report
            .sessions
            .iter()
            .find(|s| s.request.id == i as u64)
            .unwrap()
            .generated;
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "request {i}: batched serving diverged from solo decode"
        );
    }
}

#[test]
fn lane_recycling_more_requests_than_lanes() {
    let Some(eng) = engine() else {
        return;
    };
    // 5 requests through a 2-lane batch → at least one lane is recycled
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request::new(i, vec![(i as u32 * 31 + 5) % 512]).gen_len(3))
        .collect();
    let report = Server::new(&eng, opts(2)).serve(reqs).unwrap();
    assert_eq!(report.sessions.len(), 5);
    for s in &report.sessions {
        assert_eq!(s.generated.len(), 3);
    }
    // recycled-lane results must equal fresh-lane results for identical
    // requests: run request 0 again alone and compare
    let solo = Server::new(&eng, opts(2))
        .serve(vec![Request::new(99, vec![5]).gen_len(3)])
        .unwrap();
    let first = report
        .sessions
        .iter()
        .find(|s| s.request.id == 0)
        .unwrap();
    assert_eq!(first.generated, solo.sessions[0].generated);
}

#[test]
fn staggered_arrivals_all_served() {
    let Some(eng) = engine() else {
        return;
    };
    let reqs: Vec<Request> = (0..4)
        // arrivals spread over ~100ms
        .map(|i| Request::new(i, vec![10 + i as u32]).gen_len(2).arrival_ms(i * 30))
        .collect();
    let report = Server::new(&eng, opts(2)).serve(reqs).unwrap();
    assert_eq!(report.sessions.len(), 4);
    assert!(report.metrics.mean_occupancy > 0.0);
}
