//! Native (textbook) decode attention — the baseline every speedup in
//! Fig. 7(b) is normalized against.
//!
//! Three sequential phases, with the full score vector materialized:
//! 1. `s_t = q·k_t/√d` for all `t` (scores written to a buffer),
//! 2. numerically-stable softmax over the buffer (max scan, exp pass,
//!    per-element normalization — the N divisions the paper's cycle
//!    analysis charges this algorithm for),
//! 3. `out = P·V`.

use super::{dot_f32, HeadProblem};

/// Compute attention natively, returning the output vector.
pub fn attend(p: &HeadProblem) -> Vec<f32> {
    let scores = score_pass(p);
    let probs = softmax_pass(&scores);
    pv_pass(p, &probs)
}

/// Phase 1: materialize all attention scores (Eq. 5).
pub fn score_pass(p: &HeadProblem) -> Vec<f32> {
    let scale = p.scale();
    (0..p.len).map(|t| dot_f32(p.q, p.key(t)) * scale).collect()
}

/// Phase 2: numerically-stable softmax over the materialized scores.
pub fn softmax_pass(scores: &[f32]) -> Vec<f32> {
    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = scores.iter().map(|s| (s - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Phase 3: probability-weighted sum of the value cache.
pub fn pv_pass(p: &HeadProblem, probs: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; p.d];
    for (t, &w) in probs.iter().enumerate() {
        for (o, &v) in out.iter_mut().zip(p.value(t)) {
            *o += w * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::ProblemData;

    #[test]
    fn probabilities_sum_to_one() {
        let data = ProblemData::random(1, 16, 33, 1.0);
        let p = data.problem();
        let probs = softmax_pass(&score_pass(&p));
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "sum={sum}");
        assert!(probs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn single_token_returns_value_row() {
        let data = ProblemData::random(2, 8, 1, 1.0);
        let p = data.problem();
        let out = attend(&p);
        for (o, v) in out.iter().zip(p.value(0)) {
            assert!((o - v).abs() < 1e-6);
        }
    }

    #[test]
    fn uniform_keys_average_values() {
        // identical keys → uniform probabilities → mean of value rows
        let d = 4;
        let len = 7;
        let q = vec![0.3f32; d];
        let k = vec![1.0f32; d * len];
        let v: Vec<f32> = (0..d * len).map(|i| i as f32).collect();
        let p = HeadProblem::new(&q, &k, &v, d, len);
        let out = attend(&p);
        for (j, o) in out.iter().enumerate() {
            let mean: f32 =
                (0..len).map(|t| v[t * d + j]).sum::<f32>() / len as f32;
            assert!((o - mean).abs() < 1e-4, "col {j}: {o} vs {mean}");
        }
    }

    #[test]
    fn extreme_scores_stable() {
        let data = ProblemData::random(3, 16, 64, 40.0);
        let out = attend(&data.problem());
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
