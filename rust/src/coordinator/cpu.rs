//! CPU batch serving over the pure-Rust tiny model — the default-feature
//! serving path (no PJRT required).
//!
//! Same continuous-batching shape as the PJRT [`super::server`]: queue →
//! [`super::batcher::Batcher`] → one batch step → greedy sample → retire.
//! Prompt tokens are consumed **chunked**: a prefill lane feeds up to
//! [`CpuServeOptions::prefill_chunk`] prompt tokens per iteration through
//! the fused causal sweep ([`TinyModel::prefill_into`]) instead of one
//! decode step per token, computing the logits projection only when the
//! chunk reaches the last prompt token — the TTFT win of chunked
//! prefill. The chunk is bounded by default so one long prompt cannot
//! stall the decode lanes sharing the iteration.
//!
//! Decoding is weight-bandwidth bound, so the batch step batches at the
//! **operator** level instead of lane-per-thread: every decode-phase
//! lane (single-token sampling chunk) joins one
//! [`TinyModel::decode_steps_into`] call that streams each packed
//! weight matrix **once for the whole batch** (B lanes pay 1 weight
//! pass per step, not B — surfaced as
//! [`ServeMetrics::weight_passes_per_step`]), while prefill lanes run
//! their chunks per lane. Parallelism comes from a **persistent**
//! [`crate::kernels::WorkerPool`] that lives for the whole run — the
//! batched step splits its GEMMs by output-column range and its
//! attention phase by lane, prefill chunks run one task per lane, and
//! nothing spawns per iteration (the old `std::thread::scope` fan-out
//! paid a spawn/join per step and re-streamed the weights per lane). A
//! lone decode lane skips the pool and runs the inline solo step, so
//! single-lane latency does not regress. Each lane owns its
//! [`DecodeState`] (per-layer block tables +
//! [`crate::kernels::DecodeScratch`]), so a steady-state lane step
//! performs zero heap allocation and lanes never contend on memory —
//! the KV rows live in **one shared [`crate::kernels::BlockPool`]**
//! that every lane draws fixed-size blocks from, sized by
//! [`CpuServeOptions::kv_block_len`] /
//! [`CpuServeOptions::kv_pool_blocks`]; the only contended state is the
//! pool's free list, touched once per `block_len` tokens per layer.
//! Grouped-query models serve unchanged: the pool's rows are sized
//! `n_kv_heads * d_head` by [`TinyModel::new_pool`], so a GQA model cuts
//! pooled KV memory (and streamed KV bytes per step) by the group
//! factor. Recycled lanes restart at position 0 via
//! [`DecodeState::reset_for_reuse`], which returns their blocks to the
//! pool for other lanes — reclamation, not re-allocation.

use super::batcher::{Batcher, LaneChunk};
use super::metrics::{Percentiles, ServeMetrics};
use super::session::Session;
use crate::kernels::{BlockPool, SharedMut, WorkerPool};
use crate::model::tiny::{argmax, BatchLane, DecodeState};
use crate::model::{LlmConfig, NumericsMode, Request, TinyModel, DEFAULT_KV_BLOCK_LEN};
use crate::sim::{layer_sched, ArchConfig};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Default prompt tokens a lane may consume in one chunked-prefill step
/// (`swiftkv serve --prefill-chunk` overrides; `0` = whole prompt).
/// Bounded so one long prompt cannot monopolize an iteration: step wall
/// time is the max over lanes, so an unbounded prefill chunk would stall
/// every decode lane for the whole prompt instead of `8` tokens' worth.
pub const DEFAULT_PREFILL_CHUNK: usize = 8;

/// CPU serving configuration.
#[derive(Debug, Clone)]
pub struct CpuServeOptions {
    /// Number of decode lanes (threads at full occupancy).
    pub lanes: usize,
    /// Numerics mode every lane decodes in.
    pub mode: NumericsMode,
    /// Safety cap on batch iterations (0 = unlimited).
    pub max_iterations: u64,
    /// Model config used for the simulated-accelerator metrics.
    pub sim_model: LlmConfig,
    /// Tokens per KV cache block in the shared pool.
    pub kv_block_len: usize,
    /// Total blocks in the shared pool; `0` sizes it for the worst case
    /// (`lanes × blocks_per_seq`, i.e. every lane at full context).
    pub kv_pool_blocks: usize,
    /// Max prompt tokens per lane per iteration (chunked prefill
    /// through the fused causal sweep); `0` = whole remaining prompt in
    /// one step. `1` reproduces the old one-decode-step-per-prompt-token
    /// prefill.
    pub prefill_chunk: usize,
    /// OS threads stepping the engine (the serving thread plus
    /// `workers - 1` persistent pool workers); `0` = one per available
    /// CPU, `1` = fully inline (no pool).
    pub workers: usize,
}

impl Default for CpuServeOptions {
    fn default() -> Self {
        CpuServeOptions {
            lanes: 4,
            mode: NumericsMode::DesktopF32,
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
            kv_block_len: DEFAULT_KV_BLOCK_LEN,
            kv_pool_blocks: 0,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            workers: 0,
        }
    }
}

/// One prefill-phase lane's work for an iteration: a prompt chunk fed
/// through the fused causal sweep (`samples` = the chunk ends on the
/// last prompt token, so its logits are wanted).
struct PrefillTask<'a> {
    st: &'a mut DecodeState,
    tokens: &'a [u32],
    samples: bool,
    out: &'a mut [f32],
}

/// Result of a CPU serving run.
pub struct CpuServeReport {
    pub sessions: Vec<Session>,
    pub metrics: ServeMetrics,
    /// The shared KV block pool the lanes served from (all blocks are
    /// back on its free list by the time `serve` returns).
    pub kv_pool: Arc<BlockPool>,
}

/// The CPU decode server.
pub struct CpuServer<'m> {
    model: &'m TinyModel,
    opts: CpuServeOptions,
}

impl<'m> CpuServer<'m> {
    pub fn new(model: &'m TinyModel, opts: CpuServeOptions) -> Self {
        assert!(opts.lanes >= 1, "need at least one lane");
        assert!(opts.kv_block_len >= 1, "need at least one token per KV block");
        assert!(
            model.n_kv_heads >= 1 && model.n_heads % model.n_kv_heads == 0,
            "model GQA shape invalid: {} query heads over {} KV heads",
            model.n_heads,
            model.n_kv_heads
        );
        CpuServer { model, opts }
    }

    /// Blocks the shared pool will hold: the configured count, or the
    /// worst case (every lane at full context) when unset.
    fn pool_blocks(&self) -> usize {
        if self.opts.kv_pool_blocks > 0 {
            self.opts.kv_pool_blocks
        } else {
            self.opts.lanes * self.model.blocks_per_seq(self.opts.kv_block_len)
        }
    }

    /// Serve a request stream to completion (arrival times are honoured in
    /// iteration order, like the PJRT server).
    pub fn serve(&self, requests: Vec<Request>) -> CpuServeReport {
        let lanes = self.opts.lanes;
        let model = self.model;
        let mode = self.opts.mode;
        let vocab = model.vocab;
        let mut batcher = Batcher::new(lanes, model.n_ctx);
        // one block pool for every lane: blocks migrate between lanes as
        // sequences retire (reclamation in reset_for_reuse / Drop)
        let kv_pool = model.new_pool(self.pool_blocks(), self.opts.kv_block_len);
        let mut states: Vec<DecodeState> = (0..lanes)
            .map(|_| model.new_state_in(kv_pool.clone()))
            .collect();
        let mut logits = vec![0.0f32; lanes * vocab];

        let mut pending: VecDeque<Request> = requests.into();

        // the persistent worker pool for the whole run: the batched
        // decode step splits its GEMMs by output columns and its
        // attention phase by lane, prefill chunks run one task per lane
        // — no per-iteration thread spawns
        let threads = if self.opts.workers > 0 {
            self.opts.workers
        } else {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        };
        let worker_pool = (threads > 1).then(|| WorkerPool::new(threads - 1));
        let mut batch_scratch = model.new_batch_scratch();

        let t0 = Instant::now();
        let mut iteration = 0u64;
        let mut step_ms: Vec<f64> = Vec::new();
        let mut occupancy_acc = 0.0;
        let mut sim_cycles: u64 = 0;
        let arch = ArchConfig::default();
        let mut iter_end_ms: Vec<f64> = Vec::new();
        let mut batch_widths: Vec<f64> = Vec::new();
        let mut weight_passes: u64 = 0;

        // 0 = unbounded: a whole remaining prompt in one chunked step
        let max_prefill = if self.opts.prefill_chunk == 0 {
            usize::MAX
        } else {
            self.opts.prefill_chunk
        };

        loop {
            // admit every request whose arrival time has passed
            let now_ms = t0.elapsed().as_secs_f64() * 1e3;
            while let Some(r) = pending.front() {
                if r.arrival_ms as f64 <= now_ms {
                    let r = pending.pop_front().unwrap();
                    if let Err(rejected) = batcher.submit(r) {
                        // oversized for the context window: dropped by
                        // design, but never silently — the batcher
                        // counted it and ServeMetrics::requests_rejected
                        // surfaces it at the end of the run
                        drop(rejected);
                    }
                } else {
                    break;
                }
            }
            batcher.admit(iteration);
            if batcher.is_drained() {
                if pending.is_empty() {
                    break;
                }
                // idle until the next arrival
                std::thread::sleep(std::time::Duration::from_micros(200));
                continue;
            }

            let chunks = batcher.gather_chunks(max_prefill);
            let fed: Vec<usize> = chunks.iter().map(|c| c.tokens.len()).collect();
            let sampling: Vec<bool> = chunks.iter().map(|c| c.active && c.samples).collect();
            let was_active: Vec<bool> = chunks.iter().map(|c| c.active).collect();
            occupancy_acc += batcher.occupancy();

            // lanes starting a fresh session restart their decode state
            // (their retired predecessor's blocks were already reclaimed
            // at retirement below; this also covers any future path that
            // hands a lane a new session without an idle iteration)
            for (i, st) in states.iter_mut().enumerate() {
                if chunks[i].active && chunks[i].pos == 0 && st.pos != 0 {
                    st.reset_for_reuse();
                }
            }

            // partition the active lanes: single-token sampling chunks
            // are decode-phase and batch into ONE shared-weight step;
            // multi-token or non-sampling chunks (prefill) run per lane.
            // B batched lanes stream the weight set once, not B times.
            let is_batched = |c: &LaneChunk<'_>| c.active && c.tokens.len() == 1 && c.samples;
            let n_batched = chunks.iter().filter(|c| is_batched(c)).count();
            let n_prefill = chunks.iter().filter(|c| c.active).count() - n_batched;

            let ts = Instant::now();
            // 1) prefill lanes: chunked prefill through the fused causal
            //    sweep, one persistent-pool task per lane (logits only
            //    when the chunk ends on a sampling position)
            if n_prefill > 0 {
                let mut tasks: Vec<PrefillTask> = states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                    .filter(|(i, _)| chunks[*i].active && !is_batched(&chunks[*i]))
                    .map(|(i, (st, out))| PrefillTask {
                        st,
                        tokens: chunks[i].tokens,
                        samples: chunks[i].samples,
                        out,
                    })
                    .collect();
                let run_one = |t: &mut PrefillTask<'_>| {
                    let out = if t.samples { Some(&mut t.out[..]) } else { None };
                    model.prefill_into(t.st, t.tokens, mode, out);
                };
                match &worker_pool {
                    Some(p) if tasks.len() > 1 => {
                        let ptr = SharedMut(tasks.as_mut_ptr());
                        p.run(tasks.len(), |i| {
                            // Safety: task indices are distinct, so each
                            // task is this index's only reference
                            run_one(unsafe { &mut *ptr.0.add(i) });
                        });
                    }
                    _ => {
                        for t in tasks.iter_mut() {
                            run_one(t);
                        }
                    }
                }
            }
            // 2) decode lanes: one batched step, weights streamed once
            //    for the whole batch; a lone lane runs the inline solo
            //    path (operator splitting cannot beat it at width 1)
            if n_batched > 0 {
                let mut lanes: Vec<BatchLane> = states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                    .filter(|(i, _)| is_batched(&chunks[*i]))
                    .map(|(i, (st, out))| BatchLane {
                        state: st,
                        token: chunks[i].tokens[0],
                        logits: out,
                    })
                    .collect();
                if let [lane] = &mut lanes[..] {
                    // a lone decode lane takes the solo step verbatim —
                    // no batch-scratch gather/scatter, no pool
                    model.decode_step_into(lane.state, lane.token, mode, lane.logits);
                } else {
                    model.decode_steps_into(
                        &mut lanes,
                        mode,
                        &mut batch_scratch,
                        worker_pool.as_ref(),
                    );
                }
            }
            step_ms.push(ts.elapsed().as_secs_f64() * 1e3);

            // weight-streaming accounting: the batched decode group pays
            // one layer-stack weight pass regardless of its width; a
            // prefill lane pays one per chunk token (prefill_into runs
            // the per-token QKV/O/MLP GEMVs for every token it feeds)
            let prefill_passes: u64 = chunks
                .iter()
                .filter(|c| c.active && !is_batched(c))
                .map(|c| c.tokens.len() as u64)
                .sum();
            weight_passes += prefill_passes + u64::from(n_batched > 0);
            if n_batched > 0 {
                batch_widths.push(n_batched as f64);
            }

            // simulated accelerator cost: a chunked iteration is billed
            // one simulated decode step per consumed token position —
            // lanes run in lockstep, so the batch pays the longest chunk
            // at the largest live context, token by token. With fed == 1
            // everywhere this reduces exactly to the old
            // one-simulate_token-per-iteration accounting.
            let max_fed = chunks
                .iter()
                .filter(|c| c.active)
                .map(|c| c.tokens.len())
                .max()
                .unwrap_or(1);
            let base_ctx = chunks
                .iter()
                .filter(|c| c.active)
                .map(|c| c.pos)
                .max()
                .unwrap_or(0);
            for k in 1..=max_fed {
                let sim = layer_sched::simulate_token(&arch, &self.opts.sim_model, base_ctx + k);
                sim_cycles += sim.total_cycles;
            }

            // greedy sample — only for lanes whose chunk ended on a
            // sampling position; idle lanes and mid-prompt prefill
            // chunks skip the argmax entirely (their logits are stale
            // or were never computed)
            let samples: Vec<u32> = (0..lanes)
                .map(|i| {
                    if sampling[i] {
                        argmax(&logits[i * vocab..(i + 1) * vocab]) as u32
                    } else {
                        0
                    }
                })
                .collect();
            let retired = batcher.scatter_chunk_outputs(&fed, &samples, iteration);
            if !retired.is_empty() {
                // reclaim at retirement, not at the lane's next admission:
                // an idle lane must not pin a dead sequence's blocks while
                // other lanes grow (a lane inactive after scatter has no
                // session, so its blocks are unreachable)
                let (_, _, still_active) = batcher.gather_inputs();
                for (i, st) in states.iter_mut().enumerate() {
                    if was_active[i] && !still_active[i] && st.pos != 0 {
                        st.reset_for_reuse();
                    }
                }
            }
            iter_end_ms.push(t0.elapsed().as_secs_f64() * 1e3);

            iteration += 1;
            if self.opts.max_iterations > 0 && iteration >= self.opts.max_iterations {
                break;
            }
        }

        // retire the lane states: every block returns to the pool (the
        // Drop impl covers panicking paths; this makes it explicit and
        // lets callers assert full reclamation on the returned pool)
        drop(states);
        debug_assert_eq!(kv_pool.free_blocks(), kv_pool.total_blocks());

        let wall_s = t0.elapsed().as_secs_f64();
        // admission accounting must reach the metrics: a rejected
        // (oversized) request is dropped by design, never silently
        let (requests_admitted, requests_rejected) = batcher.counters();
        let sessions = batcher.finished;
        let total_tokens: usize = sessions.iter().map(|s| s.generated.len()).sum();
        let at_ms = |it: u64| -> f64 {
            iter_end_ms
                .get(it as usize)
                .copied()
                .unwrap_or(wall_s * 1e3)
        };
        let latencies: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.finished_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();
        let ttfts: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.first_token_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();

        let zero = Percentiles {
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
            mean: 0.0,
            max: 0.0,
        };
        let sim_ms = arch.cycles_to_ms(sim_cycles);
        let metrics = ServeMetrics {
            requests: sessions.len(),
            requests_admitted,
            requests_rejected,
            total_tokens_generated: total_tokens,
            iterations: iteration,
            wall_s,
            step_ms: Percentiles::compute(&step_ms).unwrap_or(zero),
            request_latency_ms: Percentiles::compute(&latencies).unwrap_or(zero),
            ttft_ms: Percentiles::compute(&ttfts).unwrap_or(zero),
            mean_occupancy: if iteration > 0 {
                occupancy_acc / iteration as f64
            } else {
                0.0
            },
            batch_width: Percentiles::compute(&batch_widths).unwrap_or(zero),
            weight_passes,
            weight_passes_per_step: if iteration > 0 {
                weight_passes as f64 / iteration as f64
            } else {
                0.0
            },
            tokens_per_s: if wall_s > 0.0 {
                total_tokens as f64 / wall_s
            } else {
                0.0
            },
            simulated_accel_ms: sim_ms,
            simulated_tokens_per_s: if sim_ms > 0.0 {
                total_tokens as f64 / (sim_ms / 1e3)
            } else {
                0.0
            },
        };
        CpuServeReport {
            sessions,
            metrics,
            kv_pool,
        }
    }
}
