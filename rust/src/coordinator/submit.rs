//! The redesigned submission API: submit → per-request token stream →
//! final [`SessionOutcome`].
//!
//! A [`ServeHandle`] is the only way work enters a running continuous
//! engine ([`super::cpu::CpuServer::serve_continuous`]): callers submit
//! a [`crate::model::Request`] and get back a [`PendingRequest`] — a
//! per-request stream of [`TokenEvent`]s that ends with the request's
//! final outcome. The handle is cheap to clone (one clone per HTTP
//! connection thread, one per load-generator worker); dropping every
//! clone closes the engine's intake, which lets it drain and retire.
//!
//! The engine stays runtime-agnostic behind this surface: events ride
//! plain `std::sync::mpsc` channels, so the same handle serves the
//! blocking offline path, thread-per-connection HTTP/SSE, or any async
//! runtime a caller wants to bridge from.

use super::session::SessionOutcome;
use crate::model::Request;
use std::sync::mpsc::{Receiver, Sender};

/// One event on a request's output stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenEvent {
    /// One generated token, in generation order. Tokens are emitted as
    /// they are sampled; a preempted-and-requeued request re-decodes
    /// bit-identically, so already-streamed positions are never re-sent.
    Token(u32),
    /// The request retired with this outcome. Always the stream's last
    /// event (when the engine survives long enough to send it).
    Done(SessionOutcome),
}

/// One unit of work on the engine's intake channel: the request plus
/// (for streaming submitters) the sender half of its event stream.
pub(crate) struct Submission {
    pub(crate) request: Request,
    pub(crate) events: Option<Sender<TokenEvent>>,
}

/// Why a submission failed to enter the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The engine's intake is gone — the serving loop has exited (hit
    /// `max_iterations`, or the scope is shutting down).
    EngineClosed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EngineClosed => write!(f, "engine closed: serving loop has exited"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Submission handle onto a running continuous engine. Clone freely —
/// every clone feeds the same lane array; the engine's intake closes
/// when the last clone drops.
#[derive(Clone)]
pub struct ServeHandle {
    tx: Sender<Submission>,
}

impl ServeHandle {
    pub(crate) fn new(tx: Sender<Submission>) -> ServeHandle {
        ServeHandle { tx }
    }

    /// Submit a request and stream its output. The request joins the
    /// admission queue mid-flight — it takes a lane as soon as its
    /// `arrival_ms` has passed and a lane is free, with no drain
    /// barrier. Oversized requests are not an error here: their stream
    /// reports [`SessionOutcome::Rejected`] as its only event.
    pub fn submit(&self, request: Request) -> Result<PendingRequest, SubmitError> {
        let id = request.id;
        let (etx, erx) = std::sync::mpsc::channel();
        self.tx
            .send(Submission {
                request,
                events: Some(etx),
            })
            .map_err(|_| SubmitError::EngineClosed)?;
        Ok(PendingRequest { id, rx: erx })
    }

    /// Submit without an event stream: the request's tokens and outcome
    /// are only observable through the engine's final
    /// [`super::cpu::CpuServeReport`] (the offline path).
    pub fn submit_nowait(&self, request: Request) -> Result<(), SubmitError> {
        self.tx
            .send(Submission {
                request,
                events: None,
            })
            .map_err(|_| SubmitError::EngineClosed)
    }
}

/// The receiving half of one submitted request: a blocking stream of
/// [`TokenEvent`]s ending in [`TokenEvent::Done`].
pub struct PendingRequest {
    id: u64,
    rx: Receiver<TokenEvent>,
}

impl PendingRequest {
    /// The submitted request's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the stream is over (after
    /// `Done`, or if the engine died without retiring the request).
    pub fn next_event(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Block until the request retires, collecting its tokens. An
    /// engine that exits without retiring the request (e.g. a
    /// `max_iterations` cap) yields a `Failed` outcome rather than a
    /// hang or a panic.
    pub fn wait(self) -> FinishedRequest {
        let mut tokens = Vec::new();
        loop {
            match self.rx.recv() {
                Ok(TokenEvent::Token(t)) => tokens.push(t),
                Ok(TokenEvent::Done(outcome)) => {
                    return FinishedRequest {
                        id: self.id,
                        tokens,
                        outcome,
                    }
                }
                Err(_) => {
                    return FinishedRequest {
                        id: self.id,
                        tokens,
                        outcome: SessionOutcome::Failed(
                            "engine terminated before the request finished".to_string(),
                        ),
                    }
                }
            }
        }
    }
}

/// A retired request as seen through the submission API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinishedRequest {
    pub id: u64,
    /// Every token streamed before retirement (the full generation for
    /// `Completed`, a prefix for failures).
    pub tokens: Vec<u32>,
    pub outcome: SessionOutcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_collects_tokens_then_outcome() {
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = ServeHandle::new(tx);
        let pending = handle
            .submit(Request::new(7, vec![1, 2]).gen_len(3))
            .expect("intake open");
        assert_eq!(pending.id(), 7);
        // play the engine side
        let sub = rx.recv().expect("submission arrives");
        assert_eq!(sub.request.id, 7);
        let events = sub.events.expect("streaming submission carries a sink");
        for t in [10u32, 11, 12] {
            events.send(TokenEvent::Token(t)).expect("receiver alive");
        }
        events
            .send(TokenEvent::Done(SessionOutcome::Completed))
            .expect("receiver alive");
        let fin = pending.wait();
        assert_eq!(fin.tokens, vec![10, 11, 12]);
        assert!(fin.outcome.is_completed());
    }

    #[test]
    fn engine_death_maps_to_failed_outcome() {
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = ServeHandle::new(tx);
        let pending = handle.submit(Request::new(0, vec![1])).expect("intake open");
        let sub = rx.recv().expect("submission arrives");
        let events = sub.events.expect("sink");
        events.send(TokenEvent::Token(5)).expect("receiver alive");
        drop(events); // engine dies without sending Done
        let fin = pending.wait();
        assert_eq!(fin.tokens, vec![5]);
        assert!(
            matches!(&fin.outcome, SessionOutcome::Failed(m) if m.contains("engine terminated")),
            "got {:?}",
            fin.outcome
        );
    }

    #[test]
    fn submit_after_engine_exit_errors() {
        let (tx, rx) = std::sync::mpsc::channel::<Submission>();
        let handle = ServeHandle::new(tx);
        drop(rx);
        assert_eq!(
            handle.submit(Request::new(0, vec![1])).err(),
            Some(SubmitError::EngineClosed)
        );
        assert_eq!(
            handle.submit_nowait(Request::new(1, vec![1])),
            Err(SubmitError::EngineClosed)
        );
    }
}
