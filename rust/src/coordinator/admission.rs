//! Admission control: bounded intake with an explicit shedding policy.
//!
//! Under sustained overload the engine must refuse work it cannot serve
//! instead of queuing unboundedly — VEDA's eviction-under-pressure
//! framing (PAPERS.md), applied one stage earlier: shed before a
//! request ever holds KV blocks, and tell the client when to come back.
//!
//! The policy is deliberately simple and fully deterministic:
//!
//! - **Queue-depth cap.** When the admission queue holds
//!   `max_queue_depth` requests, new arrivals are shed with a
//!   `Retry-After` hint derived from the engine's observed step time.
//!   Fairness is *oldest-first*: queued requests keep their FIFO
//!   positions and new arrivals are tail-dropped, so under sustained
//!   pressure the oldest waiting request is always the next served and
//!   no request can be starved by later arrivals.
//! - **Deadline-aware early rejection.** A request whose wall-clock
//!   deadline has already passed — or provably cannot be met even if it
//!   started decoding *now* at the fastest step time the engine has
//!   ever observed — is rejected at the door rather than occupying
//!   queue and KV capacity it is guaranteed to waste. Only a
//!   lower-bound proof rejects; an optimistic request that *might* make
//!   it is admitted and left to the runtime deadline checker.

use crate::model::Request;

/// Online estimate of engine step latency, fed from the serve loop's
/// per-iteration timings. `min_ms` is the fastest step ever observed —
/// the lower bound the deadline proof uses; `mean_ms` sizes the
/// `Retry-After` hint.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepEstimate {
    /// Fastest observed step, milliseconds (0 until the first sample).
    pub min_ms: f64,
    /// Running mean step time, milliseconds.
    pub mean_ms: f64,
    /// Samples folded in so far.
    pub n: u64,
}

impl StepEstimate {
    /// Fold in one measured engine-step duration.
    pub fn record(&mut self, step_ms: f64) {
        if !step_ms.is_finite() || step_ms < 0.0 {
            return;
        }
        if self.n == 0 || step_ms < self.min_ms {
            self.min_ms = step_ms;
        }
        self.n += 1;
        self.mean_ms += (step_ms - self.mean_ms) / self.n as f64;
    }
}

/// What admission control decided for one arriving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Join the queue.
    Admit,
    /// Queue at cap (or engine draining): shed now, retry after the
    /// hinted backoff.
    Shed {
        /// Suggested client backoff, milliseconds (the front door
        /// rounds this up to whole seconds for `Retry-After`).
        retry_after_ms: u64,
    },
    /// The request provably cannot meet its `deadline_ms` even if it
    /// started immediately — reject without queuing.
    DeadlineUnmeetable,
}

/// The shedding policy: a queue-depth cap plus the deadline lower-bound
/// proof. `max_queue_depth == 0` disables the cap (unbounded intake,
/// the pre-overload-layer behavior).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    pub max_queue_depth: usize,
}

/// Fallback `Retry-After` before the engine has timed a single step.
const RETRY_COLD_MS: u64 = 50;
/// Clamp for the retry hint: at least 10ms (a meaningful backoff), at
/// most 10s (never tell a client to go away for longer than a human
/// would wait).
const RETRY_MIN_MS: u64 = 10;
const RETRY_MAX_MS: u64 = 10_000;

impl AdmissionPolicy {
    pub fn new(max_queue_depth: usize) -> AdmissionPolicy {
        AdmissionPolicy { max_queue_depth }
    }

    /// Decide one arriving request against the current queue depth and
    /// step-time estimate. `now_ms` is stream-relative wall clock (the
    /// same clock `arrival_ms`/`deadline_ms` are measured on).
    pub fn decide(
        &self,
        req: &Request,
        queue_depth: usize,
        now_ms: f64,
        est: &StepEstimate,
    ) -> AdmissionDecision {
        if req.deadline_ms > 0 {
            let deadline = (req.arrival_ms + req.deadline_ms) as f64;
            if deadline <= now_ms {
                return AdmissionDecision::DeadlineUnmeetable;
            }
            // Lower-bound proof: even starting now, on a free lane, at
            // the fastest step the engine has ever run, the request
            // needs ≥ gen_len steps to finish (prefill chunks add more;
            // ignoring them keeps this a true lower bound).
            if est.n > 0 && now_ms + req.gen_len as f64 * est.min_ms > deadline {
                return AdmissionDecision::DeadlineUnmeetable;
            }
        }
        if self.max_queue_depth > 0 && queue_depth >= self.max_queue_depth {
            return AdmissionDecision::Shed {
                retry_after_ms: self.retry_after_ms(queue_depth, est),
            };
        }
        AdmissionDecision::Admit
    }

    /// Size the backoff to the backlog: roughly the time the engine
    /// needs to work off the current queue (depth × mean step × a small
    /// multiplier for prefill and co-batching slack), clamped.
    pub fn retry_after_ms(&self, queue_depth: usize, est: &StepEstimate) -> u64 {
        if est.n == 0 {
            return RETRY_COLD_MS;
        }
        let hint = queue_depth as f64 * est.mean_ms * 8.0;
        (hint as u64).clamp(RETRY_MIN_MS, RETRY_MAX_MS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3]).gen_len(4)
    }

    fn warm() -> StepEstimate {
        let mut e = StepEstimate::default();
        e.record(2.0);
        e.record(4.0);
        e
    }

    #[test]
    fn step_estimate_tracks_min_and_mean() {
        let e = warm();
        assert_eq!(e.n, 2);
        assert!((e.min_ms - 2.0).abs() < 1e-9);
        assert!((e.mean_ms - 3.0).abs() < 1e-9);
        let mut p = warm();
        p.record(f64::NAN);
        p.record(-1.0);
        assert_eq!(p.n, 2, "non-finite and negative samples are ignored");
    }

    #[test]
    fn uncapped_policy_admits_under_any_depth() {
        let p = AdmissionPolicy::new(0);
        assert_eq!(
            p.decide(&req(0), 10_000, 0.0, &StepEstimate::default()),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn queue_cap_sheds_at_depth() {
        let p = AdmissionPolicy::new(2);
        let e = warm();
        assert_eq!(p.decide(&req(0), 1, 0.0, &e), AdmissionDecision::Admit);
        match p.decide(&req(1), 2, 0.0, &e) {
            AdmissionDecision::Shed { retry_after_ms } => {
                // 2 deep × 3ms mean × 8 = 48ms
                assert_eq!(retry_after_ms, 48);
            }
            other => panic!("expected shed at cap, got {other:?}"),
        }
    }

    #[test]
    fn cold_engine_uses_fallback_retry_hint() {
        let p = AdmissionPolicy::new(1);
        match p.decide(&req(0), 5, 0.0, &StepEstimate::default()) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 50),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn retry_hint_clamped_to_bounds() {
        let p = AdmissionPolicy::new(1);
        let mut slow = StepEstimate::default();
        slow.record(10_000.0);
        assert_eq!(p.retry_after_ms(100, &slow), 10_000, "upper clamp");
        let mut fast = StepEstimate::default();
        fast.record(0.001);
        assert_eq!(p.retry_after_ms(1, &fast), 10, "lower clamp");
    }

    #[test]
    fn passed_deadline_rejected_even_uncapped() {
        let p = AdmissionPolicy::new(0);
        let r = Request::new(0, vec![1]).gen_len(1).deadline_ms(10);
        // arrival 0 + deadline 10 ≤ now 10 → already dead
        assert_eq!(
            p.decide(&r, 0, 10.0, &StepEstimate::default()),
            AdmissionDecision::DeadlineUnmeetable
        );
    }

    #[test]
    fn provably_unmeetable_deadline_rejected() {
        let p = AdmissionPolicy::new(0);
        let e = warm(); // min step 2ms
        // 4 tokens × 2ms = 8ms lower bound, but only 5ms of budget left
        let r = Request::new(0, vec![1]).gen_len(4).deadline_ms(5);
        assert_eq!(p.decide(&r, 0, 0.0, &e), AdmissionDecision::DeadlineUnmeetable);
        // a cold engine has no proof — optimistically admit
        assert_eq!(
            p.decide(&r, 0, 0.0, &StepEstimate::default()),
            AdmissionDecision::Admit
        );
        // plenty of budget → admit
        let r2 = Request::new(1, vec![1]).gen_len(4).deadline_ms(1_000);
        assert_eq!(p.decide(&r2, 0, 0.0, &e), AdmissionDecision::Admit);
    }
}
