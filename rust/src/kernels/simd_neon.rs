//! NEON microkernels behind [`super::isa`] (aarch64).
//!
//! NEON is part of the aarch64 baseline, so [`super::isa::table_for`]
//! registers this table unconditionally on that architecture. The f32
//! entries are vectorized 4-wide; the Q15.17 and integer entries
//! deliberately reuse the scalar kernels — they are bit-exact by
//! definition, and this keeps the amount of unsafe code that CI can only
//! type-check (via `cargo check --target aarch64-unknown-linux-gnu`)
//! to the minimum. Widening them is a follow-up once an aarch64 runner
//! can execute the property suite.
//!
//! Numerics: [`dot_f32`] uses `vfmaq_f32` (FMA) — re-association
//! tolerance like the AVX2 kernel; `axpy`/`scale_axpy`/`scale` use
//! mul-then-add and are bit-identical to scalar.
//!
//! lint: hotpath

use std::arch::aarch64::*;

use super::isa::{Isa, KernelTable};

/// The NEON kernel table (see module docs for the numerics contract).
pub static TABLE: KernelTable = KernelTable {
    name: "neon",
    isa: Isa::Neon,
    dot_f32,
    axpy_f32,
    scale_axpy_f32,
    scale_f32,
    dot_fxp_wide: crate::fxp::vector::dot_wide_scalar,
    axpy_fxp: crate::fxp::vector::axpy_scalar,
    scale_axpy_fxp: crate::fxp::vector::scale_axpy_scalar,
    dot_i8: crate::quant::gemv::dot_i8_scalar,
    w4a8_col: crate::quant::gemv::w4a8_col_scalar,
};

fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: NEON is baseline on every aarch64 target this module
    // compiles for.
    unsafe { dot_f32_neon(a, b) }
}

/// # Safety
///
/// NEON must be available (baseline on aarch64). `a` and `b` must have
/// equal lengths (loops index only through `min(a.len(), b.len())`).
#[target_feature(enable = "neon")]
unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: every pointer offset is bounds-guarded — the vector loops
    // require `i + 8 <= n` / `i + 4 <= n` and the scalar tail `i < n`,
    // with `n = a.len()`.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }
}

fn axpy_f32(beta: f32, y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: NEON is baseline on aarch64.
    unsafe { axpy_f32_neon(beta, y, x) }
}

/// # Safety
///
/// NEON must be available (baseline on aarch64). `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "neon")]
unsafe fn axpy_f32_neon(beta: f32, y: &mut [f32], x: &[f32]) {
    // SAFETY: all loads/stores stay inside `y`/`x` — the vector loop
    // requires `i + 4 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let px = x.as_ptr();
        let vb = vdupq_n_f32(beta);
        let mut i = 0usize;
        while i + 4 <= n {
            // mul then add — NOT vfmaq — bit-identical to the scalar kernel
            let yv = vld1q_f32(py.add(i));
            let xv = vld1q_f32(px.add(i));
            vst1q_f32(py.add(i), vaddq_f32(yv, vmulq_f32(vb, xv)));
            i += 4;
        }
        while i < n {
            *py.add(i) += beta * *px.add(i);
            i += 1;
        }
    }
}

fn scale_axpy_f32(alpha: f32, y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    // SAFETY: NEON is baseline on aarch64.
    unsafe { scale_axpy_f32_neon(alpha, y, x) }
}

/// # Safety
///
/// NEON must be available (baseline on aarch64). `y` and `x` must have
/// equal lengths (loops index only through `min(y.len(), x.len())`).
#[target_feature(enable = "neon")]
unsafe fn scale_axpy_f32_neon(alpha: f32, y: &mut [f32], x: &[f32]) {
    // SAFETY: all loads/stores stay inside `y`/`x` — the vector loop
    // requires `i + 4 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let px = x.as_ptr();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            // mul then add (no FMA): bit-identical to `y[i] = alpha*y[i] + x[i]`
            let yv = vld1q_f32(py.add(i));
            let xv = vld1q_f32(px.add(i));
            vst1q_f32(py.add(i), vaddq_f32(vmulq_f32(va, yv), xv));
            i += 4;
        }
        while i < n {
            *py.add(i) = alpha * *py.add(i) + *px.add(i);
            i += 1;
        }
    }
}

fn scale_f32(alpha: f32, y: &mut [f32]) {
    // SAFETY: NEON is baseline on aarch64.
    unsafe { scale_f32_neon(alpha, y) }
}

/// # Safety
///
/// NEON must be available (baseline on aarch64).
#[target_feature(enable = "neon")]
unsafe fn scale_f32_neon(alpha: f32, y: &mut [f32]) {
    // SAFETY: all loads/stores stay inside `y` — the vector loop
    // requires `i + 4 <= n` and the tail `i < n`, with `n = y.len()`.
    unsafe {
        let n = y.len();
        let py = y.as_mut_ptr();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(py.add(i), vmulq_f32(va, vld1q_f32(py.add(i))));
            i += 4;
        }
        while i < n {
            *py.add(i) *= alpha;
            i += 1;
        }
    }
}
