//! Minimal HTTP/SSE front door over the continuous engine.
//!
//! `swiftkv serve --listen HOST:PORT` boots this: a hand-rolled
//! thread-per-connection HTTP server over [`std::net`] (no async
//! runtime, no framework — the only external dependency stays
//! `anyhow`). Each `POST /v1/generate` submits one request through the
//! shared [`ServeHandle`] and streams its tokens back as server-sent
//! events; the engine never learns HTTP exists, so the same engine
//! binary serves the offline path, this front door, or any runtime a
//! caller bridges from.
//!
//! Protocol:
//!
//! - `POST /v1/generate` with body
//!   `{"prompt": [1, 2, 3], "gen_len": 8, "deadline_ms": 0}` →
//!   `Content-Type: text/event-stream`, one `data: {"token": N}` event
//!   per generated token, then a final
//!   `data: {"done": true, "outcome": "completed"}` event. Failure
//!   outcomes carry a `"reason"` field. The status line is deferred
//!   until the engine's *first* event, so admission-control outcomes
//!   map to real HTTP statuses instead of a 200 that immediately
//!   fails: shed → `503` with a `Retry-After` header, oversized →
//!   `400`, provably-unmeetable deadline → `504`.
//! - `GET /healthz` → `200` with a queue-depth snapshot while serving,
//!   `503 {"state":"draining"}` once shutdown begins, and
//!   `503 {"state":"overloaded"}` while the admission queue sits at its
//!   cap — load balancers can stop routing before requests are shed.
//!
//! The request joins the engine **mid-flight**: it takes a lane as soon
//! as one frees, while other connections' requests keep decoding — no
//! drain barrier between HTTP requests.
//!
//! Overload hardening: every connection runs under read *and* write
//! timeouts (a stalled client cannot pin a connection thread past
//! them), and `Ctrl-C` (when [`HttpServerConfig::install_sigint`] is
//! set) turns into a graceful shutdown — the accept loop stops taking
//! connections, the engine sheds its queue and drains running lanes
//! under its `drain_ms` bound, and the process exits through the normal
//! pool-leak audit.

use super::cpu::{CpuServeReport, CpuServer, ServeConfig};
use super::session::SessionOutcome;
use super::submit::{ServeHandle, TokenEvent};
use crate::model::{Request, TinyModel};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Front-door configuration (the engine's own knobs live in
/// [`ServeConfig`]).
#[derive(Debug, Clone)]
pub struct HttpServerConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks a free port —
    /// the bound address reaches the caller through `on_ready`).
    pub listen: String,
    /// Shut the server down after this much wall time (ms); `0` = run
    /// until `max_requests` (or forever). CI's smoke job bounds runs
    /// with this.
    pub max_wall_ms: u64,
    /// Shut the server down after this many `/v1/generate` requests
    /// have finished streaming; `0` = unbounded. Tests use this for a
    /// deterministic shutdown.
    pub max_requests: u64,
    /// Per-connection socket read timeout, milliseconds (`0` = none).
    /// Bounds how long a connection thread can sit in a blocking read
    /// against a stalled client.
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout, milliseconds (`0` = none).
    /// A client that stops draining its SSE stream fails the write and
    /// the engine cancels its lane, instead of the connection thread
    /// blocking forever.
    pub write_timeout_ms: u64,
    /// Install a `SIGINT` handler that converts `Ctrl-C` into a
    /// graceful shutdown (stop admission, drain lanes, exit through the
    /// pool audit). The CLI turns this on; tests leave it off — a
    /// process-global signal handler does not belong in a test harness.
    pub install_sigint: bool,
}

impl Default for HttpServerConfig {
    fn default() -> Self {
        HttpServerConfig {
            listen: "127.0.0.1:8080".to_string(),
            max_wall_ms: 0,
            max_requests: 0,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            install_sigint: false,
        }
    }
}

/// `SIGINT` → graceful shutdown, with no signal-handling dependency:
/// the handler only sets a flag (the one thing that is async-signal
/// safe), and the accept loop polls it between accepts.
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static FIRED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        // only an atomic store: allocation, locking, or I/O here would
        // be undefined behavior in a signal handler
        FIRED.store(true, Ordering::SeqCst);
    }

    type SigHandler = extern "C" fn(i32);
    extern "C" {
        /// POSIX `signal(2)` from the platform libc (already linked by
        /// `std`); the return value is the previous handler, which we
        /// never need to restore.
        fn signal(signum: i32, handler: SigHandler) -> usize;
    }

    /// `SIGINT` on every POSIX platform this crate targets.
    const SIGINT: i32 = 2;

    pub fn install() {
        // SAFETY: `signal` is the POSIX libc entry point; SIGINT is a
        // valid signal number and `on_sigint` is an `extern "C"`
        // function that only performs an atomic store, which is
        // async-signal-safe. Replacing the default handler for the
        // whole process is exactly the intent (opt-in via
        // `install_sigint`).
        unsafe {
            signal(SIGINT, on_sigint);
        }
    }

    pub fn fired() -> bool {
        FIRED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}
    pub fn fired() -> bool {
        false
    }
}

/// What the front door saw over its lifetime, plus the engine's own
/// report (per-session outcomes, serving metrics, the KV pool for
/// reclamation asserts).
pub struct HttpServeReport {
    pub report: CpuServeReport,
    /// TCP connections accepted.
    pub connections: u64,
    /// `/v1/generate` requests that finished streaming (any outcome).
    pub requests_served: u64,
    /// The address actually bound (differs from `listen` for `:0`).
    pub local_addr: SocketAddr,
}

/// Run the continuous engine with an HTTP/SSE front door until the
/// configured bound (wall clock or request count) is reached.
/// `on_ready` fires once the socket is bound, with the live address —
/// the CLI prints it, tests connect to it.
pub fn serve_http(
    model: &TinyModel,
    cfg: ServeConfig,
    http: &HttpServerConfig,
    on_ready: impl FnOnce(SocketAddr),
) -> std::io::Result<HttpServeReport> {
    let listener = TcpListener::bind(&http.listen)?;
    let local_addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    on_ready(local_addr);

    let server = CpuServer::new(model, cfg);
    let vocab = model.vocab;
    let connections = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let next_id = AtomicU64::new(0);

    if http.install_sigint {
        sigint::install();
    }
    let (report, accept_result) = server.serve_continuous(|handle| {
        let t0 = Instant::now();
        std::thread::scope(|s| -> std::io::Result<()> {
            loop {
                if http.max_wall_ms > 0 && t0.elapsed() >= Duration::from_millis(http.max_wall_ms)
                {
                    break;
                }
                if http.max_requests > 0 && served.load(Ordering::SeqCst) >= http.max_requests {
                    break;
                }
                // Ctrl-C (or any caller's request_shutdown): stop
                // accepting, ask the engine to drain, and fall out to
                // the scope join — in-flight connections finish their
                // streams (each bounded by the engine's drain bound
                // plus its socket timeouts)
                if sigint::fired() || handle.status().is_draining() {
                    handle.request_shutdown();
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        connections.fetch_add(1, Ordering::SeqCst);
                        // Sender is !Sync: each connection thread gets
                        // its own clone of the handle
                        let conn_handle = handle.clone();
                        let served = &served;
                        let next_id = &next_id;
                        s.spawn(move || {
                            // a broken client connection is that
                            // client's problem, not the server's
                            let _ = handle_connection(
                                stream,
                                &conn_handle,
                                vocab,
                                next_id,
                                served,
                                http,
                            );
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(())
            // scope exit joins every in-flight connection thread (each
            // bounded by its stream's read/write timeouts)
        })
    });

    accept_result?;
    Ok(HttpServeReport {
        report,
        connections: connections.load(Ordering::SeqCst),
        requests_served: served.load(Ordering::SeqCst),
        local_addr,
    })
}

/// Read one HTTP/1.1 request (head capped at 16 KiB, body at 1 MiB).
fn read_request(stream: &mut TcpStream) -> std::io::Result<(String, String, Vec<u8>)> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        if buf.len() > 16 * 1024 {
            return Err(bad("request head too large"));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(bad("connection closed mid-request"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.lines();
    let request_line = lines.next().ok_or_else(|| bad("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| bad("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| bad("missing path"))?.to_string();
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().map_err(|_| bad("bad content-length"))?;
            }
        }
    }
    if content_len > 1024 * 1024 {
        return Err(bad("request body too large"));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(bad("connection closed mid-body"));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_len);
    Ok((method, path, body))
}

fn write_simple(stream: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// A JSON response with optional extra headers (each pre-formatted as
/// `Name: value`) — the shape `/healthz` and the shed 503 use.
fn write_json(
    stream: &mut TcpStream,
    status: &str,
    extra_headers: &[String],
    body: &str,
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {status}\r\n");
    for h in extra_headers {
        head.push_str(h);
        head.push_str("\r\n");
    }
    write!(
        stream,
        "{head}Content-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Parse a `/v1/generate` body into a [`Request`]. Validation happens
/// here because the engine trusts its inputs: an empty prompt or an
/// out-of-vocab token must bounce with a 400, not reach a lane.
fn parse_generate(body: &[u8], vocab: usize, id: u64) -> Result<Request, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let prompt_json = json
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or("missing \"prompt\" array")?;
    if prompt_json.is_empty() {
        return Err("\"prompt\" must not be empty".to_string());
    }
    let mut prompt = Vec::with_capacity(prompt_json.len());
    for t in prompt_json {
        let v = t.as_f64().ok_or("\"prompt\" tokens must be numbers")?;
        if v < 0.0 || v.fract() != 0.0 || v as usize >= vocab {
            return Err(format!("token {v} out of vocab (0..{vocab})"));
        }
        prompt.push(v as u32);
    }
    let gen_len = json.get("gen_len").and_then(Json::as_usize).unwrap_or(1);
    if gen_len == 0 {
        return Err("\"gen_len\" must be >= 1".to_string());
    }
    let deadline = json
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as u64;
    Ok(Request::new(id, prompt).gen_len(gen_len).deadline_ms(deadline))
}

fn sse_event(obj: BTreeMap<String, Json>) -> String {
    format!("data: {}\n\n", Json::Obj(obj))
}

fn outcome_event(outcome: &SessionOutcome) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("done".to_string(), Json::Bool(true));
    let label = match outcome {
        SessionOutcome::Completed => "completed",
        SessionOutcome::Failed(reason) => {
            obj.insert("reason".to_string(), Json::Str(reason.clone()));
            "failed"
        }
        SessionOutcome::DeadlineExpired => "deadline_expired",
        SessionOutcome::Rejected => "rejected",
        SessionOutcome::Cancelled => "cancelled",
        SessionOutcome::Shed => "shed",
    };
    obj.insert("outcome".to_string(), Json::Str(label.to_string()));
    sse_event(obj)
}

/// Serve `/healthz` from the engine's live status block: `503` while
/// draining or at the admission cap (load balancers stop routing before
/// requests are shed), `200` with a queue-depth snapshot otherwise.
fn write_healthz(stream: &mut TcpStream, handle: &ServeHandle) -> std::io::Result<()> {
    let status = handle.status();
    if status.is_draining() {
        return write_json(stream, "503 Service Unavailable", &[], "{\"state\":\"draining\"}");
    }
    if status.is_overloaded() {
        let retry = retry_after_secs(status.retry_after_ms());
        return write_json(
            stream,
            "503 Service Unavailable",
            &[format!("Retry-After: {retry}")],
            "{\"state\":\"overloaded\"}",
        );
    }
    let body = format!(
        "{{\"state\":\"ok\",\"queue_depth\":{},\"active_lanes\":{},\"shed_total\":{}}}",
        status.queue_depth(),
        status.active_lanes(),
        status.shed_total()
    );
    write_json(stream, "200 OK", &[], &body)
}

/// `Retry-After` is whole seconds; round the engine's ms hint up and
/// never tell a client "0" (which reads as "immediately retry, as hard
/// as you can").
fn retry_after_secs(ms: u64) -> u64 {
    ms.div_ceil(1000).max(1)
}

fn handle_connection(
    mut stream: TcpStream,
    handle: &ServeHandle,
    vocab: usize,
    next_id: &AtomicU64,
    served: &AtomicU64,
    http: &HttpServerConfig,
) -> std::io::Result<()> {
    // a stalled or dead client must not pin this thread (scope join at
    // shutdown waits for it): reads bound how long we wait for the
    // request, writes bound how long a full SSE send may stall
    if http.read_timeout_ms > 0 {
        stream.set_read_timeout(Some(Duration::from_millis(http.read_timeout_ms)))?;
    }
    if http.write_timeout_ms > 0 {
        stream.set_write_timeout(Some(Duration::from_millis(http.write_timeout_ms)))?;
    }
    stream.set_nonblocking(false)?;
    let (method, path, body) = read_request(&mut stream)?;
    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => write_healthz(&mut stream, handle),
        ("POST", "/v1/generate") => {
            let id = next_id.fetch_add(1, Ordering::SeqCst);
            let request = match parse_generate(&body, vocab, id) {
                Ok(r) => r,
                Err(msg) => return write_simple(&mut stream, "400 Bad Request", &msg),
            };
            let pending = match handle.submit(request) {
                Ok(p) => p,
                Err(_) => {
                    return write_simple(&mut stream, "503 Service Unavailable", "engine closed")
                }
            };
            // defer the status line until the engine's first event, so
            // admission outcomes become real HTTP statuses: a shed
            // request gets `503 + Retry-After`, not a 200 SSE stream
            // whose only event is a failure
            let first = match pending.next_event() {
                Some(ev) => ev,
                // engine died before retiring the request
                None => return write_simple(&mut stream, "500 Internal Server Error", "engine terminated"),
            };
            match &first {
                TokenEvent::Done(SessionOutcome::Shed) => {
                    let retry = retry_after_secs(handle.status().retry_after_ms());
                    let r = write_json(
                        &mut stream,
                        "503 Service Unavailable",
                        &[format!("Retry-After: {retry}")],
                        "{\"state\":\"shed\",\"retry\":true}",
                    );
                    served.fetch_add(1, Ordering::SeqCst);
                    return r;
                }
                TokenEvent::Done(SessionOutcome::Rejected) => {
                    let r = write_simple(
                        &mut stream,
                        "400 Bad Request",
                        "request rejected: prompt + gen_len exceed engine capacity",
                    );
                    served.fetch_add(1, Ordering::SeqCst);
                    return r;
                }
                TokenEvent::Done(SessionOutcome::DeadlineExpired) => {
                    let r = write_simple(
                        &mut stream,
                        "504 Gateway Timeout",
                        "deadline unmeetable or expired before decoding began",
                    );
                    served.fetch_add(1, Ordering::SeqCst);
                    return r;
                }
                TokenEvent::Done(SessionOutcome::Failed(reason)) => {
                    let r = write_simple(&mut stream, "500 Internal Server Error", reason);
                    served.fetch_add(1, Ordering::SeqCst);
                    return r;
                }
                // a token (the normal case), or a zero-token terminal
                // outcome that still reads as a stream — fall through to
                // SSE
                TokenEvent::Token(_) | TokenEvent::Done(_) => {}
            }
            write!(
                stream,
                "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
            )?;
            stream.flush()?;
            let mut event = Some(first);
            while let Some(ev) = event {
                match ev {
                    TokenEvent::Token(t) => {
                        let mut obj = BTreeMap::new();
                        obj.insert("token".to_string(), Json::Num(t as f64));
                        stream.write_all(sse_event(obj).as_bytes())?;
                        stream.flush()?;
                    }
                    TokenEvent::Done(outcome) => {
                        stream.write_all(outcome_event(&outcome).as_bytes())?;
                        stream.flush()?;
                        break;
                    }
                }
                event = pending.next_event();
            }
            served.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
        _ => write_simple(&mut stream, "404 Not Found", "not found\n"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NumericsMode;

    fn tiny() -> TinyModel {
        TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48)
    }

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").expect("write");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read");
        out
    }

    fn http_post(addr: SocketAddr, path: &str, body: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(
            s,
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .expect("write");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read");
        out
    }

    #[test]
    fn sse_stream_matches_solo_decode() {
        let model = tiny();
        let cfg = ServeConfig::builder()
            .lanes(2)
            .workers(1)
            .build()
            .expect("valid config");
        let prompt = vec![1u32, 2, 3];
        let gen_len = 4;
        let expect = model.generate(&prompt, gen_len, NumericsMode::DesktopF32);

        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        std::thread::scope(|s| {
            let client = s.spawn(move || {
                let addr: SocketAddr = addr_rx.recv().expect("server binds");
                let health = http_get(addr, "/healthz");
                assert!(health.contains("200 OK"), "{health}");
                assert!(
                    health.contains("\"state\":\"ok\"") && health.contains("\"queue_depth\""),
                    "healthz serves the live status snapshot: {health}"
                );
                assert!(http_post(addr, "/v1/generate", "{not json").contains("400"));
                assert!(
                    http_post(addr, "/v1/generate", "{\"prompt\": []}").contains("400"),
                    "empty prompt must bounce at the front door"
                );
                let resp =
                    http_post(addr, "/v1/generate", "{\"prompt\": [1, 2, 3], \"gen_len\": 4}");
                assert!(resp.contains("text/event-stream"), "{resp}");
                assert!(resp.contains("\"done\":true"), "{resp}");
                assert!(resp.contains("\"outcome\":\"completed\""), "{resp}");
                resp
            });
            let http_cfg = HttpServerConfig {
                listen: "127.0.0.1:0".to_string(),
                max_wall_ms: 60_000, // backstop; max_requests ends the run
                max_requests: 1,
                ..HttpServerConfig::default()
            };
            let rep = serve_http(&model, cfg, &http_cfg, |addr| {
                addr_tx.send(addr).expect("test alive");
            })
            .expect("serve");
            let resp = client.join().expect("client thread");
            // the streamed tokens are the solo generate() tokens, in order
            let streamed: Vec<u32> = resp
                .lines()
                .filter_map(|l| l.strip_prefix("data: "))
                .filter_map(|l| Json::parse(l).ok())
                .filter_map(|j| j.get("token").and_then(Json::as_f64).map(|t| t as u32))
                .collect();
            assert_eq!(streamed, expect, "SSE stream must be bit-exact");
            assert_eq!(rep.requests_served, 1);
            assert!(rep.connections >= 3, "health + 2 bad + 1 good");
            assert_eq!(rep.report.metrics.requests, 1);
            assert!(rep.report.sessions[0].outcome.is_completed());
            // full KV reclamation after the front door shuts down
            assert_eq!(
                rep.report.kv_pool.free_blocks(),
                rep.report.kv_pool.total_blocks()
            );
        });
    }

    #[test]
    fn unmeetable_deadline_maps_to_504_not_sse() {
        let model = tiny();
        let cfg = ServeConfig::builder()
            .lanes(1)
            .workers(1)
            .build()
            .expect("valid config");
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        std::thread::scope(|s| {
            s.spawn(move || {
                let addr: SocketAddr = addr_rx.recv().expect("server binds");
                // let the engine clock advance well past the 1ms
                // deadline below: admission's "already dead" check is
                // then unambiguous
                std::thread::sleep(Duration::from_millis(30));
                let resp = http_post(
                    addr,
                    "/v1/generate",
                    "{\"prompt\": [1, 2], \"gen_len\": 2, \"deadline_ms\": 1}",
                );
                assert!(resp.contains("504"), "expected 504, got: {resp}");
                assert!(!resp.contains("text/event-stream"), "{resp}");
            });
            let http_cfg = HttpServerConfig {
                listen: "127.0.0.1:0".to_string(),
                max_wall_ms: 60_000,
                max_requests: 1,
                ..HttpServerConfig::default()
            };
            let rep = serve_http(&model, cfg, &http_cfg, |addr| {
                addr_tx.send(addr).expect("test alive");
            })
            .expect("serve");
            assert_eq!(rep.requests_served, 1);
            assert_eq!(rep.report.metrics.deadline_rejected, 1);
            assert_eq!(
                rep.report.sessions[0].outcome,
                SessionOutcome::DeadlineExpired
            );
            assert_eq!(
                rep.report.kv_pool.free_blocks(),
                rep.report.kv_pool.total_blocks()
            );
        });
    }
}
