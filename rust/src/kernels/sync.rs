//! Sync-primitive alias layer for model checking.
//!
//! The pool ([`super::pool`]) and the paged-KV free list
//! ([`super::paged`]) import every synchronization primitive from this
//! module instead of `std`. A normal build re-exports `std` types
//! one-for-one (zero cost — they are the same items). A `--cfg loom`
//! build swaps in the instrumented twins from [`crate::util::mc`], so
//! `rust/tests/loom_pool.rs` can exhaustively model-check the epoch
//! publication / park / wake / panic choreography and the free-list
//! grant/release protocol without touching the production source.
//!
//! Under `--cfg loom`, code using these primitives must run inside a
//! [`crate::util::mc::model`] closure (the CI loom job builds only the
//! `loom_pool` test target, so the rest of the test suite never meets
//! the instrumented types).

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::mc::sync::{Arc, Condvar, Mutex, MutexGuard};

/// `std::sync::atomic` (or the instrumented subset under `--cfg loom`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use crate::util::mc::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// The `std::thread` surface the pool uses (spawn / yield / join).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use crate::util::mc::thread::{spawn, yield_now, JoinHandle};
}

/// Busy-wait hint; a no-op under the model checker.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub use crate::util::mc::thread::spin_loop;
}
