"""Layer-1 Pallas kernel: W4A8 GEMV (INT8 activation x INT4 weight).

Models the GEMV mode of the SKV Processor Array (Fig. 5): the input vector
is split across processors, each multiplies its chunk against the resident
weight slice with INT32 accumulation, and partial sums are reduced
(EM-Add in the SFU) and dequantized on writeback.

On TPU the chunk-per-processor mapping becomes a grid walk over output
tiles with the full reduction dimension resident per step (decode GEMV is
memory-bound; one pass over the weights is the optimal schedule). INT4 is
carried in int8 lanes (values in [-8, 7]) — the packing is a storage
detail the Rust quant module handles bit-exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_OUT = 128


def _gemv_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref):
    x = x_ref[0, :].astype(jnp.int32)          # [din]
    w = w_ref[...].astype(jnp.int32)           # [din, block_out]
    acc = jax.lax.dot_general(
        x[None, :], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)[0]   # [block_out] INT32 partial
    o_ref[0, :] = acc.astype(jnp.float32) * xs_ref[0, 0] * ws_ref[0, :]


@functools.partial(jax.jit, static_argnames=("block_out",))
def gemv_w4a8_batched(x_q: jax.Array, x_scale: jax.Array,
                      w_q: jax.Array, w_scale: jax.Array, *,
                      block_out: int = DEFAULT_BLOCK_OUT) -> jax.Array:
    """Batched quantized GEMV.

    x_q: [B, din] int8; x_scale: [B] f32 per-row activation scales;
    w_q: [din, dout] int8 (int4 values); w_scale: [dout] f32.
    Returns [B, dout] f32. Grid = (batch row, output tile).
    """
    bsz, din = x_q.shape
    dout = w_q.shape[1]
    while dout % block_out != 0:
        block_out //= 2          # fall back to the largest dividing tile
        if block_out == 0:
            raise ValueError(f"no power-of-two tile divides dout {dout}")
    nb = dout // block_out

    return pl.pallas_call(
        _gemv_kernel,
        grid=(bsz, nb),
        in_specs=[
            pl.BlockSpec((1, din), lambda i, j: (i, 0)),           # x row
            pl.BlockSpec((din, block_out), lambda i, j: (0, j)),   # w tile
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),             # x_scale
            pl.BlockSpec((1, block_out), lambda i, j: (0, j)),     # w_scale
        ],
        out_specs=pl.BlockSpec((1, block_out), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dout), jnp.float32),
        interpret=True,
    )(x_q, w_q, x_scale.reshape(-1, 1), w_scale.reshape(1, -1))


def gemv_w4a8(x_q: jax.Array, x_scale: jax.Array,
              w_q: jax.Array, w_scale: jax.Array, *,
              block_out: int = DEFAULT_BLOCK_OUT) -> jax.Array:
    """Single-vector quantized GEMV: x_q [din] -> [dout] f32."""
    out = gemv_w4a8_batched(x_q.reshape(1, -1), x_scale.reshape(1),
                            w_q, w_scale, block_out=block_out)
    return out[0]
