//! SKV Processor Array cycle model (Fig. 5): dual-mode GEMV / attention.

use super::ArchConfig;

/// GEMV mode: INT8 input × INT4 weights. The input vector is split into
/// `n_processors` chunks of `int_lanes` dims; one array pass reduces
/// `gemv_width()` dims per cycle, producing one output element per cycle
/// via pipelining (partial sums EM-Added in the SFU).
///
/// `din`-dim input, `dout` output elements → `ceil(din/width) · dout`
/// steady-state cycles plus the pipeline fill.
pub fn gemv_cycles(arch: &ArchConfig, din: usize, dout: usize) -> u64 {
    assert!(din >= 1 && dout >= 1);
    let passes = din.div_ceil(arch.gemv_width()) as u64;
    let fill = arch.dot_latency + 2; // array pipeline + EM-Add tree
    passes * dout as u64 + fill
}

/// Attention mode: each SKV processor runs one head's single-pass SwiftKV
/// attention independently (FXP32, 32-dim dot per cycle → `qk_ii` cycles
/// per token). Heads beyond `n_processors` serialize in rounds.
pub fn attention_cycles(arch: &ArchConfig, n_heads: usize, d_head: usize, len: usize) -> u64 {
    assert!(n_heads >= 1 && len >= 1);
    let ii = d_head.div_ceil(arch.fxp_lanes()) as u64;
    let fill = arch.dot_latency + 1 + arch.exp_latency + arch.mul_latency;
    let finalize = arch.div_latency + ii;
    let per_head = ii * len as u64 + fill + finalize;
    let rounds = n_heads.div_ceil(arch.n_processors) as u64;
    rounds * per_head
}

/// Decoder-RoPE cycles for one token (Fig. 6): the pair recurrence +
/// rotation is a 3-stage pipeline over `d_head/2` pairs, running in every
/// SKV unit in parallel (q and k rotate concurrently on separate
/// multiplier pairs).
pub fn rope_cycles(arch: &ArchConfig, d_head: usize) -> u64 {
    arch.rope_pair_latency + (d_head as u64 / 2).saturating_sub(1)
}

/// Peak GEMV throughput in GOPS (2 ops per MAC).
pub fn gemv_peak_gops(arch: &ArchConfig) -> f64 {
    2.0 * arch.gemv_width() as f64 * arch.clock_mhz * 1e6 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn gemv_4096_square_one_output_per_cycle() {
        // 4096-dim dot in a single pass → dout cycles + fill
        let c = gemv_cycles(&arch(), 4096, 4096);
        assert!((c as i64 - 4096).unsigned_abs() < 16, "{c}");
    }

    #[test]
    fn gemv_wide_input_multiple_passes() {
        // 11008-dim input needs ceil(11008/4096) = 3 passes per output
        let c = gemv_cycles(&arch(), 11008, 4096);
        assert!((c as i64 - 3 * 4096).unsigned_abs() < 16, "{c}");
    }

    #[test]
    fn attention_32_heads_parallel_4n() {
        // 32 heads fit the array → one round of ≈ 4N cycles (paper §IV-B)
        let c = attention_cycles(&arch(), 32, 128, 512);
        assert!((c as f64 - 2048.0).abs() < 60.0, "{c}");
    }

    #[test]
    fn attention_64_heads_two_rounds() {
        let one = attention_cycles(&arch(), 32, 128, 512);
        let two = attention_cycles(&arch(), 64, 128, 512);
        assert_eq!(two, 2 * one);
    }

    #[test]
    fn gqa_fewer_kv_heads_same_rounds() {
        // attention parallelism is over *query* heads
        let a = attention_cycles(&arch(), 32, 128, 256);
        let b = attention_cycles(&arch(), 24, 128, 256);
        assert_eq!(a, b); // both one round
    }

    #[test]
    fn rope_three_cycles_plus_pipeline() {
        // d=128 → 64 pairs → 3 + 63 = 66 cycles
        assert_eq!(rope_cycles(&arch(), 128), 66);
        // a single pair takes exactly the paper's 3 cycles
        assert_eq!(rope_cycles(&arch(), 2), 3);
    }

    #[test]
    fn peak_gops_near_paper_1836() {
        let g = gemv_peak_gops(&arch());
        assert!((g - 1836.0).abs() / 1836.0 < 0.01, "{g}");
    }
}
