//! Minimal loom-style model checker for the crate's hand-rolled
//! concurrency (the [`crate::kernels::pool::WorkerPool`] epoch/condvar
//! protocol and the [`crate::kernels::paged::BlockPool`] free list).
//!
//! The real `loom` crate cannot be vendored here (no registry access),
//! so this module implements the same *shape* of tool in-tree:
//!
//! - Instrumented sync primitives ([`sync::Mutex`], [`sync::Condvar`],
//!   [`sync::atomic`]) and threads ([`thread::spawn`]) that route every
//!   shared-memory operation through a cooperative scheduler.
//! - One runnable thread at a time (real OS threads serialized by a
//!   token-passing mutex/condvar pair), with a *scheduling point* before
//!   every instrumented operation.
//! - Exhaustive DFS over scheduling decisions with a bounded number of
//!   preemptions per execution (the `LOOM_MAX_PREEMPTIONS` knob), plus a
//!   randomized fallback sweep when the DFS is truncated by the
//!   execution budget.
//! - Deadlock detection (no runnable thread while some are blocked —
//!   this is also how lost condvar wakeups surface), livelock detection
//!   (per-execution step budget), and panic propagation (an unhandled
//!   panic on any model thread fails the whole exploration).
//!
//! **Scope and exclusions** (documented honestly — see EXPERIMENTS.md
//! §Static-analysis): the checker explores interleavings under
//! *sequential consistency*. It does not model weak-memory reorderings,
//! so `Acquire`/`Release` annotation bugs that only manifest as
//! hardware-level reordering are out of scope; Miri/TSan cover part of
//! that gap. Condvars never wake spuriously in the model (the code under
//! test must not *require* spurious wakeups — ours does not).
//!
//! Checked code opts in through the [`crate::kernels::sync`] alias
//! layer: a `--cfg loom` build resolves `Mutex`/`Condvar`/`Atomic*` to
//! the types here, and `rust/tests/loom_pool.rs` runs the pool protocols
//! under [`model`]. The checker itself is plain std Rust and is
//! unit-tested in every tier-1 run (it finds a seeded lost update, an
//! AB-BA deadlock, and a lost wakeup below).

use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, OnceLock, PoisonError};

/// Default cap on explored executions before the DFS is declared
/// truncated (overridable via `LOOM_MAX_EXECUTIONS`).
const DEFAULT_MAX_EXECUTIONS: usize = 10_000;
/// Default cap on context-switch preemptions per execution
/// (overridable via `LOOM_MAX_PREEMPTIONS`).
const DEFAULT_MAX_PREEMPTIONS: usize = 2;
/// Per-execution scheduling-point budget; exceeding it is reported as a
/// livelock (e.g. a spin loop whose exit condition can never be met).
const DEFAULT_MAX_STEPS: usize = 50_000;
/// Randomized executions appended when the DFS truncates.
const DEFAULT_RANDOM_ITERS: usize = 500;

/// Panic payload used internally to unwind model threads when an
/// execution is aborted (deadlock, failure elsewhere). Never observable
/// by user code: the thread wrappers catch it.
struct Abort;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    /// Waiting to acquire mutex `id`.
    BlockedMutex(usize),
    /// Waiting on condvar `id` (the mutex is released while blocked).
    BlockedCondvar(usize),
    /// Waiting for thread `tid` to finish.
    BlockedJoin(usize),
    Finished,
}

/// What kind of scheduling point the current thread reached.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Point {
    /// About to perform a shared-memory op; staying on the current
    /// thread is the default, switching costs a preemption.
    Progress,
    /// Voluntary yield: prefer switching (a forced switch keeps spin
    /// loops from being explored as livelocks); not a preemption.
    Yielded,
    /// The current thread just blocked; someone else must run.
    Blocked,
}

#[derive(Clone, Copy)]
enum StrategyKind {
    /// Beyond the replay script, always take option 0 (DFS order).
    Dfs,
    /// Beyond the replay script, pick pseudo-randomly.
    Random,
}

struct SchedState {
    status: Vec<Status>,
    /// The thread currently holding the execution token.
    current: usize,
    /// `mutexes[id]` is the holder, if any.
    mutexes: Vec<Option<usize>>,
    n_condvars: usize,
    /// Option count of every multi-option decision, in order (the DFS
    /// explorer turns this into its backtracking stack).
    trace: Vec<usize>,
    decisions: usize,
    preemptions: usize,
    steps: usize,
    live: usize,
    abort: bool,
    failure: Option<String>,
    rng: u64,
}

impl SchedState {
    fn next_choice(&mut self, script: &[usize], strategy: StrategyKind, n: usize) -> usize {
        let k = self.decisions;
        self.decisions += 1;
        let idx = if k < script.len() {
            let idx = script[k];
            assert!(
                idx < n,
                "mc internal error: nondeterministic model (replay decision \
                 {k} has {n} options, script wants {idx})"
            );
            idx
        } else {
            match strategy {
                StrategyKind::Dfs => 0,
                StrategyKind::Random => {
                    // xorshift64
                    let mut x = self.rng;
                    x ^= x << 13;
                    x ^= x >> 7;
                    x ^= x << 17;
                    self.rng = x;
                    (x % n as u64) as usize
                }
            }
        };
        self.trace.push(n);
        idx
    }
}

struct Runtime {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    /// Replay prefix: option index per multi-option decision.
    script: Vec<usize>,
    strategy: StrategyKind,
    max_steps: usize,
    max_preemptions: usize,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Runtime>, usize)>> = const { RefCell::new(None) };
    static IN_MODEL: Cell<bool> = const { Cell::new(false) };
}

fn ctx_opt() -> Option<(Arc<Runtime>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn ctx() -> (Arc<Runtime>, usize) {
    ctx_opt().expect("mc primitive used outside mc::model — run the code under util::mc::model")
}

/// Install (once, process-wide) a panic hook that suppresses output from
/// model threads: every explored failing interleaving would otherwise
/// print a full panic report, and panic-propagation tests intentionally
/// panic thousands of times.
fn install_quiet_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let quiet = IN_MODEL.with(Cell::get);
            if !quiet {
                prev(info);
            }
        }));
    });
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

type StateGuard<'a> = std::sync::MutexGuard<'a, SchedState>;

impl Runtime {
    fn new(script: Vec<usize>, strategy: StrategyKind, seed: u64, b: &Builder) -> Runtime {
        Runtime {
            state: StdMutex::new(SchedState {
                status: vec![Status::Runnable],
                current: 0,
                mutexes: Vec::new(),
                n_condvars: 0,
                trace: Vec::new(),
                decisions: 0,
                preemptions: 0,
                steps: 0,
                live: 1,
                abort: false,
                failure: None,
                rng: seed | 1,
            }),
            cv: StdCondvar::new(),
            script,
            strategy,
            max_steps: b.max_steps,
            max_preemptions: b.max_preemptions,
        }
    }

    fn lock_state(&self) -> StateGuard<'_> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record a failure, abort the execution, and wake every thread so
    /// the exploration can drain. Does not panic itself.
    fn fail_locked(&self, st: &mut SchedState, msg: String) {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.abort = true;
        self.cv.notify_all();
    }

    /// Abort the calling model thread if the execution failed elsewhere.
    fn check_abort(&self, st: StateGuard<'_>) -> StateGuard<'_> {
        if st.abort {
            drop(st);
            std::panic::panic_any(Abort);
        }
        st
    }

    fn describe(st: &SchedState) -> String {
        let mut parts = Vec::new();
        for (tid, s) in st.status.iter().enumerate() {
            parts.push(format!("t{tid}:{s:?}"));
        }
        parts.join(" ")
    }

    /// Pick the next thread to run at a scheduling point reached by
    /// `me`, record the decision, and hand over the token. Returns once
    /// `me` is runnable and scheduled again (immediately, when it keeps
    /// the token).
    fn reschedule(&self, me: usize, kind: Point) {
        let mut st = self.check_abort(self.lock_state());
        st.steps += 1;
        if st.steps > self.max_steps {
            let msg = format!(
                "livelock: {} scheduling points without completion (possible \
                 spin loop whose exit condition never becomes true) [{}]",
                self.max_steps,
                Self::describe(&st)
            );
            self.fail_locked(&mut st, msg);
            drop(st);
            std::panic::panic_any(Abort);
        }
        let others: Vec<usize> = (0..st.status.len())
            .filter(|&t| t != me && st.status[t] == Status::Runnable)
            .collect();
        let mut options = Vec::new();
        match kind {
            Point::Progress => {
                options.push(me);
                if st.preemptions < self.max_preemptions {
                    options.extend_from_slice(&others);
                }
            }
            Point::Yielded => {
                if others.is_empty() {
                    options.push(me);
                } else {
                    options.extend_from_slice(&others);
                }
            }
            Point::Blocked => {
                options.extend_from_slice(&others);
            }
        }
        if options.is_empty() {
            let msg = format!(
                "deadlock: no runnable thread (blocked threads can never be \
                 woken — a lost wakeup or lock cycle) [{}]",
                Self::describe(&st)
            );
            self.fail_locked(&mut st, msg);
            drop(st);
            std::panic::panic_any(Abort);
        }
        let chosen = if options.len() == 1 {
            options[0]
        } else {
            let idx = st.next_choice(&self.script, self.strategy, options.len());
            options[idx]
        };
        if kind == Point::Progress && chosen != me {
            st.preemptions += 1;
        }
        st.current = chosen;
        if chosen == me {
            return;
        }
        self.cv.notify_all();
        self.wait_for_token(st, me);
    }

    /// Park until `me` is runnable and holds the token.
    fn wait_for_token(&self, mut st: StateGuard<'_>, me: usize) {
        loop {
            if st.abort {
                drop(st);
                std::panic::panic_any(Abort);
            }
            if st.current == me && st.status[me] == Status::Runnable {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Scheduling point before a shared-memory operation.
    fn progress_point(&self, me: usize) {
        self.reschedule(me, Point::Progress);
    }

    fn alloc_mutex(&self) -> usize {
        let mut st = self.lock_state();
        st.mutexes.push(None);
        st.mutexes.len() - 1
    }

    fn alloc_condvar(&self) -> usize {
        let mut st = self.lock_state();
        st.n_condvars += 1;
        st.n_condvars - 1
    }

    fn lock_mutex(&self, me: usize, mid: usize) {
        self.progress_point(me);
        loop {
            let mut st = self.check_abort(self.lock_state());
            if st.mutexes[mid].is_none() {
                st.mutexes[mid] = Some(me);
                return;
            }
            // hand the token to someone who can make progress; we come
            // back runnable once the holder unlocks
            st.status[me] = Status::BlockedMutex(mid);
            drop(st);
            self.reschedule(me, Point::Blocked);
        }
    }

    fn unlock_mutex(&self, me: usize, mid: usize, during_unwind: bool) {
        let mut st = self.lock_state();
        debug_assert_eq!(st.mutexes[mid], Some(me), "unlock by non-holder");
        st.mutexes[mid] = None;
        for s in st.status.iter_mut() {
            if *s == Status::BlockedMutex(mid) {
                *s = Status::Runnable;
            }
        }
        if during_unwind || st.abort {
            // never raise a second panic out of a guard drop
            self.cv.notify_all();
            return;
        }
        drop(st);
        self.progress_point(me);
    }

    fn condvar_wait(&self, me: usize, cvid: usize, mid: usize) {
        let mut st = self.check_abort(self.lock_state());
        debug_assert_eq!(st.mutexes[mid], Some(me), "wait without the lock");
        st.mutexes[mid] = None;
        for s in st.status.iter_mut() {
            if *s == Status::BlockedMutex(mid) {
                *s = Status::Runnable;
            }
        }
        st.status[me] = Status::BlockedCondvar(cvid);
        drop(st);
        self.reschedule(me, Point::Blocked);
        // woken by a notify; reacquire the mutex like everyone else
        self.lock_mutex(me, mid);
    }

    fn notify(&self, me: usize, cvid: usize, all: bool) {
        let mut st = self.check_abort(self.lock_state());
        for s in st.status.iter_mut() {
            if *s == Status::BlockedCondvar(cvid) {
                *s = Status::Runnable;
                if !all {
                    break;
                }
            }
        }
        drop(st);
        self.progress_point(me);
    }

    fn register_thread(&self) -> usize {
        let mut st = self.lock_state();
        st.status.push(Status::Runnable);
        st.live += 1;
        st.status.len() - 1
    }

    fn join_thread(&self, me: usize, tid: usize) {
        self.progress_point(me);
        loop {
            let mut st = self.check_abort(self.lock_state());
            if st.status[tid] == Status::Finished {
                return;
            }
            st.status[me] = Status::BlockedJoin(tid);
            drop(st);
            self.reschedule(me, Point::Blocked);
        }
    }

    /// Called by every model thread's wrapper as its very last runtime
    /// interaction. `panic_msg` is `Some` when user code panicked out of
    /// the thread (an unjoined, model-level failure).
    fn finish_thread(&self, me: usize, panic_msg: Option<String>) {
        let mut st = self.lock_state();
        st.status[me] = Status::Finished;
        st.live -= 1;
        for s in st.status.iter_mut() {
            if *s == Status::BlockedJoin(me) {
                *s = Status::Runnable;
            }
        }
        if let Some(msg) = panic_msg {
            self.fail_locked(&mut st, format!("thread t{me} panicked: {msg}"));
            return;
        }
        if st.abort || st.live == 0 {
            self.cv.notify_all();
            return;
        }
        // hand the token on without waiting (we are gone)
        let options: Vec<usize> = (0..st.status.len())
            .filter(|&t| st.status[t] == Status::Runnable)
            .collect();
        if options.is_empty() {
            let msg = format!(
                "deadlock: thread t{me} finished but the remaining threads \
                 are all blocked [{}]",
                Self::describe(&st)
            );
            self.fail_locked(&mut st, msg);
            return;
        }
        let chosen = if options.len() == 1 {
            options[0]
        } else {
            let idx = st.next_choice(&self.script, self.strategy, options.len());
            options[idx]
        };
        st.current = chosen;
        self.cv.notify_all();
    }

    /// Block until a freshly spawned thread is first scheduled.
    fn wait_first_schedule(&self, me: usize) {
        let st = self.lock_state();
        self.wait_for_token(st, me);
    }

    /// Explorer side: wait for every model thread to finish.
    fn wait_done(&self) -> (Option<String>, Vec<usize>) {
        let mut st = self.lock_state();
        while st.live > 0 {
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        (st.failure.clone(), st.trace.clone())
    }
}

/// Instrumented replacements for `std::sync` used by checked code via
/// the [`crate::kernels::sync`] alias layer. **Only usable on threads
/// inside a [`model`] closure** (the atomics degrade gracefully to
/// their std behavior outside one; `Mutex`/`Condvar`/`thread::spawn`
/// panic).
pub mod sync {
    use super::{ctx, OnceLock, Runtime};
    use std::cell::UnsafeCell;
    use std::marker::PhantomData;
    pub use std::sync::Arc;
    use std::sync::LockResult;

    /// Model-checked mutex. Lock acquisition order is a scheduler
    /// decision; contended acquires block the model thread.
    pub struct Mutex<T> {
        data: UnsafeCell<T>,
        id: OnceLock<usize>,
    }

    // SAFETY: the scheduler serializes model threads and the guard
    // grants access only to the single holder, exactly like std's
    // Mutex; `T: Send` is required because the protected value is
    // accessed from whichever thread holds the lock.
    unsafe impl<T: Send> Send for Mutex<T> {}
    // SAFETY: as above — `&Mutex<T>` only yields `&T`/`&mut T` through
    // the holder-exclusive guard.
    unsafe impl<T: Send> Sync for Mutex<T> {}

    impl<T> std::fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("mc::Mutex { .. }")
        }
    }

    impl<T> Mutex<T> {
        pub fn new(value: T) -> Mutex<T> {
            Mutex {
                data: UnsafeCell::new(value),
                id: OnceLock::new(),
            }
        }

        fn id(&self, rt: &Runtime) -> usize {
            *self.id.get_or_init(|| rt.alloc_mutex())
        }

        /// Acquire the lock. Never poisoned in the model (a panicking
        /// execution aborts as a whole before poisoning matters), so
        /// this always returns `Ok` — the `unwrap_or_else` recovery
        /// idiom at the call sites compiles unchanged.
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            let (rt, me) = ctx();
            let mid = self.id(&rt);
            rt.lock_mutex(me, mid);
            Ok(MutexGuard {
                lock: self,
                _not_send: PhantomData,
            })
        }
    }

    /// Guard for [`Mutex`]; releases (and passes a scheduling point) on
    /// drop.
    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        /// Guards must stay on the locking thread (like std's).
        _not_send: PhantomData<*mut ()>,
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            // SAFETY: the scheduler granted this thread exclusive hold
            // of the mutex until the guard drops.
            unsafe { &*self.lock.data.get() }
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            // SAFETY: as in `deref` — exclusive hold until drop.
            unsafe { &mut *self.lock.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let (rt, me) = ctx();
            let mid = self.lock.id(&rt);
            rt.unlock_mutex(me, mid, std::thread::panicking());
        }
    }

    /// Model-checked condvar: wakeups are never spurious, and a waiter
    /// that can never be notified is reported as a deadlock.
    #[derive(Debug, Default)]
    pub struct Condvar {
        id: OnceLock<usize>,
    }

    impl Condvar {
        pub fn new() -> Condvar {
            Condvar { id: OnceLock::new() }
        }

        fn id(&self, rt: &Runtime) -> usize {
            *self.id.get_or_init(|| rt.alloc_condvar())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let (rt, me) = ctx();
            let cvid = self.id(&rt);
            let lock = guard.lock;
            let mid = lock.id(&rt);
            // the runtime releases and reacquires the mutex itself; the
            // guard's drop must not run in between
            std::mem::forget(guard);
            rt.condvar_wait(me, cvid, mid);
            Ok(MutexGuard {
                lock,
                _not_send: PhantomData,
            })
        }

        pub fn notify_all(&self) {
            let (rt, me) = ctx();
            let cvid = self.id(&rt);
            rt.notify(me, cvid, true);
        }

        pub fn notify_one(&self) {
            let (rt, me) = ctx();
            let cvid = self.id(&rt);
            rt.notify(me, cvid, false);
        }
    }

    /// Instrumented atomics: every access is a scheduling point. The
    /// `Ordering` argument is accepted for source compatibility; the
    /// model explores interleavings under sequential consistency only.
    pub mod atomic {
        use super::super::ctx_opt;
        pub use std::sync::atomic::Ordering;

        fn point() {
            if let Some((rt, me)) = ctx_opt() {
                rt.progress_point(me);
            }
        }

        macro_rules! mc_atomic {
            ($name:ident, $std:ty, $val:ty) => {
                #[derive(Debug, Default)]
                pub struct $name {
                    inner: $std,
                }

                impl $name {
                    pub const fn new(v: $val) -> $name {
                        $name { inner: <$std>::new(v) }
                    }

                    pub fn load(&self, o: Ordering) -> $val {
                        point();
                        self.inner.load(o)
                    }

                    pub fn store(&self, v: $val, o: Ordering) {
                        point();
                        self.inner.store(v, o)
                    }

                    pub fn swap(&self, v: $val, o: Ordering) -> $val {
                        point();
                        self.inner.swap(v, o)
                    }
                }
            };
        }

        mc_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
        mc_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        mc_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

        impl AtomicU64 {
            pub fn fetch_add(&self, v: u64, o: Ordering) -> u64 {
                point();
                self.inner.fetch_add(v, o)
            }
        }

        impl AtomicUsize {
            pub fn fetch_add(&self, v: usize, o: Ordering) -> usize {
                point();
                self.inner.fetch_add(v, o)
            }

            pub fn fetch_sub(&self, v: usize, o: Ordering) -> usize {
                point();
                self.inner.fetch_sub(v, o)
            }
        }
    }
}

/// Instrumented replacement for the `std::thread` surface the pool
/// uses, plus a no-op [`thread::spin_loop`] (busy spins are pointless
/// under a serializing scheduler).
pub mod thread {
    use super::{
        catch_unwind, ctx, ctx_opt, panic_message, Abort, Arc, AssertUnwindSafe, PoisonError,
        StdMutex, CTX, IN_MODEL,
    };

    type Slot<T> = Arc<StdMutex<Option<std::thread::Result<T>>>>;

    /// Spawn a model thread. Must be called from inside a
    /// [`super::model`] closure; the new thread participates in the
    /// scheduler and must finish before the model closure returns.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (rt, me) = ctx();
        let tid = rt.register_thread();
        let slot: Slot<T> = Arc::new(StdMutex::new(None));
        let slot2 = slot.clone();
        let rt2 = rt.clone();
        std::thread::spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((rt2.clone(), tid)));
            IN_MODEL.with(|m| m.set(true));
            let result = catch_unwind(AssertUnwindSafe(|| {
                rt2.wait_first_schedule(tid);
                f()
            }));
            let panic_msg = match &result {
                Err(payload) if !payload.is::<Abort>() => Some(panic_message(payload.as_ref())),
                _ => None,
            };
            *slot2.lock().unwrap_or_else(PoisonError::into_inner) = Some(result);
            rt2.finish_thread(tid, panic_msg);
        });
        // give the scheduler the chance to run the child right away
        rt.progress_point(me);
        JoinHandle { tid, slot }
    }

    /// Handle to a model thread. Unlike std, dropping it without
    /// joining is allowed (the model still requires the thread to
    /// finish before the closure returns).
    pub struct JoinHandle<T> {
        tid: usize,
        slot: Slot<T>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            let (rt, me) = ctx();
            rt.join_thread(me, self.tid);
            self.slot
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .take()
                .expect("mc: joined thread left no result")
        }
    }

    /// Voluntary yield: the scheduler prefers switching to another
    /// runnable thread (outside a model this is std's yield).
    pub fn yield_now() {
        if let Some((rt, me)) = ctx_opt() {
            rt.reschedule(me, super::Point::Yielded);
        } else {
            std::thread::yield_now();
        }
    }

    /// Busy-wait hint: a no-op under the model (spinning cannot make
    /// another serialized thread progress).
    pub fn spin_loop() {}
}

/// Exploration knobs. [`Builder::new`] reads `LOOM_MAX_PREEMPTIONS` and
/// `LOOM_MAX_EXECUTIONS` from the environment so CI can tune depth
/// without code changes.
#[derive(Clone, Copy)]
pub struct Builder {
    /// Preemptive context switches allowed per execution (voluntary
    /// yields and blocking are free). Bounds the DFS like loom's
    /// `LOOM_MAX_PREEMPTIONS`.
    pub max_preemptions: usize,
    /// Executions explored before the DFS is declared truncated.
    pub max_executions: usize,
    /// Scheduling points per execution before a livelock is reported.
    pub max_steps: usize,
    /// Randomized executions appended when the DFS truncates.
    pub random_iters: usize,
}

impl Default for Builder {
    fn default() -> Builder {
        Builder::new()
    }
}

impl Builder {
    pub fn new() -> Builder {
        let env_usize = |key: &str, default: usize| {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(default)
        };
        Builder {
            max_preemptions: env_usize("LOOM_MAX_PREEMPTIONS", DEFAULT_MAX_PREEMPTIONS),
            max_executions: env_usize("LOOM_MAX_EXECUTIONS", DEFAULT_MAX_EXECUTIONS),
            max_steps: DEFAULT_MAX_STEPS,
            random_iters: DEFAULT_RANDOM_ITERS,
        }
    }

    /// Explore `f` across interleavings. Panics (on the calling thread,
    /// with the scheduler's diagnosis) if any interleaving deadlocks,
    /// livelocks, or lets a panic escape a model thread.
    pub fn check<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        install_quiet_hook();
        let f = Arc::new(f);
        // DFS stack: (n_options, chosen) per multi-option decision
        let mut stack: Vec<(usize, usize)> = Vec::new();
        let mut executions = 0usize;
        let mut max_depth = 0usize;
        let mut truncated = false;
        loop {
            if executions >= self.max_executions {
                truncated = true;
                break;
            }
            let script: Vec<usize> = stack.iter().map(|&(_, chosen)| chosen).collect();
            let (failure, trace) = self.run_one(&f, script, StrategyKind::Dfs, 1);
            executions += 1;
            max_depth = max_depth.max(trace.len());
            if let Some(msg) = failure {
                panic!(
                    "mc: execution {executions} failed: {msg} (decision trace \
                     depth {})",
                    trace.len()
                );
            }
            // fold newly discovered decision points into the DFS stack,
            // then advance to the next unexplored branch
            for &n in trace.iter().skip(stack.len()) {
                stack.push((n, 0));
            }
            while let Some(top) = stack.last_mut() {
                if top.1 + 1 < top.0 {
                    top.1 += 1;
                    break;
                }
                stack.pop();
            }
            if stack.is_empty() {
                break;
            }
        }
        if truncated {
            // randomized sweep over schedules the bounded DFS missed
            for i in 0..self.random_iters {
                let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1);
                let (failure, trace) = self.run_one(&f, Vec::new(), StrategyKind::Random, seed);
                executions += 1;
                max_depth = max_depth.max(trace.len());
                if let Some(msg) = failure {
                    panic!("mc: randomized execution failed: {msg}");
                }
            }
        }
        Report {
            executions,
            truncated,
            max_depth,
        }
    }

    fn run_one<F>(
        &self,
        f: &Arc<F>,
        script: Vec<usize>,
        strategy: StrategyKind,
        seed: u64,
    ) -> (Option<String>, Vec<usize>)
    where
        F: Fn() + Send + Sync + 'static,
    {
        let rt = Arc::new(Runtime::new(script, strategy, seed, self));
        let rt2 = rt.clone();
        let f2 = f.clone();
        let root = std::thread::spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((rt2.clone(), 0)));
            IN_MODEL.with(|m| m.set(true));
            let result = catch_unwind(AssertUnwindSafe(&*f2));
            let panic_msg = match &result {
                Err(payload) if !payload.is::<Abort>() => Some(panic_message(payload.as_ref())),
                _ => None,
            };
            rt2.finish_thread(0, panic_msg);
        });
        let out = rt.wait_done();
        let _ = root.join();
        out
    }
}

/// What an exploration covered. `truncated` means the DFS hit
/// `max_executions` before exhausting the schedule space (the
/// randomized sweep then ran on top); the loom test tier logs these so
/// EXPERIMENTS.md can report real interleaving counts.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub executions: usize,
    pub truncated: bool,
    /// Deepest decision trace seen (multi-option scheduling points in
    /// one execution).
    pub max_depth: usize,
}

/// Exhaustively (within bounds) model-check `f`. Panics when any
/// explored interleaving fails — see [`Builder::check`].
pub fn model<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::new().check(f)
}

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::{Arc, Condvar, Mutex};
    use super::{thread, Builder};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::PoisonError;

    fn quick() -> Builder {
        let mut b = Builder::new();
        b.max_preemptions = b.max_preemptions.max(2);
        b.max_executions = 5_000;
        b.random_iters = 50;
        b
    }

    #[test]
    fn mutex_counter_is_race_free() {
        let report = quick().check(|| {
            let counter = Arc::new(Mutex::new(0u32));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let counter = counter.clone();
                handles.push(thread::spawn(move || {
                    for _ in 0..2 {
                        let mut guard = counter.lock().unwrap_or_else(PoisonError::into_inner);
                        *guard += 1;
                    }
                }));
            }
            for h in handles {
                h.join().expect("no panics in this model");
            }
            let total = *counter.lock().unwrap_or_else(PoisonError::into_inner);
            assert_eq!(total, 4);
        });
        assert!(
            report.executions > 1,
            "two contending threads must produce multiple interleavings"
        );
    }

    #[test]
    fn finds_the_lost_update_in_a_racy_increment() {
        // load; store(load+1) on two threads without a lock: the model
        // must find the interleaving where one increment is lost
        let result = catch_unwind(AssertUnwindSafe(|| {
            quick().check(|| {
                let v = Arc::new(AtomicUsize::new(0));
                let mut handles = Vec::new();
                for _ in 0..2 {
                    let v = v.clone();
                    handles.push(thread::spawn(move || {
                        let seen = v.load(Ordering::SeqCst);
                        v.store(seen + 1, Ordering::SeqCst);
                    }));
                }
                for h in handles {
                    h.join().expect("no panics in this model");
                }
                assert_eq!(v.load(Ordering::SeqCst), 2, "an increment was lost");
            });
        }));
        let msg = match result {
            Ok(_) => panic!("the checker missed the seeded lost update"),
            Err(payload) => super::panic_message(payload.as_ref()),
        };
        assert!(msg.contains("an increment was lost"), "unexpected: {msg}");
    }

    #[test]
    fn detects_ab_ba_deadlock() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            quick().check(|| {
                let a = Arc::new(Mutex::new(0u32));
                let b = Arc::new(Mutex::new(0u32));
                let (a2, b2) = (a.clone(), b.clone());
                let t1 = thread::spawn(move || {
                    let _ga = a2.lock().unwrap_or_else(PoisonError::into_inner);
                    let _gb = b2.lock().unwrap_or_else(PoisonError::into_inner);
                });
                let (a3, b3) = (a.clone(), b.clone());
                let t2 = thread::spawn(move || {
                    let _gb = b3.lock().unwrap_or_else(PoisonError::into_inner);
                    let _ga = a3.lock().unwrap_or_else(PoisonError::into_inner);
                });
                let _ = t1.join();
                let _ = t2.join();
            });
        }));
        let msg = match result {
            Ok(_) => panic!("the checker missed the AB-BA deadlock"),
            Err(payload) => super::panic_message(payload.as_ref()),
        };
        assert!(msg.contains("deadlock"), "unexpected: {msg}");
    }

    #[test]
    fn detects_a_lost_wakeup() {
        // the notifier sets the flag but never notifies: the waiter can
        // park forever — exactly the bug class the pool's
        // publish-under-mutex discipline exists to prevent
        let result = catch_unwind(AssertUnwindSafe(|| {
            quick().check(|| {
                let state = Arc::new((Mutex::new(false), Condvar::new()));
                let s2 = state.clone();
                let waiter = thread::spawn(move || {
                    let (flag, cv) = &*s2;
                    let mut guard = flag.lock().unwrap_or_else(PoisonError::into_inner);
                    while !*guard {
                        guard = cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
                    }
                });
                let (flag, _cv) = &*state;
                let mut guard = flag.lock().unwrap_or_else(PoisonError::into_inner);
                *guard = true;
                drop(guard); // bug: no notify
                let _ = waiter.join();
            });
        }));
        let msg = match result {
            Ok(_) => panic!("the checker missed the lost wakeup"),
            Err(payload) => super::panic_message(payload.as_ref()),
        };
        assert!(msg.contains("deadlock"), "unexpected: {msg}");
    }

    #[test]
    fn notify_under_the_mutex_passes() {
        // the corrected version of the test above
        let report = quick().check(|| {
            let state = Arc::new((Mutex::new(false), Condvar::new()));
            let s2 = state.clone();
            let waiter = thread::spawn(move || {
                let (flag, cv) = &*s2;
                let mut guard = flag.lock().unwrap_or_else(PoisonError::into_inner);
                while !*guard {
                    guard = cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
                }
            });
            let (flag, cv) = &*state;
            let mut guard = flag.lock().unwrap_or_else(PoisonError::into_inner);
            *guard = true;
            cv.notify_all();
            drop(guard);
            waiter.join().expect("waiter must not panic");
        });
        assert!(!report.truncated, "tiny model must be fully explored");
    }

    #[test]
    fn join_returns_the_thread_value() {
        quick().check(|| {
            let h = thread::spawn(|| 7u32);
            let v = h.join().expect("no panic");
            assert_eq!(v, 7);
        });
    }

    #[test]
    fn zero_preemptions_still_runs_to_completion() {
        let mut b = quick();
        b.max_preemptions = 0;
        let report = b.check(|| {
            let v = Arc::new(AtomicUsize::new(0));
            let v2 = v.clone();
            let h = thread::spawn(move || {
                v2.fetch_add(1, Ordering::SeqCst);
            });
            h.join().expect("no panic");
            assert_eq!(v.load(Ordering::SeqCst), 1);
        });
        assert!(report.executions >= 1);
    }
}
