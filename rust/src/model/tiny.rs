//! Pure-Rust forward pass of the tiny AOT model, in two numerics modes,
//! running entirely on the fused multi-head decode kernels
//! ([`crate::kernels`]).
//!
//! - [`NumericsMode::DesktopF32`] — "desktop" arithmetic: exact W4A8
//!   integer GEMV + f32 single-pass SwiftKV attention (numerically equal
//!   to softmax(qKᵀ/√d)V to ~1e-6; the reference side of the paper's
//!   Table I comparison, "desktop results using the same W4A8
//!   precision").
//! - [`NumericsMode::Accelerator`] — the SwiftKV-MHA datapath: exact
//!   INT8×INT4 integer GEMV, FXP32 (Q15.17) single-pass attention with
//!   the 5-bit-LUT exponential, decoder-RoPE recurrence.
//!
//! Both modes share the exact integer GEMV, so they differ ONLY in the
//! attention datapath — precisely the contribution Table I isolates.
//!
//! Hot-path structure (§Perf): the KV caches are **paged** — token-major
//! interleaved rows (`[pos][kv_head * d_head]`) stored in fixed-size
//! blocks drawn from a [`BlockPool`], mapped per layer by a
//! [`BlockTable`] — so one decode step streams each cache row once and
//! advances *every* head in a single fused sweep
//! ([`crate::kernels::MhaSwiftKv::extend_paged`] /
//! [`crate::kernels::FxpMhaSwiftKv::extend_paged`]) — the software
//! analogue of the SwiftKV-MHA pipeline of Fig. 5. Many sequences
//! (serving lanes) share one pool and return their blocks on
//! [`DecodeState::reset_for_reuse`], so memory is bounded by the live
//! token set, not `lanes × n_ctx`. Grouped-query attention is native:
//! with `n_kv_heads < n_heads` the cache rows (and the Q15.17 mirror
//! carried inside each block) shrink to `n_kv_heads · d_head` per token
//! and each KV-head slice feeds its whole group of query heads. The
//! accelerator mode's Q15.17 mirror is appended once per token, so no
//! re-quantization of history ever happens. All intermediates live in a
//! per-sequence [`DecodeScratch`]; after pool warm-up a steady-state
//! [`TinyModel::decode_step_into`] performs **zero heap allocation**
//! (asserted by `tests/alloc_hotpath.rs`), block-boundary crossings
//! included — the pool allocates every block eagerly and `alloc`/
//! `release` only move them through a pre-reserved free list.
//!
//! Multi-lane serving decodes through the **batched** step
//! ([`TinyModel::decode_steps_into`]): decoding is weight-bandwidth
//! bound, so the batch step streams every packed weight matrix once for
//! the whole batch (gather activations → one shared W4A8 GEMM per
//! projection → per-lane fused attention) instead of once per lane,
//! while each lane keeps its own KV state and the attention kernels run
//! unchanged. Per lane the batched step is bit-identical to the solo
//! one (`tests/prop_batched_decode.rs`), and with a
//! [`crate::kernels::WorkerPool`] the shared GEMMs split across workers
//! by output-column range and the attention phase by lane.

use super::weights::WeightStore;
use crate::fxp::{vector, Exp2Lut, Fxp32};
use crate::kernels::{BatchScratch, BlockPool, BlockTable, DecodeScratch, SharedMut, WorkerPool};
use crate::quant::gemv::gemm_w4a8_raw_cols_ptr;
use crate::quant::{gemm_w4a8_raw_into, quantize_int8_into, Int4Matrix, QuantLinear};
use crate::rope::{rope_apply_cached_into, RopeState};
use crate::util::Rng;
use anyhow::{bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Default tokens per KV cache block (`swiftkv serve --kv-block-len`
/// overrides). 16 rows keeps block-table overhead ≪ 1 % of the sweep
/// while bounding per-sequence over-allocation to 15 rows per layer.
pub const DEFAULT_KV_BLOCK_LEN: usize = 16;

/// Which datapath to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumericsMode {
    /// Integer GEMV + f32 single-pass SwiftKV attention.
    DesktopF32,
    /// Integer GEMV + FXP32 LUT-exp SwiftKV attention.
    Accelerator,
}

/// A W4A8 linear layer carried in both representations.
struct DualLinear {
    quant: QuantLinear,
    dequant: Vec<f32>, // row-major [din, dout]
    din: usize,
}

impl DualLinear {
    fn load(ws: &WeightStore, name: &str) -> Result<DualLinear> {
        let wq = ws.i8_vec(&format!("{name}.q"))?;
        let scales = ws.f32_vec(&format!("{name}.scale"))?;
        let shape = ws.shape(&format!("{name}.q"))?;
        if shape.len() != 2 {
            bail!("{name}: expected rank-2 weight");
        }
        let (din, dout) = (shape[0], shape[1]);
        let mat = Int4Matrix::from_quantized(&wq, scales.clone(), din, dout);
        let mut dequant = vec![0.0f32; din * dout];
        for i in 0..din {
            for j in 0..dout {
                dequant[i * dout + j] = wq[i * dout + j] as f32 * scales[j];
            }
        }
        Ok(DualLinear {
            quant: QuantLinear::new(mat),
            dequant,
            din,
        })
    }

    /// Quantize-on-the-fly W4A8 linear from an f32 matrix (synthetic
    /// models and tests — no artifact files needed).
    fn from_f32(w: &[f32], din: usize, dout: usize) -> DualLinear {
        let mat = Int4Matrix::quantize(w, din, dout);
        let dequant = mat.dequantize();
        DualLinear {
            quant: QuantLinear::new(mat),
            dequant,
            din,
        }
    }

    /// The exact W4A8 integer GEMV (INT8×INT4→INT32 is exact on desktop
    /// hardware too), through caller-owned scratch — shared by both
    /// numerics modes.
    #[inline]
    fn forward_into(&self, x: &[f32], qbuf: &mut [i8], out: &mut [f32]) {
        assert_eq!(x.len(), self.din);
        self.quant.forward_into(x, qbuf, out);
    }

    /// Output width (test/diagnostic use).
    #[allow(dead_code)]
    fn dout(&self) -> usize {
        self.quant.dout()
    }

    /// Dequantized f32 weight view (diagnostics / error analysis).
    #[allow(dead_code)]
    fn dequant_weights(&self) -> &[f32] {
        &self.dequant
    }
}

struct LayerWeights {
    attn_norm: Vec<f32>,
    wq: DualLinear,
    wk: DualLinear,
    wv: DualLinear,
    wo: DualLinear,
    mlp_norm: Vec<f32>,
    w_gate: DualLinear,
    w_up: DualLinear,
    w_down: DualLinear,
}

/// The tiny decoder with all weights resident.
pub struct TinyModel {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (GQA/MQA when `< n_heads`; the K/V projections and caches
    /// are `n_kv_heads * d_head` wide).
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub n_ctx: usize,
    pub rope_base: f64,
    embedding: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
    lm_head: DualLinear,
    lut: Exp2Lut,
}

/// Mutable per-sequence decode state: per-layer [`BlockTable`]s over a
/// (possibly shared) [`BlockPool`] holding the token-major interleaved
/// KV rows (f32 + Q15.17 mirror), the RoPE recurrence, and the
/// pre-allocated [`DecodeScratch`].
pub struct DecodeState {
    /// One block table per layer: logical position `t` of layer `l`
    /// lives in `tables[l]` at block `t / block_len`, row
    /// `t % block_len` (rows shrink by the group factor under GQA/MQA).
    tables: Vec<BlockTable>,
    /// The pool the tables draw from — private to this sequence for
    /// [`TinyModel::new_state`], shared across lanes when created via
    /// [`TinyModel::new_state_in`].
    pool: Arc<BlockPool>,
    /// Token rows (per layer) present in the Q15.17 mirror. Lags `pos`
    /// when steps run in `DesktopF32` mode; the next `Accelerator` step
    /// backfills the gap so modes can be mixed freely on one state.
    fxp_rows: usize,
    rope: RopeState,
    pub pos: usize,
    scratch: DecodeScratch,
}

impl DecodeState {
    /// Restart the state for a new sequence, returning every KV block to
    /// the pool (lane recycling in the CPU batch server: reclaimed
    /// blocks immediately serve other lanes). Stale block contents are
    /// never read: row `t` is rewritten at step `t` before any read.
    pub fn reset_for_reuse(&mut self) {
        for table in &mut self.tables {
            table.release_into(&self.pool);
        }
        self.pos = 0;
        self.fxp_rows = 0;
        // in-place rewind: lane recycling allocates nothing
        self.rope.reset();
    }

    /// The pool this state draws its KV blocks from.
    pub fn kv_pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    /// KV blocks currently checked out across all layers.
    pub fn kv_blocks_in_use(&self) -> usize {
        self.tables.iter().map(BlockTable::num_blocks).sum()
    }

    /// Blocks this state would have to take from the pool to hold
    /// `tokens` total context — the serving loop's admission/preemption
    /// precheck. Summed per layer so a partially-grown state (e.g. after
    /// a contained fault mid-setup) is accounted exactly.
    pub fn kv_blocks_needed(&self, tokens: usize) -> usize {
        let per_layer = tokens.div_ceil(self.pool.block_len());
        self.tables
            .iter()
            .map(|t| per_layer.saturating_sub(t.num_blocks()))
            .sum()
    }

    /// Fault injection: overwrite the most recently written KV row with
    /// NaN in every layer (f32 rows only — the Q15.17 mirror has no NaN
    /// encoding, so `Accelerator`-mode decoding is unaffected by design).
    /// Returns `false` (no-op) if nothing has been written yet. The NaNs
    /// flow through the fused f32 attention sweep into this lane's
    /// logits, which the serving loop detects as a non-finite sample and
    /// retires per-request.
    pub fn poison_kv_nan(&mut self) -> bool {
        if self.pos == 0 {
            return false;
        }
        let t = self.pos - 1;
        for table in &mut self.tables {
            table.k_row_mut(t).fill(f32::NAN);
            table.v_row_mut(t).fill(f32::NAN);
        }
        true
    }
}

impl Drop for DecodeState {
    /// A retired sequence returns its blocks to the pool — the fixed
    /// pool stays whole for the remaining lanes.
    fn drop(&mut self) {
        for table in &mut self.tables {
            table.release_into(&self.pool);
        }
    }
}

/// One lane of a batched decode step ([`TinyModel::decode_steps_into`]):
/// the lane's sequence state, the token it appends this step, and the
/// buffer its logits land in. Lanes may sit at different positions —
/// each keeps its own KV tables, RoPE recurrence, and scratch.
pub struct BatchLane<'a> {
    pub state: &'a mut DecodeState,
    pub token: u32,
    /// Receives this lane's logits, `[vocab]`.
    pub logits: &'a mut [f32],
}

/// A contained per-lane failure from
/// [`TinyModel::try_decode_steps_into`]: the lane index that faulted and
/// the panic payload (or other cause) as text. The lane's `DecodeState`
/// is left partially stepped — reset it with
/// [`DecodeState::reset_for_reuse`] before reusing the lane.
#[derive(Debug, Clone)]
pub struct LaneFault {
    pub lane: usize,
    pub message: String,
}

/// Render a caught panic payload as text (`&str` and `String` payloads
/// cover every `panic!`/`assert!` in this crate).
pub(crate) fn panic_message(cause: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = cause.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = cause.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Append a contained panic to the step's fault log. Lock poisoning is
/// impossible here by construction (pushes never panic mid-hold), but
/// recover anyway — the log must survive anything.
fn record_fault(log: &Mutex<Vec<LaneFault>>, lane: usize, cause: Box<dyn std::any::Any + Send>) {
    log.lock().unwrap_or_else(|e| e.into_inner()).push(LaneFault {
        lane,
        message: panic_message(&*cause),
    });
}

impl TinyModel {
    /// Load from the artifact weight store.
    pub fn load(ws: &WeightStore) -> Result<TinyModel> {
        let m = &ws.manifest;
        let mut layers = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let p = format!("layer{l}");
            layers.push(LayerWeights {
                attn_norm: ws.f32_vec(&format!("{p}.attn_norm"))?,
                wq: DualLinear::load(ws, &format!("{p}.wq"))?,
                wk: DualLinear::load(ws, &format!("{p}.wk"))?,
                wv: DualLinear::load(ws, &format!("{p}.wv"))?,
                wo: DualLinear::load(ws, &format!("{p}.wo"))?,
                mlp_norm: ws.f32_vec(&format!("{p}.mlp_norm"))?,
                w_gate: DualLinear::load(ws, &format!("{p}.w_gate"))?,
                w_up: DualLinear::load(ws, &format!("{p}.w_up"))?,
                w_down: DualLinear::load(ws, &format!("{p}.w_down"))?,
            });
        }
        if m.d_model != m.n_heads * m.d_head {
            bail!("manifest: d_model must equal n_heads * d_head");
        }
        if m.n_kv_heads == 0 || m.n_heads % m.n_kv_heads != 0 {
            bail!("manifest: n_heads must be a multiple of n_kv_heads");
        }
        // the declared GQA shape must match the stored K/V projection
        // widths — catch a mismatched manifest here, not mid-decode
        let d_kv = m.n_kv_heads * m.d_head;
        for (l, lw) in layers.iter().enumerate() {
            for (name, w) in [("wk", &lw.wk), ("wv", &lw.wv)] {
                if w.dout() != d_kv {
                    bail!(
                        "layer{l}.{name}: projection width {} does not match \
                         n_kv_heads * d_head = {d_kv}",
                        w.dout()
                    );
                }
            }
        }
        Ok(TinyModel {
            vocab: m.vocab,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            d_head: m.d_head,
            n_layers: m.n_layers,
            d_ffn: m.d_ffn,
            n_ctx: m.n_ctx,
            rope_base: m.rope_base,
            embedding: ws.f32_vec("embedding")?,
            layers,
            final_norm: ws.f32_vec("final_norm")?,
            lm_head: DualLinear::load(ws, "lm_head")?,
            lut: Exp2Lut::new(),
        })
    }

    /// Deterministic random model with the same datapath as the AOT tiny
    /// model — lets the decode hot path (and its benches/tests) run
    /// without the Python-built artifacts. `n_kv_heads == n_heads` is
    /// plain MHA; `n_kv_heads < n_heads` builds a grouped-query model
    /// whose K/V projections (and KV caches) are `n_kv_heads * d_head`
    /// wide.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        seed: u64,
        vocab: usize,
        d_model: usize,
        n_heads: usize,
        n_kv_heads: usize,
        n_layers: usize,
        d_ffn: usize,
        n_ctx: usize,
    ) -> TinyModel {
        assert!(vocab >= 2 && n_layers >= 1 && n_ctx >= 1);
        assert!(n_heads > 0 && d_model % n_heads == 0, "d_model must split across heads");
        assert!(
            n_kv_heads > 0 && n_heads % n_kv_heads == 0,
            "n_heads must be a multiple of n_kv_heads"
        );
        let d_head = d_model / n_heads;
        let d_kv = n_kv_heads * d_head;
        assert!(d_head % 2 == 0, "RoPE needs an even head dim");
        let mut rng = Rng::seed_from_u64(seed);
        let w_scale = 1.0 / (d_model as f32).sqrt();
        let linear = |rng: &mut Rng, din: usize, dout: usize| -> DualLinear {
            DualLinear::from_f32(&rng.uniform_vec(din * dout, w_scale), din, dout)
        };
        let gain = |rng: &mut Rng, n: usize| -> Vec<f32> {
            rng.uniform_vec(n, 0.25).iter().map(|x| 1.0 + x).collect()
        };
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            layers.push(LayerWeights {
                attn_norm: gain(&mut rng, d_model),
                wq: linear(&mut rng, d_model, d_model),
                wk: linear(&mut rng, d_model, d_kv),
                wv: linear(&mut rng, d_model, d_kv),
                wo: linear(&mut rng, d_model, d_model),
                mlp_norm: gain(&mut rng, d_model),
                w_gate: linear(&mut rng, d_model, d_ffn),
                w_up: linear(&mut rng, d_model, d_ffn),
                w_down: linear(&mut rng, d_ffn, d_model),
            });
        }
        let embedding = rng.uniform_vec(vocab * d_model, 1.0);
        let final_norm = gain(&mut rng, d_model);
        let lm_head = linear(&mut rng, d_model, vocab);
        TinyModel {
            vocab,
            d_model,
            n_heads,
            n_kv_heads,
            d_head,
            n_layers,
            d_ffn,
            n_ctx,
            rope_base: 10000.0,
            embedding,
            layers,
            final_norm,
            lm_head,
            lut: Exp2Lut::new(),
        }
    }

    /// KV blocks one sequence needs at the full context window
    /// (`n_layers × ⌈n_ctx / block_len⌉`) — the worst-case live set per
    /// lane, and the unit of the pool-sizing math in
    /// EXPERIMENTS.md §Paged-KV.
    pub fn blocks_per_seq(&self, block_len: usize) -> usize {
        assert!(block_len > 0, "block_len must be positive");
        self.n_layers * self.n_ctx.div_ceil(block_len)
    }

    /// A block pool shaped for this model's KV rows
    /// (`n_kv_heads · d_head` wide). `blocks` bounds the total live
    /// tokens across every sequence drawing from it.
    pub fn new_pool(&self, blocks: usize, block_len: usize) -> Arc<BlockPool> {
        Arc::new(BlockPool::new(
            blocks,
            block_len,
            self.n_kv_heads * self.d_head,
        ))
    }

    /// Fresh decode state over a private pool sized for one full-context
    /// sequence at [`DEFAULT_KV_BLOCK_LEN`]. The KV rows (and Q15.17
    /// mirror) hold `n_kv_heads * d_head` per token — the group-factor
    /// KV shrink under GQA/MQA.
    pub fn new_state(&self) -> DecodeState {
        let pool = self.new_pool(
            self.blocks_per_seq(DEFAULT_KV_BLOCK_LEN),
            DEFAULT_KV_BLOCK_LEN,
        );
        self.new_state_in(pool)
    }

    /// Fresh decode state drawing its KV blocks from `pool` — the
    /// multi-lane form: every serving lane holds a clone of one shared
    /// pool handle and blocks migrate between lanes through it.
    pub fn new_state_in(&self, pool: Arc<BlockPool>) -> DecodeState {
        assert_eq!(
            pool.row_width(),
            self.n_kv_heads * self.d_head,
            "pool row width does not match the model's n_kv_heads * d_head"
        );
        let tables = (0..self.n_layers)
            .map(|_| BlockTable::new(&pool, self.n_ctx))
            .collect();
        DecodeState {
            tables,
            pool,
            fxp_rows: 0,
            rope: RopeState::new(self.d_head, self.rope_base),
            pos: 0,
            scratch: DecodeScratch::new(self.n_heads, self.n_kv_heads, self.d_head, self.d_ffn),
        }
    }

    /// One decode step: append `token` at the state's position, return
    /// logits over the vocabulary. Allocates only the returned vector;
    /// use [`Self::decode_step_into`] for the allocation-free variant.
    pub fn decode_step(&self, st: &mut DecodeState, token: u32, mode: NumericsMode) -> Vec<f32> {
        let mut logits = vec![0.0f32; self.vocab];
        self.decode_step_into(st, token, mode, &mut logits);
        logits
    }

    /// One decode step into a caller-owned logits buffer. Steady-state
    /// this performs **no heap allocation**: every intermediate lives in
    /// the state's [`DecodeScratch`], the fused multi-head SwiftKV states
    /// are `reset()` per layer, each KV cache row is written once and
    /// streamed once per step, and block-boundary crossings only move
    /// pre-allocated blocks out of the pool's free list.
    pub fn decode_step_into(
        &self,
        st: &mut DecodeState,
        token: u32,
        mode: NumericsMode,
        logits: &mut [f32],
    ) {
        assert!((token as usize) < self.vocab, "token out of range");
        assert!(st.pos < self.n_ctx, "context overflow");
        assert_eq!(logits.len(), self.vocab, "logits buffer size");
        let d = self.d_model;
        let (h, dh) = (self.n_heads, self.d_head);
        let h_kv = self.n_kv_heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let fxp_scale = Fxp32::from_f64(1.0 / (dh as f64).sqrt());

        // advance the shared RoPE recurrence once per token
        st.rope.advance();
        let pos = st.pos;
        let len = pos + 1;
        // first Q15.17 mirror row missing for this step (== pos when every
        // step ran in Accelerator mode; smaller after DesktopF32 steps)
        let fxp_from = st.fxp_rows.min(pos);

        // split the state into disjoint mutable borrows
        let DecodeState {
            tables,
            pool,
            rope,
            scratch: sc,
            ..
        } = st;
        debug_assert_eq!(pool.row_width(), h_kv * dh);

        // map this step's row in every layer up front: one pool
        // round-trip per block_len tokens per layer, no heap allocation
        // (blocks are pre-allocated, the block lists pre-reserved)
        for table in tables.iter_mut() {
            table.ensure_tokens(pool, len);
        }

        sc.x
            .copy_from_slice(&self.embedding[token as usize * d..(token as usize + 1) * d]);

        for (l, lw) in self.layers.iter().enumerate() {
            rms_norm_into(&sc.x, &lw.attn_norm, &mut sc.xn);
            lw.wq.forward_into(&sc.xn, &mut sc.qi8, &mut sc.q);
            lw.wk.forward_into(&sc.xn, &mut sc.qi8, &mut sc.k);
            lw.wv.forward_into(&sc.xn, &mut sc.qi8, &mut sc.v);

            // rotate q (all query heads) into scratch and k (KV heads
            // only) directly into this position's block-mapped
            // interleaved cache row; store v alongside
            let table = &mut tables[l];
            {
                for head in 0..h {
                    let o = head * dh;
                    rope_apply_cached_into(
                        &sc.q[o..o + dh],
                        &rope.cos,
                        &rope.sin,
                        &mut sc.q_rot[o..o + dh],
                    );
                }
                let krow = table.k_row_mut(pos);
                for head in 0..h_kv {
                    let o = head * dh;
                    rope_apply_cached_into(
                        &sc.k[o..o + dh],
                        &rope.cos,
                        &rope.sin,
                        &mut krow[o..o + dh],
                    );
                }
            }
            table.v_row_mut(pos).copy_from_slice(&sc.v);

            match mode {
                NumericsMode::DesktopF32 => {
                    // fused f32 sweep over the block-gathered rows: every
                    // cache row feeds all heads once
                    sc.mha.reset();
                    sc.mha.extend_paged(&sc.q_rot, table, 0, len, scale);
                    sc.mha.finalize_into(&mut sc.attn_out);
                }
                NumericsMode::Accelerator => {
                    // quantize the rotated query once per layer, append the
                    // missing (k, v) rows to the Q15.17 mirror — steady
                    // state that is exactly the current row; after
                    // DesktopF32 steps the gap is backfilled — then one
                    // fused Q15.17 sweep. History already mirrored is
                    // never re-quantized.
                    vector::quantize_into(&sc.q_rot, &mut sc.q_fxp);
                    for t in fxp_from..len {
                        table.quantize_row(t);
                    }
                    sc.fxp_mha.reset();
                    sc.fxp_mha
                        .extend_paged(&self.lut, &sc.q_fxp, table, 0, len, fxp_scale);
                    sc.fxp_mha.finalize_into(&mut sc.attn_fxp);
                    vector::dequantize_into(&sc.attn_fxp, &mut sc.attn_out);
                }
            }

            lw.wo.forward_into(&sc.attn_out, &mut sc.qi8, &mut sc.o);
            for (xi, oi) in sc.x.iter_mut().zip(&sc.o) {
                *xi += oi;
            }

            rms_norm_into(&sc.x, &lw.mlp_norm, &mut sc.xn);
            lw.w_gate.forward_into(&sc.xn, &mut sc.qi8, &mut sc.gate);
            lw.w_up.forward_into(&sc.xn, &mut sc.qi8, &mut sc.up);
            for ((a, &g), &u) in sc.act.iter_mut().zip(&sc.gate).zip(&sc.up) {
                *a = silu(g) * u;
            }
            lw.w_down.forward_into(&sc.act, &mut sc.qi8, &mut sc.down);
            for (xi, di) in sc.x.iter_mut().zip(&sc.down) {
                *xi += di;
            }
        }

        rms_norm_into(&sc.x, &self.final_norm, &mut sc.xn);
        self.lm_head.forward_into(&sc.xn, &mut sc.qi8, logits);

        if mode == NumericsMode::Accelerator {
            st.fxp_rows = len;
        }
        st.pos += 1;
    }

    /// Batch scratch shaped for this model — the shared-GEMM companion
    /// of one [`TinyModel::decode_steps_into`] call site. Keep one per
    /// serving loop; it grows once to the high-water batch width.
    pub fn new_batch_scratch(&self) -> BatchScratch {
        BatchScratch::new(
            self.n_heads,
            self.n_kv_heads,
            self.d_head,
            self.d_ffn,
            self.vocab,
        )
    }

    /// Packed weight bytes one decode step must stream through the
    /// GEMMs (all layer projections plus `lm_head`; INT4 payload +
    /// per-column f32 scales; the embedding row lookup is excluded).
    /// This is the per-step weight traffic a batched step pays **once**
    /// for the whole batch, where per-lane stepping pays it `B` times —
    /// the arithmetic in EXPERIMENTS.md §batched-weight-streaming.
    pub fn weight_stream_bytes(&self) -> usize {
        // packed_bytes already includes the per-column f32 scales
        let lin = |l: &DualLinear| l.quant.weight.packed_bytes();
        let per_layer: usize = self
            .layers
            .iter()
            .map(|lw| {
                lin(&lw.wq)
                    + lin(&lw.wk)
                    + lin(&lw.wv)
                    + lin(&lw.wo)
                    + lin(&lw.w_gate)
                    + lin(&lw.w_up)
                    + lin(&lw.w_down)
            })
            .sum();
        per_layer + lin(&self.lm_head)
    }

    /// One **batched** decode step: append each lane's token and fill
    /// each lane's logits, streaming every weight matrix **once for the
    /// whole batch** instead of once per lane.
    ///
    /// Per layer the step runs gather → shared pass → scatter: (1) per
    /// lane: RMS-norm and INT8-quantize the activation into the batch
    /// scratch's row block; (2) one batched W4A8 GEMM per projection
    /// ([`crate::quant::gemm_w4a8_raw_into`]) — Q/K/V here, O and the
    /// MLP matrices below — so the packed weights are read and
    /// nibble-unpacked once per batch step; (3) per lane: RoPE, cache
    /// append, and the fused SwiftKV attention sweep over the lane's own
    /// paged KV state, exactly as in [`Self::decode_step_into`]. The
    /// logits projection is one shared `lm_head` pass scattered to the
    /// lanes' buffers at the end.
    ///
    /// Every per-lane op runs in the same order as the solo step and the
    /// batched GEMM is bit-identical per lane to the solo GEMV, so each
    /// lane's logits are **bit-identical** to what
    /// [`Self::decode_step_into`] produces for the same sequence — in
    /// both numerics modes, across GQA shapes and paged block lengths
    /// (`tests/prop_batched_decode.rs`).
    ///
    /// With `pool` set, the shared GEMMs split by output-column range
    /// and the attention phase by lane across the persistent workers;
    /// tasks write disjoint data, so pooled results equal serial ones
    /// bit for bit. Steady state (batch scratch at capacity) the step
    /// performs **zero heap allocation** (`tests/alloc_hotpath.rs`).
    pub fn decode_steps_into(
        &self,
        lanes: &mut [BatchLane<'_>],
        mode: NumericsMode,
        batch: &mut BatchScratch,
        pool: Option<&WorkerPool>,
    ) {
        let faults = self.try_decode_steps_into(lanes, mode, batch, pool);
        if let Some(f) = faults.first() {
            panic!("batched decode lane {} faulted: {}", f.lane, f.message);
        }
    }

    /// Fault-contained variant of [`Self::decode_steps_into`]: a panic
    /// inside one lane's per-lane work (step setup, KV cache growth, or
    /// the attention sweep) marks **that lane** faulted and is returned
    /// as a [`LaneFault`] instead of unwinding the caller. Faulted lanes
    /// are skipped by every later phase of the step — their logits
    /// buffers are left untouched and their `pos` does not advance —
    /// while each surviving lane's output stays bit-identical to the
    /// fault-free step (every per-lane op touches only that lane's rows,
    /// and the shared GEMMs are row-independent, so a garbage row from a
    /// faulted lane cannot perturb its neighbors). A faulted lane's
    /// `DecodeState` is partially stepped — reset it with
    /// [`DecodeState::reset_for_reuse`] before recycling the lane.
    ///
    /// Fault-free calls return an empty `Vec` and keep the steady-state
    /// **zero-heap-allocation** guarantee (`tests/alloc_hotpath.rs`);
    /// the containment bookkeeping lives in pre-allocated
    /// [`BatchScratch::faulted`] flags. The shared weight passes are
    /// *not* guarded — a panic there is a whole-batch programming error
    /// and propagates.
    pub fn try_decode_steps_into(
        &self,
        lanes: &mut [BatchLane<'_>],
        mode: NumericsMode,
        batch: &mut BatchScratch,
        pool: Option<&WorkerPool>,
    ) -> Vec<LaneFault> {
        let b = lanes.len();
        if b == 0 {
            return Vec::new();
        }
        let d = self.d_model;
        let (h, dh) = (self.n_heads, self.d_head);
        let h_kv = self.n_kv_heads;
        let d_kv = h_kv * dh;
        let d_ffn = self.d_ffn;
        let vocab = self.vocab;
        let scale = 1.0 / (dh as f32).sqrt();
        let fxp_scale = Fxp32::from_f64(1.0 / (dh as f64).sqrt());
        batch.ensure_batch(b);
        assert_eq!(batch.d_model(), d, "batch scratch d_model mismatch");
        assert_eq!(batch.d_kv(), d_kv, "batch scratch d_kv mismatch");
        assert_eq!(batch.d_ffn(), d_ffn, "batch scratch d_ffn mismatch");
        assert_eq!(batch.vocab(), vocab, "batch scratch vocab mismatch");
        for f in &batch.faulted[..b] {
            f.store(false, Ordering::Relaxed);
        }
        // `Mutex::new(Vec::new())` allocates nothing — the fault log
        // costs heap only when a fault actually fires
        let fault_log: Mutex<Vec<LaneFault>> = Mutex::new(Vec::new());

        // per-lane step setup: advance the RoPE recurrence, map this
        // step's cache row in every layer, embed the token. Contained:
        // an out-of-range token or an exhausted KV pool faults only the
        // offending lane.
        for (i, lane) in lanes.iter_mut().enumerate() {
            let r = catch_unwind(AssertUnwindSafe(|| {
                assert!((lane.token as usize) < vocab, "token out of range");
                assert!(lane.state.pos < self.n_ctx, "context overflow");
                assert_eq!(lane.logits.len(), vocab, "logits buffer size");
                let st = &mut *lane.state;
                st.rope.advance();
                let len = st.pos + 1;
                let DecodeState {
                    tables,
                    pool: kv_pool,
                    scratch: sc,
                    ..
                } = st;
                debug_assert_eq!(kv_pool.row_width(), d_kv);
                for table in tables.iter_mut() {
                    table.ensure_tokens(kv_pool, len);
                }
                let at = lane.token as usize * d;
                sc.x.copy_from_slice(&self.embedding[at..at + d]);
            }));
            if let Err(cause) = r {
                batch.faulted[i].store(true, Ordering::Relaxed);
                record_fault(&fault_log, i, cause);
            }
        }

        for (l, lw) in self.layers.iter().enumerate() {
            // gather: norm + INT8-quantize every lane's activation row.
            // Faulted lanes are skipped; their stale scratch rows flow
            // through the shared GEMMs as dead rows (row-independent)
            // and are never scattered back.
            for (i, lane) in lanes.iter_mut().enumerate() {
                if batch.faulted[i].load(Ordering::Relaxed) {
                    continue;
                }
                let sc = &mut lane.state.scratch;
                rms_norm_into(&sc.x, &lw.attn_norm, &mut sc.xn);
                let s = quantize_int8_into(&sc.xn, &mut batch.qi8[i * d..(i + 1) * d]);
                batch.scales[i] = s;
            }
            // one shared weight pass each for Q, K, V
            let (qs, scales) = (&batch.qi8[..b * d], &batch.scales[..b]);
            batched_gemm(pool, qs, scales, &lw.wq.quant.weight, &mut batch.q[..b * d]);
            batched_gemm(pool, qs, scales, &lw.wk.quant.weight, &mut batch.k[..b * d_kv]);
            batched_gemm(pool, qs, scales, &lw.wv.quant.weight, &mut batch.v[..b * d_kv]);

            // scatter: RoPE, cache-row append, and the fused per-lane
            // attention sweep — one task per lane
            {
                let lanes_ptr = SharedMut::new(lanes.as_mut_ptr());
                let (bq, bk, bv) = (&batch.q, &batch.k, &batch.v);
                let flags = &batch.faulted;
                let attend_lane = |i: usize| {
                    if flags[i].load(Ordering::Relaxed) {
                        return;
                    }
                    // Contained: a panic in one lane's attention work
                    // (e.g. a poisoned block mapping) faults that lane
                    // only — worker-pool tasks for other lanes are
                    // untouched.
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        // SAFETY: task indices are distinct, so each task
                        // holds the only reference to its lane
                        let lane = unsafe { &mut *lanes_ptr.get().add(i) };
                        let pos = lane.state.pos;
                        let len = pos + 1;
                        let fxp_from = lane.state.fxp_rows.min(pos);
                        let DecodeState {
                            tables,
                            rope,
                            scratch: sc,
                            ..
                        } = &mut *lane.state;
                        let table = &mut tables[l];
                        let qrow = &bq[i * d..(i + 1) * d];
                        for head in 0..h {
                            let o = head * dh;
                            rope_apply_cached_into(
                                &qrow[o..o + dh],
                                &rope.cos,
                                &rope.sin,
                                &mut sc.q_rot[o..o + dh],
                            );
                        }
                        let ksrc = &bk[i * d_kv..(i + 1) * d_kv];
                        let krow = table.k_row_mut(pos);
                        for head in 0..h_kv {
                            let o = head * dh;
                            rope_apply_cached_into(
                                &ksrc[o..o + dh],
                                &rope.cos,
                                &rope.sin,
                                &mut krow[o..o + dh],
                            );
                        }
                        table.v_row_mut(pos).copy_from_slice(&bv[i * d_kv..(i + 1) * d_kv]);
                        match mode {
                            NumericsMode::DesktopF32 => {
                                sc.mha.reset();
                                sc.mha.extend_paged(&sc.q_rot, table, 0, len, scale);
                                sc.mha.finalize_into(&mut sc.attn_out);
                            }
                            NumericsMode::Accelerator => {
                                vector::quantize_into(&sc.q_rot, &mut sc.q_fxp);
                                for t in fxp_from..len {
                                    table.quantize_row(t);
                                }
                                sc.fxp_mha.reset();
                                sc.fxp_mha
                                    .extend_paged(&self.lut, &sc.q_fxp, table, 0, len, fxp_scale);
                                sc.fxp_mha.finalize_into(&mut sc.attn_fxp);
                                vector::dequantize_into(&sc.attn_fxp, &mut sc.attn_out);
                            }
                        }
                    }));
                    if let Err(cause) = r {
                        flags[i].store(true, Ordering::Relaxed);
                        record_fault(&fault_log, i, cause);
                    }
                };
                for_each_lane(pool, b, attend_lane);
            }

            // gather the attention outputs → one shared O-projection pass
            for (i, lane) in lanes.iter_mut().enumerate() {
                if batch.faulted[i].load(Ordering::Relaxed) {
                    continue;
                }
                let sc = &mut lane.state.scratch;
                let s = quantize_int8_into(&sc.attn_out, &mut batch.qi8[i * d..(i + 1) * d]);
                batch.scales[i] = s;
            }
            batched_gemm(
                pool,
                &batch.qi8[..b * d],
                &batch.scales[..b],
                &lw.wo.quant.weight,
                &mut batch.o[..b * d],
            );

            // residual + MLP norm, gathered for the gate/up passes
            for (i, lane) in lanes.iter_mut().enumerate() {
                if batch.faulted[i].load(Ordering::Relaxed) {
                    continue;
                }
                let sc = &mut lane.state.scratch;
                for (xi, oi) in sc.x.iter_mut().zip(&batch.o[i * d..(i + 1) * d]) {
                    *xi += oi;
                }
                rms_norm_into(&sc.x, &lw.mlp_norm, &mut sc.xn);
                let s = quantize_int8_into(&sc.xn, &mut batch.qi8[i * d..(i + 1) * d]);
                batch.scales[i] = s;
            }
            let (qs, scales) = (&batch.qi8[..b * d], &batch.scales[..b]);
            batched_gemm(pool, qs, scales, &lw.w_gate.quant.weight, &mut batch.gate[..b * d_ffn]);
            batched_gemm(pool, qs, scales, &lw.w_up.quant.weight, &mut batch.up[..b * d_ffn]);

            // SwiGLU per lane, gathered for the shared down pass
            for (i, lane) in lanes.iter_mut().enumerate() {
                if batch.faulted[i].load(Ordering::Relaxed) {
                    continue;
                }
                let sc = &mut lane.state.scratch;
                let gate = &batch.gate[i * d_ffn..(i + 1) * d_ffn];
                let up = &batch.up[i * d_ffn..(i + 1) * d_ffn];
                for ((a, &g), &u) in sc.act.iter_mut().zip(gate).zip(up) {
                    *a = silu(g) * u;
                }
                let s =
                    quantize_int8_into(&sc.act, &mut batch.qi8_ffn[i * d_ffn..(i + 1) * d_ffn]);
                batch.scales[i] = s;
            }
            batched_gemm(
                pool,
                &batch.qi8_ffn[..b * d_ffn],
                &batch.scales[..b],
                &lw.w_down.quant.weight,
                &mut batch.o[..b * d],
            );
            for (i, lane) in lanes.iter_mut().enumerate() {
                if batch.faulted[i].load(Ordering::Relaxed) {
                    continue;
                }
                let sc = &mut lane.state.scratch;
                for (xi, di) in sc.x.iter_mut().zip(&batch.o[i * d..(i + 1) * d]) {
                    *xi += di;
                }
            }
        }

        // final norm per lane → ONE shared lm_head pass → scatter the
        // logits rows into the lanes' buffers
        for (i, lane) in lanes.iter_mut().enumerate() {
            if batch.faulted[i].load(Ordering::Relaxed) {
                continue;
            }
            let sc = &mut lane.state.scratch;
            rms_norm_into(&sc.x, &self.final_norm, &mut sc.xn);
            let s = quantize_int8_into(&sc.xn, &mut batch.qi8[i * d..(i + 1) * d]);
            batch.scales[i] = s;
        }
        batched_gemm(
            pool,
            &batch.qi8[..b * d],
            &batch.scales[..b],
            &self.lm_head.quant.weight,
            &mut batch.logits[..b * vocab],
        );
        for (i, lane) in lanes.iter_mut().enumerate() {
            // a faulted lane's step never happened: logits untouched,
            // position unadvanced
            if batch.faulted[i].load(Ordering::Relaxed) {
                continue;
            }
            lane.logits
                .copy_from_slice(&batch.logits[i * vocab..(i + 1) * vocab]);
            let st = &mut *lane.state;
            if mode == NumericsMode::Accelerator {
                st.fxp_rows = st.pos + 1;
            }
            st.pos += 1;
        }
        fault_log.into_inner().unwrap_or_else(|e| e.into_inner())
    }

    /// Chunked prefill: feed a whole chunk of prompt tokens through the
    /// fused causal sweep in one call, instead of one [`Self::decode_step_into`]
    /// per token. Per layer the chunk runs in three passes — (1) per
    /// token: norm, QKV projections, RoPE, append the interleaved cache
    /// row; (2) one causal fused multi-head sweep per chunk query over
    /// its own prefix ([`crate::kernels::MhaSwiftKv::attend_chunk_paged`] /
    /// [`crate::kernels::FxpMhaSwiftKv::attend_chunk_paged`]); (3) per
    /// token: output projection, residual, MLP — so each layer's weights
    /// are streamed once per *chunk* rather than once per token, and the
    /// final-norm + logits projection run **only for the last chunk
    /// token** (pass `None` to skip them entirely for non-final chunks —
    /// the TTFT win of the serving path). Every per-token op is issued in
    /// the same order as the single-token decode path, so chunked
    /// prefill is bit-identical in `DesktopF32` and bit-exact in
    /// `Accelerator` numerics versus feeding the same tokens one
    /// `decode_step` at a time (`tests/prop_prefill.rs`).
    ///
    /// The per-token layer pipeline is intentionally *not* shared with
    /// [`Self::decode_step_into`]: the two bodies are independent
    /// implementations of the same op order, and the prefill property
    /// sweep cross-validates them against each other — a change that
    /// breaks the order in one path fails `prop_prefill.rs` instead of
    /// silently shifting both.
    ///
    /// Steady-state chunks (at or below the scratch's warmed-up chunk
    /// capacity) perform **zero heap allocation**, like the decode step.
    pub fn prefill_into(
        &self,
        st: &mut DecodeState,
        tokens: &[u32],
        mode: NumericsMode,
        logits: Option<&mut [f32]>,
    ) {
        let chunk = tokens.len();
        assert!(chunk > 0, "empty prefill chunk");
        assert!(
            tokens.iter().all(|&t| (t as usize) < self.vocab),
            "token out of range"
        );
        assert!(st.pos + chunk <= self.n_ctx, "context overflow");
        if let Some(ref out) = logits {
            assert_eq!(out.len(), self.vocab, "logits buffer size");
        }
        let d = self.d_model;
        let (h, dh) = (self.n_heads, self.d_head);
        let h_kv = self.n_kv_heads;
        let d_half = dh / 2;
        let scale = 1.0 / (dh as f32).sqrt();
        let fxp_scale = Fxp32::from_f64(1.0 / (dh as f64).sqrt());

        let pos = st.pos;
        let len = pos + chunk;
        let fxp_from = st.fxp_rows.min(pos);

        let DecodeState {
            tables,
            pool,
            rope,
            scratch: sc,
            ..
        } = st;
        debug_assert_eq!(pool.row_width(), h_kv * dh);
        sc.ensure_chunk(chunk);

        // advance the shared RoPE recurrence once per chunk token,
        // capturing each position's (cos, sin) row — the same recurrence
        // steps the per-token decode path takes, so the captured values
        // are bit-identical
        for j in 0..chunk {
            rope.advance();
            sc.rope_cos[j * d_half..(j + 1) * d_half].copy_from_slice(&rope.cos);
            sc.rope_sin[j * d_half..(j + 1) * d_half].copy_from_slice(&rope.sin);
        }

        // map every chunk row in every layer up front (blocks are
        // pre-allocated; this only moves them off the pool's free list)
        for table in tables.iter_mut() {
            table.ensure_tokens(pool, len);
        }

        // embed the whole chunk into its residual streams
        for (j, &t) in tokens.iter().enumerate() {
            sc.xs[j * d..(j + 1) * d]
                .copy_from_slice(&self.embedding[t as usize * d..(t as usize + 1) * d]);
        }

        for (l, lw) in self.layers.iter().enumerate() {
            let table = &mut tables[l];

            // pass 1 — per chunk token: norm, QKV, RoPE, cache-row append.
            // Row pos+j is written before any later chunk query sweeps it,
            // so causality within the chunk holds by construction.
            for j in 0..chunk {
                rms_norm_into(&sc.xs[j * d..(j + 1) * d], &lw.attn_norm, &mut sc.xn);
                lw.wq.forward_into(&sc.xn, &mut sc.qi8, &mut sc.q);
                lw.wk.forward_into(&sc.xn, &mut sc.qi8, &mut sc.k);
                lw.wv.forward_into(&sc.xn, &mut sc.qi8, &mut sc.v);
                let cos = &sc.rope_cos[j * d_half..(j + 1) * d_half];
                let sin = &sc.rope_sin[j * d_half..(j + 1) * d_half];
                for head in 0..h {
                    let o = head * dh;
                    rope_apply_cached_into(
                        &sc.q[o..o + dh],
                        cos,
                        sin,
                        &mut sc.q_rots[j * d + o..j * d + o + dh],
                    );
                }
                let krow = table.k_row_mut(pos + j);
                for head in 0..h_kv {
                    let o = head * dh;
                    rope_apply_cached_into(&sc.k[o..o + dh], cos, sin, &mut krow[o..o + dh]);
                }
                table.v_row_mut(pos + j).copy_from_slice(&sc.v);
            }

            // pass 2 — the fused causal chunk sweep: every chunk query
            // advances all heads over its own prefix, same op order as
            // the per-token path
            match mode {
                NumericsMode::DesktopF32 => {
                    sc.mha.attend_chunk_paged(
                        &sc.q_rots[..chunk * d],
                        table,
                        pos,
                        chunk,
                        scale,
                        &mut sc.attn_outs[..chunk * d],
                    );
                }
                NumericsMode::Accelerator => {
                    // quantize the rotated chunk queries once per layer and
                    // append the missing (k, v) rows to the Q15.17 mirror —
                    // steady state that is exactly this chunk's rows; after
                    // DesktopF32 steps the gap is backfilled. Mirrored
                    // history is never re-quantized.
                    vector::quantize_into(&sc.q_rots[..chunk * d], &mut sc.q_fxps[..chunk * d]);
                    for t in fxp_from..len {
                        table.quantize_row(t);
                    }
                    sc.fxp_mha.attend_chunk_paged(
                        &self.lut,
                        &sc.q_fxps[..chunk * d],
                        table,
                        pos,
                        chunk,
                        fxp_scale,
                        &mut sc.attn_fxps[..chunk * d],
                    );
                    vector::dequantize_into(
                        &sc.attn_fxps[..chunk * d],
                        &mut sc.attn_outs[..chunk * d],
                    );
                }
            }

            // pass 3 — per chunk token: output projection, residual, MLP
            for j in 0..chunk {
                lw.wo
                    .forward_into(&sc.attn_outs[j * d..(j + 1) * d], &mut sc.qi8, &mut sc.o);
                for (xi, oi) in sc.xs[j * d..(j + 1) * d].iter_mut().zip(&sc.o) {
                    *xi += oi;
                }
                rms_norm_into(&sc.xs[j * d..(j + 1) * d], &lw.mlp_norm, &mut sc.xn);
                lw.w_gate.forward_into(&sc.xn, &mut sc.qi8, &mut sc.gate);
                lw.w_up.forward_into(&sc.xn, &mut sc.qi8, &mut sc.up);
                for ((a, &g), &u) in sc.act.iter_mut().zip(&sc.gate).zip(&sc.up) {
                    *a = silu(g) * u;
                }
                lw.w_down.forward_into(&sc.act, &mut sc.qi8, &mut sc.down);
                for (xi, di) in sc.xs[j * d..(j + 1) * d].iter_mut().zip(&sc.down) {
                    *xi += di;
                }
            }
        }

        // the logits projection runs only for the final chunk token —
        // every earlier position's logits would be discarded anyway
        if let Some(out) = logits {
            rms_norm_into(&sc.xs[(chunk - 1) * d..chunk * d], &self.final_norm, &mut sc.xn);
            self.lm_head.forward_into(&sc.xn, &mut sc.qi8, out);
        }

        if mode == NumericsMode::Accelerator {
            st.fxp_rows = len;
        }
        st.pos = len;
    }

    /// [`Self::prefill_into`] returning freshly-allocated logits for the
    /// final chunk token.
    pub fn prefill(&self, st: &mut DecodeState, tokens: &[u32], mode: NumericsMode) -> Vec<f32> {
        let mut logits = vec![0.0f32; self.vocab];
        self.prefill_into(st, tokens, mode, Some(&mut logits[..]));
        logits
    }

    /// Debug access to cache rows (cross-validation against the JAX side).
    /// Returns the `[d_head]` K/V slices of (layer, **KV** head, position),
    /// read through the layer's block table.
    pub fn debug_cache<'a>(
        &self,
        st: &'a DecodeState,
        l: usize,
        h: usize,
        t: usize,
    ) -> (&'a [f32], &'a [f32]) {
        assert!(h < self.n_kv_heads, "KV head out of range");
        let o = h * self.d_head;
        (
            &st.tables[l].k_row(t)[o..o + self.d_head],
            &st.tables[l].v_row(t)[o..o + self.d_head],
        )
    }

    /// Debug access to the RoPE recurrence values.
    pub fn debug_rope(st: &DecodeState) -> (&[f32], &[f32]) {
        (&st.rope.cos, &st.rope.sin)
    }

    /// Greedy generation: prefill `prompt` through the fused chunked
    /// sweep ([`Self::prefill_into`], one pass, logits only for the last
    /// prompt token), then generate `steps` tokens one decode step at a
    /// time. The logits buffer is allocated once and reused.
    ///
    /// # Panics
    /// When `prompt.len() + steps > n_ctx` — the request cannot fit the
    /// context window. Checked up front so the caller always receives
    /// exactly `steps` tokens instead of a silently truncated tail.
    pub fn generate(&self, prompt: &[u32], steps: usize, mode: NumericsMode) -> Vec<u32> {
        assert!(
            prompt.len() + steps <= self.n_ctx,
            "generate would overflow the context window: prompt {} + steps {steps} > n_ctx {}",
            prompt.len(),
            self.n_ctx
        );
        let mut st = self.new_state();
        let mut logits = vec![0.0f32; self.vocab];
        if !prompt.is_empty() {
            self.prefill_into(&mut st, prompt, mode, Some(&mut logits[..]));
        }
        let mut out = Vec::with_capacity(steps);
        for i in 0..steps {
            let next = argmax(&logits) as u32;
            out.push(next);
            // the final sampled token is never fed back — its logits
            // would be discarded
            if i + 1 < steps {
                self.decode_step_into(&mut st, next, mode, &mut logits);
            }
        }
        out
    }
}

/// One shared W4A8 weight pass over `xscales.len()` gathered INT8
/// activation rows, optionally split across the worker pool by
/// output-column range. Tasks write disjoint columns of `out`, so the
/// pooled result is identical to the serial call for any worker count
/// or schedule.
fn batched_gemm(
    pool: Option<&WorkerPool>,
    qs: &[i8],
    xscales: &[f32],
    w: &Int4Matrix,
    out: &mut [f32],
) {
    match pool {
        None => gemm_w4a8_raw_into(qs, xscales, w, out),
        Some(p) => {
            let dout = w.dout;
            let parts = p.parallelism().min(dout);
            let out_ptr = SharedMut::new(out.as_mut_ptr());
            let out_len = out.len();
            p.run(parts, |t| {
                let j0 = dout * t / parts;
                let j1 = dout * (t + 1) / parts;
                // SAFETY: tasks cover disjoint column ranges of `out`,
                // whose exclusive borrow the caller holds across the run
                unsafe {
                    gemm_w4a8_raw_cols_ptr(qs, xscales, w, j0, j1, out_ptr.get(), out_len);
                }
            });
        }
    }
}

/// Run `f(0) … f(lanes - 1)` inline, or one task per lane across the
/// worker pool. `f` must make concurrent calls with distinct indices
/// safe (each touches only its own lane).
fn for_each_lane<F: Fn(usize) + Sync>(pool: Option<&WorkerPool>, lanes: usize, f: F) {
    match pool {
        None => {
            for i in 0..lanes {
                f(i);
            }
        }
        Some(p) => p.run(lanes, f),
    }
}

/// RMS normalization (SFU op).
pub fn rms_norm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rms_norm_into(x, g, &mut out);
    out
}

/// [`rms_norm`] into a caller-owned buffer (no allocation).
pub fn rms_norm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let var = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + 1e-5).sqrt();
    for ((o, &v), &w) in out.iter_mut().zip(x).zip(g) {
        *o = v * r * w;
    }
}

/// SiLU activation (SFU op).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Index of the maximum logit (greedy sampling). Total over all f32
/// values: NaNs never win a comparison, so a NaN-poisoned logit row
/// yields the best finite index (0 if every entry is NaN) instead of
/// panicking mid-serve.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best = i;
            best_v = x;
        }
    }
    best
}

/// Indices of the top-k logits, descending. Same NaN contract as
/// [`argmax`]: NaNs never outrank a finite value (they sort as −∞
/// regardless of sign bit) and never panic the sort.
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let nan_last = |x: f32| if x.is_nan() { f32::NEG_INFINITY } else { x };
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| nan_last(xs[b]).total_cmp(&nan_last(xs[a])));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::WeightStore;

    fn model() -> Option<TinyModel> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| TinyModel::load(&WeightStore::load(&dir).unwrap()).unwrap())
    }

    fn tiny_synth() -> TinyModel {
        TinyModel::synthetic(42, 64, 32, 4, 4, 2, 64, 48)
    }

    /// Grouped-query variant: 4 query heads sharing 2 KV heads.
    fn tiny_synth_gqa() -> TinyModel {
        TinyModel::synthetic(42, 64, 32, 4, 2, 2, 64, 48)
    }

    #[test]
    fn decode_produces_finite_logits_both_modes() {
        let Some(m) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            let logits = m.decode_step(&mut st, 7, mode);
            assert_eq!(logits.len(), m.vocab);
            assert!(logits.iter().all(|x| x.is_finite()), "{mode:?}");
        }
    }

    #[test]
    fn modes_agree_on_top1_short_sequence() {
        let Some(m) = model() else {
            return;
        };
        let mut sd = m.new_state();
        let mut sa = m.new_state();
        for &t in &[1u32, 5, 9, 2] {
            let ld = m.decode_step(&mut sd, t, NumericsMode::DesktopF32);
            let la = m.decode_step(&mut sa, t, NumericsMode::Accelerator);
            assert_eq!(argmax(&ld), argmax(&la), "top-1 diverged at token {t}");
        }
    }

    #[test]
    fn generation_deterministic() {
        let Some(m) = model() else {
            return;
        };
        let a = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        let b = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        assert_eq!(a, b);
    }

    #[test]
    fn dump_intermediates_for_cross_check() {
        // printed with --nocapture; compared against the python dump in
        // the build log (manual diff aid, asserts only basic sanity)
        let Some(m) = model() else {
            return;
        };
        let mut st = m.new_state();
        for (i, &t) in [3u32, 141, 27].iter().enumerate() {
            let l = m.decode_step(&mut st, t, NumericsMode::DesktopF32);
            println!("step {i}: logits[:4] = {:?}, argmax = {}", &l[..4], argmax(&l));
        }
        let (cos, _sin) = TinyModel::debug_rope(&st);
        println!("cos[:4] {:?}", &cos[..4]);
        let (k0, _) = m.debug_cache(&st, 0, 0, 0);
        let (k1, v1) = m.debug_cache(&st, 0, 0, 1);
        println!("kc l0 h0 row0[:4] {:?}", &k0[..4]);
        println!("kc l0 h0 row1[:4] {:?}", &k1[..4]);
        println!("vc l0 h0 row1[:4] {:?}", &v1[..4]);
    }

    #[test]
    fn synthetic_decode_finite_logits_both_modes() {
        let m = tiny_synth();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            for &t in &[7u32, 1, 63, 0] {
                let logits = m.decode_step(&mut st, t, mode);
                assert_eq!(logits.len(), m.vocab);
                assert!(logits.iter().all(|x| x.is_finite()), "{mode:?}");
            }
        }
    }

    #[test]
    fn decode_step_into_matches_decode_step() {
        let m = tiny_synth();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut s1 = m.new_state();
            let mut s2 = m.new_state();
            let mut buf = vec![0.0f32; m.vocab];
            for &t in &[1u32, 9, 30, 2, 2] {
                let a = m.decode_step(&mut s1, t, mode);
                m.decode_step_into(&mut s2, t, mode, &mut buf);
                assert_eq!(a, buf, "{mode:?} diverged at token {t}");
            }
        }
    }

    #[test]
    fn reset_state_matches_fresh_state() {
        let m = tiny_synth();
        let mut st = m.new_state();
        for &t in &[3u32, 5, 7] {
            m.decode_step(&mut st, t, NumericsMode::Accelerator);
        }
        assert!(st.kv_blocks_in_use() > 0);
        st.reset_for_reuse();
        assert_eq!(st.pos, 0);
        // reclamation: every block is back in the pool
        assert_eq!(st.kv_blocks_in_use(), 0);
        assert_eq!(
            st.kv_pool().free_blocks(),
            st.kv_pool().total_blocks(),
            "reset_for_reuse must return all blocks to the pool"
        );
        let a = m.decode_step(&mut st, 11, NumericsMode::Accelerator);
        let mut fresh = m.new_state();
        let b = m.decode_step(&mut fresh, 11, NumericsMode::Accelerator);
        assert_eq!(a, b, "recycled state must decode like a fresh one");
    }

    #[test]
    fn dropping_a_state_returns_blocks_to_the_shared_pool() {
        let m = tiny_synth();
        let pool = m.new_pool(m.blocks_per_seq(4), 4);
        {
            let mut st = m.new_state_in(pool.clone());
            for &t in &[3u32, 5, 7, 9, 2] {
                m.decode_step(&mut st, t, NumericsMode::DesktopF32);
            }
            assert!(pool.free_blocks() < pool.total_blocks());
        }
        assert_eq!(pool.free_blocks(), pool.total_blocks());
    }

    #[test]
    fn block_len_does_not_change_decode_results() {
        // the storage contract changed; the numbers must not — decode
        // over 1-, 3- and 16-token blocks is bit-identical per mode
        let m = tiny_synth_gqa();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut base_st = m.new_state();
            let mut base = Vec::new();
            for &t in &[1u32, 9, 30, 2, 2, 17] {
                base.push(m.decode_step(&mut base_st, t, mode));
            }
            for block_len in [1usize, 3, 16] {
                let pool = m.new_pool(m.blocks_per_seq(block_len), block_len);
                let mut st = m.new_state_in(pool);
                for (i, &t) in [1u32, 9, 30, 2, 2, 17].iter().enumerate() {
                    let logits = m.decode_step(&mut st, t, mode);
                    assert_eq!(
                        logits, base[i],
                        "{mode:?} bl={block_len} step {i}: paged decode diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn mixed_modes_backfill_quantized_mirror() {
        // DesktopF32 steps leave the Q15.17 mirror behind; the next
        // Accelerator step must backfill it from the f32 cache so the
        // fused sweep sees real history, not zeros.
        let m = tiny_synth();
        let mut st = m.new_state();
        for &t in &[3u32, 9, 27] {
            m.decode_step(&mut st, t, NumericsMode::DesktopF32);
        }
        assert_eq!(st.fxp_rows, 0);
        let logits = m.decode_step(&mut st, 11, NumericsMode::Accelerator);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(st.fxp_rows, 4);
        for l in 0..m.n_layers {
            for t in 0..4 {
                let table = &st.tables[l];
                for (i, (q, &f)) in table.kq_row(t).iter().zip(table.k_row(t)).enumerate() {
                    assert_eq!(
                        q.raw(),
                        Fxp32::from_f32(f).raw(),
                        "k mirror stale at layer {l} row {t} lane {i}"
                    );
                }
                for (i, (q, &f)) in table.vq_row(t).iter().zip(table.v_row(t)).enumerate() {
                    assert_eq!(
                        q.raw(),
                        Fxp32::from_f32(f).raw(),
                        "v mirror stale at layer {l} row {t} lane {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn synthetic_generation_deterministic_and_in_vocab() {
        let m = tiny_synth();
        let a = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        let b = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (t as usize) < m.vocab));
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn synthetic_shapes_consistent() {
        let m = tiny_synth();
        assert_eq!(m.d_model, m.n_heads * m.d_head);
        assert_eq!(m.lm_head.dout(), m.vocab);
        assert_eq!(m.layers.len(), m.n_layers);
    }

    #[test]
    fn gqa_synthetic_shapes_and_cache_shrink() {
        let m = tiny_synth_gqa();
        assert_eq!(m.n_kv_heads, 2);
        let d_kv = m.n_kv_heads * m.d_head;
        assert_eq!(m.layers[0].wk.dout(), d_kv);
        assert_eq!(m.layers[0].wv.dout(), d_kv);
        assert_eq!(m.layers[0].wq.dout(), m.d_model);
        // pool rows hold n_kv_heads * d_head — half of an MHA block here
        let st = m.new_state();
        assert_eq!(st.kv_pool().row_width(), d_kv);
        let mha_pool = tiny_synth().new_state().kv_pool().clone();
        assert_eq!(mha_pool.row_width(), st.kv_pool().row_width() * 2);
        assert_eq!(
            mha_pool.bytes_per_block(),
            st.kv_pool().bytes_per_block() * 2,
            "GQA must halve per-block KV bytes at equal block_len"
        );
        // both pools cover one full-context sequence
        assert_eq!(
            st.kv_pool().total_blocks(),
            m.blocks_per_seq(DEFAULT_KV_BLOCK_LEN)
        );
    }

    #[test]
    fn gqa_decode_finite_logits_both_modes() {
        let m = tiny_synth_gqa();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            for &t in &[7u32, 1, 63, 0] {
                let logits = m.decode_step(&mut st, t, mode);
                assert_eq!(logits.len(), m.vocab);
                assert!(logits.iter().all(|x| x.is_finite()), "{mode:?}");
            }
        }
    }

    #[test]
    fn gqa_decode_step_into_matches_decode_step() {
        let m = tiny_synth_gqa();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut s1 = m.new_state();
            let mut s2 = m.new_state();
            let mut buf = vec![0.0f32; m.vocab];
            for &t in &[1u32, 9, 30, 2, 2] {
                let a = m.decode_step(&mut s1, t, mode);
                m.decode_step_into(&mut s2, t, mode, &mut buf);
                assert_eq!(a, buf, "{mode:?} diverged at token {t}");
            }
        }
    }

    #[test]
    fn batched_decode_steps_match_solo_steps() {
        // 3 lanes with different token streams: every lane of the
        // batched step must be bit-identical to its solo twin
        for m in [tiny_synth(), tiny_synth_gqa()] {
            for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
                let mut solo: Vec<DecodeState> = (0..3).map(|_| m.new_state()).collect();
                let mut batched: Vec<DecodeState> = (0..3).map(|_| m.new_state()).collect();
                let mut batch = m.new_batch_scratch();
                let mut want = vec![0.0f32; m.vocab];
                let mut got = vec![0.0f32; 3 * m.vocab];
                for step in 0..5u32 {
                    let tokens: Vec<u32> =
                        (0..3u32).map(|i| (step * 7 + i * 13 + 1) % m.vocab as u32).collect();
                    let mut lanes: Vec<BatchLane> = batched
                        .iter_mut()
                        .zip(got.chunks_mut(m.vocab))
                        .zip(&tokens)
                        .map(|((state, logits), &token)| BatchLane {
                            state,
                            token,
                            logits,
                        })
                        .collect();
                    m.decode_steps_into(&mut lanes, mode, &mut batch, None);
                    for (i, st) in solo.iter_mut().enumerate() {
                        m.decode_step_into(st, tokens[i], mode, &mut want);
                        assert_eq!(
                            &got[i * m.vocab..(i + 1) * m.vocab],
                            &want[..],
                            "{mode:?} step {step} lane {i}: batched decode diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batched_decode_width_one_matches_solo() {
        let m = tiny_synth();
        let mut solo_st = m.new_state();
        let mut batch_st = m.new_state();
        let mut batch = m.new_batch_scratch();
        let mut want = vec![0.0f32; m.vocab];
        let mut got = vec![0.0f32; m.vocab];
        for &t in &[5u32, 9, 1, 30] {
            m.decode_step_into(&mut solo_st, t, NumericsMode::Accelerator, &mut want);
            let mut lanes = [BatchLane {
                state: &mut batch_st,
                token: t,
                logits: &mut got[..],
            }];
            m.decode_steps_into(&mut lanes, NumericsMode::Accelerator, &mut batch, None);
            assert_eq!(got, want, "width-1 batched step diverged at token {t}");
        }
    }

    #[test]
    fn batched_decode_empty_is_a_noop() {
        let m = tiny_synth();
        let mut batch = m.new_batch_scratch();
        let mut lanes: [BatchLane; 0] = [];
        m.decode_steps_into(&mut lanes, NumericsMode::DesktopF32, &mut batch, None);
        assert_eq!(batch.batch_capacity(), 0);
    }

    #[test]
    fn gqa_generation_deterministic_and_reset_safe() {
        let m = tiny_synth_gqa();
        let a = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        let b = m.generate(&[1, 2, 3], 8, NumericsMode::Accelerator);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (t as usize) < m.vocab));
        // recycled GQA state decodes like a fresh one
        let mut st = m.new_state();
        for &t in &[3u32, 5, 7] {
            m.decode_step(&mut st, t, NumericsMode::DesktopF32);
        }
        st.reset_for_reuse();
        let x = m.decode_step(&mut st, 11, NumericsMode::DesktopF32);
        let mut fresh = m.new_state();
        let y = m.decode_step(&mut fresh, 11, NumericsMode::DesktopF32);
        assert_eq!(x, y);
    }

    #[test]
    fn gqa_mixed_modes_backfill_quantized_mirror() {
        let m = tiny_synth_gqa();
        let mut st = m.new_state();
        for &t in &[3u32, 9] {
            m.decode_step(&mut st, t, NumericsMode::DesktopF32);
        }
        assert_eq!(st.fxp_rows, 0);
        let logits = m.decode_step(&mut st, 11, NumericsMode::Accelerator);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(st.fxp_rows, 3);
        for l in 0..m.n_layers {
            for t in 0..3 {
                let table = &st.tables[l];
                for (i, (q, &f)) in table.kq_row(t).iter().zip(table.k_row(t)).enumerate() {
                    assert_eq!(
                        q.raw(),
                        Fxp32::from_f32(f).raw(),
                        "k mirror stale at layer {l} row {t} lane {i}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "KV head out of range")]
    fn debug_cache_rejects_query_head_index() {
        let m = tiny_synth_gqa();
        let mut st = m.new_state();
        m.decode_step(&mut st, 1, NumericsMode::DesktopF32);
        // head 2 is a valid *query* head but not a KV head (only 2 exist)
        let _ = m.debug_cache(&st, 0, 2, 0);
    }

    #[test]
    fn top_k_ordering() {
        let xs = vec![0.1f32, 3.0, -1.0, 2.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 0]);
        assert_eq!(argmax(&xs), 1);
    }

    #[test]
    fn argmax_is_nan_total() {
        // NaNs must never panic the sampler and must never win
        assert_eq!(argmax(&[f32::NAN, 1.0, f32::NAN, 0.5]), 1);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0, f32::NAN]), 1);
        assert_eq!(argmax(&[]), 0);
        // top_k shares the contract: NaN never outranks a finite value
        assert_eq!(top_k(&[1.0, f32::NAN, 2.0], 2), vec![2, 0]);
        assert_eq!(top_k(&[f32::NAN, 7.0], 2), vec![1, 0]);
    }

    #[test]
    fn prefill_matches_per_token_decode_both_modes() {
        for m in [tiny_synth(), tiny_synth_gqa()] {
            let prompt = [1u32, 9, 30, 2, 2, 17, 5];
            for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
                // reference: one decode step per prompt token
                let mut ref_st = m.new_state();
                let mut want = vec![0.0f32; m.vocab];
                for &t in &prompt {
                    m.decode_step_into(&mut ref_st, t, mode, &mut want);
                }
                // whole-prompt chunk
                let mut st = m.new_state();
                let got = m.prefill(&mut st, &prompt, mode);
                assert_eq!(got, want, "{mode:?}: whole-prompt prefill diverged");
                assert_eq!(st.pos, prompt.len());
                // split chunks (3 + 4), logits skipped for the first
                let mut st2 = m.new_state();
                m.prefill_into(&mut st2, &prompt[..3], mode, None);
                let got2 = m.prefill(&mut st2, &prompt[3..], mode);
                assert_eq!(got2, want, "{mode:?}: split-chunk prefill diverged");
            }
        }
    }

    #[test]
    fn prefill_then_decode_matches_pure_decode() {
        let m = tiny_synth_gqa();
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut ref_st = m.new_state();
            let mut want = vec![0.0f32; m.vocab];
            for &t in &[4u32, 8, 15, 16, 23] {
                m.decode_step_into(&mut ref_st, t, mode, &mut want);
            }
            let mut st = m.new_state();
            m.prefill_into(&mut st, &[4, 8, 15, 16], mode, None);
            let got = m.decode_step(&mut st, 23, mode);
            assert_eq!(got, want, "{mode:?}: decode after chunked prefill diverged");
        }
    }

    #[test]
    fn generate_uses_chunked_prefill_deterministically() {
        let m = tiny_synth();
        // generate (chunked prefill) vs a hand-rolled per-token loop
        let prompt = [1u32, 2, 3, 30];
        let steps = 6;
        let mut st = m.new_state();
        let mut logits = vec![0.0f32; m.vocab];
        for &t in &prompt {
            m.decode_step_into(&mut st, t, NumericsMode::Accelerator, &mut logits);
        }
        let mut want = Vec::new();
        for i in 0..steps {
            let next = argmax(&logits) as u32;
            want.push(next);
            if i + 1 < steps {
                m.decode_step_into(&mut st, next, NumericsMode::Accelerator, &mut logits);
            }
        }
        assert_eq!(m.generate(&prompt, steps, NumericsMode::Accelerator), want);
    }

    #[test]
    #[should_panic(expected = "overflow the context window")]
    fn generate_rejects_oversized_request_up_front() {
        let m = tiny_synth(); // n_ctx = 48
        let prompt: Vec<u32> = (0..40).map(|i| i % m.vocab as u32).collect();
        let _ = m.generate(&prompt, 9, NumericsMode::DesktopF32);
    }

    #[test]
    fn generate_fills_the_context_window_exactly() {
        let m = tiny_synth(); // n_ctx = 48
        let prompt: Vec<u32> = (0..40).map(|i| i % m.vocab as u32).collect();
        let out = m.generate(&prompt, 8, NumericsMode::DesktopF32);
        assert_eq!(out.len(), 8, "a request that exactly fits must not truncate");
    }

    #[test]
    #[should_panic(expected = "empty prefill chunk")]
    fn prefill_rejects_empty_chunk() {
        let m = tiny_synth();
        let mut st = m.new_state();
        m.prefill_into(&mut st, &[], NumericsMode::DesktopF32, None);
    }

    #[test]
    fn prefill_backfills_quantized_mirror_after_desktop_steps() {
        // DesktopF32 chunk, then an Accelerator chunk: the fxp mirror
        // must be backfilled for the desktop rows before the fused
        // Q15.17 sweep reads them
        let m = tiny_synth();
        let mut st = m.new_state();
        m.prefill_into(&mut st, &[3, 9, 27], NumericsMode::DesktopF32, None);
        assert_eq!(st.fxp_rows, 0);
        let logits = m.prefill(&mut st, &[11, 4], NumericsMode::Accelerator);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(st.fxp_rows, 5);
        // and it must agree with the pure per-token mixed-mode run
        let mut ref_st = m.new_state();
        let mut want = vec![0.0f32; m.vocab];
        for &t in &[3u32, 9, 27] {
            m.decode_step_into(&mut ref_st, t, NumericsMode::DesktopF32, &mut want);
        }
        for &t in &[11u32, 4] {
            m.decode_step_into(&mut ref_st, t, NumericsMode::Accelerator, &mut want);
        }
        assert_eq!(logits, want);
    }

    #[test]
    fn rms_norm_unit_scale() {
        let x = vec![3.0f32, -3.0, 3.0, -3.0];
        let g = vec![1.0f32; 4];
        let y = rms_norm(&x, &g);
        for v in y {
            assert!((v.abs() - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0 / (1.0 + (-10.0f32).exp())).abs() < 1e-6);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
