//! Cycle-level model of the SwiftKV-MHA accelerator (Fig. 4) and of the
//! single-hardware-set "edge accelerator" used by the Fig. 7 algorithm
//! comparison.
//!
//! The paper's performance claims decompose into *cycle counts × clock*
//! and *bytes ÷ HBM bandwidth*; this module reproduces them from the same
//! architecture parameters the paper states (225 MHz, 32 SKV processors ×
//! 128 DSPs, 460 GB/s HBM), plus a small set of micro-architectural
//! latency constants documented in [`ArchConfig`] and calibrated once
//! against Fig. 7(b) / Table III (see DESIGN.md §Calibration and
//! EXPERIMENTS.md for paper-vs-model numbers).
//!
//! Submodules:
//! - [`edge_hw`] — the Fig. 7 experiment: four attention schedules on one
//!   shared hardware set (same dot/exp/mul/div units).
//! - [`array`] — the SKV Processor Array in GEMV and attention modes.
//! - [`sfu`], [`dispatcher`] — non-MAC ops and data movement.
//! - [`hbm`] — bandwidth/traffic model.
//! - [`layer_sched`] — full per-token decode schedule of a model
//!   (Fig. 8(a) breakdown, Table III latency/throughput).
//! - [`resources`] — FPGA utilization estimate (Table II).
//! - [`power`] — power/efficiency model (Tables III/IV, Fig. 8(b)).

pub mod array;
pub mod dispatcher;
pub mod edge_hw;
pub mod hbm;
pub mod layer_sched;
pub mod power;
pub mod resources;
pub mod sfu;

pub use edge_hw::{AttentionAlg, CycleBreakdown};
pub use layer_sched::{simulate_token, TokenSim};

/// Architecture parameters of SwiftKV-MHA (§IV) plus the shared-unit
/// latencies used by the Fig. 7 single-hardware-set experiments.
///
/// The structural parameters (top block) come straight from the paper.
/// The latency constants (bottom block) are the paper's implied
/// micro-architecture: a 4-cycle pipelined dot unit, an 8-cycle exp unit
/// and a 12-cycle iterative divider; schedules differ in whether data
/// dependencies let them keep those units full (see `edge_hw`).
#[derive(Debug, Clone)]
pub struct ArchConfig {
    // ---- stated by the paper -------------------------------------------
    /// Core clock (MHz). Paper: 225 MHz on the U55C.
    pub clock_mhz: f64,
    /// Number of SKV processors (one per head). Paper: 32.
    pub n_processors: usize,
    /// DSP48E2 count per Public MAC Array. Paper: 128.
    pub dsp_per_processor: usize,
    /// DSPs consumed per FXP32×FXP32 multiply. Paper: 4 (27×18 DSPs).
    pub fxp_dsp_per_mul: usize,
    /// HBM bandwidth (GB/s). Paper: 460.
    pub hbm_gbps: f64,
    /// RoPE pair-update latency in cycles. Paper: 3 (Fig. 6).
    pub rope_pair_latency: u64,

    // ---- micro-architectural latency constants -------------------------
    /// Dot-product unit pipeline depth.
    pub dot_latency: u64,
    /// Exp unit latency (LUT lookup + interpolate + shift).
    pub exp_latency: u64,
    /// Vector multiply unit latency.
    pub mul_latency: u64,
    /// Iterative divider latency (= initiation interval when serialized).
    pub div_latency: u64,
    /// SFU vector lanes (elements per cycle for casts/adds/SiLU).
    pub sfu_lanes: usize,
    /// Dispatcher bandwidth in bytes/cycle between array, buffer and SFU.
    pub dispatch_bytes_per_cycle: u64,
    /// Fraction of the *smaller* of (compute, memory) hidden by
    /// double-buffered prefetch within a stage. Calibrated against
    /// Table III (see `layer_sched::tests::calibration_llama2`).
    pub prefetch_eff: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        ArchConfig {
            clock_mhz: 225.0,
            n_processors: 32,
            dsp_per_processor: 128,
            fxp_dsp_per_mul: 4,
            hbm_gbps: 460.0,
            rope_pair_latency: 3,
            dot_latency: 4,
            exp_latency: 8,
            mul_latency: 2,
            div_latency: 12,
            sfu_lanes: 32,
            dispatch_bytes_per_cycle: 128,
            prefetch_eff: 0.38,
        }
    }
}

impl ArchConfig {
    /// FXP32 dot-product lanes per processor (dims per cycle).
    /// Paper: 128 DSPs / 4 per multiply = 32.
    pub fn fxp_lanes(&self) -> usize {
        self.dsp_per_processor / self.fxp_dsp_per_mul
    }

    /// INT4×INT8 lanes per processor (1 DSP each). Paper: 128.
    pub fn int_lanes(&self) -> usize {
        self.dsp_per_processor
    }

    /// Array-wide GEMV reduction width (dims per cycle). Paper: 4096.
    pub fn gemv_width(&self) -> usize {
        self.n_processors * self.int_lanes()
    }

    /// HBM bytes transferred per core cycle.
    pub fn hbm_bytes_per_cycle(&self) -> f64 {
        self.hbm_gbps * 1e9 / (self.clock_mhz * 1e6)
    }

    /// Convert cycles to microseconds.
    pub fn cycles_to_us(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_mhz
    }

    /// Convert cycles to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        self.cycles_to_us(cycles) / 1e3
    }

    /// Combine a compute-cycle and memory-cycle cost for one stage:
    /// `max + (1 − prefetch_eff) · min` (double-buffering hides
    /// `prefetch_eff` of the shorter side under the longer).
    pub fn overlap(&self, compute: u64, memory: u64) -> u64 {
        let hi = compute.max(memory);
        let lo = compute.min(memory);
        hi + ((1.0 - self.prefetch_eff) * lo as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_structural_constants() {
        let a = ArchConfig::default();
        assert_eq!(a.fxp_lanes(), 32); // 32-dim FXP32 dot per cycle
        assert_eq!(a.gemv_width(), 4096); // 4096-dim INT dot per cycle
        // 460 GB/s at 225 MHz ≈ 2044 bytes per cycle
        assert!((a.hbm_bytes_per_cycle() - 2044.4).abs() < 1.0);
    }

    #[test]
    fn gemv_throughput_gops_matches_paper() {
        // §V: one 4096-dim dot per cycle at 225 MHz → 1836 GOPS
        let a = ArchConfig::default();
        let gops = 2.0 * a.gemv_width() as f64 * a.clock_mhz * 1e6 / 1e9;
        assert!((gops - 1843.2).abs() < 10.0, "GOPS = {gops}");
        // paper rounds to 1836; we are within 0.5%
        assert!((gops - 1836.0).abs() / 1836.0 < 0.01);
    }

    #[test]
    fn cycle_time_conversions() {
        let a = ArchConfig::default();
        assert!((a.cycles_to_us(225) - 1.0).abs() < 1e-9);
        assert!((a.cycles_to_ms(2_250_000) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn overlap_bounds() {
        let a = ArchConfig::default();
        let t = a.overlap(100, 100);
        assert!(t >= 100 && t <= 200);
        assert_eq!(a.overlap(100, 0), 100);
        // fully eager prefetch would be pure max
        let eager = ArchConfig {
            prefetch_eff: 1.0,
            ..ArchConfig::default()
        };
        assert_eq!(eager.overlap(70, 100), 100);
    }
}
