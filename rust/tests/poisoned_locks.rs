//! Poisoned-lock recovery: a lane that panics mid-step must not wedge
//! the shared sync state it was holding. Both mutexes on the serving
//! path recover via `unwrap_or_else(PoisonError::into_inner)` — their
//! critical sections keep the data consistent (push/pop on the KV free
//! list, a counter under the pool's sleep lock), so recovery is sound —
//! and these tests drive each one through a deliberately poisoned lock:
//!
//! - [`BlockPool`]'s free list (`poison_free_list_for_tests`),
//! - [`WorkerPool`]'s sleep mutex (`poison_sleep_mutex_for_tests`),
//! - a full `TinyModel` decode over a poisoned KV pool, which must stay
//!   bit-identical to the same decode over a healthy pool.

use std::sync::atomic::{AtomicU32, Ordering};

use swiftkv::kernels::{BlockPool, WorkerPool};
use swiftkv::model::{NumericsMode, TinyModel};

#[test]
fn block_pool_survives_a_poisoned_free_list() {
    let pool = BlockPool::new(3, 4, 8);
    pool.poison_free_list_for_tests();
    // every path through the lock still works: counting, checkout,
    // exhaustion probing, and release
    assert_eq!(pool.free_blocks(), 3);
    let a = pool.alloc();
    let b = pool.alloc();
    let c = pool.alloc();
    assert!(pool.try_alloc().is_none(), "pool of 3 must be exhausted");
    pool.release(a);
    pool.release(b);
    pool.release(c);
    assert_eq!(pool.free_blocks(), 3, "blocks lost across the poisoned lock");
}

#[test]
fn worker_pool_survives_a_poisoned_sleep_mutex() {
    let pool = WorkerPool::new(2);
    let counter = AtomicU32::new(0);
    // park the workers once before poisoning so later publications must
    // traverse the poisoned lock on both the submit and the wake side
    pool.run(8, |_| {
        counter.fetch_add(1, Ordering::Relaxed);
    });
    pool.poison_sleep_mutex_for_tests();
    for _ in 0..3 {
        pool.run(8, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
    }
    assert_eq!(counter.load(Ordering::Relaxed), 32, "jobs lost after poisoning");
}

#[test]
fn decode_stays_bit_identical_over_a_poisoned_kv_pool() {
    let m = TinyModel::synthetic(0xFEED, 48, 32, 4, 2, 2, 48, 24);
    let healthy = m.new_pool(m.blocks_per_seq(4), 4);
    let mut st_ok = m.new_state_in(healthy);

    let poisoned = m.new_pool(m.blocks_per_seq(4), 4);
    poisoned.poison_free_list_for_tests();
    let mut st_bad = m.new_state_in(poisoned);

    let mut want = vec![0.0f32; m.vocab];
    let mut got = vec![0.0f32; m.vocab];
    for s in 0..10u32 {
        let t = (s * 7 + 3) % 48;
        m.decode_step_into(&mut st_ok, t, NumericsMode::Accelerator, &mut want);
        m.decode_step_into(&mut st_bad, t, NumericsMode::Accelerator, &mut got);
        assert_eq!(want, got, "step {s}: decode over the poisoned pool diverged");
    }
}
