//! Decode-attention algorithms (Eq. 4) — the paper's algorithmic layer.
//!
//! Four implementations over the same `(q, K_cache, V_cache)` problem, all
//! validated against each other (they compute the *same function*; they
//! differ in schedule, number of passes and memory traffic — which is what
//! the cycle model in [`crate::sim`] prices):
//!
//! | module | algorithm | passes over KV | score buffer |
//! |---|---|---|---|
//! | [`native`] | textbook softmax(qKᵀ/√d)V | 3 (scores, softmax, PV) | N |
//! | [`flash`] | blockwise Flash-style online softmax | 1 (blocked) | block |
//! | [`online`] | streaming/online-softmax (two-phase, ITA-style) | 2 | N |
//! | [`swiftkv`] | SwiftKV single-pass per-token recurrence (Eqs. 5–8) | 1 | none |
//!
//! [`fxp_swiftkv`] is the bit-exact FXP32 (Q15.17) + LUT-exp model of the
//! SwiftKV core datapath (Fig. 3) — the numerics the accelerator actually
//! produces, used for the Table I accuracy experiment.

pub mod flash;
pub mod fxp_swiftkv;
pub mod native;
pub mod online;
pub mod swiftkv;

/// A single-head decode-attention problem over a row-major KV cache.
///
/// `k` and `v` are `[len, d]` row-major slices (`len * d` elements);
/// `q` has `d` elements. `len ≥ 1`.
#[derive(Debug, Clone, Copy)]
pub struct HeadProblem<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub d: usize,
    pub len: usize,
}

impl<'a> HeadProblem<'a> {
    pub fn new(q: &'a [f32], k: &'a [f32], v: &'a [f32], d: usize, len: usize) -> Self {
        assert!(d > 0 && len > 0, "empty problem");
        assert_eq!(q.len(), d);
        assert!(k.len() >= len * d, "k too short");
        assert!(v.len() >= len * d, "v too short");
        HeadProblem { q, k, v, d, len }
    }

    /// Row `t` of the key cache.
    #[inline]
    pub fn key(&self, t: usize) -> &'a [f32] {
        &self.k[t * self.d..(t + 1) * self.d]
    }

    /// Row `t` of the value cache.
    #[inline]
    pub fn value(&self, t: usize) -> &'a [f32] {
        &self.v[t * self.d..(t + 1) * self.d]
    }

    /// `1/√d` — the score scale of Eq. (5).
    #[inline]
    pub fn scale(&self) -> f32 {
        1.0 / (self.d as f32).sqrt()
    }
}

/// f32 dot product (reference arithmetic for the software algorithms).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::HeadProblem;
    use crate::util::Rng;

    /// Deterministic random problem storage (q, k, v own their data).
    pub struct ProblemData {
        pub q: Vec<f32>,
        pub k: Vec<f32>,
        pub v: Vec<f32>,
        pub d: usize,
        pub len: usize,
    }

    impl ProblemData {
        pub fn random(seed: u64, d: usize, len: usize, scale: f32) -> Self {
            let mut rng = Rng::seed_from_u64(seed);
            ProblemData {
                q: rng.uniform_vec(d, scale),
                k: rng.uniform_vec(d * len, scale),
                v: rng.uniform_vec(d * len, scale),
                d,
                len,
            }
        }

        pub fn problem(&self) -> HeadProblem<'_> {
            HeadProblem::new(&self.q, &self.k, &self.v, self.d, self.len)
        }
    }

    pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}: mismatch at {i}: {x} vs {y}"
            );
        }
    }
}
