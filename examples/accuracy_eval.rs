//! Table I experiment: Top-k token agreement between the accelerator's
//! numerics (exact W4A8 integer GEMV + FXP32 Q15.17 SwiftKV attention with
//! the 5-bit-LUT exponential) and desktop f32 attention at the same W4A8
//! weight precision.
//!
//! The paper samples 100 sequences of length 512 from PG-19 through
//! LLaMA2-7B; this reproduction runs seeded synthetic sequences through
//! the AOT tiny model (same datapath, laptop scale — see DESIGN.md
//! substitution log).
//!
//! ```sh
//! make artifacts && cargo run --release --example accuracy_eval -- \
//!     [--sequences 50] [--len 64]
//! ```

use swiftkv::model::{TinyModel, WeightStore};
use swiftkv::report;
use swiftkv::runtime::{artifacts_available, default_artifacts_dir};
use swiftkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args =
        Args::parse(&["sequences", "len"], &[]).map_err(|e| anyhow::anyhow!(e))?;
    if !artifacts_available() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    let sequences = args.get_usize("sequences", 50).unwrap();
    let len = args.get_usize("len", 64).unwrap();

    let tm = TinyModel::load(&WeightStore::load(&default_artifacts_dir())?)?;
    println!(
        "comparing accelerator (INT8×INT4 GEMV + FXP32 SwiftKV + LUT exp) vs \
         desktop f32 attention over {sequences} sequences × {len} tokens…\n"
    );
    let (table, fr) = report::table1(&tm, sequences, len);
    println!("{table}");
    println!(
        "top-1 agreement {:.2} % — the FXP32 datapath (resolution 2^-17 ≈ 7.6e-6) \
         does not change greedy decoding.",
        fr[0] * 100.0
    );
    Ok(())
}
