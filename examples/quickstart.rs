//! Quickstart: the three layers in one page.
//!
//! 1. run the SwiftKV recurrence in pure Rust (Eqs. 5–8) and check it
//!    against textbook attention;
//! 2. run the *same* computation through the AOT Pallas kernel — HLO text
//!    lowered once by `python/compile/aot.py`, executed by the PJRT CPU
//!    client (no Python at runtime);
//! 3. run the bit-exact FXP32 (Q15.17 + 5-bit-LUT exp) datapath the
//!    SwiftKV core implements in hardware;
//! 4. price the computation on the cycle model (4N-cycle single pass).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use swiftkv::attention::{fxp_swiftkv, native, swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::fxp::Exp2Lut;
#[cfg(feature = "pjrt")]
use swiftkv::runtime::{artifacts_available, default_artifacts_dir, Engine};
use swiftkv::sim::{edge_hw, ArchConfig, AttentionAlg};
use swiftkv::util::Rng;

fn main() -> anyhow::Result<()> {
    let (rows, n_ctx, d) = (8usize, 512usize, 32usize);
    let mut rng = Rng::seed_from_u64(1);
    let q = rng.uniform_vec(rows * d, 1.0);
    let k = rng.uniform_vec(rows * n_ctx * d, 1.0);
    let v = rng.uniform_vec(rows * n_ctx * d, 1.0);
    let lens: Vec<i32> = (1..=rows as i32).map(|i| i * 64).collect();

    // --- 1. pure-Rust SwiftKV vs native -------------------------------
    let mut max_err = 0f32;
    for r in 0..rows {
        let p = HeadProblem::new(
            &q[r * d..(r + 1) * d],
            &k[r * n_ctx * d..(r + 1) * n_ctx * d],
            &v[r * n_ctx * d..(r + 1) * n_ctx * d],
            d,
            lens[r] as usize,
        );
        let a = swiftkv_attn::attend(&p);
        let b = native::attend(&p);
        for (x, y) in a.iter().zip(&b) {
            max_err = max_err.max((x - y).abs());
        }
    }
    println!("[1] rust SwiftKV vs native softmax: max |Δ| = {max_err:.2e}");

    // --- 2. AOT Pallas kernel through PJRT (needs --features pjrt) -----
    #[cfg(feature = "pjrt")]
    if artifacts_available() {
        let eng = Engine::load(&default_artifacts_dir())?;
        let out = eng.attention(&lens, &q, &k, &v, rows, n_ctx, d)?;
        let mut max_err = 0f32;
        for r in 0..rows {
            let p = HeadProblem::new(
                &q[r * d..(r + 1) * d],
                &k[r * n_ctx * d..(r + 1) * n_ctx * d],
                &v[r * n_ctx * d..(r + 1) * n_ctx * d],
                d,
                lens[r] as usize,
            );
            let want = native::attend(&p);
            for (x, y) in out[r * d..(r + 1) * d].iter().zip(&want) {
                max_err = max_err.max((x - y).abs());
            }
        }
        println!("[2] AOT Pallas kernel (PJRT) vs native: max |Δ| = {max_err:.2e}");
    } else {
        println!("[2] skipped — run `make artifacts` first");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("[2] skipped — build with `--features pjrt` (and `make artifacts`)");

    // --- 3. FXP32 datapath ---------------------------------------------
    let lut = Exp2Lut::new();
    let p = HeadProblem::new(&q[..d], &k[..n_ctx * d], &v[..n_ctx * d], d, 512);
    let fx = fxp_swiftkv::attend(&lut, p.q, p.k, p.v, d, p.len);
    let fl = native::attend(&p);
    let err = fx
        .iter()
        .zip(&fl)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("[3] FXP32 (Q15.17 + LUT exp) vs f32:    max |Δ| = {err:.2e}");
    println!(
        "    exp LUT max relative error: {:.5} % (paper: 0.00586 %)",
        lut.max_relative_error() * 100.0
    );

    // --- 4. cycle model ---------------------------------------------------
    let arch = ArchConfig::default();
    let c = edge_hw::attention_cycles(&arch, AttentionAlg::SwiftKv, 512, 128);
    println!(
        "[4] SwiftKV core, ctx 512, d_head 128: {} cycles ≈ {:.2} µs @ {} MHz (≈ 4N = {})",
        c.total,
        c.us(&arch),
        arch.clock_mhz,
        4 * 512
    );
    Ok(())
}
