//! Property tests: the continuous-batching submission path. Requests
//! submitted live through a [`ServeHandle`] — joining the engine
//! mid-flight, across paged-KV block boundaries, behind staggered
//! wall-clock arrivals — must produce exactly the token stream solo
//! decode produces, in both numerics modes. Admission order, lane
//! recycling, and arrival timing are scheduling choices; the numbers
//! they feed each lane are not allowed to notice.

use swiftkv::coordinator::{CpuServer, ServeConfig, SessionOutcome};
use swiftkv::model::{NumericsMode, Request, TinyModel};
use swiftkv::util::{prop, Rng};

/// (n_heads, n_kv_heads) over d_model 32: MHA, GQA group 2, MQA.
const SHAPES: [(usize, usize); 3] = [(4, 4), (4, 2), (4, 1)];
/// KV block lengths: degenerate, odd (mid-flight joins land inside
/// ragged blocks), default.
const BLOCK_LENS: [usize; 3] = [1, 3, 16];
const N_CTX: usize = 24;
const VOCAB: usize = 48;

struct ContinuousCase {
    model: TinyModel,
    block_len: usize,
    lanes: usize,
    requests: Vec<Request>,
}

impl ContinuousCase {
    fn random(rng: &mut Rng) -> ContinuousCase {
        let (h, hkv) = SHAPES[rng.gen_range(0, SHAPES.len())];
        let block_len = BLOCK_LENS[rng.gen_range(0, BLOCK_LENS.len())];
        let model = TinyModel::synthetic(
            rng.gen_range(0, 1 << 20) as u64,
            VOCAB,
            32,
            h,
            hkv,
            2,
            48,
            N_CTX,
        );
        let lanes = rng.gen_range(1, 4);
        let n_requests = rng.gen_range(2, 7);
        let requests = (0..n_requests as u64)
            .map(|id| {
                let plen = rng.gen_range(1, 10);
                let glen = rng.gen_range(1, 1 + (N_CTX - plen).min(8));
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.gen_range(0, VOCAB) as u32).collect();
                Request::new(id, prompt).gen_len(glen)
            })
            .collect();
        ContinuousCase {
            model,
            block_len,
            lanes,
            requests,
        }
    }
}

#[test]
fn prop_continuous_stream_is_bit_identical_to_solo_decode() {
    prop::check("continuous submission == solo decode", 10, |rng, _| {
        let case = ContinuousCase::random(rng);
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let cfg = ServeConfig::builder()
                .lanes(case.lanes)
                .mode(mode)
                .max_iterations(10_000)
                .kv_block_len(case.block_len)
                .build()
                .expect("case config is valid");
            let server = CpuServer::new(&case.model, cfg);
            let (report, finished) = server.serve_continuous(|handle| {
                let mut pending = Vec::with_capacity(case.requests.len());
                for (i, req) in case.requests.iter().enumerate() {
                    // the first `lanes` requests fill the batch; every
                    // later submission lands while those lanes are
                    // decoding, so it joins the engine mid-flight
                    if i >= case.lanes {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    pending.push(
                        handle
                            .submit(req.clone())
                            .expect("engine accepts while the handle is live"),
                    );
                }
                pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
            });

            assert_eq!(finished.len(), case.requests.len());
            for fin in &finished {
                assert_eq!(
                    fin.outcome,
                    SessionOutcome::Completed,
                    "{mode:?} bl={} lanes={}: request {} did not complete",
                    case.block_len,
                    case.lanes,
                    fin.id
                );
                let req = &case.requests[fin.id as usize];
                let want = case.model.generate(&req.prompt, req.gen_len, mode);
                assert_eq!(
                    fin.tokens, want,
                    "{mode:?} bl={} lanes={}: request {} diverged from solo decode \
                     after a mid-flight join",
                    case.block_len, case.lanes, fin.id
                );
            }
            assert_eq!(
                report.kv_pool.free_blocks(),
                report.kv_pool.total_blocks(),
                "continuous run leaked KV blocks"
            );
        }
    });
}

#[test]
fn staggered_arrival_gates_do_not_change_the_stream() {
    // arrival_ms gating composes with live submission: requests carry
    // wall-clock arrival gates AND are submitted with real delays, so
    // admission interleaves decode iterations arbitrarily — outputs
    // still match solo decode exactly
    prop::check("arrival gates under continuous submission", 6, |rng, _| {
        let case = ContinuousCase::random(rng);
        let gated: Vec<Request> = case
            .requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                Request::new(r.id, r.prompt.clone())
                    .gen_len(r.gen_len)
                    .arrival_ms(i as u64 * rng.gen_range(0, 4) as u64)
            })
            .collect();
        let cfg = ServeConfig::builder()
            .lanes(case.lanes)
            .mode(NumericsMode::DesktopF32)
            .max_iterations(10_000)
            .kv_block_len(case.block_len)
            .build()
            .expect("case config is valid");
        let server = CpuServer::new(&case.model, cfg);
        let (report, finished) = server.serve_continuous(|handle| {
            let pending: Vec<_> = gated
                .iter()
                .map(|r| handle.submit(r.clone()).expect("submit"))
                .collect();
            pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
        });
        for fin in &finished {
            assert_eq!(fin.outcome, SessionOutcome::Completed);
            let req = &case.requests[fin.id as usize];
            let want = case
                .model
                .generate(&req.prompt, req.gen_len, NumericsMode::DesktopF32);
            assert_eq!(
                fin.tokens, want,
                "request {}: arrival gating changed the generated tokens",
                fin.id
            );
        }
        assert_eq!(report.kv_pool.free_blocks(), report.kv_pool.total_blocks());
    });
}
