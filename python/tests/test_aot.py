"""AOT path regressions: the artifacts the Rust runtime consumes.

The most important check here guards the elided-constant bug: HLO text
printed without ``print_large_constants=True`` contains ``constant({...})``
bodies that xla_extension 0.5.1 silently parses as *zeros* (the RoPE
cos/sin tables vanished and every position-dependent value downstream was
wrong — see aot.py::to_hlo_text).
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_built():
    return os.path.exists(os.path.join(ARTIFACTS, "manifest.json"))


# Artifact-file checks need `make artifacts`; manifest-construction and
# lowering checks run everywhere.
requires_artifacts = pytest.mark.skipif(not artifacts_built(),
                                        reason="run `make artifacts` first")


def test_model_manifest_emits_n_kv_heads():
    cfg = M.TinyConfig()
    m = aot.model_manifest(cfg, seed=0)
    assert m["n_kv_heads"] == cfg.n_kv_heads
    assert m["n_heads"] % m["n_kv_heads"] == 0
    # the Rust loader cross-checks wk/wv widths against this product
    assert m["n_kv_heads"] * m["d_head"] <= m["d_model"]
    assert m["seed"] == 0


def test_model_manifest_rejects_bad_kv_shapes():
    cfg = dataclasses.replace(M.TinyConfig(), n_kv_heads=3)  # 8 % 3 != 0
    with pytest.raises(ValueError, match="multiple of n_kv_heads"):
        aot.model_manifest(cfg, seed=0)
    cfg = dataclasses.replace(M.TinyConfig(), n_kv_heads=0)
    with pytest.raises(ValueError, match="multiple of n_kv_heads"):
        aot.model_manifest(cfg, seed=0)


def test_model_manifest_accepts_grouped_shapes():
    # GQA (group 4) and MQA manifests are first-class now — the emitted
    # n_kv_heads is what TinyModel::load validates wk/wv widths against
    for kv in (2, 1):
        cfg = dataclasses.replace(M.TinyConfig(), n_kv_heads=kv)
        m = aot.model_manifest(cfg, seed=3)
        assert m["n_kv_heads"] == kv
        assert m["n_heads"] == cfg.n_heads


def test_gqa_param_specs_shrink_kv_projections():
    # the weights.bin table and the manifest must agree on the grouped
    # K/V widths, or TinyModel::load rejects the artifact
    cfg = dataclasses.replace(M.TinyConfig(), n_kv_heads=2)
    d_kv = cfg.n_kv_heads * cfg.d_head
    specs = {name: shape for name, shape, _ in aot.M.param_specs(cfg)}
    assert specs["layer0.wk.q"] == (cfg.d_model, d_kv)
    assert specs["layer0.wv.q"] == (cfg.d_model, d_kv)
    assert specs["layer0.wk.scale"] == (d_kv,)
    assert specs["layer0.wq.q"] == (cfg.d_model, cfg.d_model)
    # and the emitted weights actually take those shapes
    params = aot.M.init_params(cfg, seed=0)
    assert params["layer0.wk.q"].shape == (cfg.d_model, d_kv)
    assert params["layer0.wv.scale"].shape == (d_kv,)


@requires_artifacts
def test_no_elided_constants_in_any_artifact():
    for name in os.listdir(ARTIFACTS):
        if name.endswith(".hlo.txt"):
            text = open(os.path.join(ARTIFACTS, name)).read()
            assert "constant({...})" not in text, (
                f"{name} contains elided constants — the 0.5.1 parser reads "
                "them as zeros (aot.py must print_large_constants)")


def test_hlo_text_lowering_preserves_constants():
    # lower a function with a large constant and check it survives
    table = jnp.arange(64, dtype=jnp.float32) * 0.5
    lowered = jax.jit(lambda x: x * table).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text
    assert "31.5" in text  # the largest table entry is printed verbatim


@requires_artifacts
def test_manifest_matches_config_and_weights():
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    cfg = M.TinyConfig()
    m = manifest["model"]
    assert m["d_model"] == cfg.d_model
    assert m["n_layers"] == cfg.n_layers
    assert m["n_heads"] == cfg.n_heads
    assert m["vocab"] == cfg.vocab

    # weights table covers param_specs exactly, in order
    specs = M.param_specs(cfg)
    table = manifest["weights"]
    assert [w["name"] for w in table] == [s[0] for s in specs]
    blob_size = os.path.getsize(os.path.join(ARTIFACTS, "weights.bin"))
    for w, (name, shape, dtype) in zip(table, specs):
        assert w["shape"] == list(shape), name
        assert w["dtype"] == dtype, name
        assert w["offset"] + w["nbytes"] <= blob_size, name
        assert w["offset"] % 64 == 0, f"{name} not 64-byte aligned"


@requires_artifacts
def test_weights_blob_roundtrip():
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    cfg = M.TinyConfig()
    params = M.init_params(cfg, seed=manifest["model"]["seed"])
    blob = open(os.path.join(ARTIFACTS, "weights.bin"), "rb").read()
    # spot-check three arrays decode to the regenerated params
    for name in ("embedding", "layer0.wq.q", "lm_head.scale"):
        meta = next(w for w in manifest["weights"] if w["name"] == name)
        raw = blob[meta["offset"]:meta["offset"] + meta["nbytes"]]
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        np.testing.assert_array_equal(arr, np.asarray(params[name]), err_msg=name)


@requires_artifacts
def test_manifest_declares_n_kv_heads_on_disk():
    # the committed artifact set must carry the explicit GQA shape the
    # Rust loader validates (older manifests defaulted it to n_heads)
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    m = manifest["model"]
    assert m["n_kv_heads"] == M.TinyConfig().n_kv_heads
    assert m["n_heads"] % m["n_kv_heads"] == 0


@requires_artifacts
def test_all_declared_artifacts_exist():
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for key, art in manifest["artifacts"].items():
        path = os.path.join(ARTIFACTS, art["file"])
        assert os.path.exists(path), key
        assert os.path.getsize(path) > 1000, key


@requires_artifacts
def test_decode_artifact_parameter_count():
    # tokens, pos, kc, vc, cos, sin + every weight = HLO entry params
    manifest = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    n_weights = len(manifest["weights"])
    text = open(os.path.join(ARTIFACTS, "tiny_decode_b1.hlo.txt")).read()
    import re
    params = set(re.findall(r"parameter\((\d+)\)", text))
    assert len(params) == 6 + n_weights
