//! Integration: every paper exhibit regenerates, and the artifact-backed
//! Table I lands in the paper's range.

use swiftkv::model::{LlmConfig, TinyModel, WeightStore};
use swiftkv::report;
use swiftkv::runtime::{artifacts_available, default_artifacts_dir};
use swiftkv::sim::ArchConfig;

#[test]
fn every_exhibit_regenerates() {
    let arch = ArchConfig::default();
    let exhibits = [
        ("fig7a", report::fig7a(&arch)),
        ("fig7b", report::fig7b(&arch)),
        ("explut", report::exp_lut_error()),
        ("table2", report::table2(&arch)),
        ("fig8a", report::fig8a(&arch, &LlmConfig::llama2_7b(), 512)),
        ("table3", report::table3(&arch)),
        ("fig8b", report::fig8b(&arch)),
        ("table4", report::table4(&arch)),
    ];
    for (name, text) in exhibits {
        assert!(
            text.lines().count() >= if name == "explut" { 1 } else { 3 },
            "{name} too short"
        );
        assert!(!text.contains("NaN") && !text.contains(" inf "), "{name} has bad values:\n{text}");
    }
}

#[test]
fn table1_topk_agreement_matches_paper_band() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let tm = TinyModel::load(&WeightStore::load(&default_artifacts_dir()).unwrap()).unwrap();
    let (_, fr) = report::table1(&tm, 12, 40);
    // paper: Top-1 100%, Top-2 100%, Top-3 99%, Top-5 98% on LLaMA2-7B.
    // Our tiny random-weight model has near-uniform logits over a 512
    // vocab, so exact top-k SET agreement is brittle at larger k (near
    // ties flip on 1e-5-level FXP noise); the greedy path (top-1) is what
    // decoding actually uses and must stay ≈ paper. See EXPERIMENTS.md E3.
    assert!(fr[0] >= 0.97, "Top-1 {:.3}", fr[0]);
    assert!(fr[1] >= 0.92, "Top-2 {:.3}", fr[1]);
    assert!(fr[2] >= 0.85, "Top-3 {:.3}", fr[2]);
    assert!(fr[3] >= 0.70, "Top-5 {:.3}", fr[3]);
    // sets ordered: agreement can only drop as k grows... not strictly
    // (set equality), but Top-1 must dominate Top-5
    assert!(fr[0] >= fr[3] - 1e-9);
}

#[test]
fn exp_lut_error_value() {
    let s = report::exp_lut_error();
    // "0.00587 %" printed — parse it back and check the paper band
    let pct: f64 = s
        .split_whitespace()
        .find_map(|w| w.parse::<f64>().ok().filter(|x| *x > 0.001 && *x < 0.01))
        .expect("no percentage found");
    assert!((pct - 0.00586).abs() < 0.0002, "{pct}");
}
