//! Model shape configurations.

/// Decoder-only LLM shapes relevant to the accelerator schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct LlmConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (≠ n_heads under GQA/MQA).
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    /// Number of FFN weight matrices of each shape: gated MLPs (SwiGLU)
    /// have two `d→ffn` and one `ffn→d`.
    pub gated_mlp: bool,
    pub vocab: usize,
    pub rope_base: f64,
}

impl LlmConfig {
    /// LLaMA2-7B — the paper's primary evaluation model.
    pub fn llama2_7b() -> Self {
        LlmConfig {
            name: "Llama-2-7B",
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            d_head: 128,
            d_ffn: 11008,
            gated_mlp: true,
            vocab: 32000,
            rope_base: 10000.0,
        }
    }

    /// ChatGLM-6B — the paper's second evaluation model (GLM block:
    /// MQA-free 32-head attention, non-gated 4×d FFN, large vocab).
    pub fn chatglm_6b() -> Self {
        LlmConfig {
            name: "ChatGLM-6B",
            n_layers: 28,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            d_head: 128,
            d_ffn: 16384,
            gated_mlp: false,
            vocab: 65024,
            rope_base: 10000.0,
        }
    }

    /// LLaMA2-70B (GQA: 64 query heads sharing 8 KV heads) — the classic
    /// grouped-query shape; its KV cache is 8× smaller per token than an
    /// MHA layout of the same width.
    pub fn llama2_70b() -> Self {
        LlmConfig {
            name: "Llama-2-70B",
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            d_head: 128,
            d_ffn: 28672,
            gated_mlp: true,
            vocab: 32000,
            rope_base: 10000.0,
        }
    }

    /// LLaMA3-8B (GQA: 8 KV heads) — listed in §IV-A as a target class.
    pub fn llama3_8b() -> Self {
        LlmConfig {
            name: "Llama-3-8B",
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            d_head: 128,
            d_ffn: 14336,
            gated_mlp: true,
            vocab: 128256,
            rope_base: 500000.0,
        }
    }

    /// Qwen3-8B (GQA: 8 KV heads) — listed in §IV-A as a target class.
    pub fn qwen3_8b() -> Self {
        LlmConfig {
            name: "Qwen3-8B",
            n_layers: 36,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            d_head: 128,
            d_ffn: 12288,
            gated_mlp: true,
            vocab: 151936,
            rope_base: 1000000.0,
        }
    }

    /// The tiny AOT-compiled model the PJRT runtime actually serves.
    pub fn tiny() -> Self {
        LlmConfig {
            name: "tiny",
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 8,
            d_head: 32,
            d_ffn: 768,
            gated_mlp: true,
            vocab: 512,
            rope_base: 10000.0,
        }
    }

    /// All full-size configs the paper references.
    pub fn paper_models() -> Vec<LlmConfig> {
        vec![
            Self::llama2_7b(),
            Self::chatglm_6b(),
            Self::llama3_8b(),
            Self::qwen3_8b(),
        ]
    }

    /// Query heads per KV head (`1` for MHA, `n_heads` for MQA) — the
    /// factor by which GQA shrinks KV-cache traffic.
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let ffn = self.d_ffn as u64;
        let kv_dim = (self.n_kv_heads * self.d_head) as u64;
        let attn = d * d // Wq
            + 2 * d * kv_dim // Wk, Wv
            + d * d; // Wo
        let mlp = if self.gated_mlp {
            2 * d * ffn + ffn * d
        } else {
            d * ffn + ffn * d
        };
        let norms = 2 * d;
        let blocks = self.n_layers as u64 * (attn + mlp + norms);
        let emb = self.vocab as u64 * d;
        let head = self.vocab as u64 * d;
        blocks + emb + head + d
    }

    /// Bytes of INT4 weight storage (plus per-channel f32 scales),
    /// excluding the f32 embedding table (streamed separately).
    pub fn weight_bytes_w4(&self) -> u64 {
        // matrices quantized; norms/embeddings in f32
        let d = self.d_model as u64;
        let ffn = self.d_ffn as u64;
        let kv_dim = (self.n_kv_heads * self.d_head) as u64;
        let mut mat_params = 0u64;
        let mut mat_cols = 0u64;
        let attn_mats: [(u64, u64); 4] = [(d, d), (d, kv_dim), (d, kv_dim), (d, d)];
        for (i, o) in attn_mats {
            mat_params += i * o * self.n_layers as u64;
            mat_cols += o * self.n_layers as u64;
        }
        let mlp_mats: Vec<(u64, u64)> = if self.gated_mlp {
            vec![(d, ffn), (d, ffn), (ffn, d)]
        } else {
            vec![(d, ffn), (ffn, d)]
        };
        for (i, o) in mlp_mats {
            mat_params += i * o * self.n_layers as u64;
            mat_cols += o * self.n_layers as u64;
        }
        // lm head
        mat_params += d * self.vocab as u64;
        mat_cols += self.vocab as u64;
        mat_params / 2 + mat_cols * 4
    }

    /// KV-cache bytes appended per token per layer (INT8 storage — the
    /// SFU casts FXP32 → INT8 before the HBM write; see DESIGN.md).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * (self.n_kv_heads * self.d_head) as u64
    }

    /// Total KV bytes read per decode step at context length `n`.
    pub fn kv_read_bytes(&self, n: usize) -> u64 {
        self.n_layers as u64 * self.kv_bytes_per_token_layer() * n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_param_count_near_7b() {
        let p = LlmConfig::llama2_7b().params();
        assert!(
            (6.5e9..7.1e9).contains(&(p as f64)),
            "llama2-7b params = {p}"
        );
    }

    #[test]
    fn chatglm_param_count_near_6b() {
        let p = LlmConfig::chatglm_6b().params();
        assert!(
            (5.8e9..6.9e9).contains(&(p as f64)),
            "chatglm-6b params = {p}"
        );
    }

    #[test]
    fn llama3_param_count_near_8b() {
        let p = LlmConfig::llama3_8b().params();
        assert!((7.3e9..8.3e9).contains(&(p as f64)), "llama3-8b = {p}");
    }

    #[test]
    fn w4_storage_roughly_half_param_count() {
        let cfg = LlmConfig::llama2_7b();
        let bytes = cfg.weight_bytes_w4();
        // ~0.5 byte/param plus scale overhead
        let per_param = bytes as f64 / cfg.params() as f64;
        assert!((0.4..0.6).contains(&per_param), "bytes/param = {per_param}");
    }

    #[test]
    fn gqa_shrinks_kv() {
        let mha = LlmConfig::llama2_7b();
        let gqa = LlmConfig::llama3_8b();
        assert!(gqa.kv_bytes_per_token_layer() < mha.kv_bytes_per_token_layer());
        assert_eq!(
            mha.kv_bytes_per_token_layer(),
            2 * 32 * 128 // 2 (K+V) × heads × d_head × 1 byte
        );
        // the shrink is exactly the group factor
        assert_eq!(mha.group(), 1);
        assert_eq!(gqa.group(), 4);
        assert_eq!(
            mha.kv_bytes_per_token_layer(),
            gqa.kv_bytes_per_token_layer() * gqa.group() as u64
        );
    }

    #[test]
    fn llama2_70b_group_of_eight() {
        let cfg = LlmConfig::llama2_70b();
        assert_eq!(cfg.group(), 8);
        assert_eq!(cfg.kv_bytes_per_token_layer(), 2 * 8 * 128);
        let p = cfg.params() as f64;
        assert!((6.4e10..7.1e10).contains(&p), "llama2-70b params = {p}");
    }

    #[test]
    fn kv_read_scales_linearly() {
        let cfg = LlmConfig::llama2_7b();
        assert_eq!(cfg.kv_read_bytes(1024), 2 * cfg.kv_read_bytes(512));
    }

    #[test]
    fn tiny_matches_manifest_shapes() {
        let t = LlmConfig::tiny();
        assert_eq!(t.d_model, t.n_heads * t.d_head);
        assert!(t.params() < 10_000_000);
    }
}
