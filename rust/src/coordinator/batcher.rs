//! Continuous batching over the engine's fixed lanes.
//!
//! The PJRT engine compiles one executable per batch variant; the batcher
//! keeps a lane array of the chosen variant's width, admits queued
//! requests into free lanes at every iteration boundary, and reports the
//! per-iteration (token, position) vectors the engine consumes. Lanes are
//! recycled: a new session simply starts at position 0 (the model resets
//! the lane's RoPE state on `pos == 0`, and attention masks by length, so
//! stale cache rows are never read).

use super::session::{Session, SessionOutcome};
use crate::model::Request;
use std::collections::{BTreeMap, VecDeque};

/// What occupies a lane.
#[derive(Debug, Clone)]
pub enum LaneState {
    Idle,
    Busy(Session),
}

impl LaneState {
    pub fn is_idle(&self) -> bool {
        matches!(self, LaneState::Idle)
    }
}

/// One lane's input for a chunked engine step
/// ([`Batcher::gather_chunks`]): the slice of tokens to feed this
/// iteration — a prompt chunk during prefill, the single last-sampled
/// token during decode, empty when the lane is idle.
#[derive(Debug, Clone, Copy)]
pub struct LaneChunk<'a> {
    /// Whether the lane holds a session this step.
    pub active: bool,
    /// KV position of the chunk's first token.
    pub pos: usize,
    /// The tokens to feed (borrowed from the session's prompt or its
    /// generated tail; valid until the next `&mut` use of the batcher).
    pub tokens: &'a [u32],
    /// Whether this chunk ends on a sampling position — when `false`
    /// the engine skips the logits projection and the sampler.
    pub samples: bool,
    /// Id of the request the lane serves (0 when idle — check `active`).
    pub request_id: u64,
    /// Tokens the lane's session has generated so far (fault-plan
    /// trigger coordinate: `s<STEP>` fires when `generated == STEP` on a
    /// sampling chunk).
    pub generated: usize,
}

/// Outcome of [`Batcher::preempt_lane`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptOutcome {
    /// The request went back to the front of the queue for re-prefill.
    Requeued,
    /// The request had already been requeued `max_requeues` times and
    /// was retired as failed instead.
    FailedRetryBudget,
}

/// Why a running lane is being cancelled ([`Batcher::cancel_lane`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelKind {
    /// The client went away: its [`super::submit::PendingRequest`] was
    /// dropped or its SSE socket closed.
    Disconnect,
    /// The client's bounded event stream filled up — it is consuming
    /// tokens slower than the engine produces them.
    SlowClient,
    /// Graceful shutdown hit its drain bound with the lane still
    /// running.
    Drain,
}

/// Fault-tolerance counters the batcher accumulates over a run
/// (surfaced through [`super::metrics::ServeMetrics`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Requests retired with [`SessionOutcome::Failed`].
    pub failed: u64,
    /// Lanes preempted mid-flight to free KV blocks.
    pub preemptions: u64,
    /// Preempted requests returned to the queue for re-prefill.
    pub requeues: u64,
    /// Requests cancelled past their wall-clock deadline.
    pub deadline_expired: u64,
    /// Lanes cancelled mid-flight for any [`CancelKind`].
    pub cancelled: u64,
    /// Subset of `cancelled`: slow-client back-pressure cancellations.
    pub slow_client: u64,
    /// Subset of `cancelled`: lanes cancelled at the drain bound.
    pub drain_cancelled: u64,
    /// Requests shed by admission control (never took a lane).
    pub shed: u64,
}

/// The dynamic batcher.
pub struct Batcher {
    lanes: Vec<LaneState>,
    queue: VecDeque<Request>,
    /// Context capacity per lane (engine's n_ctx).
    n_ctx: usize,
    /// Completed sessions, in finish order.
    pub finished: Vec<Session>,
    admitted: u64,
    rejected: u64,
    faults: FaultCounters,
    /// Times each request id has been preempted-and-requeued (bounded
    /// retry accounting for [`Batcher::preempt_lane`]).
    requeue_counts: BTreeMap<u64, u32>,
}

impl Batcher {
    pub fn new(n_lanes: usize, n_ctx: usize) -> Self {
        assert!(n_lanes >= 1);
        Batcher {
            lanes: (0..n_lanes).map(|_| LaneState::Idle).collect(),
            queue: VecDeque::new(),
            n_ctx,
            finished: Vec::new(),
            admitted: 0,
            rejected: 0,
            faults: FaultCounters::default(),
            requeue_counts: BTreeMap::new(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Enqueue a request. Requests longer than the context capacity are
    /// rejected immediately (returned as `Err`).
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        if req.prompt.len() + req.gen_len > self.n_ctx {
            self.rejected += 1;
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    /// Admit queued requests into idle lanes (continuous batching step).
    /// Returns the number admitted.
    pub fn admit(&mut self, iteration: u64) -> usize {
        let mut n = 0;
        for lane in self.lanes.iter_mut() {
            if lane.is_idle() {
                if let Some(req) = self.queue.pop_front() {
                    *lane = LaneState::Busy(Session::new(req, iteration));
                    self.admitted += 1;
                    n += 1;
                } else {
                    break;
                }
            }
        }
        n
    }

    /// Requests waiting in the admission queue (submitted, not yet on a
    /// lane) — sampled per iteration for the queue-depth percentiles on
    /// [`super::metrics::ServeMetrics`].
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Number of busy lanes.
    pub fn active(&self) -> usize {
        self.lanes.iter().filter(|l| !l.is_idle()).count()
    }

    /// Anything left to do?
    pub fn is_drained(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Build the chunked step inputs: one [`LaneChunk`] per lane.
    /// Prefill lanes expose up to `max_prefill` remaining prompt tokens
    /// (the whole tail when it is shorter); decode lanes expose their
    /// single last-sampled token; idle lanes an empty, inactive chunk.
    /// `samples` is precomputed so the engine can skip the logits
    /// projection and the sampler for prefill chunks that stop short of
    /// the last prompt token.
    pub fn gather_chunks(&self, max_prefill: usize) -> Vec<LaneChunk<'_>> {
        assert!(max_prefill >= 1, "chunks must hold at least one token");
        self.lanes
            .iter()
            .map(|lane| match lane {
                LaneState::Idle => LaneChunk {
                    active: false,
                    pos: 0,
                    tokens: &[],
                    samples: false,
                    request_id: 0,
                    generated: 0,
                },
                LaneState::Busy(s) => {
                    let tokens = s.next_chunk(max_prefill);
                    LaneChunk {
                        active: true,
                        pos: s.pos,
                        tokens,
                        samples: s.samples_after(tokens.len()),
                        request_id: s.request.id,
                        generated: s.generated.len(),
                    }
                }
            })
            .collect()
    }

    /// Build the engine step inputs: `(tokens, positions, active_mask)`.
    /// Idle lanes carry `(0, 0)` — harmless, masked by their own restart.
    pub fn gather_inputs(&self) -> (Vec<i32>, Vec<i32>, Vec<bool>) {
        let mut tokens = Vec::with_capacity(self.lanes.len());
        let mut pos = Vec::with_capacity(self.lanes.len());
        let mut active = Vec::with_capacity(self.lanes.len());
        for lane in &self.lanes {
            match lane {
                LaneState::Idle => {
                    tokens.push(0);
                    pos.push(0);
                    active.push(false);
                }
                LaneState::Busy(s) => {
                    tokens.push(s.next_input() as i32);
                    pos.push(s.pos as i32);
                    active.push(true);
                }
            }
        }
        (tokens, pos, active)
    }

    /// Apply one step's sampled tokens (`samples[i]` = greedy token of
    /// lane `i`). Finished sessions are retired and their lanes freed.
    /// Returns the ids of requests that finished this step.
    pub fn scatter_outputs(&mut self, samples: &[u32], iteration: u64) -> Vec<u64> {
        let fed = vec![1usize; self.lanes.len()];
        self.scatter_chunk_outputs(&fed, samples, iteration)
    }

    /// Apply one chunked step's outcome: lane `i` consumed `fed[i]`
    /// tokens (its [`LaneChunk`]'s length) and — when the chunk reached
    /// a sampling position — produced `samples[i]`. A lane with
    /// `fed[i] == 0` made no progress this iteration (stalled on KV
    /// capacity, or retired early by the fault path) and is left
    /// untouched. Finished sessions are retired and their lanes freed.
    /// Returns the ids of requests that finished this step.
    pub fn scatter_chunk_outputs(
        &mut self,
        fed: &[usize],
        samples: &[u32],
        iteration: u64,
    ) -> Vec<u64> {
        assert_eq!(fed.len(), self.lanes.len());
        assert_eq!(samples.len(), self.lanes.len());
        let mut done = Vec::new();
        for ((lane, &n), &tok) in self.lanes.iter_mut().zip(fed).zip(samples) {
            if n == 0 {
                continue;
            }
            if let LaneState::Busy(s) = lane {
                if s.advance_chunk(n, tok, iteration) {
                    done.push(s.request.id);
                    let finished = std::mem::replace(lane, LaneState::Idle);
                    if let LaneState::Busy(s) = finished {
                        self.finished.push(s);
                    }
                }
            }
        }
        done
    }

    /// The session occupying lane `i`, if any.
    pub fn lane_session(&self, lane: usize) -> Option<&Session> {
        match &self.lanes[lane] {
            LaneState::Busy(s) => Some(s),
            LaneState::Idle => None,
        }
    }

    /// Retire lane `i`'s session as failed (contained lane panic,
    /// non-finite logits, …). The lane is freed for the next admission;
    /// the session lands in [`Batcher::finished`] with
    /// [`SessionOutcome::Failed`]. Returns the failed request's id.
    pub fn fail_lane(&mut self, lane: usize, iteration: u64, reason: &str) -> Option<u64> {
        match std::mem::replace(&mut self.lanes[lane], LaneState::Idle) {
            LaneState::Idle => None,
            LaneState::Busy(mut s) => {
                let id = s.request.id;
                s.finished_at = Some(iteration);
                s.outcome = SessionOutcome::Failed(reason.to_string());
                self.faults.failed += 1;
                self.finished.push(s);
                Some(id)
            }
        }
    }

    /// Cancel lane `i`'s session mid-decode: the lane is freed, the
    /// session retires as [`SessionOutcome::Cancelled`] with whatever it
    /// generated so far, and the caller reclaims its KV blocks. Returns
    /// the cancelled request's id (or `None` if the lane was idle).
    pub fn cancel_lane(&mut self, lane: usize, iteration: u64, kind: CancelKind) -> Option<u64> {
        match std::mem::replace(&mut self.lanes[lane], LaneState::Idle) {
            LaneState::Idle => None,
            LaneState::Busy(mut s) => {
                let id = s.request.id;
                s.finished_at = Some(iteration);
                s.outcome = SessionOutcome::Cancelled;
                self.faults.cancelled += 1;
                match kind {
                    CancelKind::Disconnect => {}
                    CancelKind::SlowClient => self.faults.slow_client += 1,
                    CancelKind::Drain => self.faults.drain_cancelled += 1,
                }
                self.finished.push(s);
                Some(id)
            }
        }
    }

    /// Shed a request at admission time (queue-depth cap, or draining):
    /// it retires immediately as [`SessionOutcome::Shed`] without ever
    /// holding a lane.
    pub fn shed(&mut self, req: Request, iteration: u64) {
        let mut s = Session::new(req, iteration);
        s.finished_at = Some(iteration);
        s.outcome = SessionOutcome::Shed;
        self.faults.shed += 1;
        self.finished.push(s);
    }

    /// Shed everything still waiting in the admission queue (graceful
    /// shutdown stops admission). Returns the shed request ids.
    pub fn shed_queue(&mut self, iteration: u64) -> Vec<u64> {
        let drained: Vec<Request> = self.queue.drain(..).collect();
        let ids = drained.iter().map(|r| r.id).collect();
        for req in drained {
            self.shed(req, iteration);
        }
        ids
    }

    /// Reject a request at admission because it provably cannot meet its
    /// wall-clock deadline: retires as [`SessionOutcome::DeadlineExpired`]
    /// without holding a lane (counted with the other deadline expiries).
    pub fn reject_deadline(&mut self, req: Request, iteration: u64) {
        let mut s = Session::new(req, iteration);
        s.finished_at = Some(iteration);
        s.outcome = SessionOutcome::DeadlineExpired;
        self.faults.deadline_expired += 1;
        self.finished.push(s);
    }

    /// Preempt lane `i` to free its KV blocks: the session's progress is
    /// discarded and its request goes back to the **front** of the queue
    /// for re-prefill once capacity frees — unless the request has
    /// already been requeued `max_requeues` times, in which case it is
    /// retired as failed (bounded retry, no preemption livelock).
    pub fn preempt_lane(
        &mut self,
        lane: usize,
        iteration: u64,
        max_requeues: u32,
    ) -> Option<PreemptOutcome> {
        match std::mem::replace(&mut self.lanes[lane], LaneState::Idle) {
            LaneState::Idle => None,
            LaneState::Busy(mut s) => {
                self.faults.preemptions += 1;
                let count = self.requeue_counts.entry(s.request.id).or_insert(0);
                if *count >= max_requeues {
                    s.finished_at = Some(iteration);
                    s.outcome = SessionOutcome::Failed(format!(
                        "preempted with requeue budget exhausted ({max_requeues} requeues)"
                    ));
                    self.faults.failed += 1;
                    self.finished.push(s);
                    Some(PreemptOutcome::FailedRetryBudget)
                } else {
                    *count += 1;
                    self.faults.requeues += 1;
                    self.queue.push_front(s.request);
                    Some(PreemptOutcome::Requeued)
                }
            }
        }
    }

    /// Cancel every session (running or queued) whose wall-clock
    /// deadline has passed (`now_ms` is stream-relative, the clock
    /// arrivals are measured on). Expired lanes are freed; expired
    /// queued requests retire without ever running. Returns the indices
    /// of lanes that were cancelled, so the server can reclaim their KV
    /// blocks.
    pub fn expire_deadlines(&mut self, now_ms: f64, iteration: u64) -> Vec<usize> {
        let mut expired_lanes = Vec::new();
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let expired = matches!(
                lane,
                LaneState::Busy(s) if s.deadline_at_ms().is_some_and(|d| (d as f64) <= now_ms)
            );
            if expired {
                if let LaneState::Busy(mut s) = std::mem::replace(lane, LaneState::Idle) {
                    s.finished_at = Some(iteration);
                    s.outcome = SessionOutcome::DeadlineExpired;
                    self.faults.deadline_expired += 1;
                    self.finished.push(s);
                }
                expired_lanes.push(i);
            }
        }
        // queued requests can expire without ever reaching a lane (e.g.
        // a preempted request waiting out its requeue)
        let mut still_queued = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            let deadline = (req.deadline_ms > 0).then(|| req.arrival_ms + req.deadline_ms);
            if deadline.is_some_and(|d| (d as f64) <= now_ms) {
                let mut s = Session::new(req, iteration);
                s.finished_at = Some(iteration);
                s.outcome = SessionOutcome::DeadlineExpired;
                self.faults.deadline_expired += 1;
                self.finished.push(s);
            } else {
                still_queued.push_back(req);
            }
        }
        self.queue = still_queued;
        expired_lanes
    }

    /// Fault-tolerance counters accumulated so far.
    pub fn fault_counters(&self) -> FaultCounters {
        self.faults
    }

    /// (admitted, rejected) counters.
    pub fn counters(&self) -> (u64, u64) {
        (self.admitted, self.rejected)
    }

    /// Occupancy in [0, 1] for this iteration.
    pub fn occupancy(&self) -> f64 {
        self.active() as f64 / self.lanes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, gen_len: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).collect()).gen_len(gen_len)
    }

    #[test]
    fn admission_fills_free_lanes() {
        let mut b = Batcher::new(2, 64);
        for i in 0..3 {
            b.submit(req(i, 2, 1)).unwrap();
        }
        assert_eq!(b.admit(0), 2);
        assert_eq!(b.active(), 2);
        // third request waits
        assert_eq!(b.admit(0), 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let mut b = Batcher::new(1, 16);
        assert!(b.submit(req(0, 10, 7)).is_err());
        assert!(b.submit(req(1, 10, 6)).is_ok());
        assert_eq!(b.counters(), (0, 1));
    }

    #[test]
    fn full_lifecycle_single_lane() {
        let mut b = Batcher::new(1, 64);
        b.submit(req(7, 2, 2)).unwrap();
        b.admit(0);
        // step 1: feed prompt[0]
        let (t, p, a) = b.gather_inputs();
        assert_eq!((t[0], p[0], a[0]), (0, 0, true));
        assert!(b.scatter_outputs(&[99], 0).is_empty());
        // step 2: feed prompt[1] → first sample
        let (t, p, _) = b.gather_inputs();
        assert_eq!((t[0], p[0]), (1, 1));
        assert!(b.scatter_outputs(&[42], 1).is_empty());
        // step 3: feed sampled 42 → finishes
        let (t, p, _) = b.gather_inputs();
        assert_eq!((t[0], p[0]), (42, 2));
        let done = b.scatter_outputs(&[43], 2);
        assert_eq!(done, vec![7]);
        assert!(b.is_drained());
        assert_eq!(b.finished[0].generated, vec![42, 43]);
    }

    #[test]
    fn lane_recycled_for_next_request() {
        let mut b = Batcher::new(1, 64);
        b.submit(req(0, 1, 1)).unwrap();
        b.submit(req(1, 1, 1)).unwrap();
        b.admit(0);
        b.scatter_outputs(&[5], 0); // finishes request 0
        assert_eq!(b.active(), 0);
        assert_eq!(b.admit(1), 1); // request 1 takes the lane
        let (_, p, _) = b.gather_inputs();
        assert_eq!(p[0], 0, "recycled lane must restart at position 0");
    }

    #[test]
    fn chunked_lifecycle_single_lane() {
        let mut b = Batcher::new(2, 64);
        b.submit(req(7, 5, 2)).unwrap();
        b.admit(0);
        // step 1: prompt chunk capped at 3, no sample
        let chunks = b.gather_chunks(3);
        assert_eq!(chunks.len(), 2);
        assert!(chunks[0].active && !chunks[1].active);
        assert_eq!(chunks[0].tokens, &[0, 1, 2]);
        assert_eq!(chunks[0].pos, 0);
        assert!(!chunks[0].samples);
        assert!(chunks[1].tokens.is_empty());
        assert!(b.scatter_chunk_outputs(&[3, 0], &[99, 0], 0).is_empty());
        // step 2: prompt tail [3, 4] → first sample
        let chunks = b.gather_chunks(3);
        assert_eq!(chunks[0].tokens, &[3, 4]);
        assert_eq!(chunks[0].pos, 3);
        assert!(chunks[0].samples);
        assert!(b.scatter_chunk_outputs(&[2, 0], &[42, 0], 1).is_empty());
        // step 3: decode chunk is the sampled token → finishes
        let chunks = b.gather_chunks(3);
        assert_eq!(chunks[0].tokens, &[42]);
        assert!(chunks[0].samples);
        let done = b.scatter_chunk_outputs(&[1, 0], &[43, 0], 2);
        assert_eq!(done, vec![7]);
        assert!(b.is_drained());
        assert_eq!(b.finished[0].generated, vec![42, 43]);
        // chunked prefill reaches the first sample in 2 iterations, not 5
        assert_eq!(b.finished[0].first_token_at, Some(1));
    }

    #[test]
    fn scatter_outputs_is_the_single_token_chunk_case() {
        let mut a = Batcher::new(1, 64);
        let mut c = Batcher::new(1, 64);
        a.submit(req(0, 2, 2)).unwrap();
        c.submit(req(0, 2, 2)).unwrap();
        a.admit(0);
        c.admit(0);
        for it in 0..3 {
            a.scatter_outputs(&[it as u32 + 10], it);
            c.scatter_chunk_outputs(&[1], &[it as u32 + 10], it);
        }
        assert_eq!(a.finished.len(), c.finished.len());
        if let (Some(x), Some(y)) = (a.finished.first(), c.finished.first()) {
            assert_eq!(x.generated, y.generated);
        }
    }

    #[test]
    fn idle_lanes_masked() {
        let b = Batcher::new(3, 64);
        let (t, p, a) = b.gather_inputs();
        assert_eq!(t, vec![0, 0, 0]);
        assert_eq!(p, vec![0, 0, 0]);
        assert_eq!(a, vec![false, false, false]);
    }

    #[test]
    fn queue_len_tracks_waiting_requests() {
        let mut b = Batcher::new(1, 64);
        for i in 0..3 {
            b.submit(req(i, 2, 1)).unwrap();
        }
        assert_eq!(b.queue_len(), 3);
        b.admit(0);
        assert_eq!(b.queue_len(), 2, "admission drains the queue into lanes");
    }

    #[test]
    fn occupancy_tracks_active() {
        let mut b = Batcher::new(4, 64);
        for i in 0..2 {
            b.submit(req(i, 1, 1)).unwrap();
        }
        b.admit(0);
        assert!((b.occupancy() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cancel_lane_retires_session_with_partial_tokens() {
        let mut b = Batcher::new(2, 64);
        b.submit(req(9, 1, 4)).unwrap();
        b.admit(0);
        b.scatter_outputs(&[11, 0], 0); // first token
        b.scatter_outputs(&[12, 0], 1); // second token
        assert_eq!(b.cancel_lane(0, 2, CancelKind::Disconnect), Some(9));
        assert_eq!(b.cancel_lane(1, 2, CancelKind::Disconnect), None, "idle lane");
        assert_eq!(b.active(), 0, "cancelled lane is freed");
        let s = &b.finished[0];
        assert_eq!(s.outcome, SessionOutcome::Cancelled);
        assert_eq!(s.generated, vec![11, 12], "streamed prefix stands");
        assert_eq!(s.finished_at, Some(2));
        let fc = b.fault_counters();
        assert_eq!((fc.cancelled, fc.slow_client, fc.drain_cancelled), (1, 0, 0));
    }

    #[test]
    fn cancel_kinds_split_counters() {
        let mut b = Batcher::new(3, 64);
        for i in 0..3 {
            b.submit(req(i, 1, 4)).unwrap();
        }
        b.admit(0);
        b.cancel_lane(0, 0, CancelKind::Disconnect);
        b.cancel_lane(1, 0, CancelKind::SlowClient);
        b.cancel_lane(2, 0, CancelKind::Drain);
        let fc = b.fault_counters();
        assert_eq!(fc.cancelled, 3);
        assert_eq!(fc.slow_client, 1);
        assert_eq!(fc.drain_cancelled, 1);
    }

    #[test]
    fn shed_and_shed_queue_retire_without_lanes() {
        let mut b = Batcher::new(1, 64);
        b.shed(req(5, 2, 3), 7);
        assert_eq!(b.finished[0].outcome, SessionOutcome::Shed);
        assert_eq!(b.finished[0].generated.len(), 0, "shed requests never decode");
        for i in 10..13 {
            b.submit(req(i, 2, 1)).unwrap();
        }
        let ids = b.shed_queue(8);
        assert_eq!(ids, vec![10, 11, 12]);
        assert_eq!(b.queue_len(), 0);
        assert_eq!(b.fault_counters().shed, 4);
        assert!(b.finished.iter().all(|s| s.outcome == SessionOutcome::Shed));
    }

    #[test]
    fn reject_deadline_counts_as_deadline_expired() {
        let mut b = Batcher::new(1, 64);
        b.reject_deadline(req(3, 2, 2), 4);
        assert_eq!(b.finished[0].outcome, SessionOutcome::DeadlineExpired);
        assert_eq!(b.fault_counters().deadline_expired, 1);
        assert_eq!(b.fault_counters().shed, 0);
    }
}
