//! Serving metrics: wall-clock (CPU PJRT) and modelled-accelerator
//! (SwiftKV-MHA cycle model) views of the same schedule.

/// Simple percentile summary over a set of samples.
///
/// Non-finite samples (NaN/±∞ — e.g. timestamps from a faulted lane)
/// are excluded from the statistics and counted in [`non_finite`]
/// instead: `f64::total_cmp` sorts NaN *last*, so including them would
/// silently poison `max` (and, with enough of them, `p90`/`p99`) and
/// turn `mean` into NaN for the whole run.
///
/// [`non_finite`]: Percentiles::non_finite
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
    /// Samples dropped from the statistics for being NaN or ±∞.
    pub non_finite: usize,
}

impl Percentiles {
    /// All-zero summary — the "no samples" placeholder.
    pub const ZERO: Percentiles = Percentiles {
        p50: 0.0,
        p90: 0.0,
        p99: 0.0,
        mean: 0.0,
        max: 0.0,
        non_finite: 0,
    };

    pub fn compute(samples: &[f64]) -> Option<Percentiles> {
        if samples.is_empty() {
            return None;
        }
        // a poisoned sample must not panic — or silently poison — the
        // metrics pass of an otherwise-survived run: keep the finite
        // samples, count the rest
        let mut s: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        let non_finite = samples.len() - s.len();
        if s.is_empty() {
            return Some(Percentiles {
                non_finite,
                ..Percentiles::ZERO
            });
        }
        s.sort_by(f64::total_cmp);
        let at = |q: f64| s[((s.len() - 1) as f64 * q).floor() as usize];
        Some(Percentiles {
            p50: at(0.50),
            p90: at(0.90),
            p99: at(0.99),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            max: s[s.len() - 1],
            non_finite,
        })
    }
}

/// Aggregated serving metrics for one run.
///
/// `Default` is the all-zero report (useful with `..Default::default()`
/// when a serving path does not produce every statistic).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    /// Requests the batcher accepted into the queue.
    pub requests_admitted: u64,
    /// Requests rejected at submission (oversized for the context
    /// window). Dropped by design — but never silently: this counter is
    /// the serving loop's only record of them.
    pub requests_rejected: u64,
    /// Requests retired with a `Failed` outcome: a contained lane panic,
    /// non-finite logits, or an exhausted preemption-requeue budget.
    /// Every other lane of the same batch kept its bit-exact output.
    pub requests_failed: u64,
    /// Lanes preempted mid-flight (KV blocks released, request
    /// requeued) because the shared block pool could not grow any lane.
    pub preemptions: u64,
    /// Preempted requests returned to the queue for re-prefill (≤
    /// `preemptions`; a preemption past the retry budget fails instead).
    pub requeues: u64,
    /// Requests cancelled at an iteration boundary after their
    /// wall-clock deadline passed (running or still queued).
    pub deadline_expired: u64,
    /// Lanes cancelled mid-flight because their client vanished,
    /// stalled, or the shutdown drain bound hit — KV blocks reclaimed,
    /// co-batched survivors bit-exact.
    pub requests_cancelled: u64,
    /// Requests shed by admission control (queue-depth cap or draining
    /// engine) — `503 + Retry-After` at the front door, never a lane.
    pub requests_shed: u64,
    /// Subset of `requests_cancelled`: clients that fell behind their
    /// bounded event stream.
    pub slow_client_cancels: u64,
    /// Subset of `requests_cancelled`: lanes still running when the
    /// graceful-shutdown drain bound expired.
    pub drain_cancels: u64,
    /// Subset of `deadline_expired`: requests rejected at admission
    /// because they provably could not meet their deadline (never
    /// queued, never held KV).
    pub deadline_rejected: u64,
    /// Times the engine parked on its intake gate with every lane idle
    /// (woken by submission, intake close, or shutdown — not a poll).
    pub idle_parks: u64,
    pub total_tokens_generated: usize,
    pub iterations: u64,
    /// Wall-clock duration of the serving loop (seconds).
    pub wall_s: f64,
    /// Wall-clock per engine step (ms).
    pub step_ms: Percentiles,
    /// Request latency (ms, admission → finish), wall-clock.
    pub request_latency_ms: Percentiles,
    /// Time-to-first-token (ms, admission → first sample), wall-clock.
    pub ttft_ms: Percentiles,
    /// Time-per-output-token (ms): per completed request, the mean
    /// inter-token gap over its decode phase (first token excluded —
    /// that is TTFT's job). The steady-state latency a streaming client
    /// observes between tokens.
    pub tpot_ms: Percentiles,
    /// Time each request waited between reaching the engine (or its
    /// nominal arrival, whichever is later) and taking a lane (ms).
    /// Grows without bound once the offered load exceeds lane capacity
    /// — the saturation signal of the continuous engine.
    pub time_in_queue_ms: Percentiles,
    /// Admission-queue depth sampled once per productive engine
    /// iteration (idle-wait iterations are not samples).
    pub queue_depth: Percentiles,
    /// Iterations where adaptive prefill co-scheduling shrank the
    /// prefill chunk below its configured bound because decode lanes
    /// were live ([`super::ServeConfig::adaptive_prefill`]).
    pub adaptive_prefill_shrinks: u64,
    /// Mean lane occupancy over the run.
    pub mean_occupancy: f64,
    /// Decode-batch width per iteration that stepped at least one
    /// batched decode lane — the lane count whose projections shared
    /// ONE weight pass that step.
    pub batch_width: Percentiles,
    /// Layer-stack weight passes streamed over the run: a batched
    /// decode step pays exactly one regardless of its width; a prefill
    /// lane pays one per chunk token it feeds (the per-token GEMVs of
    /// `prefill_into` each stream the layer weights).
    pub weight_passes: u64,
    /// Mean weight passes per engine iteration. `1.0` on decode-heavy
    /// traffic = perfectly amortized decode batching; `≈ lanes` would
    /// be the old lane-per-thread decode behavior (every lane
    /// re-streaming the weights each step).
    pub weight_passes_per_step: f64,
    /// Tokens/second, wall-clock.
    pub tokens_per_s: f64,
    /// Modelled SwiftKV-MHA time for the same schedule (ms): every
    /// iteration costs one simulated decode step at the batch's maximum
    /// live context.
    pub simulated_accel_ms: f64,
    /// Modelled accelerator tokens/second.
    pub simulated_tokens_per_s: f64,
}

impl ServeMetrics {
    pub fn format_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests                {:>10}\n",
            self.requests
        ));
        out.push_str(&format!(
            "admitted / rejected     {:>7} / {}\n",
            self.requests_admitted, self.requests_rejected
        ));
        if self.requests_failed + self.preemptions + self.deadline_expired > 0 {
            out.push_str(&format!(
                "failed / expired        {:>7} / {}\n",
                self.requests_failed, self.deadline_expired
            ));
            out.push_str(&format!(
                "preempted / requeued    {:>7} / {}\n",
                self.preemptions, self.requeues
            ));
        }
        if self.requests_cancelled + self.requests_shed > 0 {
            out.push_str(&format!(
                "cancelled / shed        {:>7} / {}\n",
                self.requests_cancelled, self.requests_shed
            ));
            out.push_str(&format!(
                "slow-client / drain     {:>7} / {}\n",
                self.slow_client_cancels, self.drain_cancels
            ));
        }
        if self.deadline_rejected > 0 {
            out.push_str(&format!(
                "deadline-rejected       {:>10}\n",
                self.deadline_rejected
            ));
        }
        if self.idle_parks > 0 {
            out.push_str(&format!("idle parks              {:>10}\n", self.idle_parks));
        }
        out.push_str(&format!(
            "tokens generated        {:>10}\n",
            self.total_tokens_generated
        ));
        out.push_str(&format!("engine iterations       {:>10}\n", self.iterations));
        out.push_str(&format!("wall time               {:>10.2} s\n", self.wall_s));
        out.push_str(&format!(
            "throughput (wall)       {:>10.1} tok/s\n",
            self.tokens_per_s
        ));
        out.push_str(&format!(
            "step latency p50/p90    {:>7.2} / {:.2} ms\n",
            self.step_ms.p50, self.step_ms.p90
        ));
        out.push_str(&format!(
            "request latency p50/p99 {:>7.1} / {:.1} ms\n",
            self.request_latency_ms.p50, self.request_latency_ms.p99
        ));
        out.push_str(&format!(
            "TTFT p50                {:>10.1} ms\n",
            self.ttft_ms.p50
        ));
        out.push_str(&format!(
            "TPOT p50/p99            {:>7.2} / {:.2} ms\n",
            self.tpot_ms.p50, self.tpot_ms.p99
        ));
        out.push_str(&format!(
            "time in queue p50/p99   {:>7.1} / {:.1} ms\n",
            self.time_in_queue_ms.p50, self.time_in_queue_ms.p99
        ));
        out.push_str(&format!(
            "queue depth p50         {:>10.1} (max {:.0})\n",
            self.queue_depth.p50, self.queue_depth.max
        ));
        if self.adaptive_prefill_shrinks > 0 {
            out.push_str(&format!(
                "adaptive chunk shrinks  {:>10}\n",
                self.adaptive_prefill_shrinks
            ));
        }
        out.push_str(&format!(
            "mean occupancy          {:>10.2}\n",
            self.mean_occupancy
        ));
        out.push_str(&format!(
            "decode batch width p50  {:>10.1} (max {:.0})\n",
            self.batch_width.p50, self.batch_width.max
        ));
        out.push_str(&format!(
            "weight passes / step    {:>10.2} ({} total)\n",
            self.weight_passes_per_step, self.weight_passes
        ));
        out.push_str(&format!(
            "simulated accel time    {:>10.2} ms ({:.1} tok/s)\n",
            self.simulated_accel_ms, self.simulated_tokens_per_s
        ));
        let dropped = self.step_ms.non_finite
            + self.request_latency_ms.non_finite
            + self.ttft_ms.non_finite
            + self.tpot_ms.non_finite
            + self.time_in_queue_ms.non_finite
            + self.queue_depth.non_finite
            + self.batch_width.non_finite;
        if dropped > 0 {
            out.push_str(&format!(
                "non-finite samples      {:>10} (dropped from the stats above)\n",
                dropped
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::compute(&samples).unwrap();
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p90, 90.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_samples_none() {
        assert!(Percentiles::compute(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let p = Percentiles::compute(&[7.0]).unwrap();
        assert_eq!(p.p50, 7.0);
        assert_eq!(p.p99, 7.0);
    }

    #[test]
    fn nan_samples_are_dropped_not_poisoning() {
        // regression: total_cmp sorts NaN last, so one NaN used to make
        // `max` (and `mean`) print as NaN in the serve table
        let samples = [1.0, f64::NAN, 3.0, 2.0];
        let p = Percentiles::compute(&samples).unwrap();
        assert_eq!(p.non_finite, 1);
        assert_eq!(p.max, 3.0);
        assert_eq!(p.p50, 2.0);
        assert!((p.mean - 2.0).abs() < 1e-12);
        assert!(p.p90.is_finite() && p.p99.is_finite());
    }

    #[test]
    fn infinities_count_as_non_finite() {
        let samples = [f64::INFINITY, 5.0, f64::NEG_INFINITY, f64::NAN, 1.0];
        let p = Percentiles::compute(&samples).unwrap();
        assert_eq!(p.non_finite, 3);
        assert_eq!(p.max, 5.0);
        assert_eq!(p.p50, 1.0);
        assert!((p.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn all_non_finite_yields_zeroed_stats_with_count() {
        let p = Percentiles::compute(&[f64::NAN, f64::INFINITY]).unwrap();
        assert_eq!(p.non_finite, 2);
        assert_eq!(p.max, 0.0);
        assert_eq!(p.mean, 0.0);
        // empty input still reports "no data", distinct from "all bad"
        assert!(Percentiles::compute(&[]).is_none());
    }

    #[test]
    fn format_table_surfaces_dropped_samples() {
        let mut m = ServeMetrics {
            requests: 1,
            requests_admitted: 1,
            total_tokens_generated: 4,
            iterations: 4,
            wall_s: 0.1,
            step_ms: Percentiles::compute(&[1.0, f64::NAN, 2.0]).unwrap(),
            mean_occupancy: 1.0,
            weight_passes: 4,
            weight_passes_per_step: 1.0,
            tokens_per_s: 40.0,
            simulated_accel_ms: 0.5,
            simulated_tokens_per_s: 8000.0,
            ..Default::default()
        };
        assert!(m.format_table().contains("non-finite samples"));
        assert!(!m.format_table().contains("NaN"), "stats must stay finite");
        m.step_ms = Percentiles::ZERO;
        assert!(!m.format_table().contains("non-finite samples"));
    }

    #[test]
    fn format_table_reports_queueing_stats() {
        let mut m = ServeMetrics {
            tpot_ms: Percentiles::compute(&[2.0, 3.0]).unwrap(),
            time_in_queue_ms: Percentiles::compute(&[10.0]).unwrap(),
            queue_depth: Percentiles::compute(&[0.0, 5.0]).unwrap(),
            ..Default::default()
        };
        let table = m.format_table();
        assert!(table.contains("TPOT"));
        assert!(table.contains("time in queue"));
        assert!(table.contains("queue depth"));
        // the adaptive line only appears once the policy actually fired
        assert!(!table.contains("adaptive chunk shrinks"));
        m.adaptive_prefill_shrinks = 3;
        assert!(m.format_table().contains("adaptive chunk shrinks"));
    }

    #[test]
    fn format_table_overload_rows_are_conditional() {
        let mut m = ServeMetrics::default();
        let table = m.format_table();
        assert!(!table.contains("cancelled / shed"));
        assert!(!table.contains("deadline-rejected"));
        assert!(!table.contains("idle parks"));
        m.requests_cancelled = 2;
        m.requests_shed = 5;
        m.slow_client_cancels = 1;
        m.drain_cancels = 1;
        m.deadline_rejected = 3;
        m.idle_parks = 7;
        let table = m.format_table();
        assert!(table.contains("cancelled / shed"));
        assert!(table.contains("slow-client / drain"));
        assert!(table.contains("deadline-rejected"));
        assert!(table.contains("idle parks"));
    }
}
