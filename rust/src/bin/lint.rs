//! Repo-invariant lint runner — `cargo run --bin lint`.
//!
//! Runs the [`swiftkv::util::lint`] pass over the crate (`src/`,
//! `tests/`, `benches/`) and exits non-zero on any violation, printing
//! each as `file:line: [rule] message`. The same pass also runs as a
//! plain test via `tests/lint_repo.rs`, so CI catches violations even
//! where running extra binaries is awkward.

use std::path::Path;
use std::process::ExitCode;

use swiftkv::util::lint;

fn main() -> ExitCode {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = match lint::lint_crate(root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("lint: failed to scan crate: {e}");
            return ExitCode::FAILURE;
        }
    };
    if violations.is_empty() {
        println!("lint: clean — {} rules over {}", lint::RULES.len(), root.display());
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        eprintln!("{v}");
    }
    eprintln!("lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}
