//! LLM model descriptions and workload generation.
//!
//! [`config::LlmConfig`] captures the shapes the accelerator schedules
//! against (the paper's targets: LLaMA2-7B, ChatGLM-6B, LLaMA3-8B,
//! Qwen3-8B, plus the tiny AOT model served by the runtime), along with
//! per-token operation and byte counts used by the throughput/efficiency
//! exhibits. [`workload`] generates synthetic decode request streams for
//! the coordinator and benches; [`tiny`] is the pure-Rust forward pass of
//! the tiny model in both "desktop f32" and "accelerator W4A8+FXP32"
//! numerics (the Table I experiment).

pub mod config;
pub mod ops;
pub mod tiny;
pub mod weights;
pub mod workload;

pub use config::LlmConfig;
pub use ops::TokenCost;
pub use tiny::{BatchLane, DecodeState, LaneFault, NumericsMode, TinyModel, DEFAULT_KV_BLOCK_LEN};
pub use weights::WeightStore;
pub use workload::{Request, WorkloadGen, WorkloadSpec};
