//! Vector operations of the Public MAC Array, in Q15.17.
//!
//! The SKV Unit's dot-product part computes `q·kᵗ` with a wide internal
//! accumulator (DSP cascade), rounding once on writeback — modelled here by
//! accumulating the 64-bit products and converting a single time. The
//! update part performs the `Y ← αY + v` / `Y ← Y + βv` AXPY steps of
//! Eqs. (6)–(7).
//!
//! lint: hotpath

use super::q1517::{Fxp32, FRAC_BITS};

/// Dot product with a wide (i64) accumulator and a single rounding on
/// writeback — the DSP-cascade behaviour of the MAC array.
///
/// The wide accumulation is dispatched through
/// [`crate::kernels::isa::active`]; integer sums reassociate freely, so
/// the result is **bit-exact across every dispatch target**. The single
/// Q34 → Q17 rounding happens here, after the table call.
#[inline]
pub fn dot(a: &[Fxp32], b: &[Fxp32]) -> Fxp32 {
    debug_assert_eq!(a.len(), b.len());
    let acc = (crate::kernels::isa::active().dot_fxp_wide)(a, b);
    // one rounding at the end: Q34 → Q17
    let rounded = (acc + (1i64 << (FRAC_BITS - 1))) >> FRAC_BITS;
    Fxp32::from_raw(rounded.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
}

/// Scalar body of the wide dot: the unrounded `Σ raw(a)·raw(b)` sum.
/// Registered as the `dot_fxp_wide` fallback in the dispatch table; the
/// SIMD kernels must match it bit-for-bit.
#[inline]
pub(crate) fn dot_wide_scalar(a: &[Fxp32], b: &[Fxp32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators let the compiler vectorize the widening
    // multiply-add chain (§Perf)
    let n = a.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..chunks {
        let k = 4 * i;
        a0 += a[k].raw() as i64 * b[k].raw() as i64;
        a1 += a[k + 1].raw() as i64 * b[k + 1].raw() as i64;
        a2 += a[k + 2].raw() as i64 * b[k + 2].raw() as i64;
        a3 += a[k + 3].raw() as i64 * b[k + 3].raw() as i64;
    }
    let mut acc: i64 = a0 + a1 + a2 + a3;
    for i in 4 * chunks..n {
        acc += a[i].raw() as i64 * b[i].raw() as i64;
    }
    acc
}

/// `y ← a·y + b·x` elementwise — the combined rescale-and-accumulate of the
/// update part (covers both branches of Eqs. (6)–(7) with a ∈ {α, 1},
/// b ∈ {β, 1}).
#[inline]
pub fn axpby_inplace(a: Fxp32, y: &mut [Fxp32], b: Fxp32, x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = a.sat_mul(*yi).sat_add(b.sat_mul(*xi));
    }
}

/// `y ← y + b·x` (the β-branch of Eq. 6 — history untouched, one multiply
/// per lane; §Perf specialization of `axpby_inplace`). Dispatched; the
/// per-element round/clamp/saturate sequence is **bit-exact across every
/// dispatch target**.
#[inline]
pub fn axpy_inplace(b: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    (crate::kernels::isa::active().axpy_fxp)(b, y, x)
}

/// Scalar body of [`axpy_inplace`] — the dispatch fallback and the
/// bit-exactness reference for the SIMD kernels.
#[inline]
pub(crate) fn axpy_scalar(b: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    let braw = b.raw() as i64;
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        let prod = (braw * xi.raw() as i64 + (1i64 << (FRAC_BITS - 1))) >> FRAC_BITS;
        *yi = yi.sat_add(Fxp32::from_raw(prod.clamp(i32::MIN as i64, i32::MAX as i64) as i32));
    }
}

/// `y ← a·y + x` (the α-branch of Eq. 7 — one multiply per lane).
/// Dispatched; **bit-exact across every dispatch target**.
#[inline]
pub fn scale_axpy_inplace(a: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    (crate::kernels::isa::active().scale_axpy_fxp)(a, y, x)
}

/// Scalar body of [`scale_axpy_inplace`] — the dispatch fallback and the
/// bit-exactness reference for the SIMD kernels.
#[inline]
pub(crate) fn scale_axpy_scalar(a: Fxp32, y: &mut [Fxp32], x: &[Fxp32]) {
    debug_assert_eq!(y.len(), x.len());
    let araw = a.raw() as i64;
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        let prod = (araw * yi.raw() as i64 + (1i64 << (FRAC_BITS - 1))) >> FRAC_BITS;
        *yi = Fxp32::from_raw(prod.clamp(i32::MIN as i64, i32::MAX as i64) as i32).sat_add(*xi);
    }
}

/// Scale a vector in place: `y ← a·y`.
#[inline]
pub fn scale_inplace(a: Fxp32, y: &mut [Fxp32]) {
    for yi in y.iter_mut() {
        *yi = a.sat_mul(*yi);
    }
}

/// Elementwise divide by a scalar — the deferred one-time normalization of
/// Eq. (8). Hardware computes `1/Z` once on the divide unit and multiplies.
#[inline]
pub fn div_scalar(y: &[Fxp32], z: Fxp32) -> Vec<Fxp32> {
    // lint: allow(hotpath) — allocating convenience form; the decode
    // loop's finalize_into writes through caller-owned buffers.
    // reciprocal once, then multiply (matches the pipelined divider usage)
    y.iter().map(|yi| yi.sat_div(z)).collect()
}

/// Quantize an `f32` slice to Q15.17.
pub fn quantize(xs: &[f32]) -> Vec<Fxp32> {
    // lint: allow(hotpath) — allocating convenience form of quantize_into.
    xs.iter().map(|&x| Fxp32::from_f32(x)).collect()
}

/// [`quantize`] into a caller-owned buffer (no allocation).
#[inline]
pub fn quantize_into(xs: &[f32], out: &mut [Fxp32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = Fxp32::from_f32(x);
    }
}

/// Dequantize a Q15.17 slice to `f32`.
pub fn dequantize(xs: &[Fxp32]) -> Vec<f32> {
    // lint: allow(hotpath) — allocating convenience form of dequantize_into.
    xs.iter().map(|x| x.to_f32()).collect()
}

/// [`dequantize`] into a caller-owned buffer (no allocation).
#[inline]
pub fn dequantize_into(xs: &[Fxp32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x.to_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qv(xs: &[f64]) -> Vec<Fxp32> {
        xs.iter().map(|&x| Fxp32::from_f64(x)).collect()
    }

    #[test]
    fn dot_matches_float() {
        let a = qv(&[1.0, -2.5, 3.25, 0.125]);
        let b = qv(&[0.5, 4.0, -1.0, 8.0]);
        let want = 1.0 * 0.5 - 2.5 * 4.0 + 3.25 * -1.0 + 0.125 * 8.0;
        let got = dot(&a, &b).to_f64();
        assert!((got - want).abs() < 1e-4, "got {got}, want {want}");
    }

    #[test]
    fn dot_wide_accumulator_no_intermediate_overflow() {
        // Intermediate sums exceed i32 range but the final value fits.
        let a: Vec<Fxp32> = (0..128).map(|_| Fxp32::from_f64(100.0)).collect();
        let mut b: Vec<Fxp32> = (0..128).map(|_| Fxp32::from_f64(100.0)).collect();
        for x in b.iter_mut().skip(1).step_by(2) {
            *x = Fxp32::from_f64(-100.0);
        }
        // pairs cancel → exact zero
        assert_eq!(dot(&a, &b), Fxp32::ZERO);
    }

    #[test]
    fn axpby_both_branches() {
        // β-branch: y ← y + βx  (a = 1)
        let mut y = qv(&[1.0, 2.0]);
        axpby_inplace(Fxp32::ONE, &mut y, Fxp32::from_f64(0.5), &qv(&[4.0, -4.0]));
        assert!((y[0].to_f64() - 3.0).abs() < 1e-4);
        assert!((y[1].to_f64() - 0.0).abs() < 1e-4);
        // α-branch: y ← αy + x  (b = 1)
        let mut y = qv(&[4.0, -2.0]);
        axpby_inplace(Fxp32::from_f64(0.25), &mut y, Fxp32::ONE, &qv(&[1.0, 1.0]));
        assert!((y[0].to_f64() - 2.0).abs() < 1e-4);
        assert!((y[1].to_f64() - 0.5).abs() < 1e-4);
    }

    #[test]
    fn div_scalar_normalizes() {
        let y = qv(&[2.0, 4.0, -6.0]);
        let out = div_scalar(&y, Fxp32::from_f64(2.0));
        let vals: Vec<f64> = out.iter().map(|x| x.to_f64()).collect();
        assert!((vals[0] - 1.0).abs() < 1e-4);
        assert!((vals[1] - 2.0).abs() < 1e-4);
        assert!((vals[2] + 3.0).abs() < 1e-4);
    }

    #[test]
    fn quantize_roundtrip() {
        let xs = [0.1f32, -0.9, 3.75, -100.0];
        let back = dequantize(&quantize(&xs));
        for (x, y) in xs.iter().zip(back.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
