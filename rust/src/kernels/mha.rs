//! Fused multi-head SwiftKV decode state (f32), grouped-query aware.
//!
//! The paper's SwiftKV-MHA accelerator streams every `(k_t, v_t)` cache
//! row exactly once and feeds *all* heads from that single sweep (§IV,
//! Fig. 5): the per-token recurrence of Eqs. (5)–(8) runs in lock-step
//! across heads over an interleaved, token-major cache. This is the
//! software analogue: all heads' `(μ, Z, Y)` state packed contiguously,
//! one [`MhaSwiftKv::update_token`] call advancing every head, and a
//! non-allocating [`MhaSwiftKv::finalize_into`].
//!
//! **Grouped-query attention** (GQA/MQA — the standard KV-bandwidth
//! reduction on edge targets) is first-class: with
//! `group = n_heads / n_kv_heads`, each streamed KV row holds only
//! `n_kv_heads · d` elements and every KV-head slice is loaded once and
//! advances its whole group of query heads. `n_kv_heads == n_heads` is
//! plain MHA; `n_kv_heads == 1` is MQA.
//!
//! Layout: a cache *row* holds all **KV heads'** vectors for one token
//! position, head-major within the row —
//! `row[t] = [kv_head0[d] | kv_head1[d] | …]`, `row_width = n_kv_heads · d`.
//! Queries and outputs are packed over the **query** heads
//! (`n_heads · d`, head-major).
//!
//! Per query head the recurrence is identical (same branch structure,
//! same element-wise update order) to the per-head
//! [`crate::attention::swiftkv::SwiftKvState`]; only the dot product uses
//! the runtime-dispatched [`super::simd::dot`] (scalar multi-accumulator
//! or the native SIMD microkernel picked by [`super::isa`]), so outputs
//! agree with the per-head path to within f32 re-association noise
//! (≪ 1e-5 relative). The AXPY-shaped row updates dispatch too, but
//! those are bit-identical across ISAs by contract.
//!
//! lint: hotpath

use super::simd;

/// Packed multi-head SwiftKV recurrence state (GQA-aware).
#[derive(Debug, Clone)]
pub struct MhaSwiftKv {
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    /// Running max per query head.
    mu: Vec<f32>,
    /// Softmax denominator per query head.
    z: Vec<f32>,
    /// Unnormalized output, `[n_heads * d]`, head-major.
    y: Vec<f32>,
    consumed: usize,
}

impl MhaSwiftKv {
    /// Fresh multi-head-attention state (`n_kv_heads == n_heads`) for
    /// `n_heads` heads of dimension `d`.
    pub fn new(n_heads: usize, d: usize) -> Self {
        Self::new_grouped(n_heads, n_heads, d)
    }

    /// Fresh grouped-query state: `n_heads` query heads sharing
    /// `n_kv_heads` KV heads (`n_heads % n_kv_heads == 0`).
    pub fn new_grouped(n_heads: usize, n_kv_heads: usize, d: usize) -> Self {
        assert!(n_heads > 0 && n_kv_heads > 0 && d > 0, "empty state");
        assert!(
            n_heads % n_kv_heads == 0,
            "n_heads ({n_heads}) must be a multiple of n_kv_heads ({n_kv_heads})"
        );
        MhaSwiftKv {
            n_heads,
            n_kv_heads,
            d,
            // lint: allow(hotpath) — one-time constructor allocation; the
            // decode loop reuses the state via reset().
            mu: vec![f32::NEG_INFINITY; n_heads],
            z: vec![0.0; n_heads],
            y: vec![0.0; n_heads * d],
            consumed: 0,
        }
    }

    /// Reset for a new query without releasing the buffers (the scratch
    /// reuse that keeps the decode hot loop allocation-free). `μ`, `Z`,
    /// `Y` are re-initialized lazily by the first token's update.
    #[inline]
    pub fn reset(&mut self) {
        self.consumed = 0;
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn n_kv_heads(&self) -> usize {
        self.n_kv_heads
    }

    /// Query heads per KV head (`1` for MHA, `n_heads` for MQA).
    #[inline]
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tokens consumed since the last reset.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Width of one interleaved KV cache row (`n_kv_heads · d`).
    #[inline]
    pub fn row_width(&self) -> usize {
        self.n_kv_heads * self.d
    }

    /// Width of the packed query / output rows (`n_heads · d`).
    #[inline]
    pub fn q_width(&self) -> usize {
        self.n_heads * self.d
    }

    /// Consume one interleaved `(k_t, v_t)` cache row, advancing *every*
    /// query head in a single sweep — the fused analogue of Fig. 3's
    /// compare-and-select + update parts, Eqs. (5)–(7). Each KV-head
    /// slice is loaded once and feeds its whole group of query heads.
    ///
    /// `q` is `[n_heads * d]`; `k_t`, `v_t` are `[n_kv_heads * d]`
    /// head-major packed rows; `scale` is the `1/√d` of Eq. (5).
    #[inline]
    pub fn update_token(&mut self, q: &[f32], k_t: &[f32], v_t: &[f32], scale: f32) {
        let d = self.d;
        let group = self.group();
        debug_assert_eq!(q.len(), self.n_heads * d);
        debug_assert_eq!(k_t.len(), self.n_kv_heads * d);
        debug_assert_eq!(v_t.len(), self.n_kv_heads * d);
        if self.consumed == 0 {
            // μ₁ = s₁ branch for every head: β = exp(0) = 1
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = simd::dot(&q[o..o + d], kh) * scale;
                    self.mu[head] = s;
                    self.z[head] = 1.0;
                    self.y[o..o + d].copy_from_slice(vh);
                }
            }
        } else {
            for kv in 0..self.n_kv_heads {
                let kh = &k_t[kv * d..(kv + 1) * d];
                let vh = &v_t[kv * d..(kv + 1) * d];
                for g in 0..group {
                    let head = kv * group + g;
                    let o = head * d;
                    let s = simd::dot(&q[o..o + d], kh) * scale;
                    let yh = &mut self.y[o..o + d];
                    if s <= self.mu[head] {
                        // Eq. (6): fold the new token in at weight β ∈ (0, 1]
                        let beta = (s - self.mu[head]).exp();
                        self.z[head] += beta;
                        simd::axpy(beta, yh, vh);
                    } else {
                        // Eq. (7): rescale history by α ∈ (0, 1)
                        let alpha = (self.mu[head] - s).exp();
                        self.z[head] = alpha * self.z[head] + 1.0;
                        simd::scale_axpy(alpha, yh, vh);
                        self.mu[head] = s;
                    }
                }
            }
        }
        self.consumed += 1;
    }

    /// Extend over cache rows `[from, to)` of a token-major interleaved
    /// cache (`k`/`v` are `[len, n_kv_heads * d]` row-major). Matches the
    /// incremental-decode contract of [`crate::attention::swiftkv::extend`].
    pub fn extend(&mut self, q: &[f32], k: &[f32], v: &[f32], from: usize, to: usize, scale: f32) {
        let row = self.row_width();
        assert!(k.len() >= to * row, "k cache too short");
        assert!(v.len() >= to * row, "v cache too short");
        for t in from..to {
            self.update_token(q, &k[t * row..(t + 1) * row], &v[t * row..(t + 1) * row], scale);
        }
    }

    /// Extend over token positions `[from, to)` of a block-gathered
    /// paged cache ([`super::paged::BlockTable`]). Row values reach
    /// [`MhaSwiftKv::update_token`] in the same order and through the
    /// same per-head op sequence as [`MhaSwiftKv::extend`], so the paged
    /// sweep is bit-identical to the contiguous one over equal rows.
    pub fn extend_paged(
        &mut self,
        q: &[f32],
        table: &super::paged::BlockTable,
        from: usize,
        to: usize,
        scale: f32,
    ) {
        assert_eq!(table.row_width(), self.row_width(), "table row width mismatch");
        assert!(table.capacity_tokens() >= to, "block table too short");
        for t in from..to {
            self.update_token(q, table.k_row(t), table.v_row(t), scale);
        }
    }

    /// Causal multi-token sweep over a contiguous cache — the kernel
    /// half of chunked prefill. `qs` holds `chunk` packed query rows
    /// (`[chunk, n_heads * d]`); query row `j` sits at token position
    /// `start + j` and attends over cache rows `[0, start + j + 1)`
    /// (its causal prefix, which includes the chunk rows written before
    /// it). Each query runs the *same* reset → [`MhaSwiftKv::extend`] →
    /// [`MhaSwiftKv::finalize_into`] pipeline as the single-token decode
    /// path, in the same per-head op order, so the chunked sweep is
    /// bit-identical to feeding the tokens one `decode_step` at a time.
    /// Outputs land row-by-row in `outs` (`[chunk, n_heads * d]`); no
    /// allocation. The state is left as the last query's sweep.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_chunk(
        &mut self,
        qs: &[f32],
        k: &[f32],
        v: &[f32],
        start: usize,
        chunk: usize,
        scale: f32,
        outs: &mut [f32],
    ) {
        let qw = self.q_width();
        assert_eq!(qs.len(), chunk * qw, "qs must hold chunk packed query rows");
        assert_eq!(outs.len(), chunk * qw, "outs must hold chunk packed output rows");
        for j in 0..chunk {
            self.reset();
            self.extend(&qs[j * qw..(j + 1) * qw], k, v, 0, start + j + 1, scale);
            self.finalize_into(&mut outs[j * qw..(j + 1) * qw]);
        }
    }

    /// [`MhaSwiftKv::attend_chunk`] over a block-gathered paged cache:
    /// the chunked-prefill sweep of the serving path. Identical op order
    /// per query (reset → [`MhaSwiftKv::extend_paged`] → finalize), so
    /// results are bit-identical to the contiguous chunk sweep and to
    /// the per-token decode path over equal rows.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_chunk_paged(
        &mut self,
        qs: &[f32],
        table: &super::paged::BlockTable,
        start: usize,
        chunk: usize,
        scale: f32,
        outs: &mut [f32],
    ) {
        let qw = self.q_width();
        assert_eq!(qs.len(), chunk * qw, "qs must hold chunk packed query rows");
        assert_eq!(outs.len(), chunk * qw, "outs must hold chunk packed output rows");
        assert!(table.capacity_tokens() >= start + chunk, "block table too short");
        for j in 0..chunk {
            self.reset();
            self.extend_paged(&qs[j * qw..(j + 1) * qw], table, 0, start + j + 1, scale);
            self.finalize_into(&mut outs[j * qw..(j + 1) * qw]);
        }
    }

    /// Eq. (8): the deferred one-time normalization, written into a
    /// caller-owned `[n_heads * d]` buffer (no allocation).
    pub fn finalize_into(&self, out: &mut [f32]) {
        assert!(self.consumed > 0, "finalize before any token");
        assert_eq!(out.len(), self.n_heads * self.d);
        for head in 0..self.n_heads {
            let o = head * self.d;
            let z = self.z[head];
            for (dst, &y) in out[o..o + self.d].iter_mut().zip(&self.y[o..o + self.d]) {
                *dst = y / z;
            }
        }
    }

    /// One-shot fused attention over `len` interleaved cache rows:
    /// reset → single sweep → finalize, all into caller-owned memory.
    pub fn attend(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        len: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        self.reset();
        self.extend(q, k, v, 0, len, scale);
        self.finalize_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{native, swiftkv as swiftkv_attn, HeadProblem};
    use crate::kernels::gather_head;
    use crate::util::Rng;

    #[test]
    fn fused_matches_per_head_swiftkv() {
        let mut rng = Rng::seed_from_u64(11);
        let (h, d, len) = (4usize, 16usize, 64usize);
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);

        let mut mha = MhaSwiftKv::new(h, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, scale, &mut out);

        for head in 0..h {
            let kh = gather_head(&k, head, h, d, len);
            let vh = gather_head(&v, head, h, d, len);
            let p = HeadProblem::new(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = swiftkv_attn::attend(&p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= 5e-5 * (1.0 + b.abs()),
                    "head {head} dim {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn fused_matches_native_softmax() {
        let mut rng = Rng::seed_from_u64(12);
        let (h, d, len) = (2usize, 8usize, 33usize);
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);
        let mut mha = MhaSwiftKv::new(h, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, scale, &mut out);
        for head in 0..h {
            let kh = gather_head(&k, head, h, d, len);
            let vh = gather_head(&v, head, h, d, len);
            let p = HeadProblem::new(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = native::attend(&p);
            for (a, b) in out[head * d..(head + 1) * d].iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn grouped_matches_per_head_over_shared_kv() {
        // GQA: query head h reads KV head h / group; each query head must
        // match the per-head reference run on its shared KV slice.
        let mut rng = Rng::seed_from_u64(16);
        let (h, hkv, d, len) = (6usize, 2usize, 16usize, 40usize);
        let group = h / hkv;
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * hkv * d, 1.0);
        let v = rng.uniform_vec(len * hkv * d, 1.0);

        let mut mha = MhaSwiftKv::new_grouped(h, hkv, d);
        assert_eq!(mha.row_width(), hkv * d);
        assert_eq!(mha.q_width(), h * d);
        assert_eq!(mha.group(), group);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, scale, &mut out);

        for head in 0..h {
            let kv = head / group;
            let kh = gather_head(&k, kv, hkv, d, len);
            let vh = gather_head(&v, kv, hkv, d, len);
            let p = HeadProblem::new(&q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = swiftkv_attn::attend(&p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= 5e-5 * (1.0 + b.abs()),
                    "head {head} dim {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn mqa_identical_queries_share_output() {
        // MQA (1 KV head): query heads with identical q rows must produce
        // bit-identical outputs — they see exactly the same KV stream.
        let mut rng = Rng::seed_from_u64(17);
        let (h, d, len) = (4usize, 8usize, 12usize);
        let qh = rng.uniform_vec(d, 1.0);
        let mut q = Vec::with_capacity(h * d);
        for _ in 0..h {
            q.extend_from_slice(&qh);
        }
        let k = rng.uniform_vec(len * d, 1.0);
        let v = rng.uniform_vec(len * d, 1.0);
        let mut mha = MhaSwiftKv::new_grouped(h, 1, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, 0.7, &mut out);
        for head in 1..h {
            assert_eq!(
                &out[..d],
                &out[head * d..(head + 1) * d],
                "head {head} diverged from head 0"
            );
        }
    }

    #[test]
    fn single_token_returns_value_row() {
        let mut rng = Rng::seed_from_u64(13);
        let (h, d) = (3usize, 5usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(h * d, 1.0);
        let v = rng.uniform_vec(h * d, 1.0);
        let mut mha = MhaSwiftKv::new(h, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, 1, 1.0, &mut out);
        for (a, b) in out.iter().zip(&v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn extend_is_incremental() {
        let mut rng = Rng::seed_from_u64(14);
        let (h, d, len) = (2usize, 7usize, 40usize);
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);

        let mut one = MhaSwiftKv::new(h, d);
        let mut a = vec![0.0f32; h * d];
        one.attend(&q, &k, &v, len, scale, &mut a);

        let mut two = MhaSwiftKv::new(h, d);
        two.extend(&q, &k, &v, 0, 13, scale);
        two.extend(&q, &k, &v, 13, len, scale);
        let mut b = vec![0.0f32; h * d];
        two.finalize_into(&mut b);
        assert_eq!(a, b, "incremental extend must be bit-identical");
    }

    #[test]
    fn reset_reuses_buffers() {
        let mut rng = Rng::seed_from_u64(15);
        let (h, d, len) = (2usize, 4usize, 10usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);
        let mut mha = MhaSwiftKv::new(h, d);
        let mut a = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, 0.5, &mut a);
        let mut b = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, 0.5, &mut b);
        assert_eq!(a, b, "reset must fully re-initialize the recurrence");
    }

    #[test]
    fn paged_extend_bit_identical_to_contiguous() {
        use crate::kernels::paged::{BlockPool, BlockTable};
        let mut rng = Rng::seed_from_u64(18);
        let (h, hkv, d, len) = (4usize, 2usize, 8usize, 11usize);
        let row = hkv * d;
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);

        // block_len 3 → ragged last block (11 = 3·3 + 2)
        let pool = BlockPool::new(4, 3, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&k[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&v[t * row..(t + 1) * row]);
        }

        let mut contiguous = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![0.0f32; h * d];
        contiguous.attend(&q, &k, &v, len, scale, &mut a);

        let mut paged = MhaSwiftKv::new_grouped(h, hkv, d);
        paged.extend_paged(&q, &table, 0, 5, scale);
        paged.extend_paged(&q, &table, 5, len, scale);
        let mut b = vec![0.0f32; h * d];
        paged.finalize_into(&mut b);
        assert_eq!(a, b, "paged sweep must be bit-identical to contiguous");
        table.release_into(&pool);
    }

    #[test]
    fn chunk_sweep_matches_per_token_attend() {
        // causal chunk of 5 queries starting after a 6-row prefix: each
        // chunk query must be bit-identical to a one-shot attend over its
        // own causal prefix (the per-token decode path's op order)
        let mut rng = Rng::seed_from_u64(19);
        let (h, hkv, d, start, chunk) = (4usize, 2usize, 8usize, 6usize, 5usize);
        let row = hkv * d;
        let len = start + chunk;
        let scale = 1.0 / (d as f32).sqrt();
        let qs = rng.uniform_vec(chunk * h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);

        let mut mha = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut outs = vec![0.0f32; chunk * h * d];
        mha.attend_chunk(&qs, &k, &v, start, chunk, scale, &mut outs);

        let mut reference = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut want = vec![0.0f32; h * d];
        for j in 0..chunk {
            let q = &qs[j * h * d..(j + 1) * h * d];
            reference.attend(q, &k, &v, start + j + 1, scale, &mut want);
            assert_eq!(
                &outs[j * h * d..(j + 1) * h * d],
                want.as_slice(),
                "chunk query {j} diverged from the per-token sweep"
            );
        }
    }

    #[test]
    fn chunk_sweep_paged_bit_identical_to_contiguous() {
        use crate::kernels::paged::{BlockPool, BlockTable};
        let mut rng = Rng::seed_from_u64(20);
        let (h, hkv, d, start, chunk) = (4usize, 4usize, 8usize, 5usize, 6usize);
        let row = hkv * d;
        let len = start + chunk;
        let scale = 1.0 / (d as f32).sqrt();
        let qs = rng.uniform_vec(chunk * h * d, 1.0);
        let k = rng.uniform_vec(len * row, 1.0);
        let v = rng.uniform_vec(len * row, 1.0);

        // block_len 4 → the chunk spans a block boundary (11 = 2·4 + 3)
        let pool = BlockPool::new(3, 4, row);
        let mut table = BlockTable::new(&pool, len);
        table.ensure_tokens(&pool, len);
        for t in 0..len {
            table.k_row_mut(t).copy_from_slice(&k[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&v[t * row..(t + 1) * row]);
        }

        let mut contiguous = MhaSwiftKv::new(h, d);
        let mut a = vec![0.0f32; chunk * h * d];
        contiguous.attend_chunk(&qs, &k, &v, start, chunk, scale, &mut a);

        let mut paged = MhaSwiftKv::new(h, d);
        let mut b = vec![0.0f32; chunk * h * d];
        paged.attend_chunk_paged(&qs, &table, start, chunk, scale, &mut b);
        assert_eq!(a, b, "paged chunk sweep must be bit-identical to contiguous");
        table.release_into(&pool);
    }

    #[test]
    #[should_panic(expected = "finalize before any token")]
    fn finalize_without_tokens_panics() {
        let mha = MhaSwiftKv::new(1, 4);
        let mut out = vec![0.0f32; 4];
        mha.finalize_into(&mut out);
    }

    #[test]
    #[should_panic(expected = "multiple of n_kv_heads")]
    fn indivisible_group_panics() {
        let _ = MhaSwiftKv::new_grouped(6, 4, 8);
    }
}
