//! Tiny CLI flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Unknown flags are an error (catches typos), a
//! repeated flag is an error (no silent last-wins), and boolean flags
//! reject values outside `true|false|1|0|yes|no` at parse time — a typo
//! like `--require-baseline=off` must not silently disarm a gate.

use std::collections::BTreeMap;

/// Parsed arguments: flags + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (excluding argv[0]).
    /// `bool_flags` lists flags that take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        args: I,
        known: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&name.as_str()) && !bool_flags.contains(&name.as_str()) {
                    return Err(format!("unknown flag --{name}"));
                }
                let value = if bool_flags.contains(&name.as_str()) {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next().ok_or_else(|| format!("--{name} needs a value"))?
                };
                if bool_flags.contains(&name.as_str()) && !is_bool_value(&value) {
                    return Err(format!(
                        "--{name}: invalid boolean '{value}' (expected true|false|1|0|yes|no)"
                    ));
                }
                if out.flags.insert(name.clone(), value).is_some() {
                    return Err(format!("--{name} given more than once"));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn parse(known: &[&str], bool_flags: &[&str]) -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1), known, bool_flags)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    /// True for `true|1|yes`, false for `false|0|no` or an absent flag.
    /// Other values cannot reach here: `parse_from` rejects them.
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true" | "1" | "yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

fn is_bool_value(v: &str) -> bool {
    matches!(v, "true" | "false" | "1" | "0" | "yes" | "no")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], known: &[&str], bools: &[&str]) -> Result<Args, String> {
        Args::parse_from(args.iter().map(|s| s.to_string()), known, bools)
    }

    #[test]
    fn flag_styles() {
        let a = parse(
            &["--ctx", "512", "--model=llama2-7b", "--verbose", "cmd"],
            &["ctx", "model"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get_usize("ctx", 0).unwrap(), 512);
        assert_eq!(a.get("model"), Some("llama2-7b"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["cmd".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["--nope", "1"], &["ctx"], &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--ctx"], &["ctx"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &["ctx"], &[]).unwrap();
        assert_eq!(a.get_usize("ctx", 128).unwrap(), 128);
        assert_eq!(a.get_or("ctx", "x"), "x");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn bad_number_reported() {
        let a = parse(&["--ctx", "abc"], &["ctx"], &[]).unwrap();
        assert!(a.get_usize("ctx", 0).is_err());
    }

    #[test]
    fn invalid_bool_value_rejected_at_parse_time() {
        // regression: `--require-baseline=off` used to parse fine and
        // silently return false from get_bool — disarming the perf gate
        let err = parse(&["--verbose=off"], &[], &["verbose"]).unwrap_err();
        assert!(err.contains("invalid boolean"), "got: {err}");
        assert!(err.contains("off"), "got: {err}");
        assert!(parse(&["--verbose=maybe"], &[], &["verbose"]).is_err());
    }

    #[test]
    fn explicit_false_spellings_parse_and_read_false() {
        for v in ["false", "0", "no"] {
            let flag = format!("--verbose={v}");
            let a = parse(&[flag.as_str()], &[], &["verbose"]).unwrap();
            assert!(!a.get_bool("verbose"), "--verbose={v} must be false");
        }
        for v in ["true", "1", "yes"] {
            let flag = format!("--verbose={v}");
            let a = parse(&[flag.as_str()], &[], &["verbose"]).unwrap();
            assert!(a.get_bool("verbose"), "--verbose={v} must be true");
        }
        // bare flag still means true
        assert!(parse(&["--verbose"], &[], &["verbose"]).unwrap().get_bool("verbose"));
    }

    #[test]
    fn repeated_flag_rejected() {
        // regression: `--ctx 8 --ctx 9` used to silently keep 9
        let err = parse(&["--ctx", "8", "--ctx", "9"], &["ctx"], &[]).unwrap_err();
        assert!(err.contains("more than once"), "got: {err}");
        let err = parse(&["--verbose", "--verbose"], &[], &["verbose"]).unwrap_err();
        assert!(err.contains("more than once"), "got: {err}");
        let err = parse(&["--ctx=8", "--ctx", "9"], &["ctx"], &[]).unwrap_err();
        assert!(err.contains("more than once"), "got: {err}");
    }
}
