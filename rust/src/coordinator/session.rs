//! Per-request decode sessions.

use crate::model::Request;

/// Lifecycle phase of a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionPhase {
    /// Feeding prompt tokens (one per engine step — decode-path prefill,
    /// matching the decode-only accelerator).
    Prefill,
    /// Sampling new tokens.
    Decode,
    /// All tokens generated.
    Finished,
}

/// One request being decoded on a lane.
#[derive(Debug, Clone)]
pub struct Session {
    pub request: Request,
    /// Next position to write in the lane's KV cache.
    pub pos: usize,
    /// Generated tokens so far.
    pub generated: Vec<u32>,
    /// Iteration index at which the session was admitted.
    pub admitted_at: u64,
    /// Iteration of first generated token (TTFT accounting).
    pub first_token_at: Option<u64>,
    /// Iteration at which the session finished.
    pub finished_at: Option<u64>,
}

impl Session {
    pub fn new(request: Request, admitted_at: u64) -> Self {
        assert!(!request.prompt.is_empty(), "empty prompt");
        assert!(request.gen_len >= 1, "gen_len must be ≥ 1");
        Session {
            request,
            pos: 0,
            generated: Vec::new(),
            admitted_at,
            first_token_at: None,
            finished_at: None,
        }
    }

    pub fn phase(&self) -> SessionPhase {
        if self.generated.len() >= self.request.gen_len {
            SessionPhase::Finished
        } else if self.pos < self.request.prompt.len() {
            SessionPhase::Prefill
        } else {
            SessionPhase::Decode
        }
    }

    /// The token to feed at the current position: prompt token during
    /// prefill, last sampled token during decode.
    pub fn next_input(&self) -> u32 {
        if self.pos < self.request.prompt.len() {
            self.request.prompt[self.pos]
        } else {
            *self
                .generated
                .last()
                .expect("decode phase requires a sampled token")
        }
    }

    /// Record the outcome of one engine step. During prefill before the
    /// last prompt token, logits are discarded; otherwise `sampled` is
    /// appended. Returns `true` if the session just finished.
    pub fn advance(&mut self, sampled: u32, iteration: u64) -> bool {
        let prompt_len = self.request.prompt.len();
        let was_last_prompt_or_decode = self.pos + 1 >= prompt_len;
        self.pos += 1;
        if was_last_prompt_or_decode {
            self.generated.push(sampled);
            if self.first_token_at.is_none() {
                self.first_token_at = Some(iteration);
            }
            if self.generated.len() >= self.request.gen_len {
                self.finished_at = Some(iteration);
                return true;
            }
        }
        false
    }

    /// Total context this session will occupy (capacity check).
    pub fn max_context(&self) -> usize {
        self.request.prompt.len() + self.request.gen_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: &[u32], gen_len: usize) -> Request {
        Request {
            id: 0,
            prompt: prompt.to_vec(),
            gen_len,
            arrival_ms: 0,
        }
    }

    #[test]
    fn phase_progression() {
        let mut s = Session::new(req(&[1, 2, 3], 2), 0);
        assert_eq!(s.phase(), SessionPhase::Prefill);
        assert_eq!(s.next_input(), 1);
        assert!(!s.advance(99, 0)); // fed token 1, logits discarded
        assert_eq!(s.next_input(), 2);
        assert!(!s.advance(99, 1));
        assert_eq!(s.next_input(), 3);
        assert!(!s.advance(42, 2)); // last prompt token → first sample
        assert_eq!(s.phase(), SessionPhase::Decode);
        assert_eq!(s.generated, vec![42]);
        assert_eq!(s.next_input(), 42);
        assert!(s.advance(43, 3)); // second sample → finished
        assert_eq!(s.phase(), SessionPhase::Finished);
        assert_eq!(s.generated, vec![42, 43]);
        assert_eq!(s.finished_at, Some(3));
    }

    #[test]
    fn first_token_recorded_once() {
        let mut s = Session::new(req(&[7], 3), 5);
        s.advance(1, 10);
        s.advance(2, 11);
        s.advance(3, 12);
        assert_eq!(s.first_token_at, Some(10));
        assert_eq!(s.finished_at, Some(12));
    }

    #[test]
    fn single_token_prompt_samples_immediately() {
        let mut s = Session::new(req(&[5], 1), 0);
        assert_eq!(s.next_input(), 5);
        assert!(s.advance(9, 0));
        assert_eq!(s.generated, vec![9]);
    }

    #[test]
    fn max_context_accounts_prompt_and_generation() {
        let s = Session::new(req(&[1, 2, 3, 4], 10), 0);
        assert_eq!(s.max_context(), 14);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Session::new(req(&[], 1), 0);
    }
}
