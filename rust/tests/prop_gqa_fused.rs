//! Property tests: fused grouped-query SwiftKV decode vs the naive
//! scalar oracle (`util::oracle`) and the per-head references, swept
//! over edge shapes — MQA (`n_kv_heads == 1`), GQA, pure-MHA regression
//! (`group == 1`), `len = 1`, empty extends, and head dims off the SIMD
//! unroll width. f32 must match the two-pass-softmax oracle to within
//! 1e-5 relative; the Q15.17 fused sweep must be **bit-for-bit** equal
//! to running each query head separately against its shared KV head.

use swiftkv::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::{gather_head, FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::util::{oracle, prop, Rng};

/// (n_heads, n_kv_heads) pairs: MQA, several GQA group factors, and the
/// `group == 1` MHA regression cases.
const GROUPS: [(usize, usize); 8] = [
    (1, 1),
    (2, 1),
    (3, 1),
    (4, 2),
    (6, 3),
    (8, 2),
    (8, 8),
    (12, 4),
];
/// Head dims below/above/misaligned-with the 4-lane SIMD unroll.
const DIMS: [usize; 6] = [1, 3, 5, 7, 16, 33];
const LENS: [usize; 5] = [1, 2, 3, 17, 96];

struct GqaData {
    h: usize,
    hkv: usize,
    d: usize,
    len: usize,
    q: Vec<f32>,
    /// Token-major interleaved `[len][hkv * d]` caches.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl GqaData {
    fn random(rng: &mut Rng, scale: f32) -> GqaData {
        let (h, hkv) = GROUPS[rng.gen_range(0, GROUPS.len())];
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        GqaData {
            h,
            hkv,
            d,
            len,
            q: rng.uniform_vec(h * d, scale),
            k: rng.uniform_vec(len * hkv * d, scale),
            v: rng.uniform_vec(len * hkv * d, scale),
        }
    }

    fn group(&self) -> usize {
        self.h / self.hkv
    }
}

#[test]
fn prop_fused_gqa_f32_matches_scalar_oracle() {
    prop::check("fused GQA f32 == two-pass scalar oracle", 50, |rng, _| {
        let data = GqaData::random(rng, 1.0);
        let (h, hkv, d, len) = (data.h, data.hkv, data.d, data.len);
        let scale = 1.0 / (d as f32).sqrt();

        let mut mha = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&data.q, &data.k, &data.v, len, scale, &mut out);

        let want = oracle::gqa_attend(&data.q, &data.k, &data.v, h, hkv, d, len, scale);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "h={h} hkv={hkv} d={d} len={len} flat-dim={i}: fused {a} vs oracle {b}"
            );
        }
    });
}

#[test]
fn prop_fused_gqa_fxp_bit_exact_vs_per_group_reference() {
    prop::check("fused GQA fxp == per-group attend_fxp (bit-exact)", 35, |rng, _| {
        let data = GqaData::random(rng, 1.0);
        let (h, hkv, d, len) = (data.h, data.hkv, data.d, data.len);
        let group = data.group();
        let lut = Exp2Lut::new();
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());

        let qq = vector::quantize(&data.q);
        let kq = vector::quantize(&data.k);
        let vq = vector::quantize(&data.v);
        let mut mha = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            // per-group reference: this query head against its shared KV
            // head's cache, gathered to the head-major per-head layout
            let kv = head / group;
            let kh = gather_head(&data.k, kv, hkv, d, len);
            let vh = gather_head(&data.v, kv, hkv, d, len);
            let p = FxpHeadProblem::quantize(&data.q[head * d..(head + 1) * d], &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(
                    a.raw(),
                    b.raw(),
                    "h={h} hkv={hkv} d={d} len={len} head={head} dim={i}: raw bits diverged"
                );
            }
        }
    });
}

#[test]
fn prop_gqa_incremental_extend_equals_one_shot() {
    prop::check("GQA chunked extend == one-shot sweep", 35, |rng, _| {
        let data = GqaData::random(rng, 1.0);
        let (h, hkv, d, len) = (data.h, data.hkv, data.d, data.len);
        let scale = 1.0 / (d as f32).sqrt();
        // cut ∈ [0, len]: 0 exercises an empty first extend
        let cut = rng.gen_range(0, len + 1);

        // f32: chunked extend must be bit-identical to the one-shot sweep
        let mut one = MhaSwiftKv::new_grouped(h, hkv, d);
        let mut a = vec![0.0f32; h * d];
        one.attend(&data.q, &data.k, &data.v, len, scale, &mut a);
        let mut two = MhaSwiftKv::new_grouped(h, hkv, d);
        two.extend(&data.q, &data.k, &data.v, 0, cut, scale);
        two.extend(&data.q, &data.k, &data.v, cut, len, scale);
        let mut b = vec![0.0f32; h * d];
        two.finalize_into(&mut b);
        assert_eq!(a, b, "h={h} hkv={hkv} d={d} len={len} cut={cut}");

        // fxp: same, on raw bits
        let lut = Exp2Lut::new();
        let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&data.q);
        let kq = vector::quantize(&data.k);
        let vq = vector::quantize(&data.v);
        let mut fone = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        let mut fa = vec![Fxp32::ZERO; h * d];
        fone.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fa);
        let mut ftwo = FxpMhaSwiftKv::new_grouped(h, hkv, d);
        ftwo.extend(&lut, &qq, &kq, &vq, 0, cut, fscale);
        ftwo.extend(&lut, &qq, &kq, &vq, cut, len, fscale);
        let mut fb = vec![Fxp32::ZERO; h * d];
        ftwo.finalize_into(&mut fb);
        for (i, (x, y)) in fa.iter().zip(&fb).enumerate() {
            assert_eq!(x.raw(), y.raw(), "fxp flat-dim {i} (cut={cut})");
        }
    });
}

#[test]
fn prop_group_one_equals_plain_mha_state() {
    // `group == 1` regression: a grouped state with n_kv_heads == n_heads
    // must be bit-identical to the pre-GQA `new(h, d)` construction.
    prop::check("new_grouped(h, h, d) == new(h, d)", 20, |rng, _| {
        let h = [1usize, 2, 3, 8][rng.gen_range(0, 4)];
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);

        let mut plain = MhaSwiftKv::new(h, d);
        let mut a = vec![0.0f32; h * d];
        plain.attend(&q, &k, &v, len, scale, &mut a);
        let mut grouped = MhaSwiftKv::new_grouped(h, h, d);
        let mut b = vec![0.0f32; h * d];
        grouped.attend(&q, &k, &v, len, scale, &mut b);
        assert_eq!(a, b, "h={h} d={d} len={len}");
    });
}

#[test]
fn prop_mqa_oracle_agreement_under_spread_scores() {
    // MQA with wider score spread (stress the rescale branch, Eq. 7)
    prop::check("MQA fused == oracle at scale 3", 25, |rng, _| {
        let h = [2usize, 4, 8][rng.gen_range(0, 3)];
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        let scale = 1.0 / (d as f32).sqrt();
        let q = rng.uniform_vec(h * d, 3.0);
        let k = rng.uniform_vec(len * d, 3.0);
        let v = rng.uniform_vec(len * d, 1.0);

        let mut mha = MhaSwiftKv::new_grouped(h, 1, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&q, &k, &v, len, scale, &mut out);
        let want = oracle::gqa_attend(&q, &k, &v, h, 1, d, len, scale);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 2e-5 * (1.0 + b.abs()),
                "h={h} d={d} len={len} flat-dim={i}: {a} vs {b}"
            );
        }
    });
}

#[test]
fn empty_extend_consumes_nothing_then_matches_one_shot() {
    // "n == 0": an extend over an empty row range is a no-op — the state
    // reports zero consumed tokens and a later full sweep is unaffected.
    let mut rng = Rng::seed_from_u64(77);
    let (h, hkv, d, len) = (4usize, 2usize, 8usize, 10usize);
    let scale = 1.0 / (d as f32).sqrt();
    let q = rng.uniform_vec(h * d, 1.0);
    let k = rng.uniform_vec(len * hkv * d, 1.0);
    let v = rng.uniform_vec(len * hkv * d, 1.0);

    let mut st = MhaSwiftKv::new_grouped(h, hkv, d);
    st.extend(&q, &k, &v, 0, 0, scale);
    assert_eq!(st.consumed(), 0, "empty extend must consume nothing");
    st.extend(&q, &k, &v, 0, len, scale);
    let mut a = vec![0.0f32; h * d];
    st.finalize_into(&mut a);

    let mut one = MhaSwiftKv::new_grouped(h, hkv, d);
    let mut b = vec![0.0f32; h * d];
    one.attend(&q, &k, &v, len, scale, &mut b);
    assert_eq!(a, b);

    // same on the Q15.17 path
    let lut = Exp2Lut::new();
    let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
    let qq = vector::quantize(&q);
    let kq = vector::quantize(&k);
    let vq = vector::quantize(&v);
    let mut fst = FxpMhaSwiftKv::new_grouped(h, hkv, d);
    fst.extend(&lut, &qq, &kq, &vq, 0, 0, fscale);
    assert_eq!(fst.consumed(), 0);
    fst.extend(&lut, &qq, &kq, &vq, 0, len, fscale);
    let mut fa = vec![Fxp32::ZERO; h * d];
    fst.finalize_into(&mut fa);
    let mut fone = FxpMhaSwiftKv::new_grouped(h, hkv, d);
    let mut fb = vec![Fxp32::ZERO; h * d];
    fone.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fb);
    assert_eq!(
        fa.iter().map(|x| x.raw()).collect::<Vec<_>>(),
        fb.iter().map(|x| x.raw()).collect::<Vec<_>>()
    );
}

#[test]
fn single_token_gqa_broadcasts_value_rows() {
    // len == 1: every query head's output is its KV head's value slice
    let mut rng = Rng::seed_from_u64(78);
    let (h, hkv, d) = (6usize, 2usize, 5usize);
    let group = h / hkv;
    let q = rng.uniform_vec(h * d, 1.0);
    let k = rng.uniform_vec(hkv * d, 1.0);
    let v = rng.uniform_vec(hkv * d, 1.0);
    let mut mha = MhaSwiftKv::new_grouped(h, hkv, d);
    let mut out = vec![0.0f32; h * d];
    mha.attend(&q, &k, &v, 1, 1.0, &mut out);
    for head in 0..h {
        let kv = head / group;
        for i in 0..d {
            assert!(
                (out[head * d + i] - v[kv * d + i]).abs() < 1e-6,
                "head {head} dim {i}"
            );
        }
    }
}
