//! Model-checked concurrency tests for the serving-path sync code:
//! [`WorkerPool`]'s epoch publication / park / wake / panic protocol and
//! [`BlockPool`]'s mutex-guarded free list.
//!
//! Built only under `RUSTFLAGS="--cfg loom"`, which swaps the
//! `kernels::sync` alias layer from `std` to the in-tree model checker
//! (`swiftkv::util::mc`): every atomic access, lock, and park becomes a
//! scheduling point and each test body is re-executed across a bounded
//! DFS of thread interleavings (plus a randomized sweep past the
//! bound). `LOOM_MAX_PREEMPTIONS` / `LOOM_MAX_EXECUTIONS` tune depth —
//! CI runs with `LOOM_MAX_PREEMPTIONS=3`.
//!
//! Shapes are deliberately tiny (one background worker, two tasks, one
//! cache block): the properties under test are protocol properties —
//! no lost wakeups, no lost tasks, no double grants — and small shapes
//! keep the schedule space exhaustible.

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use swiftkv::kernels::sync::atomic::{AtomicUsize, Ordering};
use swiftkv::kernels::sync::{thread, Arc};
use swiftkv::kernels::{BlockPool, SharedMut, WorkerPool};
use swiftkv::util::mc;

#[test]
fn every_task_runs_exactly_once() {
    let report = mc::model(|| {
        let pool = WorkerPool::new(1);
        let hits = [AtomicUsize::new(0), AtomicUsize::new(0)];
        pool.run(2, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits[0].load(Ordering::Relaxed), 1, "task 0 lost or duplicated");
        assert_eq!(hits[1].load(Ordering::Relaxed), 1, "task 1 lost or duplicated");
    });
    eprintln!("every_task_runs_exactly_once: {report:?}");
}

#[test]
fn park_wake_sequencing_across_epochs() {
    // Two back-to-back jobs: the worker may still be spinning, already
    // parked, or mid-checkout when the second epoch publishes; none of
    // those schedules may lose the wakeup or re-run the first job.
    let report = mc::model(|| {
        let pool = WorkerPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        for round in 1..=2usize {
            let counter = counter.clone();
            pool.run(2, move |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), 2 * round, "epoch {round} lost tasks");
        }
    });
    eprintln!("park_wake_sequencing_across_epochs: {report:?}");
}

#[test]
fn task_panic_propagates_and_pool_stays_usable() {
    let report = mc::model(|| {
        let pool = WorkerPool::new(1);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |i| {
                if i == 1 {
                    panic!("model task failure");
                }
            });
        }));
        assert!(result.is_err(), "a task panic must fail the submitting run");
        // The pool must come back clean for the next epoch: the panicked
        // flag resets and the worker re-enters its wait loop.
        let ok = AtomicUsize::new(0);
        pool.run(2, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2, "pool wedged after a task panic");
    });
    eprintln!("task_panic_propagates_and_pool_stays_usable: {report:?}");
}

#[test]
fn drop_while_worker_parked_or_spinning_shuts_down() {
    // No job is ever submitted: the worker is somewhere between its
    // first spin and a condvar park when Drop publishes shutdown. Every
    // schedule must terminate (the model checker reports a deadlock if
    // the shutdown wakeup can be lost).
    let report = mc::model(|| {
        let pool = WorkerPool::new(1);
        drop(pool);
    });
    eprintln!("drop_while_worker_parked_or_spinning_shuts_down: {report:?}");
}

#[test]
fn disjoint_writes_through_shared_mut() {
    let report = mc::model(|| {
        let pool = WorkerPool::new(1);
        let mut out = [0u64; 2];
        let ptr = SharedMut::new(out.as_mut_ptr());
        pool.run(2, |i| {
            // SAFETY: one task per index writes only element `i`, and
            // `out` outlives the `run` call (run returns only after
            // every worker checked out of the job).
            unsafe { ptr.get().add(i).write(i as u64 + 7) };
        });
        assert_eq!(out, [7, 8]);
    });
    eprintln!("disjoint_writes_through_shared_mut: {report:?}");
}

#[test]
fn block_pool_never_double_grants() {
    // One block, two contending threads, no releases: exactly one
    // try_alloc may succeed in every schedule.
    let report = mc::model(|| {
        let pool = Arc::new(BlockPool::new(1, 2, 4));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let pool = pool.clone();
            handles.push(thread::spawn(move || pool.try_alloc()));
        }
        let mut grants = 0usize;
        for h in handles {
            let block = h.join().expect("model thread panicked");
            if let Some(b) = block {
                grants += 1;
                pool.release(b);
            }
        }
        assert_eq!(grants, 1, "one block granted to more than one thread");
        assert_eq!(pool.free_blocks(), 1, "block leaked after release");
    });
    eprintln!("block_pool_never_double_grants: {report:?}");
}

#[test]
fn block_pool_grant_release_interleavings_conserve_blocks() {
    // Two threads each do an alloc → release round trip against a
    // one-block pool: depending on the schedule either both succeed in
    // turn or one finds the pool momentarily empty, but block
    // accounting must balance in every interleaving.
    let report = mc::model(|| {
        let pool = Arc::new(BlockPool::new(1, 2, 4));
        let grants = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let pool = pool.clone();
            let grants = grants.clone();
            handles.push(thread::spawn(move || {
                if let Some(b) = pool.try_alloc() {
                    grants.fetch_add(1, Ordering::Relaxed);
                    pool.release(b);
                }
            }));
        }
        for h in handles {
            h.join().expect("model thread panicked");
        }
        let n = grants.load(Ordering::Relaxed);
        assert!((1..=2).contains(&n), "a one-block pool served {n} grants");
        assert_eq!(pool.free_blocks(), 1, "round trips must conserve the free list");
    });
    eprintln!("block_pool_grant_release_interleavings_conserve_blocks: {report:?}");
}
