//! Exhibit generators — one function per paper table/figure, each
//! printing the same rows/series the paper reports (used by
//! `examples/paper_tables.rs`, the benches and the exhibit tests).

use crate::baselines::{self, AcceleratorPoint};
use crate::fxp::Exp2Lut;
use crate::model::{LlmConfig, TokenCost};
use crate::sim::{edge_hw, layer_sched, power, resources, ArchConfig};

/// Fig. 7(a): attention time (µs) vs context length.
pub fn fig7a(arch: &ArchConfig) -> String {
    let contexts = [64, 128, 256, 512, 1024, 2048, 4096];
    let curves = edge_hw::fig7a_curves(arch, &contexts, 128);
    let mut out = String::from(
        "Fig 7(a): decode attention time vs context length (d_head = 128)\n",
    );
    out.push_str(&format!("{:>8}", "ctx"));
    for (label, _) in &curves {
        out.push_str(&format!("{label:>22}"));
    }
    out.push('\n');
    for (i, &n) in contexts.iter().enumerate() {
        out.push_str(&format!("{n:>8}"));
        for (_, pts) in &curves {
            out.push_str(&format!("{:>19.2} µs", pts[i].1));
        }
        out.push('\n');
    }
    out
}

/// Fig. 7(b): speedup over native attention at context 512.
/// Paper: native 1×, Flash(32) 1.46×, Streaming 2.15×, SwiftKV 7.16×.
pub fn fig7b(arch: &ArchConfig) -> String {
    let mut out =
        String::from("Fig 7(b): attention speedup over native (ctx = 512, d_head = 128)\n");
    out.push_str(&format!(
        "{:<24}{:>10}{:>12}\n",
        "algorithm", "speedup", "paper"
    ));
    let paper = [1.0, 1.46, 2.15, 7.16];
    for ((label, s), p) in edge_hw::fig7b_speedups(arch, 512, 128).iter().zip(paper) {
        out.push_str(&format!("{label:<24}{s:>9.2}x{p:>11.2}x\n"));
    }
    out
}

/// §V: exp-LUT maximum relative error over (−1, 0].
/// Paper: 0.00586 %.
pub fn exp_lut_error() -> String {
    let err = Exp2Lut::new().max_relative_error() * 100.0;
    format!(
        "exp LUT (Eq. 9-10) max relative error over (-1, 0]: {err:.5} %  (paper: 0.00586 %)\n"
    )
}

/// Table II: FPGA utilization.
pub fn table2(arch: &ArchConfig) -> String {
    let r = resources::estimate(arch);
    let mut out = String::from("Table II: hardware utilization of SwiftKV-MHA on Alveo U55C\n");
    out.push_str(&format!(
        "{:<18}{:>9}{:>9}{:>7}{:>7}\n",
        "Component", "LUT", "FF", "BRAM", "DSP"
    ));
    for c in &r.components {
        out.push_str(&format!(
            "{:<18}{:>8}K{:>8}K{:>7}{:>7}\n",
            c.name,
            c.lut / 1000,
            c.ff / 1000,
            c.bram,
            c.dsp
        ));
    }
    let t = r.total();
    out.push_str(&format!(
        "{:<18}{:>8}K{:>8}K{:>7}{:>7}\n",
        "Total",
        t.lut / 1000,
        t.ff / 1000,
        t.bram,
        t.dsp
    ));
    let (l, f, b, d) = r.utilization_pct();
    out.push_str(&format!(
        "{:<18}{:>8.1}%{:>8.1}%{:>6.1}%{:>6.1}%\n",
        "(device)", l, f, b, d
    ));
    out
}

/// Fig. 8(a): decode latency breakdown per module.
/// Paper: attention ≈ 3.19 % (13.48× lower share than DFX's 43 %).
pub fn fig8a(arch: &ArchConfig, cfg: &LlmConfig, n_ctx: usize) -> String {
    let sim = layer_sched::simulate_token(arch, cfg, n_ctx);
    let mut out = format!(
        "Fig 8(a): decode latency breakdown — {} @ ctx {} ({:.2} ms/token)\n",
        cfg.name, n_ctx, sim.latency_ms
    );
    let total: u64 = sim.module_breakdown().iter().map(|(_, c)| c).sum();
    for (module, cycles) in sim.module_breakdown() {
        out.push_str(&format!(
            "{:<22}{:>10} cycles  {:>6.2} %\n",
            module,
            cycles,
            100.0 * cycles as f64 / total as f64
        ));
    }
    let attn = sim.module_share("Attention (SKV)");
    out.push_str(&format!(
        "attention share {:.2} % (paper 3.19 %); reduction vs DFX 43 %: {:.2}x (paper 13.48x)\n",
        attn * 100.0,
        baselines::DFX_ATTENTION_SHARE / attn
    ));
    out
}

/// One Table III row for our accelerator.
fn this_work_row(arch: &ArchConfig, cfg: &LlmConfig) -> (f64, f64, f64, f64) {
    let sim = layer_sched::simulate_token(arch, cfg, 512);
    let p = power::power(arch, 1.0);
    let tokens_per_s = sim.tokens_per_s;
    let tpj = power::tokens_per_joule(tokens_per_s, p.system_w());
    (sim.latency_ms, tokens_per_s, p.system_w(), tpj)
}

/// Table III: comparison with FlightLLM/EdgeLLM.
pub fn table3(arch: &ArchConfig) -> String {
    let mut out = String::from(
        "Table III: FPGA transformer accelerators, identical settings (W4A8, 460 GB/s, 225 MHz)\n",
    );
    out.push_str(&format!(
        "{:<22}{:<14}{:>6}{:>13}{:>12}{:>10}{:>10}\n",
        "work", "model", "DSP", "latency", "tok/s", "power", "tok/J"
    ));
    for b in baselines::table3_baselines() {
        out.push_str(&format!(
            "{:<22}{:<14}{:>6}{:>10.1} ms{:>12.1}{:>8.1} W{:>10.2}\n",
            format!("{} ({})", b.name, b.platform),
            b.model,
            b.dsp,
            b.latency_ms,
            b.tokens_per_s(),
            b.system_power_w,
            b.tokens_per_joule()
        ));
    }
    let dsp = resources::estimate(arch).total().dsp;
    for cfg in [LlmConfig::llama2_7b(), LlmConfig::chatglm_6b()] {
        let (lat, tps, pw, tpj) = this_work_row(arch, &cfg);
        out.push_str(&format!(
            "{:<22}{:<14}{:>6}{:>10.1} ms{:>12.1}{:>8.1} W{:>10.2}\n",
            "This Work (U55C)", cfg.name, dsp, lat, tps, pw, tpj
        ));
    }
    out.push_str("paper (this work): llama2 12.3 ms / 81.5 tok/s / 33.8 W / 2.41 tok/J; chatglm 10.4 ms / 96.3 tok/s / 2.85 tok/J\n");
    out
}

/// Fig. 8(b): attention latency (per token) + token efficiency comparison.
pub fn fig8b(arch: &ArchConfig) -> String {
    let cfg = LlmConfig::llama2_7b();
    let mut out = String::from("Fig 8(b): attention latency and token efficiency\n");
    let ours = layer_sched::simulate_token(arch, &cfg, 512);
    let ours_attn_ms = ours.latency_ms * ours.module_share("Attention (SKV)");
    out.push_str(&format!(
        "{:<22}{:>16}{:>14}\n",
        "work", "attn ms/token", "token/J"
    ));
    for b in baselines::table3_baselines()
        .iter()
        .filter(|b| b.model == "Llama-2-7B")
    {
        // prior accelerators: attention ≈ DFX's 43 % share of decode [5]
        let attn_ms = b.latency_ms * baselines::DFX_ATTENTION_SHARE;
        out.push_str(&format!(
            "{:<22}{:>13.2} ms{:>14.2}\n",
            b.name,
            attn_ms,
            b.tokens_per_joule()
        ));
    }
    let p = power::power(arch, 1.0);
    out.push_str(&format!(
        "{:<22}{:>13.2} ms{:>14.2}\n",
        "This Work",
        ours_attn_ms,
        power::tokens_per_joule(ours.tokens_per_s, p.system_w())
    ));
    let best_prior = baselines::table3_baselines()
        .iter()
        .filter(|b| b.model == "Llama-2-7B")
        .map(|b| b.tokens_per_joule())
        .fold(0.0f64, f64::max);
    out.push_str(&format!(
        "token-efficiency gain over best prior: {:.2}x (paper: 1.98x)\n",
        power::tokens_per_joule(ours.tokens_per_s, p.system_w()) / best_prior
    ));
    out
}

/// Table IV: throughput/efficiency vs prior FPGA accelerators.
pub fn table4(arch: &ArchConfig) -> String {
    let cfg = LlmConfig::llama2_7b();
    let sim = layer_sched::simulate_token(arch, &cfg, 512);
    let gops = TokenCost::of(&cfg, 512).gops_at(sim.latency_ms / 1e3);
    let p = power::power(arch, 1.0);
    let eff = power::gops_per_watt(gops, p.chip_w());

    let mut out = String::from("Table IV: comparison with existing FPGA-based works\n");
    out.push_str(&format!(
        "{:<16}{:<14}{:<20}{:>8}{:>12}{:>14}\n",
        "work", "platform", "model", "MHz", "GOPS", "GOPS/W"
    ));
    for b in baselines::table4_baselines() {
        out.push_str(&format!(
            "{:<16}{:<14}{:<20}{:>8.0}{:>12.1}{:>14.2}\n",
            b.name, b.platform, b.model, b.freq_mhz, b.gops, b.gops_per_w
        ));
    }
    out.push_str(&format!(
        "{:<16}{:<14}{:<20}{:>8.0}{:>12.1}{:>14.2}\n",
        "This Work", "Alveo U55C", cfg.name, arch.clock_mhz, gops, eff
    ));
    out.push_str("paper (this work): 1100.3 GOPS, 60.12 GOPS/W\n");
    out
}

/// Table I: Top-k agreement between accelerator numerics (W4A8 + FXP32
/// SwiftKV attention + LUT exp) and desktop f32 attention, over seeded
/// synthetic sequences (PG-19 stand-in; see DESIGN.md substitution log).
/// Returns (table text, per-k agreement fractions for k = 1, 2, 3, 5).
pub fn table1(
    tm: &crate::model::TinyModel,
    sequences: usize,
    len: usize,
) -> (String, [f64; 4]) {
    use crate::model::tiny::{argmax, top_k};
    use crate::model::NumericsMode;
    use crate::util::Rng;
    let mut rng = Rng::seed_from_u64(7);
    let ks = [1usize, 2, 3, 5];
    let mut agree = [0usize; 4];
    let mut total = 0usize;
    for _ in 0..sequences {
        let mut sd = tm.new_state();
        let mut sa = tm.new_state();
        let mut tok: u32 = rng.gen_range(0, tm.vocab) as u32;
        for t in 0..len.min(tm.n_ctx - 1) {
            let ld = tm.decode_step(&mut sd, tok, NumericsMode::DesktopF32);
            let la = tm.decode_step(&mut sa, tok, NumericsMode::Accelerator);
            for (i, &k) in ks.iter().enumerate() {
                if top_k(&ld, k) == top_k(&la, k) {
                    agree[i] += 1;
                }
            }
            total += 1;
            // follow the desktop greedy path; occasionally jump randomly
            // to cover more of the vocabulary
            tok = if t % 7 == 6 {
                rng.gen_range(0, tm.vocab) as u32
            } else {
                argmax(&ld) as u32
            };
        }
    }
    let fr: [f64; 4] = std::array::from_fn(|i| agree[i] as f64 / total as f64);
    let mut out = String::from(
        "Table I: token inference accuracy, accelerator vs desktop (same W4A8)\n",
    );
    out.push_str(&format!("{:<10}", ""));
    for k in ks {
        out.push_str(&format!("{:>9}", format!("Top-{k}")));
    }
    out.push('\n');
    out.push_str(&format!("{:<10}", "Accuracy"));
    for f in fr {
        out.push_str(&format!("{:>8.1}%", 100.0 * f));
    }
    out.push_str("\npaper:         100%     100%      99%      98%\n");
    (out, fr)
}

/// Derived headline numbers (§V prose claims) as machine-checkable values.
pub struct Headlines {
    pub swiftkv_speedup: f64,
    pub attention_share: f64,
    pub attention_reduction: f64,
    pub tokens_per_s: f64,
    pub speed_gain_vs_best_prior: f64,
    pub token_eff_gain: f64,
    pub gops: f64,
    pub gops_per_w: f64,
}

/// Compute all §V headline numbers from the models.
pub fn headlines(arch: &ArchConfig) -> Headlines {
    let cfg = LlmConfig::llama2_7b();
    let sp = edge_hw::fig7b_speedups(arch, 512, 128);
    let swiftkv_row = sp
        .iter()
        .find(|(l, _)| l == "SwiftKV")
        .expect("fig7b_speedups always includes the SwiftKV row");
    let swiftkv_speedup = swiftkv_row.1;
    let sim = layer_sched::simulate_token(arch, &cfg, 512);
    let share = sim.module_share("Attention (SKV)");
    let p = power::power(arch, 1.0);
    let gops = TokenCost::of(&cfg, 512).gops_at(sim.latency_ms / 1e3);
    let best_prior: &AcceleratorPoint = &baselines::table3_baselines()[1]; // EdgeLLM llama2
    Headlines {
        swiftkv_speedup,
        attention_share: share,
        attention_reduction: baselines::DFX_ATTENTION_SHARE / share,
        tokens_per_s: sim.tokens_per_s,
        speed_gain_vs_best_prior: sim.tokens_per_s / best_prior.tokens_per_s() - 1.0,
        token_eff_gain: power::tokens_per_joule(sim.tokens_per_s, p.system_w())
            / best_prior.tokens_per_joule(),
        gops,
        gops_per_w: power::gops_per_watt(gops, p.chip_w()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_exhibits_render() {
        let arch = ArchConfig::default();
        for s in [
            fig7a(&arch),
            fig7b(&arch),
            exp_lut_error(),
            table2(&arch),
            fig8a(&arch, &LlmConfig::llama2_7b(), 512),
            table3(&arch),
            fig8b(&arch),
            table4(&arch),
        ] {
            assert!(s.len() > 40, "exhibit too short:\n{s}");
        }
    }

    #[test]
    fn headline_numbers_in_paper_range() {
        let h = headlines(&ArchConfig::default());
        assert!((h.swiftkv_speedup - 7.16).abs() < 0.25);
        assert!((h.attention_reduction - 13.48).abs() < 13.48 * 0.35);
        assert!((h.tokens_per_s - 81.5).abs() < 8.0);
        assert!((h.token_eff_gain - 1.98).abs() < 0.35);
        assert!((h.gops - 1100.3).abs() < 120.0);
        assert!((h.gops_per_w - 60.12).abs() < 9.0);
        assert!(h.speed_gain_vs_best_prior > 0.05);
    }
}
