//! End-to-end serving driver (the system demo): load the AOT tiny model,
//! serve a batched decode workload through the coordinator, and report
//! both wall-clock (CPU PJRT) and modelled SwiftKV-MHA timing.
//!
//! This is the run recorded in EXPERIMENTS.md §E9.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_decode -- \
//!     [--requests 24] [--batch 8] [--gap-ms 5]
//! ```

use swiftkv::coordinator::{ServeOptions, Server};
use swiftkv::model::{LlmConfig, WorkloadGen, WorkloadSpec};
use swiftkv::runtime::{artifacts_available, default_artifacts_dir, Engine};
use swiftkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["requests", "batch", "gap-ms", "seed"], &[])
        .map_err(|e| anyhow::anyhow!(e))?;
    if !artifacts_available() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    let eng = Engine::load(&default_artifacts_dir())?;
    println!(
        "engine: tiny model d={} L={} H={} ctx={} — batch variants {:?}",
        eng.manifest.d_model,
        eng.manifest.n_layers,
        eng.manifest.n_heads,
        eng.manifest.n_ctx,
        eng.batch_variants()
    );

    let requests = args.get_usize("requests", 24).unwrap();
    let batch = args.get_usize("batch", 8).unwrap();
    let spec = WorkloadSpec {
        num_requests: requests,
        vocab: eng.manifest.vocab,
        prompt_len: (4, 24),
        gen_len: (8, 48),
        mean_gap_ms: args.get_f64("gap-ms", 0.0).unwrap(),
        seed: args.get_usize("seed", 0).unwrap() as u64,
    };
    let reqs = WorkloadGen::new(spec).generate();
    let total_gen: usize = reqs.iter().map(|r| r.gen_len).sum();
    println!("workload: {requests} requests, {total_gen} tokens to generate, batch {batch}\n");

    let report = Server::new(
        &eng,
        ServeOptions {
            batch: Some(batch),
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
        },
    )
    .serve(reqs)?;

    println!("{}", report.metrics.format_table());
    println!("sample generations:");
    for s in report.sessions.iter().take(4) {
        println!(
            "  req {:>2}  prompt {:?} → {:?}",
            s.request.id,
            &s.request.prompt[..s.request.prompt.len().min(6)],
            &s.generated[..s.generated.len().min(10)]
        );
    }
    Ok(())
}
