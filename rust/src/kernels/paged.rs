//! Paged KV cache: a shared pool of fixed-size blocks behind the fused
//! decode sweep.
//!
//! The uniform per-token sweep of the SwiftKV recurrence reads every
//! `(k_t, v_t)` cache row exactly once, which makes the KV layout the
//! system's real memory contract. Up to now each sequence owned one
//! contiguous token-major cache sized for the full context window —
//! simple, but every serving lane pays worst-case memory even for short
//! sequences, and long contexts cannot outgrow their lane. This module
//! replaces that contract with block-table indirection (the paged-KV
//! design of vLLM, here over the paper's interleaved token-major rows):
//!
//! - [`KvBlock`] — `block_len` interleaved rows of `n_kv_heads · d` f32
//!   K and V, plus their Q15.17 mirrors (the accelerator datapath's
//!   no-re-quantization contract rides along per block),
//! - [`BlockPool`] — a fixed set of blocks allocated once up front and
//!   recycled through a mutex-guarded free list; many sequences (serving
//!   lanes) draw from one pool and return blocks on
//!   [`crate::model::tiny::DecodeState::reset_for_reuse`],
//! - [`BlockTable`] — a per-sequence (per-layer) ordered list of
//!   checked-out blocks mapping logical token position `t` to block
//!   `t / block_len`, row `t % block_len`.
//!
//! Blocks own their storage (`Vec`s moved in and out of the pool), so
//! sharing one pool across the serving lanes' worker threads is plain
//! safe Rust: the free list is the only contended state, touched once per
//! `block_len` tokens per layer. After pool warm-up (construction
//! allocates every block eagerly) the decode hot path stays
//! **allocation-free**: `alloc`/`release` move blocks through a
//! pre-reserved `Vec`, and each table reserves its worst-case block
//! count at creation.
//!
//! The paged sweeps ([`super::mha::MhaSwiftKv::extend_paged`],
//! [`super::fxp_mha::FxpMhaSwiftKv::extend_paged`]) walk block-gathered
//! rows through the *same* `update_token` as the contiguous path, in the
//! same order — so the f32 path is bit-identical and the Q15.17 path is
//! bit-exact versus the contiguous cache (asserted across block lengths,
//! ragged last blocks, and recycled pools by `tests/prop_paged.rs`).

use super::sync::{Mutex, MutexGuard};
use crate::fxp::{vector, Fxp32};

/// One fixed-size cache block: `block_len` interleaved token-major rows
/// of f32 K/V plus their Q15.17 mirrors.
#[derive(Debug)]
pub struct KvBlock {
    k: Vec<f32>,
    v: Vec<f32>,
    kq: Vec<Fxp32>,
    vq: Vec<Fxp32>,
}

impl KvBlock {
    fn new(block_len: usize, row: usize) -> KvBlock {
        let n = block_len * row;
        KvBlock {
            k: vec![0.0; n],
            v: vec![0.0; n],
            kq: vec![Fxp32::ZERO; n],
            vq: vec![Fxp32::ZERO; n],
        }
    }

    /// Quantize row `o` of the f32 K/V into the Q15.17 mirror (the
    /// append-once mirror contract: history is never re-quantized).
    #[inline]
    fn quantize_row(&mut self, o: usize, row: usize) {
        let at = o * row;
        vector::quantize_into(&self.k[at..at + row], &mut self.kq[at..at + row]);
        vector::quantize_into(&self.v[at..at + row], &mut self.vq[at..at + row]);
    }
}

/// A fixed pool of [`KvBlock`]s shared by every sequence (serving lane)
/// of one model shape. All blocks are allocated eagerly at construction;
/// afterwards [`BlockPool::alloc`] / [`BlockPool::release`] only move
/// blocks through the pre-reserved free list — no heap traffic.
#[derive(Debug)]
pub struct BlockPool {
    block_len: usize,
    row: usize,
    total: usize,
    free: Mutex<Vec<KvBlock>>,
}

impl BlockPool {
    /// Eagerly allocate `blocks` blocks of `block_len` rows of width
    /// `row` (`n_kv_heads · d`).
    pub fn new(blocks: usize, block_len: usize, row: usize) -> BlockPool {
        assert!(blocks > 0, "pool needs at least one block");
        assert!(block_len > 0, "block_len must be positive");
        assert!(row > 0, "row width must be positive");
        let mut free = Vec::with_capacity(blocks);
        for _ in 0..blocks {
            free.push(KvBlock::new(block_len, row));
        }
        BlockPool {
            block_len,
            row,
            total: blocks,
            free: Mutex::new(free),
        }
    }

    /// Tokens per block.
    pub fn block_len(&self) -> usize {
        self.block_len
    }

    /// Width of one interleaved cache row (`n_kv_heads · d`).
    pub fn row_width(&self) -> usize {
        self.row
    }

    /// Total blocks owned by the pool (checked out + free).
    pub fn total_blocks(&self) -> usize {
        self.total
    }

    /// Blocks currently available for checkout.
    pub fn free_blocks(&self) -> usize {
        self.lock().len()
    }

    /// Bytes of cache storage per block (f32 K/V + Q15.17 mirrors) —
    /// the pool-sizing arithmetic of EXPERIMENTS.md §Paged-KV.
    pub fn bytes_per_block(&self) -> usize {
        let n = self.block_len * self.row;
        2 * n * std::mem::size_of::<f32>() + 2 * n * std::mem::size_of::<Fxp32>()
    }

    /// Check a block out of the pool, or `None` when exhausted.
    pub fn try_alloc(&self) -> Option<KvBlock> {
        self.lock().pop()
    }

    /// Check a block out of the pool.
    ///
    /// # Panics
    /// When the pool is exhausted — size it for the worst-case live set
    /// (`lanes × n_layers × ⌈n_ctx / block_len⌉` for the CPU server, or
    /// raise `--kv-pool-blocks`).
    pub fn alloc(&self) -> KvBlock {
        self.try_alloc().unwrap_or_else(|| {
            panic!(
                "KV block pool exhausted ({} blocks of {} tokens in flight); \
                 size the pool for the worst-case live set",
                self.total, self.block_len
            )
        })
    }

    /// Return a checked-out block to the pool.
    pub fn release(&self, block: KvBlock) {
        debug_assert_eq!(block.k.len(), self.block_len * self.row, "foreign block");
        let mut free = self.lock();
        debug_assert!(free.len() < self.total, "released more blocks than allocated");
        free.push(block);
    }

    fn lock(&self) -> MutexGuard<'_, Vec<KvBlock>> {
        // a lane that panicked mid-step poisons the lock; the free list
        // itself is always in a consistent state (push/pop are atomic
        // under the guard), so recover rather than cascade the panic
        self.free.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Test hook for the poisoned-lock recovery path: panic a throwaway
    /// thread while it holds the free-list mutex, leaving the lock
    /// poisoned the same way a lane panicking mid-`alloc`/`release`
    /// would. `tests/poisoned_locks.rs` uses this to assert the
    /// `into_inner` recovery keeps serving.
    #[doc(hidden)]
    #[cfg(not(loom))]
    pub fn poison_free_list_for_tests(&self) {
        std::thread::scope(|s| {
            let handle = s.spawn(|| {
                let _guard = self.free.lock().unwrap_or_else(|e| e.into_inner());
                panic!("deliberately poisoning the BlockPool free-list mutex");
            });
            assert!(handle.join().is_err(), "the poisoning thread must panic");
        });
    }
}

/// Per-sequence (per-layer) block-table indirection: an ordered list of
/// checked-out blocks mapping token position `t` to block
/// `t / block_len`, row `t % block_len`. Capacity for the worst case
/// (`max_tokens`) is reserved at creation so appends never allocate.
#[derive(Debug)]
pub struct BlockTable {
    blocks: Vec<KvBlock>,
    block_len: usize,
    row: usize,
}

impl BlockTable {
    /// Empty table for up to `max_tokens` positions of rows shaped like
    /// `pool`'s blocks. Checks no blocks out yet.
    pub fn new(pool: &BlockPool, max_tokens: usize) -> BlockTable {
        BlockTable {
            blocks: Vec::with_capacity(max_tokens.div_ceil(pool.block_len)),
            block_len: pool.block_len,
            row: pool.row,
        }
    }

    /// Tokens per block.
    pub fn block_len(&self) -> usize {
        self.block_len
    }

    /// Width of one interleaved cache row.
    pub fn row_width(&self) -> usize {
        self.row
    }

    /// Blocks currently checked out by this table.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Token positions the checked-out blocks can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.blocks.len() * self.block_len
    }

    /// Check out blocks from `pool` until at least `tokens` positions
    /// are mapped. Amortized cost: one pool round-trip per `block_len`
    /// tokens; no heap allocation (the block list is pre-reserved).
    pub fn ensure_tokens(&mut self, pool: &BlockPool, tokens: usize) {
        assert_eq!(pool.block_len, self.block_len, "pool/table block_len mismatch");
        assert_eq!(pool.row, self.row, "pool/table row width mismatch");
        while self.capacity_tokens() < tokens {
            self.blocks.push(pool.alloc());
        }
    }

    /// Return every checked-out block to `pool` (lane recycling /
    /// sequence retirement). The table is empty afterwards.
    pub fn release_into(&mut self, pool: &BlockPool) {
        for block in self.blocks.drain(..) {
            pool.release(block);
        }
    }

    #[inline]
    fn locate(&self, t: usize) -> (usize, usize) {
        let b = t / self.block_len;
        assert!(b < self.blocks.len(), "token {t} beyond mapped blocks");
        (b, (t % self.block_len) * self.row)
    }

    /// f32 K row at token position `t`.
    #[inline]
    pub fn k_row(&self, t: usize) -> &[f32] {
        let (b, at) = self.locate(t);
        &self.blocks[b].k[at..at + self.row]
    }

    /// f32 V row at token position `t`.
    #[inline]
    pub fn v_row(&self, t: usize) -> &[f32] {
        let (b, at) = self.locate(t);
        &self.blocks[b].v[at..at + self.row]
    }

    /// Q15.17 K mirror row at token position `t`.
    #[inline]
    pub fn kq_row(&self, t: usize) -> &[Fxp32] {
        let (b, at) = self.locate(t);
        &self.blocks[b].kq[at..at + self.row]
    }

    /// Q15.17 V mirror row at token position `t`.
    #[inline]
    pub fn vq_row(&self, t: usize) -> &[Fxp32] {
        let (b, at) = self.locate(t);
        &self.blocks[b].vq[at..at + self.row]
    }

    /// Mutable f32 K row at token position `t`.
    #[inline]
    pub fn k_row_mut(&mut self, t: usize) -> &mut [f32] {
        let (b, at) = self.locate(t);
        &mut self.blocks[b].k[at..at + self.row]
    }

    /// Mutable f32 V row at token position `t`.
    #[inline]
    pub fn v_row_mut(&mut self, t: usize) -> &mut [f32] {
        let (b, at) = self.locate(t);
        &mut self.blocks[b].v[at..at + self.row]
    }

    /// Quantize the f32 K/V row at `t` into the Q15.17 mirror.
    #[inline]
    pub fn quantize_row(&mut self, t: usize) {
        let b = t / self.block_len;
        assert!(b < self.blocks.len(), "token {t} beyond mapped blocks");
        let row = self.row;
        self.blocks[b].quantize_row(t % self.block_len, row);
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn poisoned_free_list_recovers() {
        let pool = BlockPool::new(2, 2, 4);
        pool.poison_free_list_for_tests();
        // every path through the recovered lock must still work
        assert_eq!(pool.free_blocks(), 2);
        let blk = pool.alloc();
        assert_eq!(pool.free_blocks(), 1);
        pool.release(blk);
        assert_eq!(pool.free_blocks(), 2);
    }

    #[test]
    fn pool_allocates_eagerly_and_recycles() {
        let pool = BlockPool::new(3, 4, 8);
        assert_eq!(pool.total_blocks(), 3);
        assert_eq!(pool.free_blocks(), 3);
        assert_eq!(pool.block_len(), 4);
        assert_eq!(pool.row_width(), 8);

        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.free_blocks(), 1);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_blocks(), 3);
    }

    #[test]
    fn try_alloc_reports_exhaustion() {
        let pool = BlockPool::new(1, 2, 4);
        let blk = pool.try_alloc().expect("one block available");
        assert!(pool.try_alloc().is_none());
        pool.release(blk);
        assert!(pool.try_alloc().is_some());
    }

    #[test]
    #[should_panic(expected = "KV block pool exhausted")]
    fn alloc_panics_when_exhausted() {
        let pool = BlockPool::new(1, 2, 4);
        let _held = pool.alloc();
        let _ = pool.alloc();
    }

    #[test]
    fn table_maps_tokens_to_block_rows() {
        let pool = BlockPool::new(4, 3, 2);
        let mut table = BlockTable::new(&pool, 10);
        assert_eq!(table.capacity_tokens(), 0);
        table.ensure_tokens(&pool, 7); // 3 blocks of 3 rows, last ragged
        assert_eq!(table.num_blocks(), 3);
        assert_eq!(table.capacity_tokens(), 9);
        assert_eq!(pool.free_blocks(), 1);

        for t in 0..7 {
            table.k_row_mut(t).copy_from_slice(&[t as f32, -(t as f32)]);
            table.v_row_mut(t).copy_from_slice(&[10.0 + t as f32, 0.5]);
        }
        for t in 0..7 {
            assert_eq!(table.k_row(t), &[t as f32, -(t as f32)]);
            assert_eq!(table.v_row(t), &[10.0 + t as f32, 0.5]);
        }

        table.release_into(&pool);
        assert_eq!(table.num_blocks(), 0);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn ensure_tokens_is_idempotent() {
        let pool = BlockPool::new(4, 2, 2);
        let mut table = BlockTable::new(&pool, 8);
        table.ensure_tokens(&pool, 3);
        assert_eq!(table.num_blocks(), 2);
        table.ensure_tokens(&pool, 3);
        table.ensure_tokens(&pool, 4); // still fits in 2 blocks
        assert_eq!(table.num_blocks(), 2);
        table.ensure_tokens(&pool, 5);
        assert_eq!(table.num_blocks(), 3);
        table.release_into(&pool);
    }

    #[test]
    fn quantize_row_mirrors_f32_rows() {
        let pool = BlockPool::new(2, 2, 3);
        let mut table = BlockTable::new(&pool, 4);
        table.ensure_tokens(&pool, 3);
        for t in 0..3 {
            let vals = [0.25 * t as f32, -1.5, 2.0];
            table.k_row_mut(t).copy_from_slice(&vals);
            table.v_row_mut(t).copy_from_slice(&vals);
            table.quantize_row(t);
        }
        for t in 0..3 {
            for (q, &f) in table.kq_row(t).iter().zip(table.k_row(t)) {
                assert_eq!(q.raw(), Fxp32::from_f32(f).raw());
            }
            for (q, &f) in table.vq_row(t).iter().zip(table.v_row(t)) {
                assert_eq!(q.raw(), Fxp32::from_f32(f).raw());
            }
        }
        table.release_into(&pool);
    }

    #[test]
    #[should_panic(expected = "beyond mapped blocks")]
    fn unmapped_token_panics() {
        let pool = BlockPool::new(2, 2, 2);
        let mut table = BlockTable::new(&pool, 4);
        table.ensure_tokens(&pool, 2);
        let _ = table.k_row(2);
    }

    #[test]
    fn bytes_per_block_accounts_mirrors() {
        let pool = BlockPool::new(1, 16, 128);
        // 16 rows × 128 lanes × (K + V) × (f32 + Q15.17) = 32 KiB
        assert_eq!(pool.bytes_per_block(), 16 * 128 * 2 * (4 + 4));
    }
}
