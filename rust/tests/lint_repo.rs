//! The repo-invariant lint pass, run as a plain test so the tier-1
//! suite enforces it without invoking the `lint` binary. Rules and
//! scanner live in `src/util/lint.rs` (unit-tested there against
//! seeded violations); this test asserts the tree itself is clean.

use std::path::Path;

use swiftkv::util::lint;

#[test]
fn repo_has_no_lint_violations() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = lint::lint_crate(root).expect("lint pass must be able to scan the crate");
    assert!(
        violations.is_empty(),
        "repo violates its own invariants:\n{}",
        violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
    );
}
