//! Integration: the overload-hardening layer of the serving loop —
//! bounded admission with explicit shedding (`503 + Retry-After` at the
//! front door), deadline-aware early rejection, graceful shutdown with
//! a drain bound, burst faults, slow-client cancellation, and idle
//! parking. The bar everywhere: every request is accounted for with an
//! explicit outcome, admitted requests stay bit-exact against solo
//! decode, and the KV pool drains to empty.

use std::time::Duration;

use swiftkv::coordinator::{CpuServer, FaultPlan, ServeConfig, SessionOutcome};
use swiftkv::model::{NumericsMode, Request, TinyModel};

fn model() -> TinyModel {
    TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48)
}

fn req(id: u64, prompt: Vec<u32>, gen_len: usize) -> Request {
    Request::new(id, prompt).gen_len(gen_len)
}

fn opts(lanes: usize) -> ServeConfig {
    ServeConfig::builder()
        .lanes(lanes)
        .mode(NumericsMode::DesktopF32)
        .max_iterations(100_000)
        .build()
        .expect("test serve config is valid")
}

fn assert_pool_reclaimed(report: &swiftkv::coordinator::CpuServeReport) {
    assert_eq!(
        report.kv_pool.free_blocks(),
        report.kv_pool.total_blocks(),
        "overload handling leaked KV blocks"
    );
}

#[test]
fn queue_cap_sheds_tail_keeps_oldest() {
    // 8 simultaneous arrivals, 1 lane, queue capped at 2: the two
    // oldest requests are served (bit-exact), the six newest are shed
    // with an explicit outcome — tail-drop, never starvation of a
    // queued request by a later arrival.
    let tm = model();
    let mut o = opts(1);
    o.max_queue_depth = 2;
    let reqs: Vec<Request> = (0..8u64).map(|i| req(i, vec![1 + i as u32], 6)).collect();
    let report = CpuServer::new(&tm, o).serve(reqs);

    assert_eq!(report.sessions.len(), 8, "every request must be accounted for");
    assert_eq!(report.metrics.requests_shed, 6);
    assert_eq!(report.metrics.requests_failed, 0);
    for s in &report.sessions {
        if s.request.id < 2 {
            assert!(s.outcome.is_completed(), "oldest request {} must be served", s.request.id);
            let want = tm.generate(&s.request.prompt, 6, NumericsMode::DesktopF32);
            assert_eq!(s.generated, want, "request {} perturbed by shedding", s.request.id);
        } else {
            assert_eq!(
                s.outcome,
                SessionOutcome::Shed,
                "request {} past the cap must be shed",
                s.request.id
            );
            assert!(s.generated.is_empty(), "shed requests never decode");
        }
    }
    assert_pool_reclaimed(&report);
    // shedding surfaces in the human-readable table
    assert!(report.metrics.format_table().contains("shed"), "metrics table");
}

#[test]
fn uncapped_queue_preserves_pre_overload_behavior() {
    // max_queue_depth = 0 (the default): same 8-request pileup, nothing
    // shed, everything completes bit-exact.
    let tm = model();
    let reqs: Vec<Request> = (0..8u64).map(|i| req(i, vec![1 + i as u32], 6)).collect();
    let report = CpuServer::new(&tm, opts(1)).serve(reqs);
    assert_eq!(report.sessions.len(), 8);
    assert_eq!(report.metrics.requests_shed, 0);
    for s in &report.sessions {
        assert!(s.outcome.is_completed());
        let want = tm.generate(&s.request.prompt, 6, NumericsMode::DesktopF32);
        assert_eq!(s.generated, want);
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn dead_on_arrival_deadline_is_rejected_at_the_door() {
    // A request submitted after its own deadline has already passed
    // (arrival 0 + deadline 1ms, submitted ≥20ms into the run) must be
    // rejected by admission — it never queues, never takes a lane.
    let tm = model();
    let server = CpuServer::new(&tm, opts(1));
    let (report, (warm, dead)) = server.serve_continuous(|handle| {
        let warm = handle
            .submit(req(0, vec![3], 6))
            .expect("engine accepts while the handle is live")
            .wait();
        std::thread::sleep(Duration::from_millis(20));
        let dead = handle
            .submit(req(1, vec![5], 6).deadline_ms(1))
            .expect("engine accepts while the handle is live")
            .wait();
        (warm, dead)
    });

    assert!(warm.outcome.is_completed());
    assert_eq!(warm.tokens, tm.generate(&[3], 6, NumericsMode::DesktopF32));
    assert_eq!(
        dead.outcome,
        SessionOutcome::DeadlineExpired,
        "a dead-on-arrival request must be rejected at admission"
    );
    assert!(dead.tokens.is_empty(), "rejected requests never decode");
    assert_eq!(report.metrics.deadline_rejected, 1);
    assert_pool_reclaimed(&report);
}

#[test]
fn graceful_shutdown_drains_running_and_sheds_queued() {
    // One running request, one scheduled far in the future (so the
    // engine is parked on it when shutdown lands). Shutdown must: stop
    // admission (the scheduled request is shed, not served), let the
    // running request finish bit-exact within the drain bound, wake the
    // parked engine, and return.
    let tm = model();
    let server = CpuServer::new(&tm, opts(1));
    let (report, (running, queued)) = server.serve_continuous(|handle| {
        let running = handle
            .submit(req(0, vec![3], 8))
            .expect("engine accepts while the handle is live");
        let queued = handle
            .submit(req(1, vec![5], 8).arrival_ms(60_000))
            .expect("engine accepts while the handle is live");
        let running = running.wait();
        handle.request_shutdown();
        assert!(handle.status().is_draining(), "shutdown must latch draining");
        (running, queued.wait())
    });

    assert!(running.outcome.is_completed(), "in-flight work survives a graceful drain");
    assert_eq!(running.tokens, tm.generate(&[3], 8, NumericsMode::DesktopF32));
    assert_eq!(
        queued.outcome,
        SessionOutcome::Shed,
        "admission is closed the moment shutdown is requested"
    );
    assert_eq!(report.metrics.requests_shed, 1);
    assert_pool_reclaimed(&report);
}

#[test]
fn zero_drain_budget_cancels_running_lanes() {
    // drain_ms = 0: shutdown cancels the running lane at the next
    // iteration boundary instead of waiting for it. Long generation so
    // the shutdown provably lands mid-decode.
    let tm = TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 256);
    let mut o = opts(1);
    o.drain_ms = 0;
    let server = CpuServer::new(&tm, o);
    let (report, fin) = server.serve_continuous(|handle| {
        let pending = handle
            .submit(req(0, vec![3, 4], 250))
            .expect("engine accepts while the handle is live");
        // wait for decode to be provably underway, then pull the plug
        let first = match pending.next_event() {
            Some(swiftkv::coordinator::TokenEvent::Token(t)) => t,
            other => panic!("engine must stream before shutdown, got {other:?}"),
        };
        handle.request_shutdown();
        let fin = pending.wait();
        (first, fin)
    });
    let (first, fin) = fin;

    assert_eq!(
        fin.outcome,
        SessionOutcome::Cancelled,
        "a zero drain budget must cancel the running lane"
    );
    // `wait` collects only post-`next_event` tokens; stitch the stream
    // back together and it must be a bit-exact solo prefix, cut short
    let streamed = 1 + fin.tokens.len();
    assert!(streamed < 250, "the lane ran to completion past shutdown");
    let solo = tm.generate(&[3, 4], 250, NumericsMode::DesktopF32);
    assert_eq!(first, solo[0], "first streamed token diverged");
    assert_eq!(fin.tokens, solo[1..streamed], "pre-cancel tokens diverged");
    assert_eq!(report.metrics.drain_cancels, 1);
    assert_pool_reclaimed(&report);
}

#[test]
fn burst_fault_floods_admission_and_is_shed_at_the_cap() {
    // burst@i3:n10 with both lanes busy and a 2-deep queue: 2 of the 10
    // synthetic requests queue, 8 are shed, and the real co-batched
    // requests never notice.
    let tm = model();
    let mut o = opts(2);
    o.max_queue_depth = 2;
    o.faults = Some(FaultPlan::parse("burst@i3:n10").expect("spec parses"));
    let reqs: Vec<Request> = (0..2u64).map(|i| req(i, vec![1 + i as u32], 8)).collect();
    let report = CpuServer::new(&tm, o).serve(reqs);

    assert_eq!(report.sessions.len(), 12, "2 real + 10 burst, all accounted for");
    assert_eq!(report.metrics.requests_shed, 8);
    assert_eq!(report.metrics.requests_failed, 0);
    for id in [0u64, 1] {
        let s = report.sessions.iter().find(|s| s.request.id == id).expect("real session");
        assert!(s.outcome.is_completed(), "real request {id} must complete");
        let want = tm.generate(&s.request.prompt, 8, NumericsMode::DesktopF32);
        assert_eq!(s.generated, want, "request {id}: burst traffic perturbed its output");
    }
    // burst ids live in the reserved high range — they never collide
    for s in report.sessions.iter().filter(|s| s.request.id >= 1 << 40) {
        assert!(
            matches!(s.outcome, SessionOutcome::Completed | SessionOutcome::Shed),
            "burst request {} ended {:?}",
            s.request.id,
            s.outcome
        );
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn slow_client_fault_cancels_instead_of_buffering_unboundedly() {
    // slowclient@r0: the client stalls from its first token; the lane
    // is cancelled as a slow client, a co-batched request is untouched.
    let tm = model();
    let mut o = opts(2);
    o.faults = Some(FaultPlan::parse("slowclient@r0").expect("spec parses"));
    let server = CpuServer::new(&tm, o);
    let (report, finished) = server.serve_continuous(|handle| {
        let pending: Vec<_> = (0..2u64)
            .map(|i| {
                handle
                    .submit(req(i, vec![1 + i as u32], 8))
                    .expect("engine accepts while the handle is live")
            })
            .collect();
        pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
    });

    assert_eq!(finished.len(), 2);
    assert_eq!(report.metrics.slow_client_cancels, 1);
    for fin in &finished {
        let solo = tm.generate(&[1 + fin.id as u32], 8, NumericsMode::DesktopF32);
        if fin.id == 0 {
            assert_eq!(fin.outcome, SessionOutcome::Cancelled, "the stalled client's lane");
        } else {
            assert!(fin.outcome.is_completed());
            assert_eq!(fin.tokens, solo, "survivor perturbed by a slow-client cancel");
        }
    }
    assert_pool_reclaimed(&report);
}

#[test]
fn idle_engine_parks_and_wakes_for_late_submissions() {
    // Submit, drain, go idle, submit again: the engine must park (not
    // spin) through the idle window and wake for the second request,
    // which completes bit-exact.
    let tm = model();
    let server = CpuServer::new(&tm, opts(2));
    let (report, (a, b)) = server.serve_continuous(|handle| {
        let a = handle
            .submit(req(0, vec![3], 6))
            .expect("engine accepts while the handle is live")
            .wait();
        std::thread::sleep(Duration::from_millis(10));
        let b = handle
            .submit(req(1, vec![5], 6))
            .expect("engine accepts while the handle is live")
            .wait();
        (a, b)
    });

    for (fin, prompt) in [(&a, 3u32), (&b, 5u32)] {
        assert!(fin.outcome.is_completed());
        assert_eq!(fin.tokens, tm.generate(&[prompt], 6, NumericsMode::DesktopF32));
    }
    assert!(
        report.metrics.idle_parks >= 1,
        "a 10ms idle window must park the engine at least once, got {}",
        report.metrics.idle_parks
    );
    assert_pool_reclaimed(&report);
}
