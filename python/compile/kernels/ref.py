"""Pure-jnp oracles for the SwiftKV kernels.

Every Pallas kernel in this package is validated against a reference here
(pytest + hypothesis, see ``python/tests``). Two attention references are
provided:

- :func:`native_attention` — the textbook ``softmax(qK^T/sqrt(d))V``
  (Eq. 4), the ground truth both implementations must match;
- :func:`swiftkv_attention_scan` — a literal per-token transcription of the
  SwiftKV recurrence, Eqs. (5)-(8), via ``lax.scan``. This is the
  *algorithmic* oracle: it proves the single-pass recurrence is exact, and
  it is what the Rust fixed-point implementation mirrors bit-for-bit
  (modulo FXP32 quantization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def native_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array | int | None = None) -> jax.Array:
    """Textbook decode attention (Eq. 4) for one head.

    q: [d]; k, v: [N, d]; length: number of valid cache rows (<= N).
    Returns [d].
    """
    d = q.shape[-1]
    s = (k @ q) / jnp.sqrt(jnp.asarray(d, q.dtype))  # [N]
    if length is not None:
        pos = jnp.arange(k.shape[0])
        s = jnp.where(pos < length, s, -jnp.inf)
    p = jax.nn.softmax(s)
    return p @ v


def native_attention_rows(q: jax.Array, k: jax.Array, v: jax.Array,
                          lens: jax.Array) -> jax.Array:
    """Row-batched native attention: q [R, d], k/v [R, N, d], lens [R]."""
    return jax.vmap(native_attention)(q, k, v, lens)


def swiftkv_attention_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                           length: jax.Array | int | None = None) -> jax.Array:
    """Literal per-token SwiftKV recurrence, Eqs. (5)-(8), for one head.

    Each (k_t, v_t) is consumed exactly once; state is (mu, Z, Y).
    The two branches of Eqs. (6)/(7) are expressed with ``jnp.where`` so the
    scan stays traceable; masked (invalid) positions leave the state
    untouched.
    """
    d = q.shape[-1]
    n = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    if length is None:
        length = n

    def step(carry, xs):
        mu, z, y = carry
        k_t, v_t, t = xs
        s_t = (q @ k_t) * scale                        # Eq. (5)
        valid = t < length
        take_beta = s_t <= mu                          # branch select
        beta = jnp.exp(s_t - mu)                       # Eq. (6)
        alpha = jnp.exp(mu - s_t)                      # Eq. (7)
        z_beta = z + beta
        y_beta = y + beta * v_t
        z_alpha = alpha * z + 1.0
        y_alpha = alpha * y + v_t
        mu_new = jnp.where(take_beta, mu, s_t)
        z_new = jnp.where(take_beta, z_beta, z_alpha)
        y_new = jnp.where(take_beta, y_beta, y_alpha)
        mu_new = jnp.where(valid, mu_new, mu)
        z_new = jnp.where(valid, z_new, z)
        y_new = jnp.where(valid, y_new, y)
        return (mu_new, z_new, y_new), None

    init = (jnp.asarray(-jnp.inf, q.dtype), jnp.asarray(0.0, q.dtype),
            jnp.zeros_like(q))
    (mu, z, y), _ = jax.lax.scan(
        step, init, (k, v, jnp.arange(n)))
    return y / z                                       # Eq. (8)


def swiftkv_attention_scan_rows(q: jax.Array, k: jax.Array, v: jax.Array,
                                lens: jax.Array) -> jax.Array:
    """Row-batched scan reference."""
    return jax.vmap(swiftkv_attention_scan)(q, k, v, lens)


# ---------------------------------------------------------------------------
# RoPE references
# ---------------------------------------------------------------------------

def rope_freqs(d: int, base: float = 10000.0) -> np.ndarray:
    """Angular frequencies omega_i = base^{-2(i-1)/d}, i = 1..d/2 (Eq. 1)."""
    i = np.arange(d // 2, dtype=np.float64)
    return base ** (-2.0 * i / d)


def rope_standard(x: jax.Array, m, base: float = 10000.0) -> jax.Array:
    """Direct RoPE(x, m) (Eq. 3): rotate consecutive channel pairs.

    x: [..., d]; m: scalar position.
    """
    d = x.shape[-1]
    omega = jnp.asarray(rope_freqs(d, base), x.dtype)
    theta = m * omega                                   # Eq. (2)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    return jnp.stack([out_even, out_odd], axis=-1).reshape(x.shape)


def rope_incremental_step(cos_m: jax.Array, sin_m: jax.Array,
                          a: jax.Array, b: jax.Array):
    """One decoder-RoPE recurrence step (the angle-addition core of Eq. 11).

    (cos m*theta, sin m*theta) -> (cos (m+1)*theta, sin (m+1)*theta), with
    a = cos(theta), b = sin(theta) stored as constants in each SKV unit.
    """
    cos_next = cos_m * a - sin_m * b
    sin_next = cos_m * b + sin_m * a
    return cos_next, sin_next


def rope_apply(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate channel pairs of x [..., d] by cached (cos, sin) [..., d/2]."""
    x_even = x[..., 0::2]
    x_odd = x[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    return jnp.stack([out_even, out_odd], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# W4A8 GEMV reference
# ---------------------------------------------------------------------------

def gemv_w4a8(x_q: jax.Array, x_scale: jax.Array,
              w_q: jax.Array, w_scale: jax.Array) -> jax.Array:
    """W4A8 GEMV reference: INT8 activation x INT4 weight -> f32.

    x_q: [din] int8; x_scale: scalar f32; w_q: [din, dout] int8 holding
    int4 values in [-8, 7]; w_scale: [dout] f32 per-output-channel scales.
    Accumulation in int32 (the INT4xINT8 -> INT32 DSP path of Fig. 5(b)),
    dequantized on writeback (SFU cast).
    """
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)     # [dout]
    return acc.astype(jnp.float32) * x_scale * w_scale


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor INT8 activation quantization."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_int4(w: jax.Array):
    """Symmetric per-output-channel INT4 weight quantization.

    w: [din, dout] f32 -> (w_q int8 in [-7, 7], w_scale [dout] f32).
    """
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)  # [dout]
    scale = amax / 7.0
    q = jnp.clip(jnp.round(w / scale), -7, 7).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
