//! `loadgen` — open-loop serving load generator.
//!
//! ```text
//! loadgen [--rates 100,200,400] [--requests 24] [--lanes 4] [--seed 0]
//!         [--adaptive-prefill] [--cancel-frac 0.0]
//!         [--out ../BENCH_hotpath.json] [--no-write]
//! loadgen --target http://127.0.0.1:8080 [--duration-ms 3000]
//!         [--concurrency 4] [--cancel-frac 0.0] [--smoke] [--require-shed]
//! ```
//!
//! **In-process mode** (default): replays the same Poisson arrival
//! stream (length mixes from `model/workload.rs`, inter-arrival gaps
//! from `Rng::gen_exp`) against two serving disciplines at equal lane
//! count —
//!
//! - `continuous`: requests are submitted through a [`ServeHandle`] at
//!   their arrival instants and join the running engine mid-flight;
//!   per-request latency is measured open-loop, submission → final
//!   event.
//! - `drain`: the pre-continuous discipline — requests accumulate into
//!   groups of `lanes`, the group is served as one batch once its last
//!   member has arrived, and nothing new starts until the batch drains;
//!   per-request latency is batch-completion − arrival.
//!
//! Each (rate, discipline) point lands in `BENCH_hotpath.json` as a
//! `serve/loadgen …` entry (median = p99 latency; p50 / throughput /
//! outcome counts in `extras`), merged in next to the kernel benches —
//! the throughput-vs-p99 curve the continuous engine is judged on. This
//! is also what first **arms** the serving benches in CI's perf-gate
//! baseline, the way `cargo bench --bench hotpath` arms the kernel ones.
//!
//! **HTTP mode** (`--target`): drives a live `swiftkv serve --listen`
//! over the wire with a hand-rolled HTTP/SSE client for a bounded wall
//! clock. With `--smoke` the exit code asserts the serving contract
//! (every request completed or was deliberately cancelled/shed, none
//! failed) — CI's `serve-smoke` and `overload-smoke` jobs.
//!
//! **Client cancellation** (`--cancel-frac F`, both modes): a seeded
//! per-request draw aborts that fraction of requests mid-stream — the
//! in-process waiter drops its `PendingRequest` after 1–3 tokens, the
//! HTTP client closes its socket mid-SSE. Cancelled requests are
//! reported separately (never as failures, never in the latency
//! percentiles) and land in `BENCH_hotpath.json` extras alongside the
//! shed count. A `503 + Retry-After` from an overloaded server counts
//! as `shed` and the worker honors the backoff (capped at 2 s);
//! `--require-shed` makes the smoke contract additionally demand at
//! least one shed response (the overload-smoke job's proof that
//! admission control actually engaged).

use swiftkv::coordinator::{CpuServer, ServeConfig, ServeHandle, SessionOutcome, TokenEvent};
use swiftkv::model::{NumericsMode, Request, TinyModel, WorkloadGen, WorkloadSpec};
use swiftkv::util::bench::{fmt_ns, merge_into_json_file, Measurement};
use swiftkv::util::cli::Args;
use swiftkv::util::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("loadgen: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let args = Args::parse(
        &[
            "rates", "requests", "lanes", "seed", "out", "target", "duration-ms", "concurrency",
            "cancel-frac",
        ],
        &["help", "smoke", "no-write", "adaptive-prefill", "require-shed"],
    )?;
    if args.get_bool("help") {
        println!(
            "usage: loadgen [--rates 100,200,400] [--requests 24] [--lanes 4] [--seed 0]\n\
             \x20              [--adaptive-prefill] [--cancel-frac 0.0] [--out PATH] [--no-write]\n\
             \x20      loadgen --target http://HOST:PORT [--duration-ms 3000] \
             [--concurrency 4] [--cancel-frac 0.0] [--smoke] [--require-shed]"
        );
        return Ok(());
    }
    match args.get("target") {
        Some(target) => drive_http(&args, target),
        None => sweep_in_process(&args),
    }
}

/// Parse `--cancel-frac` into a fraction in `[0, 1]`.
fn cancel_frac(args: &Args) -> Result<f64, String> {
    let f = args
        .get_or("cancel-frac", "0")
        .parse::<f64>()
        .map_err(|_| "bad --cancel-frac (expected a number in [0, 1])".to_string())?;
    if !(0.0..=1.0).contains(&f) {
        return Err(format!("--cancel-frac {f} out of range [0, 1]"));
    }
    Ok(f)
}

/// Latency/outcome summary of one (rate, discipline) run. Cancelled and
/// shed requests are tracked apart from failures: they are deliberate
/// (client aborts, admission control) and excluded from the latency
/// percentiles so the p99 keeps measuring served requests.
struct RunStats {
    latencies_ms: Vec<f64>,
    completed: u64,
    failed: u64,
    cancelled: u64,
    shed: u64,
    tokens: u64,
    wall_s: f64,
}

impl RunStats {
    fn percentile(&self, q: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.latencies_ms.clone();
        s.sort_by(f64::total_cmp);
        s[((s.len() - 1) as f64 * q).floor() as usize]
    }

    fn tok_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

fn workload(rate_per_s: f64, requests: usize, vocab: usize, seed: u64) -> Vec<Request> {
    WorkloadGen::new(WorkloadSpec {
        num_requests: requests,
        vocab,
        prompt_len: (4, 12),
        gen_len: (6, 16),
        mean_gap_ms: 1000.0 / rate_per_s,
        deadline_ms: 0,
        seed,
    })
    .generate()
}

fn sleep_until(t0: Instant, target_ms: u64) {
    let due = Duration::from_millis(target_ms);
    let now = t0.elapsed();
    if due > now {
        std::thread::sleep(due - now);
    }
}

/// Continuous discipline: open-loop submission through the ServeHandle
/// at each request's arrival instant; one waiter thread per request
/// records submission → final-event latency. With `cancel_frac > 0` a
/// seeded draw marks that fraction of requests for mid-stream abort:
/// their waiters consume 1–3 tokens and drop the `PendingRequest` — the
/// engine must cancel the lane and reclaim its blocks while co-batched
/// survivors decode on untouched.
fn run_continuous(
    model: &TinyModel,
    cfg: &ServeConfig,
    reqs: &[Request],
    cancel_frac: f64,
    seed: u64,
) -> RunStats {
    let server = CpuServer::new(model, cfg.clone());
    // one draw per request, fixed before submission so the abort set is
    // reproducible from the seed alone
    let mut rng = Rng::seed_from_u64(seed ^ 0xCA9CE1);
    let cancel_after: Vec<Option<usize>> = reqs
        .iter()
        .map(|_| (rng.gen_f64() < cancel_frac).then(|| rng.gen_range(1, 4)))
        .collect();
    let t0 = Instant::now();
    let (report, results) = server.serve_continuous(|handle: &ServeHandle| {
        std::thread::scope(|s| {
            let mut waiters = Vec::with_capacity(reqs.len());
            for (req, &abort_at) in reqs.iter().zip(&cancel_after) {
                sleep_until(t0, req.arrival_ms);
                let submitted = t0.elapsed();
                // strip the arrival gate: the generator already paced
                // this submission in real time
                let wire = Request::new(req.id, req.prompt.clone()).gen_len(req.gen_len);
                match handle.submit(wire) {
                    Ok(pending) => waiters.push(s.spawn(move || {
                        if let Some(k) = abort_at {
                            // consume k tokens, then vanish mid-stream
                            let mut got = 0u64;
                            loop {
                                match pending.next_event() {
                                    Some(TokenEvent::Token(_)) => {
                                        got += 1;
                                        if got >= k as u64 {
                                            break;
                                        }
                                    }
                                    // retired before the abort point —
                                    // report the engine's outcome
                                    Some(TokenEvent::Done(outcome)) => {
                                        let lat_ms =
                                            (t0.elapsed() - submitted).as_secs_f64() * 1e3;
                                        return (outcome, got, lat_ms);
                                    }
                                    None => {
                                        let lat_ms =
                                            (t0.elapsed() - submitted).as_secs_f64() * 1e3;
                                        return (
                                            SessionOutcome::Failed(
                                                "stream closed without Done".to_string(),
                                            ),
                                            got,
                                            lat_ms,
                                        );
                                    }
                                }
                            }
                            let lat_ms = (t0.elapsed() - submitted).as_secs_f64() * 1e3;
                            drop(pending);
                            (SessionOutcome::Cancelled, got, lat_ms)
                        } else {
                            let fin = pending.wait();
                            let lat_ms = (t0.elapsed() - submitted).as_secs_f64() * 1e3;
                            (fin.outcome, fin.tokens.len() as u64, lat_ms)
                        }
                    })),
                    Err(e) => eprintln!("loadgen: submit failed: {e}"),
                }
            }
            waiters
                .into_iter()
                .filter_map(|w| w.join().ok())
                .collect::<Vec<_>>()
        })
    });
    let mut stats = RunStats {
        latencies_ms: Vec::new(),
        completed: 0,
        failed: 0,
        cancelled: 0,
        shed: 0,
        tokens: 0,
        wall_s: report.metrics.wall_s,
    };
    for (outcome, tokens, lat_ms) in results {
        stats.tokens += tokens;
        match outcome {
            SessionOutcome::Completed => {
                stats.completed += 1;
                stats.latencies_ms.push(lat_ms);
            }
            SessionOutcome::Cancelled => stats.cancelled += 1,
            SessionOutcome::Shed => stats.shed += 1,
            _ => {
                stats.failed += 1;
                stats.latencies_ms.push(lat_ms);
            }
        }
    }
    stats
}

/// Drain-barrier discipline: the pre-continuous serving shape. Requests
/// accumulate into groups of `lanes`; a group is served as one offline
/// batch once its last member has arrived, and the next group waits for
/// the full drain. Per-request latency is batch-completion − arrival —
/// the barrier's cost made visible.
fn run_drain(model: &TinyModel, cfg: &ServeConfig, reqs: &[Request]) -> RunStats {
    let server = CpuServer::new(model, cfg.clone());
    let lanes = cfg.lanes;
    let t0 = Instant::now();
    let mut stats = RunStats {
        latencies_ms: Vec::new(),
        completed: 0,
        failed: 0,
        cancelled: 0,
        shed: 0,
        tokens: 0,
        wall_s: 0.0,
    };
    for group in reqs.chunks(lanes) {
        if let Some(last) = group.last() {
            sleep_until(t0, last.arrival_ms);
        }
        let batch: Vec<Request> = group
            .iter()
            .map(|r| Request::new(r.id, r.prompt.clone()).gen_len(r.gen_len))
            .collect();
        let report = server.serve(batch);
        let end_ms = t0.elapsed().as_secs_f64() * 1e3;
        for r in group {
            stats.latencies_ms.push(end_ms - r.arrival_ms as f64);
        }
        for s in &report.sessions {
            stats.tokens += s.generated.len() as u64;
            if s.outcome.is_completed() {
                stats.completed += 1;
            } else {
                stats.failed += 1;
            }
        }
    }
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats
}

fn sweep_in_process(args: &Args) -> Result<(), String> {
    let rates: Vec<f64> = args
        .get_or("rates", "100,200,400")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| format!("bad rate '{s}'"))
        })
        .collect::<Result<_, _>>()?;
    if rates.iter().any(|&r| r <= 0.0) {
        return Err("rates must be positive (requests per second)".into());
    }
    let requests = args.get_usize("requests", 24)?;
    let lanes = args.get_usize("lanes", 4)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let cancel = cancel_frac(args)?;
    let model = TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48);
    let cfg = ServeConfig::builder()
        .lanes(lanes)
        .mode(NumericsMode::DesktopF32)
        .adaptive_prefill(args.get_bool("adaptive-prefill"))
        .build()?;

    println!(
        "loadgen: {} requests, {} lanes, Poisson rates {:?} req/s (seed {seed})",
        requests, lanes, rates
    );
    let mut entries: Vec<Measurement> = Vec::new();
    for &rate in &rates {
        let reqs = workload(rate, requests, model.vocab, seed);
        let cont = run_continuous(&model, &cfg, &reqs, cancel, seed);
        let drain = run_drain(&model, &cfg, &reqs);
        for (disc, stats) in [("continuous", &cont), ("drain", &drain)] {
            println!(
                "rate={rate:>6.0} {disc:<10} p50 {} p99 {} {:>8.1} tok/s \
                 ({} ok / {} failed / {} cancelled / {} shed)",
                fmt_ns(stats.percentile(0.50) * 1e6),
                fmt_ns(stats.percentile(0.99) * 1e6),
                stats.tok_per_s(),
                stats.completed,
                stats.failed,
                stats.cancelled,
                stats.shed,
            );
            entries.push(
                Measurement::external(
                    &format!("serve/loadgen {disc} lanes={lanes} rate={rate:.0}"),
                    stats.percentile(0.99) * 1e6, // p99 latency, in ns
                    stats.latencies_ms.len() as u64,
                )
                .with_extra("p50_ms", stats.percentile(0.50))
                .with_extra("p99_ms", stats.percentile(0.99))
                .with_extra("tok_per_s", stats.tok_per_s())
                .with_extra("completed", stats.completed as f64)
                .with_extra("failed", stats.failed as f64)
                .with_extra("cancelled", stats.cancelled as f64)
                .with_extra("shed", stats.shed as f64),
            );
        }
        let speedup = drain.percentile(0.99) / cont.percentile(0.99).max(1e-9);
        println!(
            "rate={rate:>6.0} continuous p99 is {speedup:.2}x better than the drain barrier"
        );
    }
    if entries.iter().any(|m| {
        m.extras.get("failed").copied().unwrap_or(0.0) > 0.0
            || m.extras.get("completed").copied().unwrap_or(0.0) == 0.0
    }) {
        return Err("serving contract violated: a request failed or none completed".into());
    }
    if !args.get_bool("no-write") {
        let out = match args.get("out") {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .ok_or("cannot locate repository root")?
                .join("BENCH_hotpath.json"),
        };
        merge_into_json_file(&out, &entries).map_err(|e| format!("write {out:?}: {e}"))?;
        println!("merged {} serve entries into {}", entries.len(), out.display());
    }
    Ok(())
}

/// What one HTTP round trip against a live server came back as.
enum HttpOutcome {
    /// 200 SSE stream ending in `"outcome":"completed"`, with this many
    /// streamed tokens.
    Completed(u64),
    /// Deliberate mid-stream abort after this many tokens (the client
    /// closed its socket — the server must cancel the lane).
    Cancelled(u64),
    /// `503` from admission control, with the server's `Retry-After`
    /// backoff in seconds.
    Shed(u64),
    /// Anything else: transport error, non-completed outcome, bad
    /// status.
    Failed(String),
}

/// One SSE round trip against a live server, reading incrementally so a
/// `cancel_after` abort can close the socket mid-stream (the server's
/// next `try_send` sees the dead receiver and cancels the lane).
fn http_generate(
    addr: &str,
    prompt: &[u32],
    gen_len: usize,
    cancel_after: Option<usize>,
) -> Result<HttpOutcome, String> {
    let body = format!(
        "{{\"prompt\": [{}], \"gen_len\": {gen_len}}}",
        prompt
            .iter()
            .map(u32::to_string)
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .map_err(|e| e.to_string())?;
    let mut resp = String::new();
    let mut chunk = [0u8; 1024];
    // headers first: the status line decides which shape this is
    while !resp.contains("\r\n\r\n") {
        let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
        if n == 0 {
            return Ok(HttpOutcome::Failed("connection closed before headers".to_string()));
        }
        resp.push_str(&String::from_utf8_lossy(&chunk[..n]));
    }
    let status_line = resp.lines().next().unwrap_or("").to_string();
    if status_line.contains("503") {
        let retry = resp
            .lines()
            .find_map(|l| l.strip_prefix("Retry-After:"))
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(1);
        return Ok(HttpOutcome::Shed(retry));
    }
    if !status_line.starts_with("HTTP/1.1 200") {
        return Ok(HttpOutcome::Failed(format!("non-200 response: {status_line}")));
    }
    // 200 SSE: stream events until done (or the deliberate abort point)
    loop {
        let tokens = resp.matches("\"token\":").count() as u64;
        if let Some(k) = cancel_after {
            if tokens >= k as u64 {
                // dropping the stream closes the socket mid-SSE
                return Ok(HttpOutcome::Cancelled(tokens));
            }
        }
        if resp.contains("\"done\":true") {
            return Ok(if resp.contains("\"outcome\":\"completed\"") {
                HttpOutcome::Completed(tokens)
            } else {
                HttpOutcome::Failed("stream ended with a non-completed outcome".to_string())
            });
        }
        let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
        if n == 0 {
            return Ok(HttpOutcome::Failed("connection closed mid-stream".to_string()));
        }
        resp.push_str(&String::from_utf8_lossy(&chunk[..n]));
    }
}

fn drive_http(args: &Args, target: &str) -> Result<(), String> {
    let addr = target
        .strip_prefix("http://")
        .unwrap_or(target)
        .trim_end_matches('/')
        .to_string();
    let duration = Duration::from_millis(args.get_usize("duration-ms", 3000)? as u64);
    let concurrency = args.get_usize("concurrency", 4)?.max(1);
    let seed = args.get_usize("seed", 0)? as u64;
    let cancel = cancel_frac(args)?;
    // the CLI's synthetic fallback model has vocab 512; stay inside it
    const VOCAB: u32 = 512;

    let t0 = Instant::now();
    let results: Vec<(u64, u64, u64, u64, u64)> = std::thread::scope(|s| {
        (0..concurrency)
            .map(|w| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut rng = Rng::seed_from_u64(seed.wrapping_add(w as u64 * 7919));
                    let (mut completed, mut failed, mut cancelled, mut shed, mut tokens) =
                        (0u64, 0u64, 0u64, 0u64, 0u64);
                    while t0.elapsed() < duration {
                        let plen = rng.gen_range(3, 10);
                        let prompt: Vec<u32> =
                            (0..plen).map(|_| rng.gen_range(1, VOCAB as usize) as u32).collect();
                        let glen = rng.gen_range(4, 10);
                        let abort_at =
                            (rng.gen_f64() < cancel).then(|| rng.gen_range(1, 4));
                        match http_generate(&addr, &prompt, glen, abort_at) {
                            Ok(HttpOutcome::Completed(t)) => {
                                completed += 1;
                                tokens += t;
                            }
                            Ok(HttpOutcome::Cancelled(t)) => {
                                cancelled += 1;
                                tokens += t;
                            }
                            Ok(HttpOutcome::Shed(retry_s)) => {
                                // honor the server's backoff, capped so a
                                // bounded smoke run still makes progress
                                shed += 1;
                                std::thread::sleep(
                                    Duration::from_secs(retry_s).min(Duration::from_secs(2)),
                                );
                            }
                            Ok(HttpOutcome::Failed(reason)) => {
                                eprintln!("loadgen: worker {w}: {reason}");
                                failed += 1;
                            }
                            Err(e) => {
                                eprintln!("loadgen: worker {w}: {e}");
                                failed += 1;
                            }
                        }
                    }
                    (completed, failed, cancelled, shed, tokens)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .filter_map(|h| h.join().ok())
            .collect()
    });
    let completed: u64 = results.iter().map(|r| r.0).sum();
    let failed: u64 = results.iter().map(|r| r.1).sum();
    let cancelled: u64 = results.iter().map(|r| r.2).sum();
    let shed: u64 = results.iter().map(|r| r.3).sum();
    let tokens: u64 = results.iter().map(|r| r.4).sum();
    let wall_s = t0.elapsed().as_secs_f64();
    println!(
        "loadgen: target {addr}: {completed} completed, {failed} failed, \
         {cancelled} cancelled, {shed} shed, {tokens} tokens in {wall_s:.2} s ({:.1} tok/s)",
        tokens as f64 / wall_s.max(1e-9)
    );
    if args.get_bool("smoke") && (completed == 0 || failed > 0) {
        return Err(format!(
            "smoke contract violated: completed={completed} failed={failed} \
             (need completed > 0 and failed == 0)"
        ));
    }
    if args.get_bool("require-shed") && shed == 0 {
        return Err(
            "overload contract violated: --require-shed was set but the server never \
             shed a request (admission control did not engage)"
                .to_string(),
        );
    }
    Ok(())
}
