"""Layer-1 Pallas kernel: decoder-specialized RoPE (Eq. 11).

During decode only the *new* token needs rotating, and the angle
``(m+1)*theta_i`` is obtained from the cached ``(cos m*theta, sin m*theta)``
by one angle-addition step with the stored constants ``a_i = cos(theta_i)``,
``b_i = sin(theta_i)`` — four multiplies per channel pair, no CORDIC, no
large-angle reduction (§IV-C).

The kernel fuses the recurrence update with the pair rotation and is
row-batched like the attention kernel: ``R`` rows of ``q``/``k`` (one per
head x sequence) share per-sequence (cos, sin) state via the index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(q_ref, k_ref, cos_ref, sin_ref, a_ref, b_ref,
                 qo_ref, ko_ref, cos_o_ref, sin_o_ref):
    cos_m = cos_ref[0, :]
    sin_m = sin_ref[0, :]
    a = a_ref[0, :]
    b = b_ref[0, :]
    # angle addition: cos/sin((m+1) theta) from cos/sin(m theta)
    cos_n = cos_m * a - sin_m * b
    sin_n = cos_m * b + sin_m * a
    for x_ref, o_ref in ((q_ref, qo_ref), (k_ref, ko_ref)):
        x = x_ref[0, :]
        x_even = x[0::2]
        x_odd = x[1::2]
        o_even = x_even * cos_n - x_odd * sin_n
        o_odd = x_even * sin_n + x_odd * cos_n
        o_ref[0, :] = jnp.stack([o_even, o_odd], axis=-1).reshape(x.shape)
    cos_o_ref[0, :] = cos_n
    sin_o_ref[0, :] = sin_n


@functools.partial(jax.jit, static_argnames=("heads_per_seq",))
def rope_decode_step(q: jax.Array, k: jax.Array,
                     cos_m: jax.Array, sin_m: jax.Array,
                     a: jax.Array, b: jax.Array, *, heads_per_seq: int = 1):
    """Rotate new-token q and k rows and advance the (cos, sin) cache.

    q, k: [R, d] with R = B * heads_per_seq rows (head-major within a
    sequence); cos_m, sin_m: [B, d/2] cached values for position m;
    a, b: [d/2] the constants cos(theta_i), sin(theta_i).

    Returns (q', k', cos_{m+1}, sin_{m+1}); the rotated k' row is what gets
    appended to the KV cache (already position-encoded, so cached keys are
    never re-rotated — the paper's key point).
    """
    r, d = q.shape
    bsz = cos_m.shape[0]
    if r != bsz * heads_per_seq:
        raise ValueError(f"rows {r} != batch {bsz} x heads {heads_per_seq}")
    h = heads_per_seq
    a2 = a.reshape(1, -1)
    b2 = b.reshape(1, -1)
    half = d // 2

    qo, ko, cos_rows, sin_rows = pl.pallas_call(
        _rope_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),            # q row
            pl.BlockSpec((1, d), lambda i: (i, 0)),            # k row
            pl.BlockSpec((1, half), lambda i: (i // h, 0)),    # cos (shared)
            pl.BlockSpec((1, half), lambda i: (i // h, 0)),    # sin (shared)
            pl.BlockSpec((1, half), lambda i: (0, 0)),         # a
            pl.BlockSpec((1, half), lambda i: (0, 0)),         # b
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, half), lambda i: (i, 0)),
            pl.BlockSpec((1, half), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d), q.dtype),
            jax.ShapeDtypeStruct((r, d), k.dtype),
            jax.ShapeDtypeStruct((r, half), cos_m.dtype),
            jax.ShapeDtypeStruct((r, half), sin_m.dtype),
        ],
        interpret=True,
    )(q, k, cos_m, sin_m, a2, b2)

    # every head of a sequence computed the same (cos, sin); keep one copy
    cos_next = cos_rows[::h, :]
    sin_next = sin_rows[::h, :]
    return qo, ko, cos_next, sin_next
