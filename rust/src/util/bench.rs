//! Timing harness for `rust/benches/*` (offline replacement for criterion).
//!
//! Warmup, then adaptive measurement until a time budget or iteration cap
//! is reached; reports min/median/mean and a robust spread estimate.
//! Results can be serialized to JSON ([`Bencher::write_json`]) so each
//! bench run leaves a machine-readable perf trajectory (e.g.
//! `BENCH_hotpath.json` at the repository root).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Median absolute deviation (scaled) — robust spread.
    pub mad_ns: f64,
    /// Numeric annotations attached via [`Bencher::annotate`] (modeled
    /// bytes per op, group factors, …); serialized under `"extras"`.
    pub extras: BTreeMap<String, f64>,
}

impl Measurement {
    /// A measurement taken outside the [`Bencher`] loop — e.g. the
    /// serving load generator, which measures wall-clock request
    /// latencies itself and records the summary as a bench entry.
    pub fn external(name: &str, median_ns: f64, iters: u64) -> Measurement {
        Measurement {
            name: name.to_string(),
            iters,
            min_ns: median_ns,
            median_ns,
            mean_ns: median_ns,
            mad_ns: 0.0,
            extras: BTreeMap::new(),
        }
    }

    /// Attach a numeric annotation (serialized under `"extras"`).
    pub fn with_extra(mut self, key: &str, value: f64) -> Measurement {
        self.extras.insert(key.to_string(), value);
        self
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }

    /// JSON object with every recorded statistic.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("min_ns".to_string(), Json::Num(self.min_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("mad_ns".to_string(), Json::Num(self.mad_ns));
        m.insert(
            "throughput_per_sec".to_string(),
            Json::Num(self.throughput_per_sec()),
        );
        if !self.extras.is_empty() {
            let extras = self
                .extras
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect();
            m.insert("extras".to_string(), Json::Obj(extras));
        }
        Json::Obj(m)
    }
}

/// Bench runner with a global time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup_ms: u64, measure_ms: u64) -> Self {
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Run `f` repeatedly; `f` must return something observable to prevent
    /// the optimizer from deleting the work (use `std::hint::black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // warmup
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // measurement: sample batches, record per-iteration times
        let mut samples: Vec<f64> = Vec::new();
        let batch = warm_iters.clamp(1, 1024);
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.measure && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(dt);
            iters += batch;
        }
        samples.sort_by(f64::total_cmp);
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = devs[devs.len() / 2];

        let m = Measurement {
            name: name.to_string(),
            iters,
            min_ns: min,
            median_ns: median,
            mean_ns: mean,
            mad_ns: mad,
            extras: BTreeMap::new(),
        };
        println!(
            "{:<48} time: [{} {} {}]  ({} iters)",
            m.name,
            fmt_ns(m.min_ns),
            fmt_ns(m.median_ns),
            fmt_ns(m.mean_ns),
            m.iters
        );
        self.results.push(m);
        self.results.last().expect("pushed one line above")
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Append an externally-taken measurement (see
    /// [`Measurement::external`]) to the result set, so it reaches the
    /// same JSON document as the timed benches.
    pub fn record(&mut self, m: Measurement) {
        self.results.push(m);
    }

    /// Look up a recorded measurement by exact name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }

    /// Attach a numeric annotation to an already-recorded measurement —
    /// modeled quantities (streamed KV bytes per token, group factor, …)
    /// that belong next to the timing in the JSON trajectory. No-op if
    /// the name was never benched.
    pub fn annotate(&mut self, name: &str, key: &str, value: f64) {
        if let Some(m) = self.results.iter_mut().find(|m| m.name == name) {
            m.extras.insert(key.to_string(), value);
        }
    }

    /// All results as a JSON document (`{schema, benchmarks: [...]}`).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert(
            "schema".to_string(),
            Json::Str("swiftkv-bench-v1".to_string()),
        );
        root.insert(
            "benchmarks".to_string(),
            Json::Arr(self.results.iter().map(Measurement::to_json).collect()),
        );
        Json::Obj(root)
    }

    /// Write the JSON document to `path` (overwrites).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Whether a bench document is the **unarmed placeholder** the repo
/// ships before any real run has populated it: zero benchmarks plus a
/// top-level `"note"` explaining itself. Distinct from a merely *empty*
/// document (zero benchmarks, no note), which suggests a stripped or
/// corrupted baseline rather than a never-armed one — `bench_gate`
/// reports the two states differently.
pub fn is_placeholder_doc(doc: &Json) -> bool {
    doc.get("benchmarks")
        .and_then(Json::as_arr)
        .is_some_and(|a| a.is_empty())
        && doc.get("note").and_then(Json::as_str).is_some()
}

/// Merge measurements into an existing `swiftkv-bench-v1` JSON file,
/// replacing same-name entries and keeping the rest — the load
/// generator uses this to add its serving curves to `BENCH_hotpath.json`
/// without clobbering the kernel benches already recorded there. A
/// missing, placeholder, or unparseable file is (re)armed from scratch;
/// the placeholder `"note"` is dropped once real benchmarks land.
pub fn merge_into_json_file(
    path: &std::path::Path,
    results: &[Measurement],
) -> std::io::Result<()> {
    let mut entries: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(doc) = Json::parse(&text) {
            if let Some(arr) = doc.get("benchmarks").and_then(Json::as_arr) {
                entries = arr.to_vec();
            }
        }
    }
    let new_names: std::collections::BTreeSet<&str> =
        results.iter().map(|m| m.name.as_str()).collect();
    entries.retain(|e| {
        e.get("name")
            .and_then(Json::as_str)
            .is_none_or(|n| !new_names.contains(n))
    });
    entries.extend(results.iter().map(Measurement::to_json));
    let mut root = BTreeMap::new();
    root.insert(
        "schema".to_string(),
        Json::Str("swiftkv-bench-v1".to_string()),
    );
    root.insert("benchmarks".to_string(), Json::Arr(entries));
    std::fs::write(path, format!("{}\n", Json::Obj(root)))
}

/// One row of a baseline-vs-current bench comparison.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// Percent change in median ns/op (positive = slower than baseline).
    pub delta_pct: f64,
    /// Whether this row is subject to the regression gate.
    pub gated: bool,
}

/// Result of [`compare_bench_json`]: the delta table plus the gate
/// verdict. Rendered to a GitHub-flavored markdown table for the CI job
/// summary by [`GateReport::to_markdown`].
#[derive(Debug, Clone)]
pub struct GateReport {
    pub rows: Vec<BenchDelta>,
    /// Current benches with no baseline entry (new benches — reported,
    /// never gated).
    pub unmatched: Vec<String>,
    /// Baseline benches absent from the current run. Reported always;
    /// the gated ones among them are also failures — a renamed or
    /// deleted fused bench must come with a baseline refresh in the
    /// same change, or the gate would silently lose coverage.
    pub missing: Vec<String>,
    /// Gated rows past the threshold, plus gated baseline entries
    /// missing from the current run.
    pub failures: Vec<String>,
    /// Gate substrings that matched **zero** benchmarks in the baseline
    /// or in the current document (entries read `"<substr> (no match in
    /// <which>)"`). A dead substring means the gate silently lost
    /// coverage — e.g. the gated benches were renamed, or a new gate
    /// entry predates its benches landing in the baseline. Reported as
    /// a loud warning in the markdown, never a failure.
    pub dead_gate_substrings: Vec<String>,
    /// Benchmarks present in the baseline document. `0` means the gate
    /// is **vacuous** — nothing can fail; `bench_gate --require-baseline`
    /// turns that into a hard error so CI cannot silently run ungated.
    pub baseline_count: usize,
    /// Whether the empty baseline is the repo's **unarmed placeholder**
    /// (zero benchmarks + a self-describing `"note"`), as opposed to a
    /// stripped/corrupted document. The report names the two states
    /// explicitly so "never armed" is not misread as "lost the data".
    pub baseline_placeholder: bool,
    pub gate_substr: String,
    pub max_regress_pct: f64,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// Whether the baseline document contained no benchmarks at all —
    /// the gate compared nothing and passes vacuously.
    pub fn baseline_empty(&self) -> bool {
        self.baseline_count == 0
    }

    /// Markdown delta table + verdict (the CI job-summary payload).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "### Hotpath bench vs committed baseline\n\nGate: any bench whose \
             name contains one of `{}` regressing > {:.0}% in median ns/op \
             fails the job.\n\n",
            self.gate_substr, self.max_regress_pct
        ));
        if self.baseline_empty() {
            if self.baseline_placeholder {
                out.push_str(
                    "## ⚠️ BASELINE PLACEHOLDER — never armed\n\n\
                     The baseline is still the committed placeholder (zero \
                     benchmarks, self-describing `note`): no real bench run has \
                     ever armed this gate. Arm it from a CI-class `cargo bench \
                     --bench hotpath` run (the perf-gate workflow auto-pins one \
                     on the next main push).\n",
                );
            } else {
                out.push_str(
                    "## ⚠️ BASELINE EMPTY — gate is vacuous\n\n\
                     The baseline document contains **zero benchmarks** and is \
                     NOT the placeholder — an armed baseline appears to have \
                     been stripped or corrupted. Nothing is gated and any \
                     regression ships silently. Refresh `BENCH_baseline.json` \
                     from a CI-class `cargo bench --bench hotpath` run (CI runs \
                     `bench_gate --require-baseline`, which fails on an empty \
                     baseline).\n",
                );
            }
        } else if self.rows.is_empty() {
            out.push_str(
                "No comparable baseline entries — gate passes vacuously. \
                 Refresh `BENCH_baseline.json` from a CI bench run to arm it.\n",
            );
        } else {
            out.push_str("| bench | baseline | current | Δ median | gate |\n");
            out.push_str("|---|---:|---:|---:|---|\n");
            for r in &self.rows {
                let verdict = if !r.gated {
                    "—"
                } else if r.delta_pct > self.max_regress_pct {
                    "**FAIL**"
                } else {
                    "ok"
                };
                out.push_str(&format!(
                    "| {} | {} | {} | {:+.1}% | {} |\n",
                    r.name,
                    fmt_ns(r.base_ns),
                    fmt_ns(r.cur_ns),
                    r.delta_pct,
                    verdict
                ));
            }
        }
        if !self.unmatched.is_empty() {
            out.push_str(&format!(
                "\n{} bench(es) without a baseline entry (not gated): {}\n",
                self.unmatched.len(),
                self.unmatched.join(", ")
            ));
        }
        if !self.missing.is_empty() {
            out.push_str(&format!(
                "\n⚠ {} baseline bench(es) missing from the current run \
                 (gated ones fail the job): {}\n",
                self.missing.len(),
                self.missing.join(", ")
            ));
        }
        if !self.dead_gate_substrings.is_empty() {
            out.push_str(&format!(
                "\n⚠ gate substring(s) matching zero benchmarks: {} — \
                 the gate may have lost coverage (renamed benches, or a \
                 stale baseline missing the new ones)\n",
                self.dead_gate_substrings.join(", ")
            ));
        }
        if self.passed() {
            out.push_str("\n**GATE OK**\n");
        } else {
            out.push_str(&format!(
                "\n**GATE FAILED** — {} regressed past the threshold\n",
                self.failures.join(", ")
            ));
        }
        out
    }
}

/// Compare two `swiftkv-bench-v1` JSON documents by median ns/op.
///
/// Every current benchmark that also appears in `baseline` becomes a
/// delta row; rows whose name contains **any** of the comma-separated
/// substrings in `gate_substr` (default `fused,gemm_w4a8`: the
/// fused-sweep hot paths plus the batch-amortized GEMM) fail the gate
/// when they regress by more than `max_regress_pct` percent.
/// Current-only benches (new ones) are reported but never gated;
/// baseline-only benches are reported, and the **gated** ones among
/// them fail — renaming or deleting a gated bench must come with a
/// baseline refresh, otherwise a 40% regression could hide behind a
/// rename.
pub fn compare_bench_json(
    baseline: &Json,
    current: &Json,
    gate_substr: &str,
    max_regress_pct: f64,
) -> Result<GateReport, String> {
    let is_gated = |name: &str| {
        gate_substr
            .split(',')
            .filter(|s| !s.is_empty())
            .any(|s| name.contains(s))
    };
    let entries = |doc: &Json, which: &str| -> Result<Vec<(String, f64)>, String> {
        let arr = doc
            .get("benchmarks")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("{which}: missing 'benchmarks' array"))?;
        let mut out = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{which}: benchmarks[{i}] has no name"))?;
            let median = e
                .get("median_ns")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{which}: '{name}' has no median_ns"))?;
            if median.is_nan() || median <= 0.0 {
                return Err(format!("{which}: '{name}' has non-positive median_ns"));
            }
            out.push((name.to_string(), median));
        }
        Ok(out)
    };
    let base: BTreeMap<String, f64> = entries(baseline, "baseline")?.into_iter().collect();
    let cur_entries = entries(current, "current")?;
    // surface gate substrings that gate nothing in either document — a
    // dead substring means a rename (or a stale baseline) silently
    // removed coverage from the gate
    let mut dead_gate_substrings = Vec::new();
    for s in gate_substr.split(',').filter(|s| !s.is_empty()) {
        if !base.keys().any(|n| n.contains(s)) {
            dead_gate_substrings.push(format!("`{s}` (no match in baseline)"));
        }
        if !cur_entries.iter().any(|(n, _)| n.contains(s)) {
            dead_gate_substrings.push(format!("`{s}` (no match in current)"));
        }
    }
    let mut report = GateReport {
        rows: Vec::new(),
        unmatched: Vec::new(),
        missing: Vec::new(),
        failures: Vec::new(),
        dead_gate_substrings,
        baseline_count: base.len(),
        baseline_placeholder: is_placeholder_doc(baseline),
        gate_substr: gate_substr.to_string(),
        max_regress_pct,
    };
    let mut seen: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for (name, cur_ns) in cur_entries {
        seen.insert(name.clone());
        match base.get(&name) {
            Some(&base_ns) => {
                let delta_pct = (cur_ns / base_ns - 1.0) * 100.0;
                let gated = is_gated(&name);
                if gated && delta_pct > max_regress_pct {
                    report.failures.push(name.clone());
                }
                report.rows.push(BenchDelta {
                    name,
                    base_ns,
                    cur_ns,
                    delta_pct,
                    gated,
                });
            }
            None => report.unmatched.push(name),
        }
    }
    for name in base.keys() {
        if !seen.contains(name) {
            if is_gated(name) {
                report.failures.push(format!("{name} (missing from current run)"));
            }
            report.missing.push(name.clone());
        }
    }
    Ok(report)
}

/// Human-friendly nanosecond formatting (criterion-style).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(10, 50);
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.median_ns > 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn ordering_of_workloads() {
        // a 10x bigger loop must measure meaningfully slower
        let mut b = Bencher::new(20, 100);
        let small = b
            .bench("small", || {
                let mut x = 0u64;
                for i in 0..50u64 {
                    x = x.wrapping_add(std::hint::black_box(i));
                }
                x
            })
            .median_ns;
        let large = b
            .bench("large", || {
                let mut x = 0u64;
                for i in 0..5000u64 {
                    x = x.wrapping_add(std::hint::black_box(i));
                }
                x
            })
            .median_ns;
        assert!(large > small * 3.0, "large {large} vs small {small}");
    }

    #[test]
    fn json_roundtrip() {
        let mut b = Bencher::new(5, 20);
        b.bench("alpha", || std::hint::black_box(3u64 * 7));
        b.bench("beta", || std::hint::black_box(11u64 + 2));
        let doc = b.to_json().to_string();
        let parsed = crate::util::Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("swiftkv-bench-v1"));
        let benches = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].get("name").unwrap().as_str(), Some("alpha"));
        assert!(benches[0].get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(b.get("beta").is_some());
        assert!(b.get("gamma").is_none());
    }

    #[test]
    fn annotations_survive_to_json() {
        let mut b = Bencher::new(5, 20);
        b.bench("kv_sweep", || std::hint::black_box(1u64 + 1));
        b.annotate("kv_sweep", "kv_bytes_per_token", 4096.0);
        b.annotate("kv_sweep", "group", 4.0);
        b.annotate("never_benched", "ignored", 1.0);
        assert_eq!(
            b.get("kv_sweep").unwrap().extras.get("kv_bytes_per_token"),
            Some(&4096.0)
        );
        let doc = b.to_json().to_string();
        let parsed = crate::util::Json::parse(&doc).unwrap();
        let benches = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        let extras = benches[0].get("extras").unwrap();
        assert_eq!(
            extras.get("kv_bytes_per_token").unwrap().as_f64(),
            Some(4096.0)
        );
        assert_eq!(extras.get("group").unwrap().as_f64(), Some(4.0));
    }

    fn gate_doc(entries: &[(&str, f64)]) -> Json {
        let benches = entries
            .iter()
            .map(|(name, ns)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.to_string()));
                m.insert("median_ns".to_string(), Json::Num(*ns));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str("swiftkv-bench-v1".into()));
        root.insert("benchmarks".to_string(), Json::Arr(benches));
        Json::Obj(root)
    }

    #[test]
    fn gate_passes_within_threshold_and_fails_beyond() {
        let base = gate_doc(&[
            ("hot/mha_fused 8h", 1000.0),
            ("hot/fxp_mha_fused 8h", 2000.0),
            ("hot/gemv_w4a8", 500.0),
        ]);
        // fused +10% → ok; other +80% → reported but never gated
        let ok = gate_doc(&[
            ("hot/mha_fused 8h", 1100.0),
            ("hot/fxp_mha_fused 8h", 2000.0),
            ("hot/gemv_w4a8", 900.0),
        ]);
        let r = compare_bench_json(&base, &ok, "fused", 15.0).unwrap();
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.rows.len(), 3);
        assert!(r.to_markdown().contains("GATE OK"));

        // fused +20% → gate failure
        let bad = gate_doc(&[("hot/mha_fused 8h", 1200.0), ("hot/fxp_mha_fused 8h", 2000.0)]);
        let r = compare_bench_json(&base, &bad, "fused", 15.0).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures, vec!["hot/mha_fused 8h".to_string()]);
        let md = r.to_markdown();
        assert!(md.contains("GATE FAILED"), "{md}");
        assert!(md.contains("**FAIL**"), "{md}");
        assert!(md.contains("+20.0%"), "{md}");
    }

    #[test]
    fn gate_fails_when_a_gated_baseline_bench_disappears() {
        // a renamed/deleted fused bench must not evade the gate; a
        // vanished ungated bench is only reported
        let base = gate_doc(&[("hot/mha_fused 8h", 1000.0), ("hot/gemv_w4a8", 500.0)]);
        let cur = gate_doc(&[("hot/mha_fused 8h renamed", 400.0)]);
        let r = compare_bench_json(&base, &cur, "fused", 15.0).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures, vec!["hot/mha_fused 8h (missing from current run)".to_string()]);
        assert_eq!(r.missing.len(), 2);
        assert_eq!(r.unmatched, vec!["hot/mha_fused 8h renamed".to_string()]);
        let md = r.to_markdown();
        assert!(md.contains("missing from the current run"), "{md}");
    }

    #[test]
    fn gate_matches_any_comma_separated_substring() {
        // the regression set is a union: fused sweeps AND the batched
        // GEMM entries are gated; everything else is only reported
        let base = gate_doc(&[
            ("hot/mha_fused 8h", 1000.0),
            ("hot/gemm_w4a8 512x512 batch=4", 800.0),
            ("hot/gemv_w4a8 512x512 lanes=4", 900.0),
        ]);
        let cur = gate_doc(&[
            ("hot/mha_fused 8h", 1000.0),
            ("hot/gemm_w4a8 512x512 batch=4", 1200.0), // +50% → gated FAIL
            ("hot/gemv_w4a8 512x512 lanes=4", 2000.0), // ungated, reported only
        ]);
        let r = compare_bench_json(&base, &cur, "fused,gemm_w4a8", 15.0).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures, vec!["hot/gemm_w4a8 512x512 batch=4".to_string()]);
        let gated: Vec<bool> = r.rows.iter().map(|row| row.gated).collect();
        // rows are in current-document order
        assert_eq!(gated, vec![true, true, false]);
        // a vanished gated GEMM bench fails too
        let r = compare_bench_json(
            &base,
            &gate_doc(&[("hot/mha_fused 8h", 1000.0)]),
            "fused,gemm_w4a8",
            15.0,
        )
        .unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("gemm_w4a8"));
    }

    #[test]
    fn gate_warns_on_substrings_matching_zero_benches() {
        // regression: a gate substring with no matching bench in either
        // document (rename, or a stale baseline predating new benches)
        // used to pass without a trace — now it is loudly reported
        let base = gate_doc(&[("hot/mha_fused 8h", 1000.0)]);
        let cur = gate_doc(&[
            ("hot/mha_fused 8h", 1000.0),
            ("simd/dot f32 d=768", 90.0), // new in current, absent in baseline
        ]);
        let r = compare_bench_json(&base, &cur, "fused,gemm_w4a8,simd/", 15.0).unwrap();
        assert!(r.passed(), "dead substrings warn, never fail");
        assert_eq!(
            r.dead_gate_substrings,
            vec![
                "`gemm_w4a8` (no match in baseline)".to_string(),
                "`gemm_w4a8` (no match in current)".to_string(),
                "`simd/` (no match in baseline)".to_string(),
            ]
        );
        let md = r.to_markdown();
        assert!(md.contains("matching zero benchmarks"), "{md}");
        assert!(md.contains("`gemm_w4a8` (no match in current)"), "{md}");
        // fully-covered substrings stay quiet
        let r = compare_bench_json(&cur, &cur, "fused,simd/", 15.0).unwrap();
        assert!(r.dead_gate_substrings.is_empty());
        assert!(!r.to_markdown().contains("matching zero benchmarks"));
    }

    #[test]
    fn gate_is_vacuous_without_baseline_entries() {
        let base = gate_doc(&[]);
        let cur = gate_doc(&[("hot/mha_fused 8h", 1200.0)]);
        let r = compare_bench_json(&base, &cur, "fused", 15.0).unwrap();
        assert!(r.passed());
        assert!(r.rows.is_empty());
        assert!(r.baseline_empty());
        assert_eq!(r.baseline_count, 0);
        assert_eq!(r.unmatched, vec!["hot/mha_fused 8h".to_string()]);
        // the empty-baseline state must be impossible to miss in the
        // job summary
        let md = r.to_markdown();
        assert!(md.contains("BASELINE EMPTY"), "{md}");
        assert!(md.contains("vacuous"), "{md}");
    }

    #[test]
    fn placeholder_baseline_is_distinguished_from_stripped_one() {
        // the committed seed baseline: zero benchmarks + a note
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str("swiftkv-bench-v1".into()));
        root.insert("benchmarks".to_string(), Json::Arr(vec![]));
        root.insert(
            "note".to_string(),
            Json::Str("placeholder - refresh from a CI bench run".into()),
        );
        let placeholder = Json::Obj(root);
        assert!(is_placeholder_doc(&placeholder));
        // empty-but-noteless = stripped, not placeholder
        assert!(!is_placeholder_doc(&gate_doc(&[])));
        // an armed doc is neither
        assert!(!is_placeholder_doc(&gate_doc(&[("a", 1.0)])));

        let cur = gate_doc(&[("hot/mha_fused 8h", 1200.0)]);
        let r = compare_bench_json(&placeholder, &cur, "fused", 15.0).unwrap();
        assert!(r.baseline_empty() && r.baseline_placeholder);
        let md = r.to_markdown();
        assert!(md.contains("BASELINE PLACEHOLDER"), "{md}");
        assert!(md.contains("never armed"), "{md}");
        // the stripped state keeps the corruption warning instead
        let r = compare_bench_json(&gate_doc(&[]), &cur, "fused", 15.0).unwrap();
        assert!(r.baseline_empty() && !r.baseline_placeholder);
        assert!(r.to_markdown().contains("BASELINE EMPTY"));
    }

    #[test]
    fn external_measurements_reach_json_and_merge() {
        let mut b = Bencher::new(5, 20);
        b.bench("hot/mha_fused tiny", || std::hint::black_box(6u64 * 7));
        b.record(
            Measurement::external("serve/loadgen p99 rate=100", 2.5e6, 32)
                .with_extra("tok_per_s", 4000.0),
        );
        let doc = Json::parse(&b.to_json().to_string()).unwrap();
        let benches = doc.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(
            benches[1].get("name").unwrap().as_str(),
            Some("serve/loadgen p99 rate=100")
        );
        assert_eq!(
            benches[1].get("extras").unwrap().get("tok_per_s").unwrap().as_f64(),
            Some(4000.0)
        );

        // merge into a placeholder file: note dropped, entries armed;
        // second merge replaces by name and keeps the kernel entry
        let path = std::env::temp_dir().join(format!(
            "swiftkv_bench_merge_{}.json",
            std::process::id()
        ));
        std::fs::write(
            &path,
            "{\"schema\":\"swiftkv-bench-v1\",\"benchmarks\":[],\"note\":\"placeholder\"}\n",
        )
        .unwrap();
        merge_into_json_file(&path, b.results()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(doc.get("note").is_none(), "armed file drops the note");
        assert_eq!(doc.get("benchmarks").unwrap().as_arr().unwrap().len(), 2);
        assert!(!is_placeholder_doc(&doc));

        let update = [Measurement::external("serve/loadgen p99 rate=100", 9.9e6, 64)];
        merge_into_json_file(&path, &update).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let benches = doc.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2, "replaced by name, kernel entry kept");
        let serve = benches
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("serve/loadgen p99 rate=100"))
            .unwrap();
        assert_eq!(serve.get("median_ns").unwrap().as_f64(), Some(9.9e6));
        assert!(benches
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("hot/mha_fused tiny")));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nonempty_baseline_reports_count_and_no_empty_warning() {
        let base = gate_doc(&[("hot/mha_fused 8h", 1000.0)]);
        let cur = gate_doc(&[("hot/mha_fused 8h", 1000.0)]);
        let r = compare_bench_json(&base, &cur, "fused", 15.0).unwrap();
        assert!(!r.baseline_empty());
        assert_eq!(r.baseline_count, 1);
        assert!(!r.to_markdown().contains("BASELINE EMPTY"));
    }

    #[test]
    fn gate_rejects_malformed_documents() {
        let good = gate_doc(&[("a", 1.0)]);
        assert!(compare_bench_json(&Json::Null, &good, "fused", 15.0).is_err());
        assert!(compare_bench_json(&good, &gate_doc(&[("a", 0.0)]), "fused", 15.0).is_err());
    }

    #[test]
    fn gate_report_roundtrips_through_real_bencher_json() {
        // the gate must consume exactly what Bencher::to_json emits
        let mut b = Bencher::new(5, 20);
        b.bench("hot/mha_fused tiny", || std::hint::black_box(6u64 * 7));
        let doc = Json::parse(&b.to_json().to_string()).unwrap();
        let r = compare_bench_json(&doc, &doc, "fused", 15.0).unwrap();
        assert!(r.passed());
        assert_eq!(r.rows.len(), 1);
        assert!(r.rows[0].gated);
        assert!(r.rows[0].delta_pct.abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
