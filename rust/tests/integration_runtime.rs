//! Integration: the PJRT runtime executing the AOT artifacts, cross-checked
//! against the pure-Rust tiny model (same weights, desktop numerics).
//!
//! These tests exercise the full L2→L3 seam: JAX-lowered HLO (with the
//! Pallas kernels inside) compiled and run by the `xla` crate, fed by the
//! weight blob the Python side dumped. Skipped when `make artifacts` has
//! not been run; compiled only with the `pjrt` feature (the `xla` crate
//! closure must be vendored).
#![cfg(feature = "pjrt")]

use swiftkv::attention::{native, HeadProblem};
use swiftkv::model::{tiny, NumericsMode, TinyModel, WeightStore};
use swiftkv::runtime::{artifacts_available, default_artifacts_dir, Engine};
use swiftkv::util::Rng;

fn engine() -> Option<Engine> {
    artifacts_available().then(|| Engine::load(&default_artifacts_dir()).unwrap())
}

fn rust_model() -> TinyModel {
    TinyModel::load(&WeightStore::load(&default_artifacts_dir()).unwrap()).unwrap()
}

#[test]
fn pjrt_decode_matches_rust_reference() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let tm = rust_model();
    let mut st = eng.new_state(1).unwrap();
    let mut rst = tm.new_state();
    for (i, &t) in [3u32, 141, 27, 9, 400, 13].iter().enumerate() {
        let lg = eng.decode_step(&mut st, &[t as i32], &[i as i32]).unwrap();
        let lr = tm.decode_step(&mut rst, t, NumericsMode::DesktopF32);
        assert_eq!(lg.len(), lr.len());
        // identical weights; desktop-rust reproduces the JAX graph up to
        // f32 reduction-order noise — top-1 must agree and logits be close
        let max_abs = lr.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1.0);
        let max_diff = lg
            .iter()
            .zip(&lr)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(
            max_diff / max_abs < 0.05,
            "step {i}: PJRT and rust logits diverge: {max_diff}"
        );
        assert_eq!(
            tiny::argmax(&lg),
            tiny::argmax(&lr),
            "top-1 disagrees at step {i}"
        );
    }
}

#[test]
fn pjrt_batched_decode_lanes_independent() {
    let Some(eng) = engine() else {
        return;
    };
    // decode the same token stream in lane 0 of a b2 batch and solo b1:
    // results must match exactly (batching must not mix lanes)
    let mut solo = eng.new_state(1).unwrap();
    let mut duo = eng.new_state(2).unwrap();
    for (i, &t) in [5u32, 9, 100].iter().enumerate() {
        let a = eng.decode_step(&mut solo, &[t as i32], &[i as i32]).unwrap();
        let b = eng
            .decode_step(&mut duo, &[t as i32, 77], &[i as i32, i as i32])
            .unwrap();
        let vocab = eng.manifest.vocab;
        for (x, y) in a.iter().zip(&b[..vocab]) {
            assert!((x - y).abs() < 1e-4, "lane 0 diverges at step {i}");
        }
    }
}

#[test]
fn pjrt_attention_artifact_matches_native() {
    let Some(eng) = engine() else {
        return;
    };
    let (rows, n_ctx, d) = (8usize, 512usize, 32usize);
    let mut rng = Rng::seed_from_u64(11);
    let q = rng.uniform_vec(rows * d, 1.0);
    let k = rng.uniform_vec(rows * n_ctx * d, 1.0);
    let v = rng.uniform_vec(rows * n_ctx * d, 1.0);
    let lens: Vec<i32> = (0..rows).map(|i| (i * 64 + 17) as i32).collect();

    let got = eng.attention(&lens, &q, &k, &v, rows, n_ctx, d).unwrap();
    for r in 0..rows {
        let len = lens[r] as usize;
        let p = HeadProblem::new(
            &q[r * d..(r + 1) * d],
            &k[r * n_ctx * d..(r + 1) * n_ctx * d],
            &v[r * n_ctx * d..(r + 1) * n_ctx * d],
            d,
            len,
        );
        let want = native::attend(&p);
        for (i, (a, b)) in got[r * d..(r + 1) * d].iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "row {r} dim {i}: pallas-HLO {a} vs native {b}"
            );
        }
    }
}

#[test]
fn greedy_generation_pjrt_vs_rust() {
    let Some(eng) = engine() else {
        return;
    };
    let tm = rust_model();
    let prompt = [1u32, 2, 3, 4];
    // rust reference generation
    let want = tm.generate(&prompt, 8, NumericsMode::DesktopF32);
    // PJRT generation
    let mut st = eng.new_state(1).unwrap();
    let mut logits = Vec::new();
    for (i, &t) in prompt.iter().enumerate() {
        logits = eng.decode_step(&mut st, &[t as i32], &[i as i32]).unwrap();
    }
    let mut got = Vec::new();
    let mut pos = prompt.len();
    for _ in 0..8 {
        let next = tiny::argmax(&logits) as u32;
        got.push(next);
        logits = eng
            .decode_step(&mut st, &[next as i32], &[pos as i32])
            .unwrap();
        pos += 1;
    }
    assert_eq!(got, want.as_slice(), "greedy decode paths diverge");
}
