//! Property: client cancellation is surgical. A cancelled lane stops at
//! the next iteration boundary with [`SessionOutcome::Cancelled`], its
//! KV blocks return to the pool, and co-batched survivors finish
//! bit-identical to their solo `generate()` runs — across both numerics
//! modes and paged-KV block lengths {1, 3, 16}.
//!
//! Two cancellation triggers are exercised: the injected
//! `disconnect@r:s` fault (deterministic: the client "vanishes" after
//! exactly `s` streamed tokens) and the organic path (the test drops
//! its [`PendingRequest`] so the engine's `try_send` sees a
//! disconnected stream).

use swiftkv::coordinator::{CpuServer, FaultPlan, ServeConfig, SessionOutcome};
use swiftkv::model::{NumericsMode, Request, TinyModel};

fn model() -> TinyModel {
    TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48)
}

fn req(id: u64, prompt: Vec<u32>, gen_len: usize) -> Request {
    Request::new(id, prompt).gen_len(gen_len)
}

fn opts(lanes: usize, mode: NumericsMode, block_len: usize) -> ServeConfig {
    let mut o = ServeConfig::builder()
        .lanes(lanes)
        .mode(mode)
        .max_iterations(10_000)
        .build()
        .expect("test serve config is valid");
    o.kv_block_len = block_len;
    o
}

fn assert_pool_reclaimed(report: &swiftkv::coordinator::CpuServeReport) {
    assert_eq!(
        report.kv_pool.free_blocks(),
        report.kv_pool.total_blocks(),
        "cancellation leaked KV blocks"
    );
}

#[test]
fn injected_disconnect_cancels_victim_survivors_bit_exact() {
    // 3 co-batched lanes, the client for request 1 disconnects after 2
    // streamed tokens. Sweep both numerics modes and block lengths so
    // the reclaim path is exercised at 1-token granularity, mid-block,
    // and whole-block.
    let tm = model();
    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        for block_len in [1usize, 3, 16] {
            let mut o = opts(3, mode, block_len);
            o.faults = Some(FaultPlan::parse("disconnect@r1:s2").expect("spec parses"));
            let server = CpuServer::new(&tm, o);
            let (report, finished) = server.serve_continuous(|handle| {
                let pending: Vec<_> = (0..3u64)
                    .map(|i| {
                        handle
                            .submit(req(i, vec![1 + i as u32], 8))
                            .expect("engine accepts while the handle is live")
                    })
                    .collect();
                pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
            });

            let ctx = format!("mode {mode:?} block_len {block_len}");
            assert_eq!(finished.len(), 3, "{ctx}: a request vanished");
            assert_eq!(report.metrics.requests_cancelled, 1, "{ctx}");
            assert_eq!(report.metrics.requests_failed, 0, "{ctx}");
            for fin in &finished {
                let solo = tm.generate(&[1 + fin.id as u32], 8, mode);
                if fin.id == 1 {
                    assert_eq!(
                        fin.outcome,
                        SessionOutcome::Cancelled,
                        "{ctx}: the disconnected request must be cancelled"
                    );
                    // the client saw exactly the 2 pre-disconnect tokens,
                    // and they are the solo prefix
                    assert_eq!(fin.tokens.len(), 2, "{ctx}: streamed past the disconnect");
                    assert_eq!(fin.tokens, solo[..2], "{ctx}: pre-cancel tokens diverged");
                } else {
                    assert!(
                        fin.outcome.is_completed(),
                        "{ctx}: request {} must complete, got {:?}",
                        fin.id,
                        fin.outcome
                    );
                    assert_eq!(
                        fin.tokens, solo,
                        "{ctx}: request {}: a co-batched cancel perturbed its stream",
                        fin.id
                    );
                }
            }
            assert_pool_reclaimed(&report);
        }
    }
}

#[test]
fn dropped_pending_request_cancels_organically() {
    // No fault plan: the test simply drops the victim's PendingRequest.
    // The engine's next `try_send` observes the disconnected stream and
    // cancels the lane at the following iteration boundary; the
    // surviving lane must stay bit-exact and the pool must drain.
    let tm = model();
    for block_len in [1usize, 3, 16] {
        let o = opts(2, NumericsMode::DesktopF32, block_len);
        let server = CpuServer::new(&tm, o);
        let (report, survivor) = server.serve_continuous(|handle| {
            let victim = handle
                .submit(req(0, vec![3], 40))
                .expect("engine accepts while the handle is live");
            let keeper = handle
                .submit(req(1, vec![5], 8))
                .expect("engine accepts while the handle is live");
            drop(victim);
            keeper.wait()
        });

        let ctx = format!("block_len {block_len}");
        assert!(survivor.outcome.is_completed(), "{ctx}: survivor must complete");
        let solo = tm.generate(&[5], 8, NumericsMode::DesktopF32);
        assert_eq!(survivor.tokens, solo, "{ctx}: organic cancel perturbed the survivor");

        let victim = report
            .sessions
            .iter()
            .find(|s| s.request.id == 0)
            .expect("victim session accounted for");
        assert_eq!(
            victim.outcome,
            SessionOutcome::Cancelled,
            "{ctx}: dropped stream must cancel the lane"
        );
        // cancelled at an iteration boundary: whatever ran is a solo prefix
        let solo_victim = tm.generate(&[3], 40, NumericsMode::DesktopF32);
        assert!(
            victim.generated.len() < 40,
            "{ctx}: victim ran to completion despite the dropped stream"
        );
        assert_eq!(
            victim.generated,
            solo_victim[..victim.generated.len()],
            "{ctx}: victim's partial output diverged from its solo prefix"
        );
        assert_eq!(report.metrics.requests_cancelled, 1, "{ctx}");
        assert_pool_reclaimed(&report);
    }
}

#[test]
fn cancel_then_reuse_lane_admits_queued_request_bit_exact() {
    // 2 lanes, 3 requests: the victim's disconnect frees its lane and
    // the queued third request must ride the recycled slot to a
    // bit-exact completion (reset_for_reuse left no stale KV behind).
    let tm = model();
    let mut o = opts(2, NumericsMode::DesktopF32, 3);
    o.faults = Some(FaultPlan::parse("disconnect@r0:s1").expect("spec parses"));
    let server = CpuServer::new(&tm, o);
    let (report, finished) = server.serve_continuous(|handle| {
        let pending: Vec<_> = (0..3u64)
            .map(|i| {
                handle
                    .submit(req(i, vec![1 + i as u32], 8))
                    .expect("engine accepts while the handle is live")
            })
            .collect();
        pending.into_iter().map(|p| p.wait()).collect::<Vec<_>>()
    });

    assert_eq!(finished.len(), 3);
    assert_eq!(report.metrics.requests_cancelled, 1);
    for fin in &finished {
        let solo = tm.generate(&[1 + fin.id as u32], 8, NumericsMode::DesktopF32);
        if fin.id == 0 {
            assert_eq!(fin.outcome, SessionOutcome::Cancelled);
            assert_eq!(fin.tokens, solo[..1], "pre-cancel token diverged");
        } else {
            assert!(fin.outcome.is_completed(), "request {} must complete", fin.id);
            assert_eq!(fin.tokens, solo, "request {} perturbed", fin.id);
        }
    }
    assert_pool_reclaimed(&report);
}
