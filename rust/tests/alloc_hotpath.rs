//! Steady-state allocation audit: a counting global allocator asserts
//! that the fused decode hot path performs **zero heap allocation** —
//! the acceptance gate of the fused-kernel PR.
//!
//! One test binary, one `#[test]`: the harness runs it on a single test
//! thread, so the counter observes only this path (a retry loop absorbs
//! any one-off runtime allocation that lands mid-measurement).
//!
//! Excluded under Miri: a `#[global_allocator]` hooking every allocation
//! is noise for the interpreter, and the CI Miri tier pins
//! `SWIFTKV_ISA=scalar` where the allocation claims are already covered
//! by the native runs.

#![cfg(not(miri))]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::{BlockTable, FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::model::{BatchLane, NumericsMode, TinyModel};
use swiftkv::quant::{gemm_w4a8_raw_into, quantize_int8_into, Int4Matrix, QuantLinear};
use swiftkv::util::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

// SAFETY: pure pass-through to the System allocator — every contract
// (layout validity, pointer provenance) is forwarded unchanged; the
// counter bump has no allocator-visible effect.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same contract as `GlobalAlloc::alloc`; body only counts
    // and forwards.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout contract the caller gave us.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: same contract as `GlobalAlloc::dealloc`; pure forward.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: ptr/layout come straight from the caller's contract
        // with this allocator, which System.alloc produced.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: same contract as `GlobalAlloc::realloc`; counts and
    // forwards.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded unchanged from the caller's contract.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// Run `f` up to `tries` times; pass if any run completes without a
/// single allocation. Returns the smallest delta observed.
fn min_allocs(tries: usize, mut f: impl FnMut()) -> usize {
    let mut best = usize::MAX;
    for _ in 0..tries {
        let before = alloc_count();
        f();
        let delta = alloc_count() - before;
        best = best.min(delta);
        if best == 0 {
            break;
        }
    }
    best
}

#[test]
fn fused_decode_hot_path_is_allocation_free() {
    // --- kernel level: fused MHA sweeps over preallocated buffers ------
    let mut rng = Rng::seed_from_u64(9);
    let (h, d, len) = (8usize, 64usize, 128usize);
    let scale = 1.0 / (d as f32).sqrt();
    let q = rng.uniform_vec(h * d, 1.0);
    let k = rng.uniform_vec(len * h * d, 1.0);
    let v = rng.uniform_vec(len * h * d, 1.0);
    let mut mha = MhaSwiftKv::new(h, d);
    let mut out = vec![0.0f32; h * d];
    // warm up once (first call may touch lazy runtime state)
    mha.attend(&q, &k, &v, len, scale, &mut out);
    let f32_allocs = min_allocs(5, || {
        mha.attend(&q, &k, &v, len, scale, &mut out);
    });
    assert_eq!(f32_allocs, 0, "fused f32 MHA sweep allocated");

    let lut = Exp2Lut::new();
    let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
    let qq = vector::quantize(&q);
    let kq = vector::quantize(&k);
    let vq = vector::quantize(&v);
    let mut fxp_mha = FxpMhaSwiftKv::new(h, d);
    let mut fout = vec![Fxp32::ZERO; h * d];
    fxp_mha.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fout);
    let fxp_allocs = min_allocs(5, || {
        fxp_mha.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fout);
    });
    assert_eq!(fxp_allocs, 0, "fused FXP32 MHA sweep allocated");

    // --- kernel level, grouped-query: 8 query heads over 2 KV heads ----
    let hkv = 2usize;
    let kg = rng.uniform_vec(len * hkv * d, 1.0);
    let vg = rng.uniform_vec(len * hkv * d, 1.0);
    let mut gqa = MhaSwiftKv::new_grouped(h, hkv, d);
    gqa.attend(&q, &kg, &vg, len, scale, &mut out);
    let gqa_allocs = min_allocs(5, || {
        gqa.attend(&q, &kg, &vg, len, scale, &mut out);
    });
    assert_eq!(gqa_allocs, 0, "fused f32 GQA sweep allocated");

    let kgq = vector::quantize(&kg);
    let vgq = vector::quantize(&vg);
    let mut gqa_fxp = FxpMhaSwiftKv::new_grouped(h, hkv, d);
    gqa_fxp.attend(&lut, &qq, &kgq, &vgq, len, fscale, &mut fout);
    let gqa_fxp_allocs = min_allocs(5, || {
        gqa_fxp.attend(&lut, &qq, &kgq, &vgq, len, fscale, &mut fout);
    });
    assert_eq!(gqa_fxp_allocs, 0, "fused FXP32 GQA sweep allocated");

    // --- kernel level, paged: block-gathered sweeps over a prebuilt
    // table (block_len 16 → the 128-row walk crosses 8 blocks) ----------
    let paged_pool = swiftkv::kernels::BlockPool::new(len.div_ceil(16), 16, hkv * d);
    let mut ptable = BlockTable::new(&paged_pool, len);
    ptable.ensure_tokens(&paged_pool, len);
    for t in 0..len {
        let row = hkv * d;
        ptable.k_row_mut(t).copy_from_slice(&kg[t * row..(t + 1) * row]);
        ptable.v_row_mut(t).copy_from_slice(&vg[t * row..(t + 1) * row]);
        ptable.quantize_row(t);
    }
    gqa.reset();
    gqa.extend_paged(&q, &ptable, 0, len, scale);
    gqa.finalize_into(&mut out);
    let paged_allocs = min_allocs(5, || {
        gqa.reset();
        gqa.extend_paged(&q, &ptable, 0, len, scale);
        gqa.finalize_into(&mut out);
    });
    assert_eq!(paged_allocs, 0, "paged f32 GQA sweep allocated");
    let paged_fxp_allocs = min_allocs(5, || {
        gqa_fxp.reset();
        gqa_fxp.extend_paged(&lut, &qq, &ptable, 0, len, fscale);
        gqa_fxp.finalize_into(&mut fout);
    });
    assert_eq!(paged_fxp_allocs, 0, "paged FXP32 GQA sweep allocated");
    ptable.release_into(&paged_pool);

    // --- dispatch level: the runtime-selected SIMD microkernels called
    // straight through the table — neither the calls nor the dispatch
    // itself (a OnceLock read, detection runs exactly once per process)
    // may allocate ---------------------------------------------------
    {
        use swiftkv::kernels::isa;
        let t = isa::active();
        let detections_before = isa::detections();
        let a8 = rng.uniform_vec(67, 1.0);
        let b8 = rng.uniform_vec(67, 1.0);
        let mut y8 = rng.uniform_vec(67, 1.0);
        let fa = vector::quantize(&a8);
        let fb = vector::quantize(&b8);
        let mut fy = vector::quantize(&y8);
        let i8a: Vec<i8> = (0..67).map(|i| (i as i8).wrapping_mul(37)).collect();
        let i8b: Vec<i8> = (0..67).map(|i| (i as i8).wrapping_mul(53)).collect();
        let dispatch_allocs = min_allocs(5, || {
            let _ = swiftkv::kernels::dot(&a8, &b8);
            let _ = (t.dot_f32)(&a8, &b8);
            (t.axpy_f32)(0.5, &mut y8, &b8);
            let _ = (t.dot_fxp_wide)(&fa, &fb);
            (t.axpy_fxp)(Fxp32::from_f64(0.5), &mut fy, &fb);
            let _ = (t.dot_i8)(&i8a, &i8b);
            let _ = isa::active();
        });
        assert_eq!(dispatch_allocs, 0, "dispatched microkernels allocated");
        assert_eq!(
            isa::detections(),
            detections_before,
            "ISA detection re-ran on the hot path"
        );
    }

    // --- GEMV level: forward_into through caller scratch ---------------
    let w = rng.uniform_vec(64 * 96, 0.5);
    let lin = QuantLinear::new(Int4Matrix::quantize(&w, 64, 96));
    let x = rng.uniform_vec(64, 1.0);
    let mut qbuf = vec![0i8; 64];
    let mut gout = vec![0.0f32; 96];
    lin.forward_into(&x, &mut qbuf, &mut gout);
    let gemv_allocs = min_allocs(5, || {
        lin.forward_into(&x, &mut qbuf, &mut gout);
    });
    assert_eq!(gemv_allocs, 0, "forward_into allocated");

    // --- GEMM level: one shared weight pass over 4 activation rows -----
    {
        let b = 4usize;
        let mut qrows = vec![0i8; b * 64];
        let mut scales = vec![0.0f32; b];
        for i in 0..b {
            let xr = rng.uniform_vec(64, 1.0);
            scales[i] = quantize_int8_into(&xr, &mut qrows[i * 64..(i + 1) * 64]);
        }
        let mut bout = vec![0.0f32; b * 96];
        gemm_w4a8_raw_into(&qrows, &scales, &lin.weight, &mut bout);
        let gemm_allocs = min_allocs(5, || {
            gemm_w4a8_raw_into(&qrows, &scales, &lin.weight, &mut bout);
        });
        assert_eq!(gemm_allocs, 0, "batched GEMM allocated");
    }

    // --- model level: a steady-state decode step, both numerics modes,
    // MHA and grouped-query (8q/2kv-style group of 2 on the tiny shape) --
    let tm = TinyModel::synthetic(3, 64, 32, 4, 4, 2, 64, 48);
    let tg = TinyModel::synthetic(3, 64, 32, 4, 2, 2, 64, 48);
    for (label, m) in [("mha", &tm), ("gqa", &tg)] {
        let mut logits = vec![0.0f32; m.vocab];
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            // warm up: prime the caches / branch predictors, leave headroom
            // so the measured steps stay inside the context window
            for t in 0..8u32 {
                m.decode_step_into(&mut st, t % m.vocab as u32, mode, &mut logits);
            }
            let mut t = 8u32;
            let step_allocs = min_allocs(5, || {
                m.decode_step_into(&mut st, t % m.vocab as u32, mode, &mut logits);
                t += 1;
            });
            assert_eq!(
                step_allocs, 0,
                "steady-state {label} decode step allocated in {mode:?}"
            );
        }
    }

    // --- model level, chunked prefill: after the chunk scratch is
    // warmed up (first prefill grows it once), steady-state prefill
    // chunks — multi-token causal sweeps, logits for the last token
    // only — must be allocation-free in both numerics modes -------------
    for (label, m) in [("mha", &tm), ("gqa", &tg)] {
        let mut logits = vec![0.0f32; m.vocab];
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut st = m.new_state();
            // warm up: grows the chunk scratch to 4 tokens and primes the
            // runtime; leaves ≤ 28 of the 48 context positions used
            m.prefill_into(&mut st, &[1, 2, 3, 4], mode, Some(&mut logits[..]));
            m.prefill_into(&mut st, &[5, 6, 7, 8], mode, None);
            let mut t = 9u32;
            let prefill_allocs = min_allocs(5, || {
                let v = m.vocab as u32;
                let chunk = [t % v, (t + 1) % v, (t + 2) % v, (t + 3) % v];
                m.prefill_into(&mut st, &chunk, mode, Some(&mut logits[..]));
                t += 4;
            });
            assert_eq!(
                prefill_allocs, 0,
                "steady-state {label} chunked prefill allocated in {mode:?}"
            );
        }
    }

    // --- model level, block boundaries: with 2-token blocks every other
    // step checks a fresh block out of the (pre-allocated) pool — that
    // crossing must also be allocation-free after warm-up ---------------
    {
        let m = &tg;
        let mut logits = vec![0.0f32; m.vocab];
        let pool = m.new_pool(m.blocks_per_seq(2), 2);
        let mut st = m.new_state_in(pool);
        for t in 0..8u32 {
            m.decode_step_into(&mut st, t % m.vocab as u32, NumericsMode::Accelerator, &mut logits);
        }
        let mut t = 8u32;
        // two steps per measurement: with block_len 2 every pair checks
        // exactly one fresh block per layer out of the pool
        let crossing_allocs = min_allocs(5, || {
            for _ in 0..2 {
                m.decode_step_into(
                    &mut st,
                    t % m.vocab as u32,
                    NumericsMode::Accelerator,
                    &mut logits,
                );
                t += 1;
            }
        });
        assert_eq!(
            crossing_allocs, 0,
            "decode step allocated while crossing KV block boundaries"
        );
    }

    // --- model level, batched decode: 3 lanes sharing one weight pass
    // per projection (decode_steps_into). After the batch scratch is
    // grown once, steady-state batched steps must be allocation-free in
    // both numerics modes (pool=None keeps the audit on this thread) ----
    for (label, m) in [("mha", &tm), ("gqa", &tg)] {
        let mut batch = m.new_batch_scratch();
        let mut states = [m.new_state(), m.new_state(), m.new_state()];
        let mut logits = vec![0.0f32; 3 * m.vocab];
        for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
            let mut t = 0u32;
            let step = |states: &mut [swiftkv::model::DecodeState; 3],
                            logits: &mut [f32],
                            batch: &mut swiftkv::kernels::BatchScratch,
                            t: &mut u32| {
                let v = m.vocab as u32;
                let [s0, s1, s2] = states;
                let (l0, rest) = logits.split_at_mut(m.vocab);
                let (l1, l2) = rest.split_at_mut(m.vocab);
                let mut lanes = [
                    BatchLane {
                        state: s0,
                        token: *t % v,
                        logits: l0,
                    },
                    BatchLane {
                        state: s1,
                        token: (*t + 1) % v,
                        logits: l1,
                    },
                    BatchLane {
                        state: s2,
                        token: (*t + 2) % v,
                        logits: l2,
                    },
                ];
                m.decode_steps_into(&mut lanes, mode, batch, None);
                *t += 3;
            };
            // warm up: grows the batch scratch once and primes the
            // runtime; leaves headroom inside the 48-token context
            for _ in 0..4 {
                step(&mut states, &mut logits[..], &mut batch, &mut t);
            }
            let batched_allocs = min_allocs(5, || {
                step(&mut states, &mut logits[..], &mut batch, &mut t);
            });
            assert_eq!(
                batched_allocs, 0,
                "steady-state {label} batched decode step allocated in {mode:?}"
            );
        }
    }
}
