//! FXP32 **Q15.17** fixed-point arithmetic — the paper's attention datapath.
//!
//! SwiftKV runs the whole attention recurrence (Eqs. 5–8) in 32-bit
//! fixed point with 17 fractional bits so that the multiply–accumulate
//! units used for low-bit integer GEMV can be reused for high-precision
//! attention (§III, §IV-B). This module is the *bit-exact software model*
//! of that datapath:
//!
//! - [`q1517::Fxp32`] — saturating Q15.17 scalar arithmetic,
//! - [`exp2lut::Exp2Lut`] — the shift + 5-bit-LUT + linear-interpolation
//!   exponential of Eqs. (9)–(10),
//! - [`vector`] — dot products and AXPY-style vector updates as executed
//!   by the Public MAC Array.

pub mod exp2lut;
pub mod q1517;
pub mod vector;

pub use exp2lut::Exp2Lut;
pub use q1517::{Fxp32, FRAC_BITS, ONE, RESOLUTION};
