//! The decode engine: compiled executables + resident weights + per-batch
//! state.

// Lock/slot unwraps here predate the crate-wide `unwrap_used` deny; the
// module is `pjrt`-feature-gated (off by default, never in the serving
// path), so it keeps a local exemption instead of forcing the audit.
#![allow(clippy::unwrap_used)]

use crate::model::weights::{TinyManifest, WeightStore};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Per-batch decode state: KV caches and RoPE recurrence values, kept as
/// host literals and threaded through `execute` each step (the tiny model
/// state is a few MB; see DESIGN.md §Perf for the measured step cost).
pub struct BatchState {
    pub batch: usize,
    kc: Literal,
    vc: Literal,
    cos: Literal,
    sin: Literal,
    /// Decode steps taken (positions consumed per lane are tracked by the
    /// coordinator; this is for diagnostics).
    pub steps: u64,
}

/// The PJRT decode engine.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: TinyManifest,
    /// Lazily compiled decode executables, keyed by batch size.
    decode: Mutex<BTreeMap<usize, PjRtLoadedExecutable>>,
    /// Lazily compiled attention-only executable (quickstart artifact).
    attn: Mutex<Option<PjRtLoadedExecutable>>,
    /// Weight literals in HLO-signature order.
    weights: Vec<Literal>,
}

impl Engine {
    /// Load manifest + weights and create the PJRT CPU client. Executables
    /// compile lazily on first use.
    pub fn load(dir: &Path) -> Result<Engine> {
        let ws = WeightStore::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e}"))?;
        let mut weights = Vec::with_capacity(ws.arrays().len());
        for meta in ws.arrays() {
            let ty = match meta.dtype.as_str() {
                "float32" => ElementType::F32,
                "int8" => ElementType::S8,
                "int32" => ElementType::S32,
                other => bail!("unsupported dtype {other} for {}", meta.name),
            };
            let lit = Literal::create_from_shape_and_untyped_data(
                ty,
                &meta.shape,
                ws.bytes(&meta.name)?,
            )
            .map_err(|e| anyhow!("literal {}: {e}", meta.name))?;
            weights.push(lit);
        }
        Ok(Engine {
            client,
            dir: dir.to_path_buf(),
            manifest: ws.manifest,
            decode: Mutex::new(BTreeMap::new()),
            attn: Mutex::new(None),
            weights,
        })
    }

    fn compile_file(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))
    }

    /// Batch sizes with a compiled decode variant available.
    pub fn batch_variants(&self) -> &[usize] {
        &self.manifest.batch_variants
    }

    /// Smallest compiled batch variant that fits `n` lanes.
    pub fn pick_batch(&self, n: usize) -> Option<usize> {
        self.manifest
            .batch_variants
            .iter()
            .copied()
            .find(|&b| b >= n)
            .or_else(|| self.manifest.batch_variants.last().copied())
    }

    fn ensure_decode(&self, batch: usize) -> Result<()> {
        let mut map = self.decode.lock().unwrap();
        if map.contains_key(&batch) {
            return Ok(());
        }
        let file = format!("tiny_decode_b{batch}.hlo.txt");
        let exe = self
            .compile_file(&file)
            .with_context(|| format!("decode variant b{batch}"))?;
        map.insert(batch, exe);
        Ok(())
    }

    /// Fresh zeroed state for a batch variant.
    pub fn new_state(&self, batch: usize) -> Result<BatchState> {
        if !self.manifest.batch_variants.contains(&batch) {
            bail!("no compiled variant for batch {batch}");
        }
        let m = &self.manifest;
        let cache_elems = batch * m.n_layers * m.n_heads * m.n_ctx * m.d_head;
        let half = m.d_head / 2;
        let kc = Literal::vec1(vec![0f32; cache_elems].as_slice()).reshape(&[
            batch as i64,
            m.n_layers as i64,
            m.n_heads as i64,
            m.n_ctx as i64,
            m.d_head as i64,
        ])?;
        let vc = kc.clone_literal()?;
        // RoPE seed: one step before position 0 — cos(−θ)=a, sin(−θ)=−b
        let freqs: Vec<f64> = crate::rope::rope_freqs(m.d_head, m.rope_base);
        let mut cos0 = Vec::with_capacity(batch * half);
        let mut sin0 = Vec::with_capacity(batch * half);
        for _ in 0..batch {
            cos0.extend(freqs.iter().map(|w| w.cos() as f32));
            sin0.extend(freqs.iter().map(|w| (-w.sin()) as f32));
        }
        let cos = f32_literal(&cos0, &[batch, half])?;
        let sin = f32_literal(&sin0, &[batch, half])?;
        Ok(BatchState {
            batch,
            kc,
            vc,
            cos,
            sin,
            steps: 0,
        })
    }

    /// One decode step for the whole batch. `tokens[i]` is appended at
    /// position `pos[i]` of lane `i`; returns logits `[batch * vocab]`
    /// row-major. Lanes that are idle should carry `pos = 0, token = 0`
    /// (their cache row 0 is overwritten next time they start a sequence).
    pub fn decode_step(
        &self,
        st: &mut BatchState,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<f32>> {
        if tokens.len() != st.batch || pos.len() != st.batch {
            bail!(
                "batch mismatch: state {}, tokens {}, pos {}",
                st.batch,
                tokens.len(),
                pos.len()
            );
        }
        for (i, &p) in pos.iter().enumerate() {
            if p as usize >= self.manifest.n_ctx {
                bail!("lane {i}: position {p} ≥ context capacity {}", self.manifest.n_ctx);
            }
        }
        self.ensure_decode(st.batch)?;
        let map = self.decode.lock().unwrap();
        let exe = map.get(&st.batch).unwrap();

        let tok_lit = Literal::vec1(tokens);
        let pos_lit = Literal::vec1(pos);
        let mut args: Vec<&Literal> = vec![&tok_lit, &pos_lit, &st.kc, &st.vc, &st.cos, &st.sin];
        args.extend(self.weights.iter());

        let result = exe
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let mut outs = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if outs.len() != 5 {
            bail!("expected 5 outputs, got {}", outs.len());
        }
        let sin = outs.pop().unwrap();
        let cos = outs.pop().unwrap();
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        st.kc = kc;
        st.vc = vc;
        st.cos = cos;
        st.sin = sin;
        st.steps += 1;
        logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e}"))
    }

    /// Debug: fetch the K-cache as a host vector (cross-validation).
    pub fn debug_kcache(&self, st: &BatchState) -> Result<Vec<f32>> {
        st.kc.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Debug: fetch the RoPE cos state.
    pub fn debug_cos(&self, st: &BatchState) -> Result<Vec<f32>> {
        st.cos.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Run the attention-only artifact (quickstart): row-batched SwiftKV
    /// attention as lowered from the Pallas kernel.
    pub fn attention(
        &self,
        lens: &[i32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
        rows: usize,
        n_ctx: usize,
        d_head: usize,
    ) -> Result<Vec<f32>> {
        {
            let mut slot = self.attn.lock().unwrap();
            if slot.is_none() {
                *slot = Some(self.compile_file("swiftkv_attn.hlo.txt")?);
            }
        }
        let slot = self.attn.lock().unwrap();
        let exe = slot.as_ref().unwrap();
        let (r, n, d) = (rows as i64, n_ctx as i64, d_head as i64);
        let lens_l = Literal::vec1(lens);
        let q_l = Literal::vec1(q).reshape(&[r, d])?;
        let k_l = Literal::vec1(k).reshape(&[r, n, d])?;
        let v_l = Literal::vec1(v).reshape(&[r, n, d])?;
        let result = exe
            .execute::<Literal>(&[lens_l, q_l, k_l, v_l])
            .map_err(|e| anyhow!("execute attn: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("out: {e}"))
    }
}

/// Build an f32 literal from a host slice with an explicit shape via the
/// untyped-data path (avoids `vec1().reshape()`, whose result the 0.5.1
/// runtime transfers incorrectly for some shapes).
fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    // SAFETY: reinterpreting a live &[f32] as its raw bytes — same
    // allocation, same lifetime, u8 has no alignment or validity
    // requirements, and the length covers exactly the f32 payload.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("f32 literal: {e}"))
}

/// `Literal` lacks `Clone`; round-trip through raw parts.
trait CloneLiteral {
    fn clone_literal(&self) -> Result<Literal>;
}

impl CloneLiteral for Literal {
    fn clone_literal(&self) -> Result<Literal> {
        let shape = self.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let mut bytes = vec![0u8; self.size_bytes()];
        // copy_raw_to is typed; use f32 path for f32 arrays
        match self.ty().map_err(|e| anyhow!("{e}"))? {
            xla::ElementType::F32 => {
                let mut host = vec![0f32; self.element_count()];
                self.copy_raw_to(&mut host).map_err(|e| anyhow!("{e}"))?;
                // SAFETY: byte view of the live `host` Vec<f32> — same
                // allocation and lifetime, exact f32 payload length.
                bytes.copy_from_slice(unsafe {
                    std::slice::from_raw_parts(host.as_ptr() as *const u8, host.len() * 4)
                });
                Literal::create_from_shape_and_untyped_data(ElementType::F32, &dims, &bytes)
                    .map_err(|e| anyhow!("{e}"))
            }
            other => bail!("clone_literal: unsupported {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    fn engine() -> Option<Engine> {
        artifacts_available().then(|| Engine::load(&default_artifacts_dir()).unwrap())
    }

    #[test]
    fn loads_weights_and_manifest() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(!e.weights.is_empty());
        assert!(e.batch_variants().contains(&1));
    }

    #[test]
    fn pick_batch_rounds_up() {
        let Some(e) = engine() else {
            return;
        };
        assert_eq!(e.pick_batch(1), Some(1));
        assert_eq!(e.pick_batch(3), Some(4));
        assert_eq!(e.pick_batch(100), Some(8));
    }

    #[test]
    fn state_rejects_unknown_batch() {
        let Some(e) = engine() else {
            return;
        };
        assert!(e.new_state(3).is_err());
        assert!(e.new_state(1).is_ok());
    }

    #[test]
    fn decode_step_positions_validated() {
        let Some(e) = engine() else {
            return;
        };
        let mut st = e.new_state(1).unwrap();
        let bad = e.decode_step(&mut st, &[0], &[e.manifest.n_ctx as i32]);
        assert!(bad.is_err());
    }
}

#[cfg(test)]
mod state_tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    /// The returned state must evolve: after one step, cache row 0 holds
    /// the rotated key, the RoPE state holds cos(0·θ) = 1, and untouched
    /// rows remain zero. (This is the regression test for the elided-
    /// constant bug — see aot.py's to_hlo_text docstring.)
    #[test]
    fn state_roundtrip_evolves() {
        if !artifacts_available() {
            return;
        }
        let e = Engine::load(&default_artifacts_dir()).unwrap();
        let mut st = e.new_state(1).unwrap();
        e.decode_step(&mut st, &[3], &[0]).unwrap();
        let m = &e.manifest;
        let kc = e.debug_kcache(&st).unwrap();
        let row0: f32 = kc[..m.d_head].iter().map(|x| x.abs()).sum();
        let row1: f32 = kc[m.d_head..2 * m.d_head].iter().map(|x| x.abs()).sum();
        assert!(row0 > 0.0, "cache row 0 empty after step 0");
        assert_eq!(row1, 0.0, "cache row 1 written prematurely");
        let cos = e.debug_cos(&st).unwrap();
        for (i, c) in cos.iter().enumerate() {
            assert!((c - 1.0).abs() < 1e-5, "cos[{i}] = {c}, want cos(0) = 1");
        }
    }
}
