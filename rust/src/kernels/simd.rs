//! SIMD-width f32 primitives for the fused decode kernels, dispatched
//! at runtime through [`super::isa`].
//!
//! Each public function forwards to the process-wide [`super::isa::active`]
//! kernel table: hand-written AVX2 on x86-64 with AVX2+FMA, NEON on
//! aarch64, and the portable [`scalar`] fallback everywhere else (or
//! under `SWIFTKV_ISA=scalar`). The scalar bodies are the original
//! `chunks_exact(LANES)` multi-accumulator loops.
//!
//! Cross-ISA numerics guarantees (enforced by
//! `tests/prop_simd_dispatch.rs` against the scalar table):
//!
//! - [`dot`]: partial-sum order differs per ISA (and the AVX2 kernel
//!   uses FMA), so results agree only within normal f32 re-association
//!   noise (≤ a few ulp per element) — same caveat the scalar version
//!   already carried vs a sequential reduction.
//! - [`axpy`], [`scale_axpy`], [`scale`]: element-wise with one IEEE
//!   multiply and one add per element in scalar program order on every
//!   ISA — **bit-identical** across dispatch targets.
//!
//! lint: hotpath

/// Unroll width of the scalar fallback's inner loops (f32 lanes per
/// step). Vector ISAs use wider hardware lanes (8 on AVX2, 4 on NEON);
/// property tests sweep lengths around all of these widths.
pub const LANES: usize = 4;

/// Dot product — dispatched; re-association tolerance across ISAs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (super::isa::active().dot_f32)(a, b)
}

/// `y ← y + β·x` — the β-branch of Eq. (6) (history untouched).
/// Dispatched; bit-identical across ISAs.
#[inline]
pub fn axpy(beta: f32, y: &mut [f32], x: &[f32]) {
    (super::isa::active().axpy_f32)(beta, y, x)
}

/// `y ← α·y + x` — the α-branch of Eq. (7) (history rescaled, new token
/// folded in at weight 1). Dispatched; bit-identical across ISAs.
#[inline]
pub fn scale_axpy(alpha: f32, y: &mut [f32], x: &[f32]) {
    (super::isa::active().scale_axpy_f32)(alpha, y, x)
}

/// `y ← α·y` in place. Dispatched; bit-identical across ISAs.
#[inline]
pub fn scale(alpha: f32, y: &mut [f32]) {
    (super::isa::active().scale_f32)(alpha, y)
}

/// The portable scalar kernels — the dispatch fallback and the reference
/// implementation the property tests compare every other ISA against.
///
/// Every loop is written over `chunks_exact(LANES)` with independent
/// accumulators/lanes so the compiler auto-vectorizes the body (the same
/// 4-lane trick [`crate::quant::gemv`] uses for the INT4 MAC loop and
/// [`crate::fxp::vector::dot`] uses for the wide-accumulator dot). The
/// remainder loops keep every function correct for arbitrary lengths —
/// odd `d`, `d` not a multiple of the unroll width, `d < LANES`.
pub(crate) mod scalar {
    use super::LANES;

    /// Dot product with four independent accumulators (vectorizable).
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let ca = a.chunks_exact(LANES);
        let cb = b.chunks_exact(LANES);
        let ra = ca.remainder();
        let rb = cb.remainder();
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (x, y) in ca.zip(cb) {
            a0 += x[0] * y[0];
            a1 += x[1] * y[1];
            a2 += x[2] * y[2];
            a3 += x[3] * y[3];
        }
        let mut s = (a0 + a1) + (a2 + a3);
        for (x, y) in ra.iter().zip(rb) {
            s += x * y;
        }
        s
    }

    /// `y ← y + β·x`, one multiply + add per element.
    pub fn axpy(beta: f32, y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let split = y.len() - y.len() % LANES;
        let (yv, yr) = y.split_at_mut(split);
        let (xv, xr) = x.split_at(split);
        for (yc, xc) in yv.chunks_exact_mut(LANES).zip(xv.chunks_exact(LANES)) {
            yc[0] += beta * xc[0];
            yc[1] += beta * xc[1];
            yc[2] += beta * xc[2];
            yc[3] += beta * xc[3];
        }
        for (yi, xi) in yr.iter_mut().zip(xr) {
            *yi += beta * xi;
        }
    }

    /// `y ← α·y + x`, one multiply + add per element.
    pub fn scale_axpy(alpha: f32, y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let split = y.len() - y.len() % LANES;
        let (yv, yr) = y.split_at_mut(split);
        let (xv, xr) = x.split_at(split);
        for (yc, xc) in yv.chunks_exact_mut(LANES).zip(xv.chunks_exact(LANES)) {
            yc[0] = alpha * yc[0] + xc[0];
            yc[1] = alpha * yc[1] + xc[1];
            yc[2] = alpha * yc[2] + xc[2];
            yc[3] = alpha * yc[3] + xc[3];
        }
        for (yi, xi) in yr.iter_mut().zip(xr) {
            *yi = alpha * *yi + xi;
        }
    }

    /// `y ← α·y` in place.
    pub fn scale(alpha: f32, y: &mut [f32]) {
        for yi in y.iter_mut() {
            *yi *= alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn seq_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_sequential_within_reassociation_noise() {
        let mut rng = Rng::seed_from_u64(1);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 33, 64, 127, 512] {
            let a = rng.uniform_vec(n, 2.0);
            let b = rng.uniform_vec(n, 2.0);
            let got = dot(&a, &b);
            let want = seq_dot(&a, &b);
            assert!(
                (got - want).abs() <= 1e-5 * (1.0 + want.abs()),
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn axpy_bit_identical_to_scalar() {
        let mut rng = Rng::seed_from_u64(2);
        for n in [1usize, 3, 4, 6, 17, 64] {
            let x = rng.uniform_vec(n, 1.0);
            let y0 = rng.uniform_vec(n, 1.0);
            let beta = 0.37f32;
            let mut a = y0.clone();
            axpy(beta, &mut a, &x);
            let mut b = y0.clone();
            for (yi, xi) in b.iter_mut().zip(&x) {
                *yi += beta * xi;
            }
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn scale_axpy_bit_identical_to_scalar() {
        let mut rng = Rng::seed_from_u64(3);
        for n in [1usize, 2, 5, 8, 13, 100] {
            let x = rng.uniform_vec(n, 1.0);
            let y0 = rng.uniform_vec(n, 1.0);
            let alpha = 0.81f32;
            let mut a = y0.clone();
            scale_axpy(alpha, &mut a, &x);
            let mut b = y0.clone();
            for (yi, xi) in b.iter_mut().zip(&x) {
                *yi = alpha * *yi + xi;
            }
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn scale_in_place() {
        let mut y = vec![1.0f32, -2.0, 4.0];
        scale(0.5, &mut y);
        assert_eq!(y, vec![0.5, -1.0, 2.0]);
    }

    #[test]
    fn empty_slices_are_fine() {
        assert_eq!(dot(&[], &[]), 0.0);
        let mut y: Vec<f32> = Vec::new();
        axpy(1.0, &mut y, &[]);
        scale_axpy(1.0, &mut y, &[]);
    }
}
