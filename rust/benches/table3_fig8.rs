//! Bench: regenerate Table III and Fig. 8(a)/(b) — per-token decode
//! simulation of the paper's models — and time the simulator itself.

use swiftkv::model::LlmConfig;
use swiftkv::report;
use swiftkv::sim::{layer_sched, ArchConfig};
use swiftkv::util::bench::Bencher;

fn main() {
    let arch = ArchConfig::default();
    println!("{}", report::table3(&arch));
    println!("{}", report::fig8a(&arch, &LlmConfig::llama2_7b(), 512));
    println!("{}", report::fig8a(&arch, &LlmConfig::chatglm_6b(), 512));
    println!("{}", report::fig8b(&arch));

    let mut b = Bencher::new(200, 800);
    let cfg = LlmConfig::llama2_7b();
    b.bench("sim/simulate_token llama2@512", || {
        layer_sched::simulate_token(&arch, &cfg, 512)
    });
    b.bench("sim/simulate_token llama2@4096", || {
        layer_sched::simulate_token(&arch, &cfg, 4096)
    });
}
