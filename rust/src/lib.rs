//! # SwiftKV
//!
//! Reproduction of *"SwiftKV: An Edge-Oriented Attention Algorithm and
//! Multi-Head Accelerator for Fast, Efficient LLM Decoding"* (CS.AR 2026)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** (build time): Pallas kernels for the single-pass SwiftKV
//!   attention scan, decoder-RoPE recurrence and W4A8 GEMV
//!   (`python/compile/kernels/`), checked against a pure-jnp oracle.
//! - **L2** (build time): a JAX decoder model calling the kernels, lowered
//!   once to HLO text (`python/compile/aot.py` → `artifacts/`).
//! - **L3** (this crate): the decode coordinator, the PJRT runtime that
//!   loads the AOT artifacts (behind the off-by-default `pjrt` feature),
//!   bit-exact fixed-point models of the paper's datapath ([`fxp`],
//!   [`attention`], [`rope`], [`quant`]), the fused multi-head decode
//!   kernels the serving hot path runs on ([`kernels`]), and a
//!   cycle-level model of the SwiftKV-MHA accelerator ([`sim`]) plus the
//!   baseline accelerators ([`baselines`]) used by the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every paper table/figure to a module and bench target.
//!
//! ## Unsafe-code policy
//!
//! `unsafe` is confined to the SIMD microkernels (`kernels::simd_avx2` /
//! `kernels::simd_neon`), the raw-pointer GEMM panels in [`quant`], and
//! the worker-pool job-publication protocol in [`kernels::pool`]. Three
//! crate-wide guards keep it honest (see `EXPERIMENTS.md`
//! §Static-analysis for the full catalog):
//!
//! - `deny(unsafe_op_in_unsafe_fn)` — every unsafe operation needs its
//!   own `unsafe {}` block, even inside an `unsafe fn`;
//! - `warn(clippy::undocumented_unsafe_blocks)` + the in-tree lint
//!   binary (`cargo run --bin lint`) — every `unsafe` block and `unsafe
//!   fn` carries a `// SAFETY:` / `/// # Safety` justification;
//! - `deny(clippy::unwrap_used)` outside tests — fallible paths return
//!   errors or use `expect` with an invariant message.

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod attention;
pub mod baselines;
pub mod coordinator;
pub mod fxp;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod report;
pub mod rope;
pub mod runtime;
pub mod sim;
pub mod util;
