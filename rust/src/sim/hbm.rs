//! HBM traffic model: bytes ÷ bandwidth, in core cycles.

use super::ArchConfig;

/// Cycles to stream `bytes` from HBM at the configured bandwidth.
pub fn stream_cycles(arch: &ArchConfig, bytes: u64) -> u64 {
    (bytes as f64 / arch.hbm_bytes_per_cycle()).ceil() as u64
}

/// Effective GB/s for a transfer that took `cycles` cycles.
pub fn achieved_gbps(arch: &ArchConfig, bytes: u64, cycles: u64) -> f64 {
    bytes as f64 / (cycles as f64 / (arch.clock_mhz * 1e6)) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_accounting() {
        let a = ArchConfig::default();
        // 460 GB for one second's worth of cycles
        let cycles = stream_cycles(&a, 460_000_000_000);
        let secs = cycles as f64 / (a.clock_mhz * 1e6);
        assert!((secs - 1.0).abs() < 1e-3, "secs = {secs}");
    }

    #[test]
    fn achieved_equals_configured_at_saturation() {
        let a = ArchConfig::default();
        let bytes = 1_000_000_000;
        let cycles = stream_cycles(&a, bytes);
        let g = achieved_gbps(&a, bytes, cycles);
        assert!((g - 460.0).abs() < 1.0, "{g}");
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(stream_cycles(&ArchConfig::default(), 0), 0);
    }
}
