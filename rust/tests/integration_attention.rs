//! Integration: all four attention algorithms + the FXP32 datapath agree
//! on the same randomized problems across a shape sweep.

use swiftkv::attention::{flash, fxp_swiftkv, native, online, swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::fxp::Exp2Lut;
use swiftkv::util::Rng;

struct Problem {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    d: usize,
    len: usize,
}

fn random_problem(rng: &mut Rng, d: usize, len: usize, scale: f32) -> Problem {
    Problem {
        q: rng.uniform_vec(d, scale),
        k: rng.uniform_vec(d * len, scale),
        v: rng.uniform_vec(d * len, scale),
        d,
        len,
    }
}

#[test]
fn all_algorithms_agree_across_shapes() {
    let mut rng = Rng::seed_from_u64(100);
    for &d in &[8usize, 32, 64, 128] {
        for &len in &[1usize, 7, 64, 257, 512] {
            let pr = random_problem(&mut rng, d, len, 1.0);
            let p = HeadProblem::new(&pr.q, &pr.k, &pr.v, d, len);
            let base = native::attend(&p);
            for (name, out) in [
                ("swiftkv", swiftkv_attn::attend(&p)),
                ("online", online::attend(&p)),
                ("flash8", flash::attend(&p, 8)),
                ("flash16", flash::attend(&p, 16)),
                ("flash32", flash::attend(&p, 32)),
            ] {
                for (i, (a, b)) in out.iter().zip(&base).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{name} d={d} len={len} dim {i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn fxp_datapath_tracks_f32_within_quantization() {
    let lut = Exp2Lut::new();
    let mut rng = Rng::seed_from_u64(200);
    for &len in &[16usize, 128, 512] {
        let pr = random_problem(&mut rng, 64, len, 1.0);
        let p = HeadProblem::new(&pr.q, &pr.k, &pr.v, 64, len);
        let want = native::attend(&p);
        let got = fxp_swiftkv::attend(&lut, &pr.q, &pr.k, &pr.v, 64, len);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "len={len} dim {i}: fxp {a} vs f32 {b}"
            );
        }
    }
}

#[test]
fn extreme_magnitudes_all_stable() {
    // scores spanning ±hundreds: rescaling must keep everything finite
    let mut rng = Rng::seed_from_u64(300);
    let pr = random_problem(&mut rng, 32, 256, 60.0);
    let p = HeadProblem::new(&pr.q, &pr.k, &pr.v, 32, 256);
    for out in [
        native::attend(&p),
        swiftkv_attn::attend(&p),
        online::attend(&p),
        flash::attend(&p, 32),
    ] {
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn incremental_decode_matches_batch_recompute() {
    // serving pattern: attention state extended one token at a time must
    // equal recomputing over the grown cache
    let mut rng = Rng::seed_from_u64(400);
    let d = 32;
    let max_len = 64;
    let pr = random_problem(&mut rng, d, max_len, 1.0);
    let mut st = swiftkv_attn::SwiftKvState::new(d);
    for len in 1..=max_len {
        let p = HeadProblem::new(&pr.q, &pr.k, &pr.v, d, len);
        swiftkv_attn::extend(&mut st, &p, len - 1, len);
        let inc = st.finalize();
        let full = native::attend(&p);
        for (a, b) in inc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-4, "len={len}");
        }
    }
}
