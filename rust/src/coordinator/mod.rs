//! L3 coordinator — the decode serving layer.
//!
//! The whole module tree is compiled with `clippy::unwrap_used` denied
//! (outside tests): serving-loop code must contain faults per-request,
//! never convert one into a process-wide panic via a stray `.unwrap()`.
//!
//! Shaped like a serving-system router (the SwiftKV-MHA accelerator is a
//! decode engine; this is the host side that keeps it fed):
//!
//! - [`session`] — per-request decode sessions (prompt feed → generation),
//! - [`batcher`] — continuous batching over a fixed lane count: free
//!   lanes are re-admitted from the queue every iteration,
//! - [`cpu`] — the default serving backend: the pure-Rust tiny model on
//!   the fused decode kernels; decode-phase lanes step through one
//!   operator-batched `decode_steps_into` call (one shared weight pass
//!   per batch step) over a persistent [`crate::kernels::WorkerPool`],
//! - [`server`] — the PJRT serving loop over the AOT engine (behind the
//!   `pjrt` feature): gather (token, position) per lane, one engine step,
//!   scatter logits, greedy-sample, retire finished sessions,
//! - [`metrics`] — per-request latency/throughput accounting plus the
//!   simulated SwiftKV-MHA timing for the same schedule (via
//!   [`crate::sim::layer_sched`]), so the E2E example reports both
//!   wall-clock and modelled-accelerator numbers.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod batcher;
pub mod cpu;
pub mod faults;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod session;

pub use batcher::{Batcher, FaultCounters, LaneChunk, LaneState, PreemptOutcome};
pub use cpu::{CpuServeOptions, CpuServeReport, CpuServer, DEFAULT_PREFILL_CHUNK};
pub use faults::{FaultKind, FaultPlan};
pub use metrics::{Percentiles, ServeMetrics};
#[cfg(feature = "pjrt")]
pub use server::{ServeOptions, ServeReport, Server};
pub use session::{Session, SessionOutcome, SessionPhase};
