"""L2 model tests: decode-step semantics on a reduced TinyConfig."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.TinyConfig(n_layers=2, n_ctx=64, vocab=64, d_model=64, n_heads=2,
                   n_kv_heads=2, d_head=32, d_ffn=128, block_k=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def step(params, tokens, pos, state):
    return M.decode_step(params, CFG, jnp.asarray(tokens, jnp.int32),
                         jnp.asarray(pos, jnp.int32), *state)


def test_decode_step_shapes(params):
    state = M.init_state(CFG, 3)
    logits, kc, vc, cos, sin = step(params, [1, 2, 3], [0, 0, 0], state)
    assert logits.shape == (3, CFG.vocab)
    assert kc.shape == (3, CFG.n_layers, CFG.n_heads, CFG.n_ctx, CFG.d_head)
    assert cos.shape == (3, CFG.d_head // 2)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cache_written_at_position(params):
    state = M.init_state(CFG, 1)
    _, kc, vc, *_ = step(params, [5], [0], state)
    # row 0 of every layer/head must be non-zero, the rest untouched (zero)
    assert float(jnp.max(jnp.abs(kc[0, :, :, 0, :]))) > 0
    assert float(jnp.max(jnp.abs(kc[0, :, :, 1:, :]))) == 0
    assert float(jnp.max(jnp.abs(vc[0, :, :, 1:, :]))) == 0


def test_determinism(params):
    s1 = M.init_state(CFG, 2)
    s2 = M.init_state(CFG, 2)
    l1, *_ = step(params, [9, 4], [0, 0], s1)
    l2, *_ = step(params, [9, 4], [0, 0], s2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_batch_consistency(params):
    """A sequence decoded alone equals the same sequence inside a batch."""
    state1 = M.init_state(CFG, 1)
    l_solo, kc1, vc1, c1, s1 = step(params, [7], [0], state1)
    state3 = M.init_state(CFG, 3)
    l_batch, *_ = step(params, [7, 11, 13], [0, 0, 0], state3)
    np.testing.assert_allclose(np.asarray(l_solo[0]), np.asarray(l_batch[0]),
                               rtol=1e-5, atol=1e-5)


def test_multi_step_positions_advance(params):
    state = M.init_state(CFG, 1)
    toks = [3, 1, 4, 1, 5]
    kc, vc, cos, sin = state
    for t, tok in enumerate(toks):
        logits, kc, vc, cos, sin = M.decode_step(
            params, CFG, jnp.asarray([tok], jnp.int32),
            jnp.asarray([t], jnp.int32), kc, vc, cos, sin)
    # all five cache rows populated, the sixth untouched
    assert float(jnp.max(jnp.abs(kc[0, 0, :, 4, :]))) > 0
    assert float(jnp.max(jnp.abs(kc[0, 0, :, 5:, :]))) == 0
    # rope state advanced to position 4: cos^2+sin^2 == 1 still
    np.testing.assert_allclose(np.asarray(cos**2 + sin**2),
                               np.ones_like(np.asarray(cos)), atol=1e-5)


def test_attention_inside_model_matches_oracle(params):
    """Extract one layer's cached K/V after several steps and check the
    model's attention output path against the native oracle."""
    state = M.init_state(CFG, 1)
    kc, vc, cos, sin = state
    for t, tok in enumerate([2, 3, 5, 7]):
        _, kc, vc, cos, sin = M.decode_step(
            params, CFG, jnp.asarray([tok], jnp.int32),
            jnp.asarray([t], jnp.int32), kc, vc, cos, sin)
    # re-run the kernel on the final cache vs the oracle
    from compile.kernels.swiftkv import swiftkv_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(CFG.n_heads, CFG.d_head)), jnp.float32)
    k_rows = kc[0, 0]
    v_rows = vc[0, 0]
    lens = jnp.full((CFG.n_heads,), 4, jnp.int32)
    got = swiftkv_attention(q, k_rows, v_rows, lens, block_k=CFG.block_k)
    want = ref.native_attention_rows(q, k_rows, v_rows, lens)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_greedy_generate_deterministic(params):
    out1 = M.greedy_generate(params, CFG, np.asarray([1, 2, 3]), steps=4)
    out2 = M.greedy_generate(params, CFG, np.asarray([1, 2, 3]), steps=4)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (4,)
    assert all(0 <= t < CFG.vocab for t in out1)


def test_param_specs_cover_params(params):
    specs = M.param_specs(CFG)
    assert set(n for n, _, _ in specs) == set(params.keys())
    for name, shape, dtype in specs:
        assert params[name].shape == tuple(shape), name
        assert str(params[name].dtype) == dtype, name


GQA_CFG = M.TinyConfig(n_layers=2, n_ctx=64, vocab=64, d_model=64, n_heads=2,
                       n_kv_heads=1, d_head=32, d_ffn=128, block_k=16)


def test_gqa_decode_step_shapes_and_cache_shrink():
    params = M.init_params(GQA_CFG, seed=0)
    state = M.init_state(GQA_CFG, 2)
    kc, vc, cos, sin = state
    # the cache holds n_kv_heads rows per token, not n_heads
    assert kc.shape == (2, GQA_CFG.n_layers, GQA_CFG.n_kv_heads,
                        GQA_CFG.n_ctx, GQA_CFG.d_head)
    logits, kc, vc, cos, sin = M.decode_step(
        params, GQA_CFG, jnp.asarray([1, 9], jnp.int32),
        jnp.asarray([0, 0], jnp.int32), kc, vc, cos, sin)
    assert logits.shape == (2, GQA_CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # row 0 written, the rest untouched
    assert float(jnp.max(jnp.abs(kc[:, :, :, 0, :]))) > 0
    assert float(jnp.max(jnp.abs(kc[:, :, :, 1:, :]))) == 0


def test_gqa_matches_mha_with_duplicated_kv_weights():
    """A group-2 GQA model whose single KV head carries the same weights
    as both heads of an MHA twin must produce identical attention: the
    grouped path repeats the KV rows exactly as MHA computes them."""
    mha = M.TinyConfig(n_layers=1, n_ctx=16, vocab=32, d_model=32, n_heads=2,
                       n_kv_heads=2, d_head=16, d_ffn=64, block_k=16)
    gqa = M.TinyConfig(n_layers=1, n_ctx=16, vocab=32, d_model=32, n_heads=2,
                       n_kv_heads=1, d_head=16, d_ffn=64, block_k=16)
    params = M.init_params(mha, seed=1)
    gparams = dict(params)
    # collapse the two identical-by-construction KV heads into one:
    # take head 0's columns and duplicate them into the MHA twin
    for l in range(mha.n_layers):
        for w in ("wk", "wv"):
            q = params[f"layer{l}.{w}.q"]
            s = params[f"layer{l}.{w}.scale"]
            gparams[f"layer{l}.{w}.q"] = q[:, :mha.d_head]
            gparams[f"layer{l}.{w}.scale"] = s[:mha.d_head]
            params[f"layer{l}.{w}.q"] = jnp.concatenate(
                [q[:, :mha.d_head]] * 2, axis=1)
            params[f"layer{l}.{w}.scale"] = jnp.concatenate(
                [s[:mha.d_head]] * 2)
    tok = jnp.asarray([5], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    l_mha, *_ = M.decode_step(params, mha, tok, pos, *M.init_state(mha, 1))
    l_gqa, *_ = M.decode_step(gparams, gqa, tok, pos, *M.init_state(gqa, 1))
    np.testing.assert_allclose(np.asarray(l_mha), np.asarray(l_gqa),
                               rtol=1e-5, atol=1e-5)


def test_gqa_greedy_generate_runs():
    params = M.init_params(GQA_CFG, seed=0)
    out = M.greedy_generate(params, GQA_CFG, np.asarray([1, 2, 3]), steps=4)
    assert out.shape == (4,)
    assert all(0 <= t < GQA_CFG.vocab for t in out)
