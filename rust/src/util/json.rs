//! Minimal JSON parser + writer (offline replacement for `serde_json`).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the report emitters: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are held as f64 (adequate: the manifest's
//! largest integers are byte offsets ≪ 2⁵³).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj[key]` convenience (None on type mismatch / missing).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + width > self.bytes.len() {
                            // input ends mid-sequence (e.g. a truncated file)
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        self.pos = start + width;
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("number scanner only consumes ASCII bytes");
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize (compact) — used by report emitters.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": false}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn roundtrip_display() {
        let doc = r#"{"arr":[1,2.5,"s"],"n":-7,"t":true}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn truncated_documents_error_cleanly() {
        // Truncation at any byte offset of a realistic document must
        // yield Err, never a panic (the bench gate feeds this parser
        // whatever half-written baseline file it finds on disk).
        let doc = r#"{"benchmarks":[{"name":"fused é","median_ns":12.5}]}"#;
        for cut in 0..doc.len() {
            if let Some(prefix) = doc.get(..cut) {
                assert!(Json::parse(prefix).is_err(), "cut at {cut} parsed");
            }
        }
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"k\" :\r[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{"model":{"vocab":512,"d_model":256},
                      "weights":[{"name":"embedding","dtype":"float32",
                                  "shape":[512,256],"offset":0,"nbytes":524288}]}"#;
        let v = Json::parse(doc).unwrap();
        let w = &v.get("weights").unwrap().as_arr().unwrap()[0];
        assert_eq!(w.get("nbytes").unwrap().as_usize(), Some(524288));
        assert_eq!(w.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(512));
    }
}
