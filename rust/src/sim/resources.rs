//! FPGA resource estimator — Table II (Alveo U55C, Vivado 2022.2).
//!
//! DSP counts are exact arithmetic from the architecture (each SKV
//! processor: 128 MAC DSPs + 4 RoPE multipliers + 8 in the exp/update
//! datapath = 140; 32 processors → 4480; SFU 38). LUT/FF/BRAM are
//! first-order per-unit models (crossbar muxes for the Dispatcher,
//! control + datapath per processor, 36 Kb BRAM tiles for the buffers)
//! with per-unit constants fitted once to the paper's Vivado report;
//! they scale with the architecture parameters so ablations (array width,
//! LUT depth, buffer sizes) move them plausibly.

use super::ArchConfig;

/// U55C device totals (UltraScale+ XCU55C).
pub const U55C_LUT: u64 = 1_304_000;
pub const U55C_FF: u64 = 2_607_000;
pub const U55C_BRAM: u64 = 2016;
pub const U55C_DSP: u64 = 9024;

/// Utilization of one component.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ComponentUtil {
    pub name: &'static str,
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub dsp: u64,
}

/// Full Table II estimate.
#[derive(Debug, Clone)]
pub struct ResourceReport {
    pub components: Vec<ComponentUtil>,
}

impl ResourceReport {
    pub fn total(&self) -> ComponentUtil {
        let mut t = ComponentUtil {
            name: "Total",
            lut: 0,
            ff: 0,
            bram: 0,
            dsp: 0,
        };
        for c in &self.components {
            t.lut += c.lut;
            t.ff += c.ff;
            t.bram += c.bram;
            t.dsp += c.dsp;
        }
        t
    }

    /// Percentages against the U55C device (the parenthesized row of
    /// Table II).
    pub fn utilization_pct(&self) -> (f64, f64, f64, f64) {
        let t = self.total();
        (
            100.0 * t.lut as f64 / U55C_LUT as f64,
            100.0 * t.ff as f64 / U55C_FF as f64,
            100.0 * t.bram as f64 / U55C_BRAM as f64,
            100.0 * t.dsp as f64 / U55C_DSP as f64,
        )
    }
}

/// DSPs per SKV processor: the 128-DSP Public MAC Array plus the RoPE
/// four-multiplier network (4) and the exp/update datapath (8: interpolation
/// multiply, α/β scale multipliers on Z and the Y lane group).
pub fn dsp_per_processor(arch: &ArchConfig) -> u64 {
    arch.dsp_per_processor as u64 + 4 + 8
}

/// Estimate the Table II report for an architecture configuration.
pub fn estimate(arch: &ArchConfig) -> ResourceReport {
    let np = arch.n_processors as u64;
    let lanes = arch.int_lanes() as u64;

    // --- SKV Processor Array ---------------------------------------------
    // Per processor: MAC-lane control + FXP32 post-add/select network +
    // compare-select + LUT-exp + update part. Fitted: ≈ 86.7 LUT and 80 FF
    // per lane equivalent.
    let proc_lut = (lanes as f64 * 86.7) as u64; // ≈ 11.1 K
    let proc_ff = lanes * 80; // ≈ 10.25 K
    let proc_bram = 7; // KV/weight staging: 7 × 36 Kb tiles
    let array = ComponentUtil {
        name: "Processor Array",
        lut: proc_lut * np,
        ff: proc_ff * np,
        bram: proc_bram * np,
        dsp: dsp_per_processor(arch) * np,
    };

    // --- Dispatcher --------------------------------------------------------
    // 32-way scatter/gather crossbar over 32-bit lanes: mux LUTs scale with
    // ports² × lane width; registers with ports × width.
    let ports = np;
    let disp = ComponentUtil {
        name: "Dispatcher",
        lut: ports * ports * 144 + ports * 17, // = 148 K
        ff: ports * 2032,                           // ≈ 65 K
        bram: 0,
        dsp: 0,
    };

    // --- SFU ---------------------------------------------------------------
    // 32-lane vector unit: SiLU/RMSNorm tables + cast datapaths.
    let sfu = ComponentUtil {
        name: "SFU",
        lut: arch.sfu_lanes as u64 * 438,  // ≈ 14 K
        ff: arch.sfu_lanes as u64 * 469,   // ≈ 15 K
        bram: 46,                          // SiLU/RMS lookup + staging
        dsp: 38,                           // cast/scale multipliers
    };

    // --- Global Buffer -------------------------------------------------------
    let gbuf = ComponentUtil {
        name: "Global Buffer",
        lut: 0,
        ff: 0,
        bram: 136, // Q/K/V + activation staging (Table II)
        dsp: 0,
    };

    ResourceReport {
        components: vec![sfu, disp, array, gbuf],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ResourceReport {
        estimate(&ArchConfig::default())
    }

    /// Table II exact DSP arithmetic: 4480 array + 38 SFU = 4518 (50.1%).
    #[test]
    fn dsp_counts_match_paper() {
        let r = report();
        let array = r.components.iter().find(|c| c.name == "Processor Array").unwrap();
        assert_eq!(array.dsp, 4480);
        assert_eq!(r.total().dsp, 4518);
        let (_, _, _, dsp_pct) = r.utilization_pct();
        assert!((dsp_pct - 50.1).abs() < 0.2, "DSP% = {dsp_pct:.1}");
    }

    /// Table II totals: LUT 517 K (39.6%), FF 408 K (15.6%), BRAM 406 (20.1%).
    #[test]
    fn totals_match_paper_within_tolerance() {
        let t = report().total();
        assert!((t.lut as f64 - 517_000.0).abs() / 517_000.0 < 0.03, "LUT {}", t.lut);
        assert!((t.ff as f64 - 408_000.0).abs() / 408_000.0 < 0.03, "FF {}", t.ff);
        assert_eq!(t.bram, 406);
        let (lut_pct, ff_pct, bram_pct, _) = report().utilization_pct();
        assert!((lut_pct - 39.6).abs() < 1.5, "{lut_pct}");
        assert!((ff_pct - 15.6).abs() < 1.0, "{ff_pct}");
        assert!((bram_pct - 20.1).abs() < 0.5, "{bram_pct}");
    }

    /// Table II per-component rows.
    #[test]
    fn component_rows_match_paper() {
        let r = report();
        let get = |n: &str| r.components.iter().find(|c| c.name == n).unwrap();
        let sfu = get("SFU");
        assert!((sfu.lut as f64 - 14_000.0).abs() < 1000.0);
        assert!((sfu.ff as f64 - 15_000.0).abs() < 1000.0);
        assert_eq!(sfu.bram, 46);
        assert_eq!(sfu.dsp, 38);
        let disp = get("Dispatcher");
        assert!((disp.lut as f64 - 148_000.0).abs() / 148_000.0 < 0.03);
        assert!((disp.ff as f64 - 65_000.0).abs() / 65_000.0 < 0.03);
        let array = get("Processor Array");
        assert!((array.lut as f64 - 355_000.0).abs() / 355_000.0 < 0.03);
        assert!((array.ff as f64 - 328_000.0).abs() / 328_000.0 < 0.03);
        assert_eq!(array.bram, 224);
        assert_eq!(get("Global Buffer").bram, 136);
    }

    /// The model scales: halving the array halves its DSPs.
    #[test]
    fn scales_with_processor_count() {
        let half = estimate(&ArchConfig {
            n_processors: 16,
            ..ArchConfig::default()
        });
        let full = report();
        let d_half = half.components.iter().find(|c| c.name == "Processor Array").unwrap().dsp;
        let d_full = full.components.iter().find(|c| c.name == "Processor Array").unwrap().dsp;
        assert_eq!(2 * d_half, d_full);
        assert!(half.total().lut < full.total().lut);
    }

    #[test]
    fn fits_on_device() {
        let t = report().total();
        assert!(t.lut < U55C_LUT && t.ff < U55C_FF && t.bram < U55C_BRAM && t.dsp < U55C_DSP);
    }
}
