//! Rotary Positional Embedding — standard (Eqs. 1–3) and the paper's
//! decoder-specialized incremental form (Eq. 11, §IV-C).
//!
//! The incremental unit stores `a_i = cos θ_i`, `b_i = sin θ_i` as
//! constants and advances the cached `(cos mθ_i, sin mθ_i)` by one
//! angle-addition per generated token: four multipliers, three cycles,
//! no CORDIC and no large-angle reduction. Only the *new* token's q and k
//! are rotated; cached keys are already position-encoded.

pub mod incremental;
pub mod standard;

pub use incremental::RopeState;
pub use standard::{rope_apply_cached, rope_apply_cached_into, rope_freqs, rope_standard};
