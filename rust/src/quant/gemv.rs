//! W4A8 GEMV on the (modelled) SKV Processor Array.
//!
//! `INT8 activation × INT4 weight → INT32` accumulate, dequantized on
//! writeback — exact integer arithmetic, so results are bit-identical to
//! the Pallas GEMV kernel for identical quantized inputs.

use super::int4::Int4Matrix;
use super::int8::QuantizedVec;

/// `y = dequant(Wᵀ x)` for a packed INT4 matrix and an INT8 vector.
pub fn gemv_w4a8(x: &QuantizedVec, w: &Int4Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; w.dout];
    gemv_w4a8_into(x, w, &mut out);
    out
}

/// [`gemv_w4a8`] into a caller-owned `[dout]` buffer (no allocation).
pub fn gemv_w4a8_into(x: &QuantizedVec, w: &Int4Matrix, out: &mut [f32]) {
    gemv_w4a8_raw_into(&x.data, x.scale, w, out);
}

/// The GEMV core on raw quantized lanes — `out = (Wᵀ xs) · xscale · wscale`.
///
/// Hot path (§Perf): the nibble unpack is fused into the MAC loop — each
/// packed byte contributes two lanes directly from registers, with four
/// i32 accumulators so the compiler vectorizes the reduction. This is the
/// software model of the 128-lane DSP column; see EXPERIMENTS.md §Perf
/// for the before/after. Taking `&[i8]` instead of [`QuantizedVec`] lets
/// the caller reuse one scratch buffer across layers
/// ([`QuantLinear::forward_into`]).
pub fn gemv_w4a8_raw_into(xs: &[i8], xscale: f32, w: &Int4Matrix, out: &mut [f32]) {
    assert_eq!(xs.len(), w.din, "dimension mismatch");
    assert_eq!(out.len(), w.dout, "output length mismatch");
    let stride = w.din.div_ceil(2);
    for (j, o) in out.iter_mut().enumerate() {
        let col = &w.packed[j * stride..(j + 1) * stride];
        let mut acc0 = 0i32;
        let mut acc1 = 0i32;
        let mut acc2 = 0i32;
        let mut acc3 = 0i32;
        let pairs = w.din / 2;
        let mut b = 0;
        // 2 bytes (4 lanes) per step
        while b + 2 <= pairs {
            let byte0 = col[b];
            let byte1 = col[b + 1];
            let lo0 = (((byte0 & 0x0F) << 4) as i8 >> 4) as i32;
            let hi0 = ((byte0 >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
            let lo1 = (((byte1 & 0x0F) << 4) as i8 >> 4) as i32;
            let hi1 = ((byte1 >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
            acc0 += xs[2 * b] as i32 * lo0;
            acc1 += xs[2 * b + 1] as i32 * hi0;
            acc2 += xs[2 * b + 2] as i32 * lo1;
            acc3 += xs[2 * b + 3] as i32 * hi1;
            b += 2;
        }
        while b < pairs {
            let byte = col[b];
            let lo = (((byte & 0x0F) << 4) as i8 >> 4) as i32;
            let hi = ((byte >> 4) as i8).wrapping_shl(4).wrapping_shr(4) as i32;
            acc0 += xs[2 * b] as i32 * lo;
            acc1 += xs[2 * b + 1] as i32 * hi;
            b += 1;
        }
        if w.din % 2 == 1 {
            let byte = col[pairs];
            let lo = (((byte & 0x0F) << 4) as i8 >> 4) as i32;
            acc0 += xs[w.din - 1] as i32 * lo;
        }
        let acc = acc0 + acc1 + acc2 + acc3;
        *o = acc as f32 * xscale * w.scales[j];
    }
}

/// A quantized linear layer: packed weights + the f32 forward that first
/// quantizes its activation (the full SFU→Array round trip of Fig. 5(c)).
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub weight: Int4Matrix,
}

impl QuantLinear {
    pub fn new(weight: Int4Matrix) -> Self {
        QuantLinear { weight }
    }

    /// Quantize `x` to INT8 and run the W4A8 GEMV.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.weight.dout];
        let mut qbuf = vec![0i8; self.weight.din];
        self.forward_into(x, &mut qbuf, &mut out);
        out
    }

    /// [`Self::forward`] through caller-owned scratch: `qbuf` (≥ `din`
    /// lanes, only the first `din` are used) holds the INT8 activation,
    /// `out` (`dout` lanes) receives the result. No allocation.
    pub fn forward_into(&self, x: &[f32], qbuf: &mut [i8], out: &mut [f32]) {
        let qb = &mut qbuf[..self.weight.din];
        let scale = super::int8::quantize_int8_into(x, qb);
        gemv_w4a8_raw_into(qb, scale, &self.weight, out);
    }

    pub fn din(&self) -> usize {
        self.weight.din
    }

    pub fn dout(&self) -> usize {
        self.weight.dout
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::int8::quantize_int8;
    use crate::util::Rng;

    fn random_mat(seed: u64, din: usize, dout: usize) -> (Vec<f32>, Int4Matrix) {
        let mut rng = Rng::seed_from_u64(seed);
        let w = rng.uniform_vec(din * dout, 0.5);
        let m = Int4Matrix::quantize(&w, din, dout);
        (w, m)
    }

    #[test]
    fn matches_exact_integer_reference() {
        let mut rng = Rng::seed_from_u64(1);
        let (din, dout) = (64, 32);
        let (_, m) = random_mat(2, din, dout);
        let x = rng.uniform_vec(din, 1.0);
        let xq = quantize_int8(&x);

        let got = gemv_w4a8(&xq, &m);
        // independent reference through the dequantized matrix
        let wd = m.dequantize();
        let xd = xq.dequantize();
        for j in 0..dout {
            let want: f32 = (0..din).map(|i| xd[i] * wd[i * dout + j]).sum();
            assert!(
                (got[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "col {j}: {} vs {want}",
                got[j]
            );
        }
    }

    #[test]
    fn quantized_gemv_close_to_f32() {
        let mut rng = Rng::seed_from_u64(3);
        let (din, dout) = (256, 128);
        let (w, m) = random_mat(4, din, dout);
        let x = rng.uniform_vec(din, 1.0);
        let got = QuantLinear::new(m).forward(&x);
        let mut max_ref = 0.0f32;
        let mut max_err = 0.0f32;
        for j in 0..dout {
            let want: f32 = (0..din).map(|i| x[i] * w[i * dout + j]).sum();
            max_ref = max_ref.max(want.abs());
            max_err = max_err.max((got[j] - want).abs());
        }
        assert!(
            max_err / max_ref < 0.25,
            "relative error {max_err}/{max_ref}"
        );
    }

    #[test]
    fn deterministic() {
        let (_, m) = random_mat(9, 32, 16);
        let x = vec![0.123f32; 32];
        let l = QuantLinear::new(m);
        assert_eq!(l.forward(&x), l.forward(&x));
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dimension_mismatch_panics() {
        let (_, m) = random_mat(5, 16, 8);
        let xq = quantize_int8(&[1.0; 8]);
        gemv_w4a8(&xq, &m);
    }
}
