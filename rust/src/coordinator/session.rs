//! Per-request decode sessions.

use crate::model::Request;

/// Lifecycle phase of a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionPhase {
    /// Feeding prompt tokens (a chunk per engine step through the fused
    /// causal sweep; chunk length is the scheduler's choice).
    Prefill,
    /// Sampling new tokens.
    Decode,
    /// All tokens generated.
    Finished,
}

/// How a retired session left the server. `Completed` is the normal
/// path; the other variants are the fault-tolerance layer's per-request
/// failure surface — a fault in one lane retires *that* session with a
/// non-`Completed` outcome instead of crashing the serve loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionOutcome {
    /// Generated every requested token.
    Completed,
    /// Retired early by a contained lane fault (panic, non-finite
    /// logits, or exhausted requeue budget); the reason says which.
    Failed(String),
    /// Cancelled at an iteration boundary after its wall-clock deadline
    /// passed.
    DeadlineExpired,
    /// Refused at submission (oversized for the context window) — the
    /// request never held a lane or generated a token. Only surfaces
    /// through the continuous submission API
    /// ([`super::submit::TokenEvent::Done`]); the offline path records
    /// rejections in the admission counters alone.
    Rejected,
    /// Cancelled at an iteration boundary because the client went away
    /// (dropped [`super::submit::PendingRequest`], dead SSE socket) or
    /// fell too far behind its bounded event stream, or because a
    /// graceful shutdown hit its drain bound with the lane still
    /// running. Already-streamed tokens stand; KV blocks are reclaimed.
    Cancelled,
    /// Shed by admission control before taking a lane: the queue was at
    /// its depth cap, the engine was draining, or the request provably
    /// could not meet its deadline. The front door maps this to
    /// `503 + Retry-After`.
    Shed,
}

impl SessionOutcome {
    pub fn is_completed(&self) -> bool {
        matches!(self, SessionOutcome::Completed)
    }
}

/// One request being decoded on a lane.
#[derive(Debug, Clone)]
pub struct Session {
    pub request: Request,
    /// Next position to write in the lane's KV cache.
    pub pos: usize,
    /// Generated tokens so far.
    pub generated: Vec<u32>,
    /// Iteration index at which the session was admitted.
    pub admitted_at: u64,
    /// Iteration of first generated token (TTFT accounting).
    pub first_token_at: Option<u64>,
    /// Iteration at which the session finished.
    pub finished_at: Option<u64>,
    /// How the session left the server (meaningful once retired;
    /// `Completed` while still running).
    pub outcome: SessionOutcome,
}

impl Session {
    pub fn new(request: Request, admitted_at: u64) -> Self {
        assert!(!request.prompt.is_empty(), "empty prompt");
        assert!(request.gen_len >= 1, "gen_len must be ≥ 1");
        Session {
            request,
            pos: 0,
            generated: Vec::new(),
            admitted_at,
            first_token_at: None,
            finished_at: None,
            outcome: SessionOutcome::Completed,
        }
    }

    /// Wall-clock deadline as absolute stream milliseconds, when the
    /// request carries one (`deadline_ms == 0` means none).
    pub fn deadline_at_ms(&self) -> Option<u64> {
        (self.request.deadline_ms > 0)
            .then(|| self.request.arrival_ms + self.request.deadline_ms)
    }

    pub fn phase(&self) -> SessionPhase {
        if self.generated.len() >= self.request.gen_len {
            SessionPhase::Finished
        } else if self.pos < self.request.prompt.len() {
            SessionPhase::Prefill
        } else {
            SessionPhase::Decode
        }
    }

    /// The token to feed at the current position: prompt token during
    /// prefill, last sampled token during decode.
    pub fn next_input(&self) -> u32 {
        if self.pos < self.request.prompt.len() {
            self.request.prompt[self.pos]
        } else {
            *self
                .generated
                .last()
                .expect("decode phase requires a sampled token")
        }
    }

    /// The tokens to feed this engine step, at most `max_chunk` of them:
    /// during prefill, the next slice of the remaining prompt (chunked
    /// prefill consumes it whole-chunk through the fused causal sweep);
    /// during decode, the single last-sampled token.
    pub fn next_chunk(&self, max_chunk: usize) -> &[u32] {
        assert!(max_chunk >= 1, "chunk must hold at least one token");
        let prompt = &self.request.prompt;
        if self.pos < prompt.len() {
            // saturating: max_chunk = usize::MAX means "whole prompt"
            &prompt[self.pos..prompt.len().min(self.pos.saturating_add(max_chunk))]
        } else {
            std::slice::from_ref(
                self.generated
                    .last()
                    .expect("decode phase requires a sampled token"),
            )
        }
    }

    /// Whether a step that feeds `fed` tokens from here ends on a
    /// position whose logits are sampled (the last prompt token, or any
    /// decode position). When `false` the engine can skip the logits
    /// projection and the sampler entirely for this lane.
    pub fn samples_after(&self, fed: usize) -> bool {
        self.pos + fed >= self.request.prompt.len()
    }

    /// Record the outcome of one engine step. During prefill before the
    /// last prompt token, logits are discarded; otherwise `sampled` is
    /// appended. Returns `true` if the session just finished.
    pub fn advance(&mut self, sampled: u32, iteration: u64) -> bool {
        self.advance_chunk(1, sampled, iteration)
    }

    /// Record the outcome of one engine step that fed `fed` tokens (a
    /// prompt chunk, or one decode token). `sampled` is appended only
    /// when the chunk reached the last prompt token or was a decode
    /// step ([`Session::samples_after`]). Returns `true` if the session
    /// just finished.
    pub fn advance_chunk(&mut self, fed: usize, sampled: u32, iteration: u64) -> bool {
        assert!(fed >= 1, "a step must feed at least one token");
        let prompt_len = self.request.prompt.len();
        assert!(
            self.pos >= prompt_len || self.pos + fed <= prompt_len,
            "prefill chunk must not run past the prompt (pos {}, fed {fed}, prompt {prompt_len})",
            self.pos
        );
        assert!(
            self.pos < prompt_len || fed == 1,
            "decode steps feed exactly one token"
        );
        let sampling = self.samples_after(fed);
        self.pos += fed;
        if sampling {
            self.generated.push(sampled);
            if self.first_token_at.is_none() {
                self.first_token_at = Some(iteration);
            }
            if self.generated.len() >= self.request.gen_len {
                self.finished_at = Some(iteration);
                return true;
            }
        }
        false
    }

    /// Total context this session will occupy (capacity check).
    pub fn max_context(&self) -> usize {
        self.request.prompt.len() + self.request.gen_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: &[u32], gen_len: usize) -> Request {
        Request::new(0, prompt.to_vec()).gen_len(gen_len)
    }

    #[test]
    fn phase_progression() {
        let mut s = Session::new(req(&[1, 2, 3], 2), 0);
        assert_eq!(s.phase(), SessionPhase::Prefill);
        assert_eq!(s.next_input(), 1);
        assert!(!s.advance(99, 0)); // fed token 1, logits discarded
        assert_eq!(s.next_input(), 2);
        assert!(!s.advance(99, 1));
        assert_eq!(s.next_input(), 3);
        assert!(!s.advance(42, 2)); // last prompt token → first sample
        assert_eq!(s.phase(), SessionPhase::Decode);
        assert_eq!(s.generated, vec![42]);
        assert_eq!(s.next_input(), 42);
        assert!(s.advance(43, 3)); // second sample → finished
        assert_eq!(s.phase(), SessionPhase::Finished);
        assert_eq!(s.generated, vec![42, 43]);
        assert_eq!(s.finished_at, Some(3));
    }

    #[test]
    fn first_token_recorded_once() {
        let mut s = Session::new(req(&[7], 3), 5);
        s.advance(1, 10);
        s.advance(2, 11);
        s.advance(3, 12);
        assert_eq!(s.first_token_at, Some(10));
        assert_eq!(s.finished_at, Some(12));
    }

    #[test]
    fn single_token_prompt_samples_immediately() {
        let mut s = Session::new(req(&[5], 1), 0);
        assert_eq!(s.next_input(), 5);
        assert!(s.advance(9, 0));
        assert_eq!(s.generated, vec![9]);
    }

    #[test]
    fn chunked_prefill_lifecycle() {
        let mut s = Session::new(req(&[1, 2, 3, 4, 5], 2), 0);
        // chunk capped at 3: feed [1, 2, 3], no sample
        assert_eq!(s.next_chunk(3), &[1, 2, 3]);
        assert!(!s.samples_after(3));
        assert!(!s.advance_chunk(3, 99, 0));
        assert_eq!(s.pos, 3);
        assert!(s.generated.is_empty());
        assert_eq!(s.phase(), SessionPhase::Prefill);
        // remaining prompt fits the next chunk: [4, 5] → first sample
        assert_eq!(s.next_chunk(8), &[4, 5]);
        assert!(s.samples_after(2));
        assert!(!s.advance_chunk(2, 42, 1));
        assert_eq!(s.generated, vec![42]);
        assert_eq!(s.first_token_at, Some(1));
        assert_eq!(s.phase(), SessionPhase::Decode);
        // decode: chunks are single tokens
        assert_eq!(s.next_chunk(8), &[42]);
        assert!(s.advance_chunk(1, 7, 2));
        assert_eq!(s.generated, vec![42, 7]);
        assert_eq!(s.finished_at, Some(2));
    }

    #[test]
    fn whole_prompt_chunk_samples_immediately() {
        let mut s = Session::new(req(&[1, 2, 3], 1), 0);
        assert_eq!(s.next_chunk(16), &[1, 2, 3]);
        assert!(s.advance_chunk(3, 5, 0), "gen_len 1 finishes on the first sample");
        assert_eq!(s.generated, vec![5]);
    }

    #[test]
    #[should_panic(expected = "must not run past the prompt")]
    fn chunk_past_prompt_end_rejected() {
        let mut s = Session::new(req(&[1, 2, 3], 2), 0);
        s.advance_chunk(4, 9, 0);
    }

    #[test]
    fn max_context_accounts_prompt_and_generation() {
        let s = Session::new(req(&[1, 2, 3, 4], 10), 0);
        assert_eq!(s.max_context(), 14);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Session::new(req(&[], 1), 0);
    }
}
