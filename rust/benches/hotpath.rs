//! Bench: the L3 hot paths (§Perf targets), with JSON emission — every
//! run rewrites `BENCH_hotpath.json` at the repository root so the perf
//! trajectory stays machine-readable across PRs.
//!
//! Headline comparison (the acceptance gate of the fused-kernel PR): the
//! fused multi-head SwiftKV sweep (`kernels::MhaSwiftKv` /
//! `kernels::FxpMhaSwiftKv` — one pass over a token-major interleaved
//! cache advancing all heads per row) vs the per-head loop the model used
//! to run (`swiftkv::attend` / `attend_fxp` once per head over a
//! head-major cache), at 8 heads × d_head 64 × n 512. Grouped-query
//! sweeps (8q/2kv and 32q/8kv at d=64, n=512, plus their MHA baselines)
//! measure the KV-bandwidth win of GQA directly: each entry is annotated
//! with its streamed `kv_bytes_per_token` and `group` factor in the JSON,
//! so the group-factor reduction is recorded, not assumed. Paged twins
//! (`hot/*_fused_paged … bl=16`) run the identical sweep through
//! BlockPool/BlockTable indirection, so the full cost of paging on the
//! hot path is a recorded ratio, not a guess. Also measured: allocating
//! vs `_into` GEMV, the batch-amortized W4A8 GEMM (`hot/gemm_w4a8 …
//! batch=B` — one shared weight pass — vs `hot/gemv_w4a8 … lanes=B`
//! re-streaming the matrix per lane; acceptance: batch=4 ≥ 1.5× on the
//! 8h×d64 512×512 serving shape), the full tiny-model decode step on
//! the synthetic model (no artifacts needed, MHA and GQA shapes; paged
//! KV caches) in both numerics modes, and the batched CPU-serve
//! throughput (`serve/cpu_throughput lanes={1,4}` with measured
//! `weight_passes_per_step` / `weight_bytes_per_step` annotations).
//!
//! Microkernel twins (`simd/… ` vs `simd/… scalar`) time the dispatched
//! native kernel next to the portable scalar table on the same buffers,
//! so the per-kernel ISA speedup (AVX2 vs scalar, or 1.0× when only
//! scalar is available) is a recorded ratio. The active ISA is printed
//! and annotated (`native_simd=1/0`) into the JSON.
//!
//! CI gates on this file's output: `bench_gate` compares every `*fused*`,
//! `*gemm_w4a8*` and `simd/`-prefixed entry against the committed
//! `BENCH_baseline.json` and fails the job on a >15% median-ns
//! regression (see EXPERIMENTS.md §Perf).

use swiftkv::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
use swiftkv::attention::{swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::coordinator::{CpuServer, ServeConfig};
use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::isa::{self, Isa};
use swiftkv::kernels::{BlockPool, BlockTable, FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::model::{LlmConfig, NumericsMode, Request, TinyModel, WeightStore};
use swiftkv::quant::{
    gemm_w4a8_raw_into, gemv_w4a8_raw_into, quantize_int8, quantize_int8_into, Int4Matrix,
    QuantLinear,
};
use swiftkv::runtime::{artifacts_available, default_artifacts_dir};
use swiftkv::util::bench::Bencher;
use swiftkv::util::Rng;

fn main() {
    let mut b = Bencher::new(200, 1000);
    let mut rng = Rng::seed_from_u64(5);

    // FXP32 SwiftKV scan — the SKV core inner loop
    let (d, n) = (128usize, 512usize);
    let q = rng.uniform_vec(d, 1.0);
    let k = rng.uniform_vec(n * d, 1.0);
    let v = rng.uniform_vec(n * d, 1.0);
    let lut = Exp2Lut::new();
    let fp = FxpHeadProblem::quantize(&q, &k, &v, d, n);
    b.bench("hot/fxp_swiftkv_scan n=512 d=128", || attend_fxp(&lut, &fp));
    let p = HeadProblem::new(&q, &k, &v, d, n);
    b.bench("hot/f32_swiftkv_scan n=512 d=128", || swiftkv_attn::attend(&p));

    // --- fused multi-head sweep vs per-head loop: 8 heads × d=64 × n=512
    let (h, dh) = (8usize, 64usize);
    let row = h * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let qm = rng.uniform_vec(row, 1.0);
    let km = rng.uniform_vec(n * row, 1.0); // token-major interleaved
    let vm = rng.uniform_vec(n * row, 1.0);
    // head-major copies for the per-head baseline
    let mut k_heads = vec![0.0f32; n * row];
    let mut v_heads = vec![0.0f32; n * row];
    for t in 0..n {
        for head in 0..h {
            let src = (t * h + head) * dh;
            let dst = (head * n + t) * dh;
            k_heads[dst..dst + dh].copy_from_slice(&km[src..src + dh]);
            v_heads[dst..dst + dh].copy_from_slice(&vm[src..src + dh]);
        }
    }

    let mut per_head_out = vec![0.0f32; row];
    b.bench("hot/mha_per_head 8h d=64 n=512", || {
        for head in 0..h {
            let p = HeadProblem::new(
                &qm[head * dh..(head + 1) * dh],
                &k_heads[head * n * dh..(head + 1) * n * dh],
                &v_heads[head * n * dh..(head + 1) * n * dh],
                dh,
                n,
            );
            let o = swiftkv_attn::attend(&p);
            per_head_out[head * dh..(head + 1) * dh].copy_from_slice(&o);
        }
        per_head_out[0]
    });
    let mut mha = MhaSwiftKv::new(h, dh);
    let mut fused_out = vec![0.0f32; row];
    b.bench("hot/mha_fused 8h d=64 n=512", || {
        mha.attend(&qm, &km, &vm, n, scale, &mut fused_out);
        fused_out[0]
    });
    report_speedup(
        &b,
        "fused speedup",
        "hot/mha_per_head 8h d=64 n=512",
        "hot/mha_fused 8h d=64 n=512",
    );

    // same comparison on the Q15.17 accelerator datapath
    let qq = vector::quantize(&qm);
    let kq = vector::quantize(&km);
    let vq = vector::quantize(&vm);
    let fxp_scale = Fxp32::from_f64(1.0 / (dh as f64).sqrt());
    let head_problems: Vec<FxpHeadProblem> = (0..h)
        .map(|head| {
            FxpHeadProblem::quantize(
                &qm[head * dh..(head + 1) * dh],
                &k_heads[head * n * dh..(head + 1) * n * dh],
                &v_heads[head * n * dh..(head + 1) * n * dh],
                dh,
                n,
            )
        })
        .collect();
    b.bench("hot/fxp_mha_per_head 8h d=64 n=512", || {
        let mut acc = 0i64;
        for hp in &head_problems {
            let o = attend_fxp(&lut, hp);
            acc += o[0].raw() as i64;
        }
        acc
    });
    let mut fxp_mha = FxpMhaSwiftKv::new(h, dh);
    let mut fused_fxp = vec![Fxp32::ZERO; row];
    b.bench("hot/fxp_mha_fused 8h d=64 n=512", || {
        fxp_mha.attend(&lut, &qq, &kq, &vq, n, fxp_scale, &mut fused_fxp);
        fused_fxp[0].raw()
    });
    report_speedup(
        &b,
        "fused speedup",
        "hot/fxp_mha_per_head 8h d=64 n=512",
        "hot/fxp_mha_fused 8h d=64 n=512",
    );

    // --- fused grouped-query sweeps: GQA shapes next to their MHA
    // baselines at the same query width. The cache a GQA sweep streams is
    // `group`× smaller; kv_bytes_per_token (f32 K+V bytes per cache row)
    // is annotated into the JSON so the reduction is machine-checkable.
    for (hq, hkv) in [(8usize, 8usize), (8, 2), (32, 32), (32, 8)] {
        let group = hq / hkv;
        let kv_row = hkv * dh;
        let qg = rng.uniform_vec(hq * dh, 1.0);
        let kg = rng.uniform_vec(n * kv_row, 1.0); // token-major interleaved
        let vg = rng.uniform_vec(n * kv_row, 1.0);
        let kv_bytes = (2 * kv_row * std::mem::size_of::<f32>()) as f64;

        let mut gqa = MhaSwiftKv::new_grouped(hq, hkv, dh);
        let mut gout = vec![0.0f32; hq * dh];
        let name = format!("hot/mha_fused_gqa {hq}q{hkv}kv d=64 n=512");
        b.bench(&name, || {
            gqa.attend(&qg, &kg, &vg, n, scale, &mut gout);
            gout[0]
        });
        b.annotate(&name, "kv_bytes_per_token", kv_bytes);
        b.annotate(&name, "group", group as f64);

        let qgq = vector::quantize(&qg);
        let kgq = vector::quantize(&kg);
        let vgq = vector::quantize(&vg);
        let mut gqa_fxp = FxpMhaSwiftKv::new_grouped(hq, hkv, dh);
        let mut gout_fxp = vec![Fxp32::ZERO; hq * dh];
        let name = format!("hot/fxp_mha_fused_gqa {hq}q{hkv}kv d=64 n=512");
        b.bench(&name, || {
            gqa_fxp.attend(&lut, &qgq, &kgq, &vgq, n, fxp_scale, &mut gout_fxp);
            gout_fxp[0].raw()
        });
        b.annotate(&name, "kv_bytes_per_token", kv_bytes);
        b.annotate(&name, "group", group as f64);
    }
    report_speedup(
        &b,
        "gqa kv-shrink speedup",
        "hot/mha_fused_gqa 8q8kv d=64 n=512",
        "hot/mha_fused_gqa 8q2kv d=64 n=512",
    );
    report_speedup(
        &b,
        "gqa kv-shrink speedup",
        "hot/mha_fused_gqa 32q32kv d=64 n=512",
        "hot/mha_fused_gqa 32q8kv d=64 n=512",
    );

    // --- paged sweeps: the same 8-head fused walk through block-table
    // indirection (BlockPool/BlockTable, block_len 16) next to its
    // contiguous twin above — the delta is the full price of paging on
    // the hot path (results are bit-identical; tests/prop_paged.rs)
    {
        let block_len = 16usize;
        let pool = BlockPool::new(n.div_ceil(block_len), block_len, row);
        let mut table = BlockTable::new(&pool, n);
        table.ensure_tokens(&pool, n);
        for t in 0..n {
            table.k_row_mut(t).copy_from_slice(&km[t * row..(t + 1) * row]);
            table.v_row_mut(t).copy_from_slice(&vm[t * row..(t + 1) * row]);
            table.quantize_row(t);
        }
        let kv_bytes = (2 * row * std::mem::size_of::<f32>()) as f64;

        let mut paged = MhaSwiftKv::new(h, dh);
        let name = format!("hot/mha_fused_paged 8h d=64 n=512 bl={block_len}");
        b.bench(&name, || {
            paged.reset();
            paged.extend_paged(&qm, &table, 0, n, scale);
            paged.finalize_into(&mut fused_out);
            fused_out[0]
        });
        b.annotate(&name, "block_len", block_len as f64);
        b.annotate(&name, "kv_bytes_per_token", kv_bytes);

        let mut paged_fxp = FxpMhaSwiftKv::new(h, dh);
        let name = format!("hot/fxp_mha_fused_paged 8h d=64 n=512 bl={block_len}");
        b.bench(&name, || {
            paged_fxp.reset();
            paged_fxp.extend_paged(&lut, &qq, &table, 0, n, fxp_scale);
            paged_fxp.finalize_into(&mut fused_fxp);
            fused_fxp[0].raw()
        });
        b.annotate(&name, "block_len", block_len as f64);
        b.annotate(&name, "kv_bytes_per_token", kv_bytes);
        table.release_into(&pool);
    }
    report_speedup(
        &b,
        "paging overhead (x contiguous)",
        "hot/mha_fused_paged 8h d=64 n=512 bl=16",
        "hot/mha_fused 8h d=64 n=512",
    );
    report_speedup(
        &b,
        "paging overhead (x contiguous)",
        "hot/fxp_mha_fused_paged 8h d=64 n=512 bl=16",
        "hot/fxp_mha_fused 8h d=64 n=512",
    );

    // W4A8 GEMV 256→768 (tiny model's widest projection): allocating
    // wrappers vs the caller-scratch `_into` path
    let w = rng.uniform_vec(256 * 768, 0.5);
    let lin = QuantLinear::new(Int4Matrix::quantize(&w, 256, 768));
    let x = rng.uniform_vec(256, 1.0);
    b.bench("hot/gemv_w4a8 256x768", || lin.forward(&x));
    let xq = quantize_int8(&x);
    b.bench("hot/gemv_w4a8 256x768 (prequant)", || {
        swiftkv::quant::gemv_w4a8(&xq, &lin.weight)
    });
    let mut gemv_out = vec![0.0f32; 768];
    let mut qbuf = vec![0i8; 256];
    b.bench("hot/gemv_w4a8 256x768 (into, no alloc)", || {
        lin.forward_into(&x, &mut qbuf, &mut gemv_out);
        gemv_out[0]
    });

    // --- batch-amortized W4A8 GEMM: one shared weight pass for B lanes
    // vs B independent GEMVs, on the 8h×d64 serving projection shape
    // (d_model 512 → QKV/O are 512×512). Decoding is weight-bandwidth
    // bound: the per-lane GEMVs re-stream (and re-unpack) the 128 KiB
    // packed matrix B times per batch step, the batched GEMM exactly
    // once — weight_bytes_per_step is annotated per entry so the
    // bandwidth claim is recorded in the JSON, not assumed. The batched
    // kernel is bit-identical per lane (quant::gemv unit tests +
    // tests/prop_batched_decode.rs), so the recorded ratio is pure
    // amortization. Acceptance gate: batch=4 beats 4 GEMVs by ≥ 1.5×.
    {
        let (din, dout) = (512usize, 512usize);
        let wmat = Int4Matrix::quantize(&rng.uniform_vec(din * dout, 0.5), din, dout);
        // packed_bytes = INT4 payload + per-column f32 scales
        let weight_bytes = wmat.packed_bytes() as f64;
        for batch in [1usize, 2, 4, 8] {
            let mut qrows = vec![0i8; batch * din];
            let mut scales = vec![0.0f32; batch];
            for i in 0..batch {
                let xr = rng.uniform_vec(din, 1.0);
                scales[i] = quantize_int8_into(&xr, &mut qrows[i * din..(i + 1) * din]);
            }
            let mut out = vec![0.0f32; batch * dout];
            let name = format!("hot/gemm_w4a8 512x512 batch={batch}");
            b.bench(&name, || {
                gemm_w4a8_raw_into(&qrows, &scales, &wmat, &mut out);
                out[0]
            });
            b.annotate(&name, "batch", batch as f64);
            b.annotate(&name, "weight_bytes_per_step", weight_bytes);
            let name = format!("hot/gemv_w4a8 512x512 lanes={batch}");
            b.bench(&name, || {
                for i in 0..batch {
                    gemv_w4a8_raw_into(
                        &qrows[i * din..(i + 1) * din],
                        scales[i],
                        &wmat,
                        &mut out[i * dout..(i + 1) * dout],
                    );
                }
                out[0]
            });
            b.annotate(&name, "batch", batch as f64);
            b.annotate(&name, "weight_bytes_per_step", weight_bytes * batch as f64);
        }
        report_speedup(
            &b,
            "batched GEMM amortization",
            "hot/gemv_w4a8 512x512 lanes=4",
            "hot/gemm_w4a8 512x512 batch=4",
        );
        report_speedup(
            &b,
            "batched GEMM amortization",
            "hot/gemv_w4a8 512x512 lanes=8",
            "hot/gemm_w4a8 512x512 batch=8",
        );
    }

    // --- dispatched SIMD microkernels next to the portable scalar
    // table, on identical buffers: each `simd/<kernel>` entry times the
    // runtime-selected native kernel, its ` scalar` twin the fallback,
    // so the per-kernel ISA win is a recorded ratio (1.0x when only
    // scalar is available). The FXP32 and integer kernels are bit-exact
    // across tables (tests/prop_simd_dispatch.rs), so every ratio is
    // pure speed, not a numerics trade.
    {
        let native = isa::active();
        let scalar = isa::table_for(Isa::Scalar).expect("scalar table is always available");
        println!("  (kernel dispatch: {} — override with SWIFTKV_ISA)", native.name);
        let is_native_simd = if native.isa == Isa::Scalar { 0.0 } else { 1.0 };
        let dv = 768usize;
        let xa = rng.uniform_vec(dv, 1.0);
        let xb = rng.uniform_vec(dv, 1.0);
        let mut yacc = vec![0.0f32; dv];
        let fa = vector::quantize(&xa);
        let fb = vector::quantize(&xb);
        let mut fy = vec![Fxp32::ZERO; dv];
        let di = 512usize;
        let ia: Vec<i8> = (0..di).map(|i| ((i * 37 + 11) % 255) as i8).collect();
        let ib: Vec<i8> = (0..di).map(|i| ((i * 53 + 7) % 255) as i8).collect();
        let wcol = Int4Matrix::quantize(&rng.uniform_vec(di, 0.5), di, 1);
        for (tag, t) in [("", native), (" scalar", scalar)] {
            let name = format!("simd/dot f32 d={dv}{tag}");
            b.bench(&name, || (t.dot_f32)(&xa, &xb));
            b.annotate(&name, "native_simd", is_native_simd);
            let name = format!("simd/axpy f32 d={dv}{tag}");
            b.bench(&name, || {
                (t.axpy_f32)(0.5, &mut yacc, &xb);
                yacc[0]
            });
            b.annotate(&name, "native_simd", is_native_simd);
            let name = format!("simd/fxp_dot d={dv}{tag}");
            b.bench(&name, || (t.dot_fxp_wide)(&fa, &fb));
            b.annotate(&name, "native_simd", is_native_simd);
            let name = format!("simd/fxp_axpy d={dv}{tag}");
            b.bench(&name, || {
                (t.axpy_fxp)(Fxp32::from_f64(0.5), &mut fy, &fb);
                fy[0].raw()
            });
            b.annotate(&name, "native_simd", is_native_simd);
            let name = format!("simd/i8dot d={di}{tag}");
            b.bench(&name, || (t.dot_i8)(&ia, &ib));
            b.annotate(&name, "native_simd", is_native_simd);
            let name = format!("simd/w4a8_col d={di}{tag}");
            b.bench(&name, || (t.w4a8_col)(&wcol.packed, di, &ia));
            b.annotate(&name, "native_simd", is_native_simd);
        }
        for kernel in ["dot f32 d=768", "fxp_dot d=768", "i8dot d=512", "w4a8_col d=512"] {
            report_speedup(
                &b,
                "simd dispatch speedup",
                &format!("simd/{kernel} scalar"),
                &format!("simd/{kernel}"),
            );
        }
    }

    // full decode step on the synthetic tiny model (no artifacts needed):
    // fused attention + zero-allocation scratch path, both numerics modes
    let tm = TinyModel::synthetic(5, 512, 256, 8, 8, 4, 1024, 512);
    let mut logits = vec![0.0f32; tm.vocab];
    let mut tok = 0u32;
    let mut st = tm.new_state();
    b.bench("hot/tiny_decode_step synthetic desktop", || {
        if st.pos >= tm.n_ctx {
            st.reset_for_reuse();
        }
        tok = (tok + 1) % tm.vocab as u32;
        tm.decode_step_into(&mut st, tok, NumericsMode::DesktopF32, &mut logits);
        logits[0]
    });
    let mut st2 = tm.new_state();
    b.bench("hot/tiny_decode_step synthetic accel", || {
        if st2.pos >= tm.n_ctx {
            st2.reset_for_reuse();
        }
        tok = (tok + 1) % tm.vocab as u32;
        tm.decode_step_into(&mut st2, tok, NumericsMode::Accelerator, &mut logits);
        logits[0]
    });

    // same decode step on a grouped-query synthetic model (8 query heads
    // over 2 KV heads — group 4): the KV caches, Q15.17 mirror and K/V
    // projections all shrink by the group factor
    let tg = TinyModel::synthetic(5, 512, 256, 8, 2, 4, 1024, 512);
    let mut stg = tg.new_state();
    b.bench("hot/tiny_decode_step synthetic gqa-8q2kv desktop", || {
        if stg.pos >= tg.n_ctx {
            stg.reset_for_reuse();
        }
        tok = (tok + 1) % tg.vocab as u32;
        tg.decode_step_into(&mut stg, tok, NumericsMode::DesktopF32, &mut logits);
        logits[0]
    });
    let mut stg2 = tg.new_state();
    b.bench("hot/tiny_decode_step synthetic gqa-8q2kv accel", || {
        if stg2.pos >= tg.n_ctx {
            stg2.reset_for_reuse();
        }
        tok = (tok + 1) % tg.vocab as u32;
        tg.decode_step_into(&mut stg2, tok, NumericsMode::Accelerator, &mut logits);
        logits[0]
    });
    // annotate every decode-step bench with its per-layer cache-row bytes
    // (the LlmConfig::kv_bytes_per_token_layer convention) so the GQA
    // entries cross-check against the MHA baselines in the JSON
    for (m, prefix) in [
        (&tm, "hot/tiny_decode_step synthetic"),
        (&tg, "hot/tiny_decode_step synthetic gqa-8q2kv"),
    ] {
        let bytes = (2 * m.n_kv_heads * m.d_head * std::mem::size_of::<f32>()) as f64;
        let group = (m.n_heads / m.n_kv_heads) as f64;
        for mode in ["desktop", "accel"] {
            let name = format!("{prefix} {mode}");
            b.annotate(&name, "kv_bytes_per_token_layer", bytes);
            b.annotate(&name, "group", group);
            // decode-step KV now lives in paged blocks of this length
            b.annotate(
                &name,
                "kv_block_len",
                swiftkv::model::DEFAULT_KV_BLOCK_LEN as f64,
            );
        }
    }

    // --- chunked prefill (TTFT path): a 32-token prompt through the
    // fused causal chunk sweep vs one decode_step per token, on the same
    // synthetic model. Every variant resets and re-feeds the full
    // prompt, so the recorded ratio is exactly the per-prompt TTFT win
    // (chunk_len annotated; results are bit-identical across variants —
    // tests/prop_prefill.rs).
    {
        let plen = 32usize;
        let prompt: Vec<u32> = (0..plen as u32)
            .map(|t| (t * 7 + 3) % tm.vocab as u32)
            .collect();
        let mut pst = tm.new_state();
        let name = format!("hot/tiny_prefill synthetic chunk=1 len={plen}");
        b.bench(&name, || {
            // per-token prefill: the pre-chunking serving path
            pst.reset_for_reuse();
            for &t in &prompt {
                tm.decode_step_into(&mut pst, t, NumericsMode::DesktopF32, &mut logits);
            }
            logits[0]
        });
        b.annotate(&name, "chunk_len", 1.0);
        b.annotate(&name, "prompt_len", plen as f64);
        for chunk in [8usize, plen] {
            let name = format!("hot/tiny_prefill synthetic chunk={chunk} len={plen}");
            b.bench(&name, || {
                pst.reset_for_reuse();
                let mut at = 0usize;
                while at < plen {
                    let end = plen.min(at + chunk);
                    let out = if end == plen {
                        Some(&mut logits[..])
                    } else {
                        None
                    };
                    tm.prefill_into(&mut pst, &prompt[at..end], NumericsMode::DesktopF32, out);
                    at = end;
                }
                logits[0]
            });
            b.annotate(&name, "chunk_len", chunk as f64);
            b.annotate(&name, "prompt_len", plen as f64);
        }
        report_speedup(
            &b,
            "chunked prefill speedup",
            &format!("hot/tiny_prefill synthetic chunk=1 len={plen}"),
            &format!("hot/tiny_prefill synthetic chunk={plen} len={plen}"),
        );
    }

    // --- CPU-serve TTFT: the same multi-token-prompt workload served
    // with per-token prefill (chunk 1), the default chunk, and
    // whole-prompt chunks (0). Each entry records the run's TTFT p50 as
    // an annotation, so the serving-level TTFT win lands in the JSON
    // trajectory next to the kernel-level numbers.
    {
        let sm = TinyModel::synthetic(7, 64, 32, 4, 4, 2, 64, 48);
        let reqs: Vec<Request> = (0..4)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..24).map(|t| (t * 5 + i as u32 + 1) % sm.vocab as u32).collect();
                Request::new(i, prompt).gen_len(2)
            })
            .collect();
        for prefill_chunk in [1usize, 8, 0] {
            let cfg = ServeConfig::builder()
                .lanes(2)
                .mode(NumericsMode::DesktopF32)
                .max_iterations(10_000)
                .sim_model(LlmConfig::llama2_7b())
                .prefill_chunk(prefill_chunk)
                .build()
                .expect("bench serve config is valid");
            let server = CpuServer::new(&sm, cfg);
            let name = format!("serve/cpu_ttft prefill-chunk={prefill_chunk} prompt=24");
            let mut ttft_samples: Vec<f64> = Vec::new();
            b.bench(&name, || {
                let report = server.serve(reqs.clone());
                ttft_samples.push(report.metrics.ttft_ms.p50);
                report.metrics.iterations
            });
            // median over every serve run of the bench window, not the
            // (noise-prone) last sample
            ttft_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ttft_p50 = ttft_samples[ttft_samples.len() / 2];
            b.annotate(&name, "chunk_len", prefill_chunk as f64);
            b.annotate(&name, "prompt_len", 24.0);
            b.annotate(&name, "ttft_p50_ms", ttft_p50);
        }
    }

    // --- batched CPU-serve throughput: a decode-heavy workload (1-token
    // prompts, pure decode iterations) at widths 1 and 4. Every width-4
    // iteration is ONE batched decode_steps_into call — one shared
    // weight pass for all lanes — so weight_bytes_per_step stays flat
    // while tokens/step quadruples; both are annotated per entry
    // (weight_passes_per_step measured from the run's ServeMetrics, not
    // assumed).
    {
        let reqs: Vec<Request> = (0..8)
            .map(|i| {
                Request::new(i, vec![(i as u32 * 13 + 1) % tm.vocab as u32]).gen_len(8)
            })
            .collect();
        let step_bytes = tm.weight_stream_bytes() as f64;
        for lanes in [1usize, 4] {
            let cfg = ServeConfig::builder()
                .lanes(lanes)
                .mode(NumericsMode::DesktopF32)
                .max_iterations(10_000)
                .sim_model(LlmConfig::llama2_7b())
                .build()
                .expect("bench serve config is valid");
            let server = CpuServer::new(&tm, cfg);
            let name = format!("serve/cpu_throughput lanes={lanes} decode-heavy");
            let mut tok_samples: Vec<f64> = Vec::new();
            let mut pass_samples: Vec<f64> = Vec::new();
            b.bench(&name, || {
                let report = server.serve(reqs.clone());
                tok_samples.push(report.metrics.tokens_per_s);
                pass_samples.push(report.metrics.weight_passes_per_step);
                report.metrics.iterations
            });
            tok_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            pass_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let passes = pass_samples[pass_samples.len() / 2];
            b.annotate(&name, "batch", lanes as f64);
            b.annotate(&name, "weight_passes_per_step", passes);
            b.annotate(&name, "weight_bytes_per_step", step_bytes * passes);
            b.annotate(&name, "tokens_per_s", tok_samples[tok_samples.len() / 2]);
        }
        report_speedup(
            &b,
            "batched serve speedup (4 lanes vs 1)",
            "serve/cpu_throughput lanes=1 decode-heavy",
            "serve/cpu_throughput lanes=4 decode-heavy",
        );
    }

    if artifacts_available() {
        let ws = WeightStore::load(&default_artifacts_dir()).unwrap();
        let am = TinyModel::load(&ws).unwrap();
        let mut ast = am.new_state();
        let mut alog = vec![0.0f32; am.vocab];
        let mut ai = 0u32;
        b.bench("hot/tiny_decode_step rust-desktop", || {
            if ast.pos >= am.n_ctx {
                ast.reset_for_reuse();
            }
            ai = (ai + 1) % am.vocab as u32;
            am.decode_step_into(&mut ast, ai, NumericsMode::DesktopF32, &mut alog);
            alog[0]
        });
        let mut ast2 = am.new_state();
        b.bench("hot/tiny_decode_step rust-accel", || {
            if ast2.pos >= am.n_ctx {
                ast2.reset_for_reuse();
            }
            ai = (ai + 1) % am.vocab as u32;
            am.decode_step_into(&mut ast2, ai, NumericsMode::Accelerator, &mut alog);
            alog[0]
        });

        #[cfg(feature = "pjrt")]
        pjrt_benches(&mut b);
        #[cfg(not(feature = "pjrt"))]
        println!("(pjrt feature disabled — PJRT benches skipped)");
    } else {
        println!("(artifacts not built — artifact-model benches skipped)");
    }

    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|r| r.join("BENCH_hotpath.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_hotpath.json"));
    match b.write_json(&out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}

/// Print the median-time ratio `slow / fast` for two recorded benches.
fn report_speedup(b: &Bencher, label: &str, slow: &str, fast: &str) {
    if let (Some(s), Some(f)) = (b.get(slow), b.get(fast)) {
        println!("  -> {label}: {:.2}x ({} vs {})", s.median_ns / f.median_ns, slow, fast);
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &mut Bencher) {
    use swiftkv::runtime::Engine;
    let eng = Engine::load(&default_artifacts_dir()).unwrap();
    for batch in [1usize, 8] {
        let mut bs = eng.new_state(batch).unwrap();
        let tokens = vec![7i32; batch];
        let mut pos = 0i32;
        b.bench(&format!("hot/pjrt_decode_step b{batch}"), || {
            if pos as usize >= eng.manifest.n_ctx {
                bs = eng.new_state(batch).unwrap();
                pos = 0;
            }
            let out = eng
                .decode_step(&mut bs, &tokens, &vec![pos; batch])
                .unwrap();
            pos += 1;
            out
        });
    }
}
