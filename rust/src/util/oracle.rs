//! Deliberately naive scalar attention oracle — the ground truth for the
//! fused-kernel property tests.
//!
//! Everything here is written for obviousness, not speed: scores are
//! fully materialized, the softmax is the textbook two-pass max/sum form,
//! and every loop is a plain scalar loop (no SIMD helpers, no fused
//! recurrences, no shared state). Arbitrary `n_heads` / `n_kv_heads` /
//! `d` / `len` are supported, so the same function is the reference for
//! MHA (`n_kv_heads == n_heads`), GQA (`1 < n_kv_heads < n_heads`) and
//! MQA (`n_kv_heads == 1`). `tests/prop_gqa_fused.rs` sweeps the fused
//! [`crate::kernels::MhaSwiftKv`] sweep against this across edge shapes.
//!
//! Layout contract (identical to the fused kernels): `q` is
//! `[n_heads * d]` head-major; `k`/`v` are token-major interleaved
//! `[len][n_kv_heads * d]`; query head `h` reads KV head
//! `h / (n_heads / n_kv_heads)`.

/// Scalar two-pass-softmax grouped-query attention over token-major
/// interleaved caches. Returns the `[n_heads * d]` head-major output.
///
/// Panics on inconsistent shapes or `len == 0` (attention over zero
/// tokens is undefined — the fused kernels' `finalize` panics too).
#[allow(clippy::too_many_arguments)]
pub fn gqa_attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    len: usize,
    scale: f32,
) -> Vec<f32> {
    assert!(n_heads > 0 && n_kv_heads > 0 && d > 0, "empty shape");
    assert!(len > 0, "attention over zero tokens is undefined");
    assert!(
        n_heads % n_kv_heads == 0,
        "n_heads must be a multiple of n_kv_heads"
    );
    assert_eq!(q.len(), n_heads * d, "q length");
    let row = n_kv_heads * d;
    assert!(k.len() >= len * row, "k cache too short");
    assert!(v.len() >= len * row, "v cache too short");
    let group = n_heads / n_kv_heads;

    let mut out = vec![0.0f32; n_heads * d];
    let mut scores = vec![0.0f32; len];
    for head in 0..n_heads {
        let kv = head / group;
        let qh = &q[head * d..(head + 1) * d];

        // pass 1: materialize every score, track the max
        let mut max = f32::NEG_INFINITY;
        for (t, slot) in scores.iter_mut().enumerate() {
            let ko = t * row + kv * d;
            let mut s = 0.0f32;
            for (&qi, &ki) in qh.iter().zip(&k[ko..ko + d]) {
                s += qi * ki;
            }
            let s = s * scale;
            *slot = s;
            if s > max {
                max = s;
            }
        }

        // pass 2: exponentiate against the max, sum the denominator
        let mut z = 0.0f32;
        for slot in scores.iter_mut() {
            *slot = (*slot - max).exp();
            z += *slot;
        }

        // weighted value sum, one token at a time
        let oh = &mut out[head * d..(head + 1) * d];
        for (t, &w) in scores.iter().enumerate() {
            let vo = t * row + kv * d;
            let w = w / z;
            for (o, &vi) in oh.iter_mut().zip(&v[vo..vo + d]) {
                *o += w * vi;
            }
        }
    }
    out
}

/// Plain multi-head convenience wrapper (`n_kv_heads == n_heads`).
#[allow(clippy::too_many_arguments)]
pub fn mha_attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_heads: usize,
    d: usize,
    len: usize,
    scale: f32,
) -> Vec<f32> {
    gqa_attend(q, k, v, n_heads, n_heads, d, len, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn single_token_returns_value_row_per_group() {
        // len = 1: softmax weight is 1, every query head copies its KV
        // head's value slice
        let mut rng = Rng::seed_from_u64(31);
        let (h, hkv, d) = (4usize, 2usize, 5usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(hkv * d, 1.0);
        let v = rng.uniform_vec(hkv * d, 1.0);
        let out = gqa_attend(&q, &k, &v, h, hkv, d, 1, 0.9);
        let group = h / hkv;
        for head in 0..h {
            let kv = head / group;
            for i in 0..d {
                assert!(
                    (out[head * d + i] - v[kv * d + i]).abs() < 1e-6,
                    "head {head} dim {i}"
                );
            }
        }
    }

    #[test]
    fn matches_native_single_head_attention() {
        // n_heads == n_kv_heads == 1 degenerates to the validated
        // per-head softmax reference
        let mut rng = Rng::seed_from_u64(32);
        let (d, len) = (16usize, 40usize);
        let q = rng.uniform_vec(d, 1.0);
        let k = rng.uniform_vec(len * d, 1.0);
        let v = rng.uniform_vec(len * d, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let p = crate::attention::HeadProblem::new(&q, &k, &v, d, len);
        let want = crate::attention::native::attend(&p);
        let got = gqa_attend(&q, &k, &v, 1, 1, d, len, scale);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "dim {i}: {a} vs {b}");
        }
    }

    #[test]
    fn identical_queries_in_a_group_share_output() {
        let mut rng = Rng::seed_from_u64(33);
        let (h, d, len) = (3usize, 7usize, 12usize);
        let qh = rng.uniform_vec(d, 1.0);
        let mut q = Vec::new();
        for _ in 0..h {
            q.extend_from_slice(&qh);
        }
        let k = rng.uniform_vec(len * d, 1.0);
        let v = rng.uniform_vec(len * d, 1.0);
        let out = gqa_attend(&q, &k, &v, h, 1, d, len, 0.5);
        for head in 1..h {
            assert_eq!(&out[..d], &out[head * d..(head + 1) * d]);
        }
    }

    #[test]
    fn output_stays_in_value_hull() {
        // softmax output is a convex combination of value rows
        let mut rng = Rng::seed_from_u64(34);
        let (h, hkv, d, len) = (6usize, 3usize, 4usize, 20usize);
        let row = hkv * d;
        let q = rng.uniform_vec(h * d, 2.0);
        let k = rng.uniform_vec(len * row, 2.0);
        let v = rng.uniform_vec(len * row, 2.0);
        let out = gqa_attend(&q, &k, &v, h, hkv, d, len, 1.0);
        let group = h / hkv;
        for head in 0..h {
            let kv = head / group;
            for i in 0..d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for t in 0..len {
                    let x = v[t * row + kv * d + i];
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                let o = out[head * d + i];
                assert!(o >= lo - 1e-5 && o <= hi + 1e-5, "head {head} dim {i}");
            }
        }
    }

    #[test]
    fn mha_wrapper_is_gqa_with_equal_heads() {
        let mut rng = Rng::seed_from_u64(35);
        let (h, d, len) = (2usize, 3usize, 9usize);
        let q = rng.uniform_vec(h * d, 1.0);
        let k = rng.uniform_vec(len * h * d, 1.0);
        let v = rng.uniform_vec(len * h * d, 1.0);
        assert_eq!(
            mha_attend(&q, &k, &v, h, d, len, 0.7),
            gqa_attend(&q, &k, &v, h, h, d, len, 0.7)
        );
    }

    #[test]
    #[should_panic(expected = "zero tokens")]
    fn zero_len_panics() {
        let _ = gqa_attend(&[1.0], &[], &[], 1, 1, 1, 0, 1.0);
    }
}
