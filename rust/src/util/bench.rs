//! Timing harness for `rust/benches/*` (offline replacement for criterion).
//!
//! Warmup, then adaptive measurement until a time budget or iteration cap
//! is reached; reports min/median/mean and a robust spread estimate.
//! Results can be serialized to JSON ([`Bencher::write_json`]) so each
//! bench run leaves a machine-readable perf trajectory (e.g.
//! `BENCH_hotpath.json` at the repository root).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Median absolute deviation (scaled) — robust spread.
    pub mad_ns: f64,
    /// Numeric annotations attached via [`Bencher::annotate`] (modeled
    /// bytes per op, group factors, …); serialized under `"extras"`.
    pub extras: BTreeMap<String, f64>,
}

impl Measurement {
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }

    /// JSON object with every recorded statistic.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("min_ns".to_string(), Json::Num(self.min_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("mad_ns".to_string(), Json::Num(self.mad_ns));
        m.insert(
            "throughput_per_sec".to_string(),
            Json::Num(self.throughput_per_sec()),
        );
        if !self.extras.is_empty() {
            let extras = self
                .extras
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect();
            m.insert("extras".to_string(), Json::Obj(extras));
        }
        Json::Obj(m)
    }
}

/// Bench runner with a global time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup_ms: u64, measure_ms: u64) -> Self {
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Run `f` repeatedly; `f` must return something observable to prevent
    /// the optimizer from deleting the work (use `std::hint::black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // warmup
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // measurement: sample batches, record per-iteration times
        let mut samples: Vec<f64> = Vec::new();
        let batch = warm_iters.clamp(1, 1024);
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.measure && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(dt);
            iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let m = Measurement {
            name: name.to_string(),
            iters,
            min_ns: min,
            median_ns: median,
            mean_ns: mean,
            mad_ns: mad,
            extras: BTreeMap::new(),
        };
        println!(
            "{:<48} time: [{} {} {}]  ({} iters)",
            m.name,
            fmt_ns(m.min_ns),
            fmt_ns(m.median_ns),
            fmt_ns(m.mean_ns),
            m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Look up a recorded measurement by exact name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }

    /// Attach a numeric annotation to an already-recorded measurement —
    /// modeled quantities (streamed KV bytes per token, group factor, …)
    /// that belong next to the timing in the JSON trajectory. No-op if
    /// the name was never benched.
    pub fn annotate(&mut self, name: &str, key: &str, value: f64) {
        if let Some(m) = self.results.iter_mut().find(|m| m.name == name) {
            m.extras.insert(key.to_string(), value);
        }
    }

    /// All results as a JSON document (`{schema, benchmarks: [...]}`).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert(
            "schema".to_string(),
            Json::Str("swiftkv-bench-v1".to_string()),
        );
        root.insert(
            "benchmarks".to_string(),
            Json::Arr(self.results.iter().map(Measurement::to_json).collect()),
        );
        Json::Obj(root)
    }

    /// Write the JSON document to `path` (overwrites).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Human-friendly nanosecond formatting (criterion-style).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(10, 50);
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.median_ns > 0.0);
        assert!(m.iters > 0);
    }

    #[test]
    fn ordering_of_workloads() {
        // a 10x bigger loop must measure meaningfully slower
        let mut b = Bencher::new(20, 100);
        let small = b
            .bench("small", || {
                let mut x = 0u64;
                for i in 0..50u64 {
                    x = x.wrapping_add(std::hint::black_box(i));
                }
                x
            })
            .median_ns;
        let large = b
            .bench("large", || {
                let mut x = 0u64;
                for i in 0..5000u64 {
                    x = x.wrapping_add(std::hint::black_box(i));
                }
                x
            })
            .median_ns;
        assert!(large > small * 3.0, "large {large} vs small {small}");
    }

    #[test]
    fn json_roundtrip() {
        let mut b = Bencher::new(5, 20);
        b.bench("alpha", || std::hint::black_box(3u64 * 7));
        b.bench("beta", || std::hint::black_box(11u64 + 2));
        let doc = b.to_json().to_string();
        let parsed = crate::util::Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("swiftkv-bench-v1"));
        let benches = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].get("name").unwrap().as_str(), Some("alpha"));
        assert!(benches[0].get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(b.get("beta").is_some());
        assert!(b.get("gamma").is_none());
    }

    #[test]
    fn annotations_survive_to_json() {
        let mut b = Bencher::new(5, 20);
        b.bench("kv_sweep", || std::hint::black_box(1u64 + 1));
        b.annotate("kv_sweep", "kv_bytes_per_token", 4096.0);
        b.annotate("kv_sweep", "group", 4.0);
        b.annotate("never_benched", "ignored", 1.0);
        assert_eq!(
            b.get("kv_sweep").unwrap().extras.get("kv_bytes_per_token"),
            Some(&4096.0)
        );
        let doc = b.to_json().to_string();
        let parsed = crate::util::Json::parse(&doc).unwrap();
        let benches = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        let extras = benches[0].get("extras").unwrap();
        assert_eq!(
            extras.get("kv_bytes_per_token").unwrap().as_f64(),
            Some(4096.0)
        );
        assert_eq!(extras.get("group").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
