//! Full per-token decode schedule of a model on SwiftKV-MHA — the source
//! of the Table III latency/throughput numbers and the Fig. 8(a)
//! module-level breakdown.
//!
//! The Dispatcher serializes the per-layer stages (Fig. 4's dataflow);
//! within each weight-bound GEMV stage, HBM streaming is overlapped with
//! compute up to `prefetch_eff` (Global-Buffer double buffering). The KV
//! stream of the attention stage is a fully sequential scan whose
//! addresses are known in advance, so it double-buffers perfectly:
//! `time = max(compute, kv_stream)`.

use super::{array, dispatcher, hbm, sfu, ArchConfig};
use crate::model::LlmConfig;

/// One scheduled stage: compute cycles, memory cycles, resulting time.
#[derive(Debug, Clone)]
pub struct StageCost {
    pub name: &'static str,
    /// Module group for the Fig. 8(a) breakdown.
    pub module: &'static str,
    pub compute: u64,
    pub memory: u64,
    pub time: u64,
}

/// Simulated cost of generating one token.
#[derive(Debug, Clone)]
pub struct TokenSim {
    pub model: String,
    pub n_ctx: usize,
    /// Per-layer stages (one layer's worth; layers are identical).
    pub layer_stages: Vec<StageCost>,
    /// Final stages (norm + LM head).
    pub head_stages: Vec<StageCost>,
    pub n_layers: usize,
    pub total_cycles: u64,
    pub latency_ms: f64,
    pub tokens_per_s: f64,
}

impl TokenSim {
    /// Fig. 8(a): cycles per module group, aggregated over all layers.
    pub fn module_breakdown(&self) -> Vec<(String, u64)> {
        let mut groups: Vec<(String, u64)> = Vec::new();
        let mut add = |name: &str, cycles: u64| {
            if let Some(g) = groups.iter_mut().find(|(n, _)| n == name) {
                g.1 += cycles;
            } else {
                groups.push((name.to_string(), cycles));
            }
        };
        for s in &self.layer_stages {
            add(s.module, s.time * self.n_layers as u64);
        }
        for s in &self.head_stages {
            add(s.module, s.time);
        }
        groups
    }

    /// Fraction of total latency spent in a module group.
    pub fn module_share(&self, module: &str) -> f64 {
        let total: u64 = self.module_breakdown().iter().map(|(_, c)| c).sum();
        let m = self
            .module_breakdown()
            .iter()
            .find(|(n, _)| n == module)
            .map(|(_, c)| *c)
            .unwrap_or(0);
        m as f64 / total as f64
    }
}

/// Bytes of packed W4 storage for a `[din, dout]` matrix + f32 scales.
fn w4_bytes(din: usize, dout: usize) -> u64 {
    (din as u64 * dout as u64) / 2 + dout as u64 * 4
}

/// Simulate one decode step at context length `n_ctx`.
pub fn simulate_token(arch: &ArchConfig, cfg: &LlmConfig, n_ctx: usize) -> TokenSim {
    let d = cfg.d_model;
    let kv_dim = cfg.n_kv_heads * cfg.d_head;
    let ffn = cfg.d_ffn;

    let mut stages: Vec<StageCost> = Vec::new();
    let weight_stage = |name: &'static str, module: &'static str, compute: u64, wbytes: u64| {
        let memory = hbm::stream_cycles(arch, wbytes);
        StageCost {
            name,
            module,
            compute,
            memory,
            time: arch.overlap(compute, memory),
        }
    };
    let sfu_stage = |name: &'static str, cycles: u64| StageCost {
        name,
        module: "SFU & Dispatch",
        compute: cycles,
        memory: 0,
        time: cycles,
    };

    // --- attention half of the layer ------------------------------------
    stages.push(sfu_stage(
        "attn RMSNorm + INT8 cast",
        sfu::rmsnorm_cycles(arch, d) + sfu::cast_cycles(arch, d) + dispatcher::scatter_vec_cycles(arch, d),
    ));
    stages.push(weight_stage(
        "QKV GEMV",
        "QKV/O projections",
        array::gemv_cycles(arch, d, d) + 2 * array::gemv_cycles(arch, d, kv_dim),
        w4_bytes(d, d) + 2 * w4_bytes(d, kv_dim),
    ));
    stages.push(sfu_stage(
        "QKV FXP32 cast + head split",
        3 * sfu::cast_cycles(arch, d.max(kv_dim)) + dispatcher::scatter_vec_cycles(arch, d),
    ));
    stages.push(StageCost {
        name: "decoder RoPE",
        module: "Attention (SKV)",
        compute: array::rope_cycles(arch, cfg.d_head),
        memory: 0,
        time: array::rope_cycles(arch, cfg.d_head),
    });
    // single-pass attention: per-head FXP32 scan; KV stream (INT8) is a
    // perfectly prefetchable sequential scan → time = max(compute, mem)
    {
        let compute = array::attention_cycles(arch, cfg.n_heads, cfg.d_head, n_ctx);
        let kv_bytes = cfg.kv_bytes_per_token_layer() * n_ctx as u64 // read
            + cfg.kv_bytes_per_token_layer(); // append write
        let memory = hbm::stream_cycles(arch, kv_bytes);
        stages.push(StageCost {
            name: "SwiftKV attention (all heads)",
            module: "Attention (SKV)",
            compute,
            memory,
            time: compute.max(memory),
        });
    }
    stages.push(sfu_stage(
        "attn out INT8 cast + gather",
        sfu::cast_cycles(arch, d) + dispatcher::gather_vec_cycles(arch, d),
    ));
    stages.push(weight_stage(
        "O GEMV",
        "QKV/O projections",
        array::gemv_cycles(arch, d, d),
        w4_bytes(d, d),
    ));
    stages.push(sfu_stage("residual EM-Add", sfu::elementwise_cycles(arch, d)));

    // --- MLP half of the layer -------------------------------------------
    stages.push(sfu_stage(
        "mlp RMSNorm + INT8 cast",
        sfu::rmsnorm_cycles(arch, d) + sfu::cast_cycles(arch, d),
    ));
    if cfg.gated_mlp {
        stages.push(weight_stage(
            "gate+up GEMV",
            "FFN",
            2 * array::gemv_cycles(arch, d, ffn),
            2 * w4_bytes(d, ffn),
        ));
        stages.push(sfu_stage(
            "SiLU + Hadamard + cast",
            2 * sfu::elementwise_cycles(arch, ffn) + sfu::cast_cycles(arch, ffn),
        ));
    } else {
        stages.push(weight_stage(
            "up GEMV",
            "FFN",
            array::gemv_cycles(arch, d, ffn),
            w4_bytes(d, ffn),
        ));
        stages.push(sfu_stage(
            "activation + cast",
            sfu::elementwise_cycles(arch, ffn) + sfu::cast_cycles(arch, ffn),
        ));
    }
    stages.push(weight_stage(
        "down GEMV",
        "FFN",
        array::gemv_cycles(arch, ffn, d),
        w4_bytes(ffn, d),
    ));
    stages.push(sfu_stage("residual EM-Add ", sfu::elementwise_cycles(arch, d)));

    // --- final norm + LM head ---------------------------------------------
    let head_stages = vec![
        StageCost {
            name: "final RMSNorm + cast",
            module: "SFU & Dispatch",
            compute: sfu::rmsnorm_cycles(arch, d) + sfu::cast_cycles(arch, d),
            memory: 0,
            time: sfu::rmsnorm_cycles(arch, d) + sfu::cast_cycles(arch, d),
        },
        weight_stage(
            "LM head GEMV",
            "LM head",
            array::gemv_cycles(arch, d, cfg.vocab),
            w4_bytes(d, cfg.vocab),
        ),
    ];

    let layer_cycles: u64 = stages.iter().map(|s| s.time).sum();
    let head_cycles: u64 = head_stages.iter().map(|s| s.time).sum();
    let total = layer_cycles * cfg.n_layers as u64 + head_cycles;
    let latency_ms = arch.cycles_to_ms(total);

    TokenSim {
        model: cfg.name.to_string(),
        n_ctx,
        layer_stages: stages,
        head_stages,
        n_layers: cfg.n_layers,
        total_cycles: total,
        latency_ms,
        tokens_per_s: 1000.0 / latency_ms,
    }
}

/// Average decode latency over a generation whose context grows from
/// `start_ctx` to `start_ctx + steps` (Table III measures at a fixed
/// context; this is used by the serving metrics).
pub fn average_latency_ms(arch: &ArchConfig, cfg: &LlmConfig, start_ctx: usize, steps: usize) -> f64 {
    let mut acc = 0.0;
    for i in 0..steps {
        acc += simulate_token(arch, cfg, start_ctx + i).latency_ms;
    }
    acc / steps as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    /// Table III: LLaMA2-7B decode latency 12.3 ms, 81.5 token/s.
    #[test]
    fn calibration_llama2() {
        let sim = simulate_token(&arch(), &LlmConfig::llama2_7b(), 512);
        assert!(
            (sim.latency_ms - 12.3).abs() < 1.0,
            "latency {:.2} ms vs paper 12.3",
            sim.latency_ms
        );
        assert!(
            (sim.tokens_per_s - 81.5).abs() < 7.0,
            "speed {:.1} tok/s vs paper 81.5",
            sim.tokens_per_s
        );
    }

    /// Table III: ChatGLM-6B decode latency 10.4 ms, 96.3 token/s.
    #[test]
    fn calibration_chatglm() {
        let sim = simulate_token(&arch(), &LlmConfig::chatglm_6b(), 512);
        assert!(
            (sim.latency_ms - 10.4).abs() < 1.3,
            "latency {:.2} ms vs paper 10.4",
            sim.latency_ms
        );
    }

    /// Fig. 8(a): attention is ≈ 3.19 % of end-to-end latency — a 13.48×
    /// reduction from the 43 % reported by DFX [5].
    #[test]
    fn fig8a_attention_share() {
        let sim = simulate_token(&arch(), &LlmConfig::llama2_7b(), 512);
        let share = sim.module_share("Attention (SKV)");
        assert!(
            (0.022..0.045).contains(&share),
            "attention share {:.2}% vs paper 3.19%",
            share * 100.0
        );
        let reduction = 0.43 / share;
        assert!(
            (9.5..20.0).contains(&reduction),
            "reduction {reduction:.1}× vs paper 13.48×"
        );
    }

    #[test]
    fn breakdown_sums_to_total() {
        let sim = simulate_token(&arch(), &LlmConfig::llama2_7b(), 512);
        let sum: u64 = sim.module_breakdown().iter().map(|(_, c)| c).sum();
        assert_eq!(sum, sim.total_cycles);
    }

    #[test]
    fn ffn_dominates_gemv_bound_decode() {
        // W4A8 decode is weight-bound: FFN > QKV/O > attention
        let sim = simulate_token(&arch(), &LlmConfig::llama2_7b(), 512);
        let get = |m: &str| {
            sim.module_breakdown()
                .iter()
                .find(|(n, _)| n == m)
                .map(|(_, c)| *c)
                .unwrap()
        };
        assert!(get("FFN") > get("QKV/O projections"));
        assert!(get("QKV/O projections") > get("Attention (SKV)"));
    }

    #[test]
    fn latency_grows_mildly_with_context() {
        let a = arch();
        let cfg = LlmConfig::llama2_7b();
        let short = simulate_token(&a, &cfg, 128).latency_ms;
        let long = simulate_token(&a, &cfg, 2048).latency_ms;
        assert!(long > short);
        // decode is weight-bound: 16× context costs well under 2× latency
        assert!(long / short < 1.6, "{short} → {long}");
    }

    #[test]
    fn gqa_model_cheaper_kv() {
        let a = arch();
        let mha = simulate_token(&a, &LlmConfig::llama2_7b(), 2048);
        let gqa = simulate_token(&a, &LlmConfig::llama3_8b(), 2048);
        let mha_attn = mha
            .module_breakdown()
            .iter()
            .find(|(n, _)| n == "Attention (SKV)")
            .unwrap()
            .1;
        let gqa_attn = gqa
            .module_breakdown()
            .iter()
            .find(|(n, _)| n == "Attention (SKV)")
            .unwrap()
            .1;
        // same query-head count ⇒ same compute, but the KV stream is 4×
        // smaller; at long context the attention stage must not be larger
        assert!(gqa_attn <= mha_attn);
    }

    #[test]
    fn average_latency_monotone_window() {
        let a = arch();
        let cfg = LlmConfig::llama2_7b();
        let early = average_latency_ms(&a, &cfg, 64, 16);
        let late = average_latency_ms(&a, &cfg, 1024, 16);
        assert!(late >= early);
    }
}
