//! CPU batch serving over the pure-Rust tiny model — the default-feature
//! serving path (no PJRT required).
//!
//! Same continuous-batching shape as the PJRT [`super::server`]: queue →
//! [`super::batcher::Batcher`] → one batch step → greedy sample → retire.
//! Prompt tokens are consumed **chunked**: a prefill lane feeds up to
//! [`CpuServeOptions::prefill_chunk`] prompt tokens per iteration through
//! the fused causal sweep ([`TinyModel::prefill_into`]) instead of one
//! decode step per token, computing the logits projection only when the
//! chunk reaches the last prompt token — the TTFT win of chunked
//! prefill. The chunk is bounded by default so one long prompt cannot
//! stall the decode lanes sharing the iteration.
//! The batch step fans the active lanes out across OS threads with
//! `std::thread::scope`; each lane owns its [`DecodeState`] (per-layer
//! block tables + [`crate::kernels::DecodeScratch`]), so a steady-state
//! lane step performs zero heap allocation and lanes never contend on
//! memory — the KV rows live in **one shared
//! [`crate::kernels::BlockPool`]** that every lane draws fixed-size
//! blocks from, sized by [`CpuServeOptions::kv_block_len`] /
//! [`CpuServeOptions::kv_pool_blocks`]; the only contended state is the
//! pool's free list, touched once per `block_len` tokens per layer.
//! Grouped-query models serve unchanged: the pool's rows are sized
//! `n_kv_heads * d_head` by [`TinyModel::new_pool`], so a GQA model cuts
//! pooled KV memory (and streamed KV bytes per step) by the group
//! factor. Recycled lanes restart at position 0 via
//! [`DecodeState::reset_for_reuse`], which returns their blocks to the
//! pool for other lanes — reclamation, not re-allocation.

use super::batcher::Batcher;
use super::metrics::{Percentiles, ServeMetrics};
use super::session::Session;
use crate::kernels::BlockPool;
use crate::model::tiny::{argmax, DecodeState};
use crate::model::{LlmConfig, NumericsMode, Request, TinyModel, DEFAULT_KV_BLOCK_LEN};
use crate::sim::{layer_sched, ArchConfig};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Default prompt tokens a lane may consume in one chunked-prefill step
/// (`swiftkv serve --prefill-chunk` overrides; `0` = whole prompt).
/// Bounded so one long prompt cannot monopolize an iteration: step wall
/// time is the max over lanes, so an unbounded prefill chunk would stall
/// every decode lane for the whole prompt instead of `8` tokens' worth.
pub const DEFAULT_PREFILL_CHUNK: usize = 8;

/// CPU serving configuration.
#[derive(Debug, Clone)]
pub struct CpuServeOptions {
    /// Number of decode lanes (threads at full occupancy).
    pub lanes: usize,
    /// Numerics mode every lane decodes in.
    pub mode: NumericsMode,
    /// Safety cap on batch iterations (0 = unlimited).
    pub max_iterations: u64,
    /// Model config used for the simulated-accelerator metrics.
    pub sim_model: LlmConfig,
    /// Tokens per KV cache block in the shared pool.
    pub kv_block_len: usize,
    /// Total blocks in the shared pool; `0` sizes it for the worst case
    /// (`lanes × blocks_per_seq`, i.e. every lane at full context).
    pub kv_pool_blocks: usize,
    /// Max prompt tokens per lane per iteration (chunked prefill
    /// through the fused causal sweep); `0` = whole remaining prompt in
    /// one step. `1` reproduces the old one-decode-step-per-prompt-token
    /// prefill.
    pub prefill_chunk: usize,
}

impl Default for CpuServeOptions {
    fn default() -> Self {
        CpuServeOptions {
            lanes: 4,
            mode: NumericsMode::DesktopF32,
            max_iterations: 0,
            sim_model: LlmConfig::llama2_7b(),
            kv_block_len: DEFAULT_KV_BLOCK_LEN,
            kv_pool_blocks: 0,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
        }
    }
}

/// Result of a CPU serving run.
pub struct CpuServeReport {
    pub sessions: Vec<Session>,
    pub metrics: ServeMetrics,
    /// The shared KV block pool the lanes served from (all blocks are
    /// back on its free list by the time `serve` returns).
    pub kv_pool: Arc<BlockPool>,
}

/// The CPU decode server.
pub struct CpuServer<'m> {
    model: &'m TinyModel,
    opts: CpuServeOptions,
}

impl<'m> CpuServer<'m> {
    pub fn new(model: &'m TinyModel, opts: CpuServeOptions) -> Self {
        assert!(opts.lanes >= 1, "need at least one lane");
        assert!(opts.kv_block_len >= 1, "need at least one token per KV block");
        assert!(
            model.n_kv_heads >= 1 && model.n_heads % model.n_kv_heads == 0,
            "model GQA shape invalid: {} query heads over {} KV heads",
            model.n_heads,
            model.n_kv_heads
        );
        CpuServer { model, opts }
    }

    /// Blocks the shared pool will hold: the configured count, or the
    /// worst case (every lane at full context) when unset.
    fn pool_blocks(&self) -> usize {
        if self.opts.kv_pool_blocks > 0 {
            self.opts.kv_pool_blocks
        } else {
            self.opts.lanes * self.model.blocks_per_seq(self.opts.kv_block_len)
        }
    }

    /// Serve a request stream to completion (arrival times are honoured in
    /// iteration order, like the PJRT server).
    pub fn serve(&self, requests: Vec<Request>) -> CpuServeReport {
        let lanes = self.opts.lanes;
        let model = self.model;
        let mode = self.opts.mode;
        let vocab = model.vocab;
        let mut batcher = Batcher::new(lanes, model.n_ctx);
        // one block pool for every lane: blocks migrate between lanes as
        // sequences retire (reclamation in reset_for_reuse / Drop)
        let kv_pool = model.new_pool(self.pool_blocks(), self.opts.kv_block_len);
        let mut states: Vec<DecodeState> = (0..lanes)
            .map(|_| model.new_state_in(kv_pool.clone()))
            .collect();
        let mut logits = vec![0.0f32; lanes * vocab];

        let mut pending: VecDeque<Request> = requests.into();
        let t0 = Instant::now();
        let mut iteration = 0u64;
        let mut step_ms: Vec<f64> = Vec::new();
        let mut occupancy_acc = 0.0;
        let mut sim_cycles: u64 = 0;
        let arch = ArchConfig::default();
        let mut iter_end_ms: Vec<f64> = Vec::new();

        // 0 = unbounded: a whole remaining prompt in one chunked step
        let max_prefill = if self.opts.prefill_chunk == 0 {
            usize::MAX
        } else {
            self.opts.prefill_chunk
        };

        loop {
            // admit every request whose arrival time has passed
            let now_ms = t0.elapsed().as_secs_f64() * 1e3;
            while let Some(r) = pending.front() {
                if r.arrival_ms as f64 <= now_ms {
                    let r = pending.pop_front().unwrap();
                    if let Err(rejected) = batcher.submit(r) {
                        // oversized for the context window: dropped by
                        // design, but never silently — the batcher
                        // counted it and ServeMetrics::requests_rejected
                        // surfaces it at the end of the run
                        drop(rejected);
                    }
                } else {
                    break;
                }
            }
            batcher.admit(iteration);
            if batcher.is_drained() {
                if pending.is_empty() {
                    break;
                }
                // idle until the next arrival
                std::thread::sleep(std::time::Duration::from_micros(200));
                continue;
            }

            let chunks = batcher.gather_chunks(max_prefill);
            let fed: Vec<usize> = chunks.iter().map(|c| c.tokens.len()).collect();
            let sampling: Vec<bool> = chunks.iter().map(|c| c.active && c.samples).collect();
            let was_active: Vec<bool> = chunks.iter().map(|c| c.active).collect();
            occupancy_acc += batcher.occupancy();

            // lanes starting a fresh session restart their decode state
            // (their retired predecessor's blocks were already reclaimed
            // at retirement below; this also covers any future path that
            // hands a lane a new session without an idle iteration)
            for (i, st) in states.iter_mut().enumerate() {
                if chunks[i].active && chunks[i].pos == 0 && st.pos != 0 {
                    st.reset_for_reuse();
                }
            }

            // fused batch step: one thread per active lane; a lone lane
            // runs inline to skip the spawn overhead. Prefill lanes
            // consume their whole chunk through the fused causal sweep
            // and only compute the logits projection when the chunk ends
            // on a sampling position.
            let ts = Instant::now();
            let n_active = chunks.iter().filter(|c| c.active).count();
            let lane_step = |chunk: &super::batcher::LaneChunk<'_>,
                             st: &mut DecodeState,
                             out: &mut [f32]| {
                if chunk.tokens.len() == 1 && chunk.samples {
                    // decode step (or final single-token prompt chunk):
                    // the established single-token hot path
                    model.decode_step_into(st, chunk.tokens[0], mode, out);
                } else {
                    let logits_out = if chunk.samples { Some(out) } else { None };
                    model.prefill_into(st, chunk.tokens, mode, logits_out);
                }
            };
            if n_active <= 1 {
                for (i, (st, out)) in states
                    .iter_mut()
                    .zip(logits.chunks_mut(vocab))
                    .enumerate()
                {
                    if chunks[i].active {
                        lane_step(&chunks[i], st, out);
                    }
                }
            } else {
                std::thread::scope(|scope| {
                    for (i, (st, out)) in states
                        .iter_mut()
                        .zip(logits.chunks_mut(vocab))
                        .enumerate()
                    {
                        if !chunks[i].active {
                            continue;
                        }
                        let chunk = chunks[i];
                        let lane_step = &lane_step;
                        scope.spawn(move || {
                            lane_step(&chunk, st, out);
                        });
                    }
                });
            }
            step_ms.push(ts.elapsed().as_secs_f64() * 1e3);

            // simulated accelerator cost: a chunked iteration is billed
            // one simulated decode step per consumed token position —
            // lanes run in lockstep, so the batch pays the longest chunk
            // at the largest live context, token by token. With fed == 1
            // everywhere this reduces exactly to the old
            // one-simulate_token-per-iteration accounting.
            let max_fed = chunks
                .iter()
                .filter(|c| c.active)
                .map(|c| c.tokens.len())
                .max()
                .unwrap_or(1);
            let base_ctx = chunks
                .iter()
                .filter(|c| c.active)
                .map(|c| c.pos)
                .max()
                .unwrap_or(0);
            for k in 1..=max_fed {
                let sim = layer_sched::simulate_token(&arch, &self.opts.sim_model, base_ctx + k);
                sim_cycles += sim.total_cycles;
            }

            // greedy sample — only for lanes whose chunk ended on a
            // sampling position; idle lanes and mid-prompt prefill
            // chunks skip the argmax entirely (their logits are stale
            // or were never computed)
            let samples: Vec<u32> = (0..lanes)
                .map(|i| {
                    if sampling[i] {
                        argmax(&logits[i * vocab..(i + 1) * vocab]) as u32
                    } else {
                        0
                    }
                })
                .collect();
            let retired = batcher.scatter_chunk_outputs(&fed, &samples, iteration);
            if !retired.is_empty() {
                // reclaim at retirement, not at the lane's next admission:
                // an idle lane must not pin a dead sequence's blocks while
                // other lanes grow (a lane inactive after scatter has no
                // session, so its blocks are unreachable)
                let (_, _, still_active) = batcher.gather_inputs();
                for (i, st) in states.iter_mut().enumerate() {
                    if was_active[i] && !still_active[i] && st.pos != 0 {
                        st.reset_for_reuse();
                    }
                }
            }
            iter_end_ms.push(t0.elapsed().as_secs_f64() * 1e3);

            iteration += 1;
            if self.opts.max_iterations > 0 && iteration >= self.opts.max_iterations {
                break;
            }
        }

        // retire the lane states: every block returns to the pool (the
        // Drop impl covers panicking paths; this makes it explicit and
        // lets callers assert full reclamation on the returned pool)
        drop(states);
        debug_assert_eq!(kv_pool.free_blocks(), kv_pool.total_blocks());

        let wall_s = t0.elapsed().as_secs_f64();
        // admission accounting must reach the metrics: a rejected
        // (oversized) request is dropped by design, never silently
        let (requests_admitted, requests_rejected) = batcher.counters();
        let sessions = batcher.finished;
        let total_tokens: usize = sessions.iter().map(|s| s.generated.len()).sum();
        let at_ms = |it: u64| -> f64 {
            iter_end_ms
                .get(it as usize)
                .copied()
                .unwrap_or(wall_s * 1e3)
        };
        let latencies: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.finished_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();
        let ttfts: Vec<f64> = sessions
            .iter()
            .filter_map(|s| s.first_token_at.map(|f| at_ms(f) - at_ms(s.admitted_at)))
            .collect();

        let zero = Percentiles {
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
            mean: 0.0,
            max: 0.0,
        };
        let sim_ms = arch.cycles_to_ms(sim_cycles);
        let metrics = ServeMetrics {
            requests: sessions.len(),
            requests_admitted,
            requests_rejected,
            total_tokens_generated: total_tokens,
            iterations: iteration,
            wall_s,
            step_ms: Percentiles::compute(&step_ms).unwrap_or(zero),
            request_latency_ms: Percentiles::compute(&latencies).unwrap_or(zero),
            ttft_ms: Percentiles::compute(&ttfts).unwrap_or(zero),
            mean_occupancy: if iteration > 0 {
                occupancy_acc / iteration as f64
            } else {
                0.0
            },
            tokens_per_s: if wall_s > 0.0 {
                total_tokens as f64 / wall_s
            } else {
                0.0
            },
            simulated_accel_ms: sim_ms,
            simulated_tokens_per_s: if sim_ms > 0.0 {
                total_tokens as f64 / (sim_ms / 1e3)
            } else {
                0.0
            },
        };
        CpuServeReport {
            sessions,
            metrics,
            kv_pool,
        }
    }
}
