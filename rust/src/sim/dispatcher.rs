//! Dispatcher cycle model (§IV-A): moves vectors between the Processor
//! Array, the Global Buffer and the SFU — splitting `x ∈ R^4096` across 32
//! processors and collecting results.

use super::ArchConfig;

/// Cycles to move `bytes` through the dispatcher crossbar.
pub fn move_cycles(arch: &ArchConfig, bytes: u64) -> u64 {
    bytes.div_ceil(arch.dispatch_bytes_per_cycle) + 2
}

/// Scatter an f32/FXP32 vector of `n` elements to the array.
pub fn scatter_vec_cycles(arch: &ArchConfig, n: usize) -> u64 {
    move_cycles(arch, 4 * n as u64)
}

/// Gather per-head results (`n` elements) back to the buffer/SFU.
pub fn gather_vec_cycles(arch: &ArchConfig, n: usize) -> u64 {
    move_cycles(arch, 4 * n as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_move_cost() {
        let a = ArchConfig::default();
        // 4096 f32 = 16 KiB at 128 B/cycle = 128 cycles + overhead
        assert_eq!(scatter_vec_cycles(&a, 4096), 128 + 2);
    }

    #[test]
    fn small_moves_dominated_by_overhead() {
        let a = ArchConfig::default();
        assert_eq!(move_cycles(&a, 8), 3);
    }
}
