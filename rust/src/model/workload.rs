//! Synthetic decode workload generation (requests for the coordinator and
//! the bench harness; stands in for the paper's PG-19 prompt sampling).

use crate::util::Rng;

/// A decode request: prompt tokens + number of tokens to generate.
///
/// Construct through the builder —
/// `Request::new(id, prompt).gen_len(8).arrival_ms(40).deadline_ms(500)`
/// — not a struct literal. The struct is `#[non_exhaustive]`, so
/// downstream code (tests, benches, other crates) cannot construct it
/// field-by-field: new scheduling fields can land without touching
/// every call site, and the five-field literal stops spreading through
/// the test suite. Fields stay `pub` for reading.
#[derive(Debug, Clone, PartialEq, Eq)]
#[non_exhaustive]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub gen_len: usize,
    /// Arrival time in milliseconds from stream start (Poisson process).
    pub arrival_ms: u64,
    /// Wall-clock deadline in milliseconds after arrival; `0` = none.
    /// An admitted session still running past its deadline is cancelled
    /// cleanly by the server (KV blocks reclaimed, lane recycled) and
    /// surfaces as [`crate::coordinator::ServeMetrics::deadline_expired`].
    pub deadline_ms: u64,
}

impl Request {
    /// A request with the given prompt, generating one token, arriving
    /// at stream start with no deadline. Chain the builder setters to
    /// override.
    pub fn new(id: u64, prompt: Vec<u32>) -> Request {
        Request {
            id,
            prompt,
            gen_len: 1,
            arrival_ms: 0,
            deadline_ms: 0,
        }
    }

    /// Number of tokens to generate (default 1).
    pub fn gen_len(mut self, n: usize) -> Request {
        self.gen_len = n;
        self
    }

    /// Arrival time in ms from stream start (default 0).
    pub fn arrival_ms(mut self, t: u64) -> Request {
        self.arrival_ms = t;
        self
    }

    /// Wall-clock deadline in ms after arrival (default 0 = none).
    pub fn deadline_ms(mut self, d: u64) -> Request {
        self.deadline_ms = d;
        self
    }
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub num_requests: usize,
    pub vocab: usize,
    pub prompt_len: (usize, usize),
    pub gen_len: (usize, usize),
    /// Mean inter-arrival gap in ms (0 = all arrive at t=0).
    pub mean_gap_ms: f64,
    /// Per-request deadline in ms after arrival (0 = none).
    pub deadline_ms: u64,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            num_requests: 16,
            vocab: 512,
            prompt_len: (4, 32),
            gen_len: (8, 64),
            mean_gap_ms: 0.0,
            deadline_ms: 0,
            seed: 0,
        }
    }
}

/// Deterministic request-stream generator.
pub struct WorkloadGen {
    spec: WorkloadSpec,
}

impl WorkloadGen {
    pub fn new(spec: WorkloadSpec) -> Self {
        assert!(spec.prompt_len.0 >= 1 && spec.prompt_len.1 >= spec.prompt_len.0);
        assert!(spec.gen_len.0 >= 1 && spec.gen_len.1 >= spec.gen_len.0);
        assert!(spec.vocab >= 2);
        WorkloadGen { spec }
    }

    /// Generate the full request stream, sorted by arrival time.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::seed_from_u64(self.spec.seed);
        let mut t_ms = 0f64;
        (0..self.spec.num_requests)
            .map(|i| {
                let plen = rng.gen_range(self.spec.prompt_len.0, self.spec.prompt_len.1 + 1);
                let glen = rng.gen_range(self.spec.gen_len.0, self.spec.gen_len.1 + 1);
                let prompt = (0..plen)
                    .map(|_| rng.gen_range(0, self.spec.vocab) as u32)
                    .collect();
                if self.spec.mean_gap_ms > 0.0 {
                    t_ms += rng.gen_exp(self.spec.mean_gap_ms);
                }
                Request::new(i as u64, prompt)
                    .gen_len(glen)
                    .arrival_ms(t_ms as u64)
                    .deadline_ms(self.spec.deadline_ms)
            })
            .collect()
    }

    /// Total tokens (prompt + generated) in a stream — normalization for
    /// throughput metrics.
    pub fn total_tokens(reqs: &[Request]) -> usize {
        reqs.iter().map(|r| r.prompt.len() + r.gen_len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_defaults_and_setters() {
        let r = Request::new(3, vec![1, 2]);
        assert_eq!((r.id, r.gen_len, r.arrival_ms, r.deadline_ms), (3, 1, 0, 0));
        assert_eq!(r.prompt, vec![1, 2]);
        let r = Request::new(0, vec![5]).gen_len(7).arrival_ms(40).deadline_ms(500);
        assert_eq!((r.gen_len, r.arrival_ms, r.deadline_ms), (7, 40, 500));
    }

    #[test]
    fn deterministic_stream() {
        let spec = WorkloadSpec {
            seed: 7,
            ..Default::default()
        };
        let a = WorkloadGen::new(spec.clone()).generate();
        let b = WorkloadGen::new(spec).generate();
        assert_eq!(a, b);
    }

    #[test]
    fn lengths_within_bounds() {
        let spec = WorkloadSpec {
            num_requests: 100,
            prompt_len: (3, 10),
            gen_len: (5, 9),
            ..Default::default()
        };
        for r in WorkloadGen::new(spec).generate() {
            assert!((3..=10).contains(&r.prompt.len()));
            assert!((5..=9).contains(&r.gen_len));
            assert!(r.prompt.iter().all(|&t| (t as usize) < 512));
        }
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let spec = WorkloadSpec {
            num_requests: 50,
            mean_gap_ms: 5.0,
            seed: 3,
            ..Default::default()
        };
        let reqs = WorkloadGen::new(spec).generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        assert!(reqs.last().unwrap().arrival_ms > 0);
    }

    #[test]
    fn zero_gap_means_batch_arrival() {
        let reqs = WorkloadGen::new(WorkloadSpec::default()).generate();
        assert!(reqs.iter().all(|r| r.arrival_ms == 0));
    }

    #[test]
    fn token_accounting() {
        let reqs = WorkloadGen::new(WorkloadSpec {
            num_requests: 5,
            ..Default::default()
        })
        .generate();
        let total = WorkloadGen::total_tokens(&reqs);
        assert_eq!(
            total,
            reqs.iter().map(|r| r.prompt.len() + r.gen_len).sum::<usize>()
        );
    }
}
