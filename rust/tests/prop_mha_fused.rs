//! Property tests: the fused multi-head SwiftKV kernels are equivalent to
//! the per-head reference path across random shapes — f32 to within 1e-5
//! relative (the dot product re-associates), FXP32 **bit-for-bit** (all
//! integer ops are issued in the per-head order). Shapes deliberately
//! include `len = 1`, odd `d`, `d` not a multiple of the SIMD unroll
//! width, and single-head states; a dedicated case checks incremental
//! `extend` equivalence.

use swiftkv::attention::fxp_swiftkv::{attend_fxp, FxpHeadProblem};
use swiftkv::attention::{swiftkv as swiftkv_attn, HeadProblem};
use swiftkv::fxp::{vector, Exp2Lut, Fxp32};
use swiftkv::kernels::simd;
use swiftkv::kernels::{FxpMhaSwiftKv, MhaSwiftKv};
use swiftkv::util::prop;
use swiftkv::util::Rng;

/// Shapes covering the edge cases: single token, odd head dim, head dim
/// below/above/misaligned-with the unroll width.
const HEADS: [usize; 4] = [1, 2, 3, 8];
const DIMS: [usize; 7] = [1, 2, 3, 5, 7, 16, 33];
const LENS: [usize; 5] = [1, 2, 3, 17, 96];

struct MhaData {
    h: usize,
    d: usize,
    len: usize,
    q: Vec<f32>,
    /// Token-major interleaved `[len][h*d]` caches.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl MhaData {
    fn random(rng: &mut Rng, scale: f32) -> MhaData {
        let h = HEADS[rng.gen_range(0, HEADS.len())];
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        MhaData {
            h,
            d,
            len,
            q: rng.uniform_vec(h * d, scale),
            k: rng.uniform_vec(len * h * d, scale),
            v: rng.uniform_vec(len * h * d, scale),
        }
    }

    /// Gather one head of a token-major cache into a contiguous
    /// head-major `[len, d]` buffer (what the per-head path consumes).
    fn gather(&self, cache: &[f32], head: usize) -> Vec<f32> {
        swiftkv::kernels::gather_head(cache, head, self.h, self.d, self.len)
    }

    fn head_q(&self, head: usize) -> &[f32] {
        &self.q[head * self.d..(head + 1) * self.d]
    }
}

#[test]
fn prop_fused_f32_matches_per_head_attend() {
    prop::check("fused f32 == per-head swiftkv::attend", 40, |rng, _| {
        let data = MhaData::random(rng, 1.0);
        let (h, d, len) = (data.h, data.d, data.len);
        let scale = 1.0 / (d as f32).sqrt();

        let mut mha = MhaSwiftKv::new(h, d);
        let mut out = vec![0.0f32; h * d];
        mha.attend(&data.q, &data.k, &data.v, len, scale, &mut out);

        for head in 0..h {
            let kh = data.gather(&data.k, head);
            let vh = data.gather(&data.v, head);
            let p = HeadProblem::new(data.head_q(head), &kh, &vh, d, len);
            let want = swiftkv_attn::attend(&p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= 5e-5 * (1.0 + b.abs()),
                    "h={h} d={d} len={len} head={head} dim={i}: {a} vs {b}"
                );
            }
        }
    });
}

#[test]
fn prop_fused_fxp_bit_exact_vs_per_head() {
    prop::check("fused fxp == per-head attend_fxp (bit-exact)", 30, |rng, _| {
        let data = MhaData::random(rng, 1.0);
        let (h, d, len) = (data.h, data.d, data.len);
        let lut = Exp2Lut::new();
        let scale = Fxp32::from_f64(1.0 / (d as f64).sqrt());

        let qq = vector::quantize(&data.q);
        let kq = vector::quantize(&data.k);
        let vq = vector::quantize(&data.v);
        let mut mha = FxpMhaSwiftKv::new(h, d);
        let mut out = vec![Fxp32::ZERO; h * d];
        mha.attend(&lut, &qq, &kq, &vq, len, scale, &mut out);

        for head in 0..h {
            let kh = data.gather(&data.k, head);
            let vh = data.gather(&data.v, head);
            let p = FxpHeadProblem::quantize(data.head_q(head), &kh, &vh, d, len);
            let want = attend_fxp(&lut, &p);
            for (i, (a, b)) in out[head * d..(head + 1) * d].iter().zip(&want).enumerate() {
                assert_eq!(
                    a.raw(),
                    b.raw(),
                    "h={h} d={d} len={len} head={head} dim={i}: raw bits diverged"
                );
            }
        }
    });
}

#[test]
fn prop_incremental_extend_equals_one_shot() {
    prop::check("chunked extend == one-shot sweep", 30, |rng, _| {
        let data = MhaData::random(rng, 1.0);
        let (h, d, len) = (data.h, data.d, data.len);
        let scale = 1.0 / (d as f32).sqrt();
        let cut = rng.gen_range(0, len + 1);

        // f32: chunked extend must be bit-identical to the one-shot sweep
        let mut one = MhaSwiftKv::new(h, d);
        let mut a = vec![0.0f32; h * d];
        one.attend(&data.q, &data.k, &data.v, len, scale, &mut a);
        let mut two = MhaSwiftKv::new(h, d);
        two.extend(&data.q, &data.k, &data.v, 0, cut, scale);
        two.extend(&data.q, &data.k, &data.v, cut, len, scale);
        let mut b = vec![0.0f32; h * d];
        two.finalize_into(&mut b);
        assert_eq!(a, b, "h={h} d={d} len={len} cut={cut}");

        // fxp: same, on raw bits
        let lut = Exp2Lut::new();
        let fscale = Fxp32::from_f64(1.0 / (d as f64).sqrt());
        let qq = vector::quantize(&data.q);
        let kq = vector::quantize(&data.k);
        let vq = vector::quantize(&data.v);
        let mut fone = FxpMhaSwiftKv::new(h, d);
        let mut fa = vec![Fxp32::ZERO; h * d];
        fone.attend(&lut, &qq, &kq, &vq, len, fscale, &mut fa);
        let mut ftwo = FxpMhaSwiftKv::new(h, d);
        ftwo.extend(&lut, &qq, &kq, &vq, 0, cut, fscale);
        ftwo.extend(&lut, &qq, &kq, &vq, cut, len, fscale);
        let mut fb = vec![Fxp32::ZERO; h * d];
        ftwo.finalize_into(&mut fb);
        for (i, (x, y)) in fa.iter().zip(&fb).enumerate() {
            assert_eq!(x.raw(), y.raw(), "fxp dim {i} (cut={cut})");
        }
    });
}

#[test]
fn prop_finalize_into_matches_finalize() {
    prop::check("SwiftKvState::finalize_into == finalize", 20, |rng, _| {
        let d = DIMS[rng.gen_range(0, DIMS.len())];
        let len = LENS[rng.gen_range(0, LENS.len())];
        let q = rng.uniform_vec(d, 1.0);
        let k = rng.uniform_vec(len * d, 1.0);
        let v = rng.uniform_vec(len * d, 1.0);
        let p = HeadProblem::new(&q, &k, &v, d, len);
        let mut st = swiftkv_attn::SwiftKvState::new(d);
        swiftkv_attn::extend(&mut st, &p, 0, len);
        let a = st.finalize();
        let mut b = vec![0.0f32; d];
        st.finalize_into(&mut b);
        assert_eq!(a, b);
    });
}

#[test]
fn prop_simd_dot_matches_sequential() {
    prop::check("simd::dot == sequential dot", 30, |rng, _| {
        let n = rng.gen_range(0, 300);
        let a = rng.uniform_vec(n, 2.0);
        let b = rng.uniform_vec(n, 2.0);
        let got = simd::dot(&a, &b);
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(
            (got - want).abs() <= 1e-5 * (1.0 + want.abs()),
            "n={n}: {got} vs {want}"
        );
    });
}
