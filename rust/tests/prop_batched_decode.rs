//! Property tests: the batched decode step
//! (`TinyModel::decode_steps_into` — gather activations → one shared
//! W4A8 weight pass per projection → per-lane fused attention) versus
//! the solo `decode_step_into`, swept over batch widths {1, 2, 3, 8},
//! GQA/MQA/MHA shapes, paged KV block lengths {1, 3, 16}, staggered
//! lane positions, and both numerics modes. Only the weight-streaming
//! schedule changed, so the bar is strict: every lane's logits must be
//! **bit-identical** to its solo twin, in `DesktopF32` *and*
//! `Accelerator` numerics, with and without the worker pool.

use swiftkv::kernels::WorkerPool;
use swiftkv::model::{BatchLane, DecodeState, NumericsMode, TinyModel};
use swiftkv::util::{prop, Rng};

/// Batch widths under test: solo, the 4-lane GEMM block edge on both
/// sides, and two full blocks.
const WIDTHS: [usize; 4] = [1, 2, 3, 8];
/// (n_heads, n_kv_heads): MHA, group-2 GQA, MQA.
const GROUPS: [(usize, usize); 3] = [(4, 4), (4, 2), (4, 1)];
/// KV block lengths: degenerate, odd, default-ish.
const BLOCK_LENS: [usize; 3] = [1, 3, 16];

const VOCAB: usize = 48;
const D_MODEL: usize = 32;
const N_LAYERS: usize = 2;
const D_FFN: usize = 48;
const N_CTX: usize = 24;

struct Case {
    model: TinyModel,
    width: usize,
    block_len: usize,
    /// Solo steps lane `i` takes before the batched phase (staggered
    /// positions: the batch must handle lanes at different depths).
    warmup: Vec<usize>,
    /// Batched steps to run after the warmup.
    steps: usize,
    /// Token fed to lane `i` at batched step `s`: `tokens[s][i]`.
    tokens: Vec<Vec<u32>>,
}

impl Case {
    fn random(rng: &mut Rng, case: u64) -> Case {
        let (h, hkv) = GROUPS[rng.gen_range(0, GROUPS.len())];
        let width = WIDTHS[rng.gen_range(0, WIDTHS.len())];
        let block_len = BLOCK_LENS[rng.gen_range(0, BLOCK_LENS.len())];
        let model = TinyModel::synthetic(
            0xBA7C4 + case,
            VOCAB,
            D_MODEL,
            h,
            hkv,
            N_LAYERS,
            D_FFN,
            N_CTX,
        );
        let warmup: Vec<usize> = (0..width).map(|_| rng.gen_range(0, 4)).collect();
        let steps = 1 + rng.gen_range(0, 5);
        let tokens = (0..steps)
            .map(|_| (0..width).map(|_| rng.gen_range(0, VOCAB) as u32).collect())
            .collect();
        Case {
            model,
            width,
            block_len,
            warmup,
            steps,
            tokens,
        }
    }

    /// A lane state over its own pool at this case's block length.
    fn new_state(&self) -> DecodeState {
        let pool = self
            .model
            .new_pool(self.model.blocks_per_seq(self.block_len), self.block_len);
        self.model.new_state_in(pool)
    }
}

/// Run the case: warm each lane up with solo steps on both state sets,
/// then `steps` batched steps against per-lane solo references.
fn check_case(case: &Case, mode: NumericsMode, pool: Option<&WorkerPool>) {
    let m = &case.model;
    let mut solo: Vec<DecodeState> = (0..case.width).map(|_| case.new_state()).collect();
    let mut batched: Vec<DecodeState> = (0..case.width).map(|_| case.new_state()).collect();
    let mut batch = m.new_batch_scratch();
    let mut want = vec![0.0f32; m.vocab];
    let mut got = vec![0.0f32; case.width * m.vocab];

    // stagger: lane i starts the batched phase at position warmup[i]
    for (i, &n) in case.warmup.iter().enumerate() {
        for s in 0..n {
            let t = ((i * 11 + s * 5) % VOCAB) as u32;
            m.decode_step_into(&mut solo[i], t, mode, &mut want);
            m.decode_step_into(&mut batched[i], t, mode, &mut want);
        }
    }

    for (s, step_tokens) in case.tokens.iter().enumerate() {
        let mut lanes: Vec<BatchLane> = batched
            .iter_mut()
            .zip(got.chunks_mut(m.vocab))
            .zip(step_tokens)
            .map(|((state, logits), &token)| BatchLane {
                state,
                token,
                logits,
            })
            .collect();
        m.decode_steps_into(&mut lanes, mode, &mut batch, pool);
        for (i, st) in solo.iter_mut().enumerate() {
            m.decode_step_into(st, step_tokens[i], mode, &mut want);
            assert_eq!(
                &got[i * m.vocab..(i + 1) * m.vocab],
                &want[..],
                "width {} bl {} {mode:?} step {s} lane {i}: batched decode diverged",
                case.width,
                case.block_len
            );
            assert_eq!(st.pos, batched[i].pos, "lane {i} position drifted");
        }
    }
    assert_eq!(batch.batch_capacity(), case.width);
}

#[test]
fn batched_decode_bit_identical_to_solo_desktop() {
    prop::check("batched decode == solo (f32)", 24, |rng, case| {
        let c = Case::random(rng, case);
        check_case(&c, NumericsMode::DesktopF32, None);
    });
}

#[test]
fn batched_decode_bit_identical_to_solo_accelerator() {
    prop::check("batched decode == solo (fxp)", 24, |rng, case| {
        let c = Case::random(rng, case);
        check_case(&c, NumericsMode::Accelerator, None);
    });
}

#[test]
fn pooled_batched_decode_matches_serial() {
    // operator splitting across the worker pool must not change a bit:
    // same sweep, now with GEMM columns and attention lanes distributed
    // over 3 workers (dynamic schedule — determinism comes from tasks
    // writing disjoint data, which this asserts end-to-end)
    let pool = WorkerPool::new(3);
    prop::check("pooled batched decode == solo", 10, |rng, case| {
        let c = Case::random(rng, case);
        check_case(&c, NumericsMode::DesktopF32, Some(&pool));
        check_case(&c, NumericsMode::Accelerator, Some(&pool));
    });
}

#[test]
fn batched_decode_across_block_boundaries() {
    // pin the shape: 2-token blocks force a block checkout every other
    // step; 8 lanes × 10 steps crosses boundaries in every lane
    let m = TinyModel::synthetic(77, VOCAB, D_MODEL, 4, 2, N_LAYERS, D_FFN, N_CTX);
    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        let width = 8;
        let mk = |m: &TinyModel| {
            let pool = m.new_pool(m.blocks_per_seq(2), 2);
            m.new_state_in(pool)
        };
        let mut solo: Vec<DecodeState> = (0..width).map(|_| mk(&m)).collect();
        let mut batched: Vec<DecodeState> = (0..width).map(|_| mk(&m)).collect();
        let mut batch = m.new_batch_scratch();
        let mut want = vec![0.0f32; m.vocab];
        let mut got = vec![0.0f32; width * m.vocab];
        for s in 0..10u32 {
            let tokens: Vec<u32> = (0..width as u32)
                .map(|i| (s * 13 + i * 7 + 2) % VOCAB as u32)
                .collect();
            let mut lanes: Vec<BatchLane> = batched
                .iter_mut()
                .zip(got.chunks_mut(m.vocab))
                .zip(&tokens)
                .map(|((state, logits), &token)| BatchLane {
                    state,
                    token,
                    logits,
                })
                .collect();
            m.decode_steps_into(&mut lanes, mode, &mut batch, None);
            for (i, st) in solo.iter_mut().enumerate() {
                m.decode_step_into(st, tokens[i], mode, &mut want);
                assert_eq!(
                    &got[i * m.vocab..(i + 1) * m.vocab],
                    &want[..],
                    "{mode:?} step {s} lane {i}: diverged across block boundary"
                );
            }
        }
    }
}

#[test]
fn batched_decode_after_reset_matches_fresh() {
    // lane recycling under batching: a reset state batched with fresh
    // ones must decode like a fresh solo state
    let m = TinyModel::synthetic(5, VOCAB, D_MODEL, 4, 4, N_LAYERS, D_FFN, N_CTX);
    let mut batch = m.new_batch_scratch();
    let mut recycled = m.new_state();
    let mut want = vec![0.0f32; m.vocab];
    for &t in &[3u32, 9, 27] {
        m.decode_step_into(&mut recycled, t, NumericsMode::Accelerator, &mut want);
    }
    recycled.reset_for_reuse();
    let mut fresh_ref = m.new_state();
    m.decode_step_into(&mut fresh_ref, 11, NumericsMode::Accelerator, &mut want);

    let mut other = m.new_state();
    let mut got = vec![0.0f32; 2 * m.vocab];
    let (g0, g1) = got.split_at_mut(m.vocab);
    let mut lanes = [
        BatchLane {
            state: &mut recycled,
            token: 11,
            logits: g0,
        },
        BatchLane {
            state: &mut other,
            token: 30,
            logits: g1,
        },
    ];
    m.decode_steps_into(&mut lanes, NumericsMode::Accelerator, &mut batch, None);
    assert_eq!(&got[..m.vocab], &want[..], "recycled batched lane diverged");
}

#[test]
fn panicking_lane_is_contained_and_recyclable() {
    // fault containment inside the batched step: one lane panics
    // mid-batch (out-of-range token trips its own assert), the fault is
    // caught per-lane and reported, co-batched lanes stay bit-identical
    // to their solo twins, and the faulted lane — once reset — decodes
    // like a fresh state again
    for mode in [NumericsMode::DesktopF32, NumericsMode::Accelerator] {
        let m = TinyModel::synthetic(13, VOCAB, D_MODEL, 4, 2, N_LAYERS, D_FFN, N_CTX);
        let width = 4;
        let bad = 2usize; // the lane that faults
        let mut batch = m.new_batch_scratch();
        let mut solo: Vec<DecodeState> = (0..width).map(|_| m.new_state()).collect();
        let mut batched: Vec<DecodeState> = (0..width).map(|_| m.new_state()).collect();
        let mut want = vec![0.0f32; m.vocab];
        let mut got = vec![0.0f32; width * m.vocab];

        // warm every lane so the faulted lane has KV history to lose
        for (i, (s, b)) in solo.iter_mut().zip(batched.iter_mut()).enumerate() {
            for t in 0..2u32 {
                let tok = (i as u32 * 7 + t * 3 + 1) % VOCAB as u32;
                m.decode_step_into(s, tok, mode, &mut want);
                m.decode_step_into(b, tok, mode, &mut want);
            }
        }

        let tokens: Vec<u32> = (0..width as u32)
            .map(|i| if i as usize == bad { u32::MAX } else { (i * 5 + 2) % VOCAB as u32 })
            .collect();
        let mut lanes: Vec<BatchLane> = batched
            .iter_mut()
            .zip(got.chunks_mut(m.vocab))
            .zip(&tokens)
            .map(|((state, logits), &token)| BatchLane { state, token, logits })
            .collect();
        let faults = m.try_decode_steps_into(&mut lanes, mode, &mut batch, None);
        assert_eq!(faults.len(), 1, "{mode:?}: exactly the one injected fault");
        assert_eq!(faults[0].lane, bad);
        assert!(
            faults[0].message.contains("token out of range"),
            "{mode:?}: fault message '{}' lost the panic payload",
            faults[0].message
        );

        // survivors: bit-identical logits and advanced positions
        for (i, st) in solo.iter_mut().enumerate() {
            if i == bad {
                continue;
            }
            m.decode_step_into(st, tokens[i], mode, &mut want);
            assert_eq!(
                &got[i * m.vocab..(i + 1) * m.vocab],
                &want[..],
                "{mode:?} lane {i}: co-batched lane diverged after a contained fault"
            );
            assert_eq!(st.pos, batched[i].pos, "{mode:?} lane {i}: position drifted");
        }
        // the faulted lane made no progress
        assert_eq!(batched[bad].pos, 2, "{mode:?}: faulted lane must not advance");

        // recycle the faulted lane: reset, then batch it with a healthy
        // lane — it must decode exactly like a fresh solo state
        batched[bad].reset_for_reuse();
        let mut fresh_ref = m.new_state();
        m.decode_step_into(&mut fresh_ref, 11, mode, &mut want);
        let (g0, rest) = got.split_at_mut(m.vocab);
        let (batched_bad, batched_rest) = batched.split_at_mut(bad + 1);
        let mut lanes = [
            BatchLane { state: &mut batched_bad[bad], token: 11, logits: g0 },
            BatchLane {
                state: &mut batched_rest[0],
                token: 30,
                logits: &mut rest[..m.vocab],
            },
        ];
        let faults = m.try_decode_steps_into(&mut lanes, mode, &mut batch, None);
        assert!(faults.is_empty(), "{mode:?}: recycled batch must run fault-free");
        assert_eq!(
            &got[..m.vocab],
            &want[..],
            "{mode:?}: recycled faulted lane diverged from a fresh state"
        );
    }
}
