//! `bench_gate` — CI perf-regression gate over `swiftkv-bench-v1` JSON.
//!
//! ```text
//! bench_gate <BENCH_baseline.json> <BENCH_current.json> \
//!     [--max-regress-pct 15] [--gate fused]
//! ```
//!
//! Compares median ns/op of every benchmark present in both documents
//! and prints a markdown delta table (pipe it into `$GITHUB_STEP_SUMMARY`
//! for the job summary). Exits non-zero when any benchmark whose name
//! contains the gate substring (default `fused` — the fused-sweep hot
//! paths) regressed by more than the threshold, so a slow hot path
//! fails the job instead of shipping silently. An empty baseline passes
//! vacuously: refresh `BENCH_baseline.json` from a trusted bench run to
//! arm the gate. Comparison logic lives in
//! [`swiftkv::util::bench::compare_bench_json`] (unit-tested in-tree).

use swiftkv::util::bench::compare_bench_json;
use swiftkv::util::cli::Args;
use swiftkv::util::Json;

fn main() {
    match run() {
        Ok(passed) => {
            if !passed {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("bench_gate: {e}");
            std::process::exit(2);
        }
    }
}

fn run() -> Result<bool, String> {
    let args = Args::parse(&["max-regress-pct", "gate"], &["help"])?;
    if args.get_bool("help") || args.positional().len() != 2 {
        return Err(
            "usage: bench_gate <baseline.json> <current.json> \
             [--max-regress-pct 15] [--gate fused]"
                .into(),
        );
    }
    let max_regress_pct = args.get_f64("max-regress-pct", 15.0)?;
    let gate = args.get_or("gate", "fused");
    let load = |path: &str| -> Result<Json, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{path}: {e:?}"))
    };
    let baseline = load(&args.positional()[0])?;
    let current = load(&args.positional()[1])?;
    let report = compare_bench_json(&baseline, &current, gate, max_regress_pct)?;
    println!("{}", report.to_markdown());
    Ok(report.passed())
}
