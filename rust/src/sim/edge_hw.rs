//! The Fig. 7 experiment: attention algorithms scheduled on ONE shared
//! hardware set.
//!
//! §V: "all designs are implemented on the same FPGA platform … using an
//! identical set of exp units and the same pipelined multiply and divide
//! units for computing qKᵀ, PV and normalization." The algorithms differ
//! only in *schedule* — how many passes they take, what they materialize,
//! and whether data dependencies keep the pipelined units full:
//!
//! - **native**: three serial phases with the score vector staged in the
//!   single-ported score buffer. The exp and divide passes cannot overlap
//!   successive elements (each result is written back through the same
//!   port the next read needs), so they run at initiation interval =
//!   latency.
//! - **flash (blockwise)**: saves the global passes but inherits the
//!   serialized within-block exp (block buffer, single port) and pays a
//!   rescale + drain at every block boundary; decode contexts rarely end
//!   on a boundary, so the final block is padded.
//! - **streaming** (online-softmax / ITA-style): computes the normalizer
//!   online in pass 1 (exp pipelined under the dot product) but still
//!   materializes scores and re-reads them in pass 2 to form P·V.
//! - **swiftkv**: single pass; every per-token update is hidden under the
//!   4-cycle `q·k_t` initiation interval, and the one deferred division
//!   happens once at the end (Eqs. 5–8).
//!
//! All four compute the same function (proved in `crate::attention`); the
//! cycle ratios this model produces reproduce Fig. 7(b) — see the
//! `fig7b_speedups` test.

use super::ArchConfig;

/// The attention algorithms compared in Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionAlg {
    Native,
    Flash { block: usize },
    Streaming,
    SwiftKv,
}

impl AttentionAlg {
    pub fn label(&self) -> String {
        match self {
            AttentionAlg::Native => "Native".into(),
            AttentionAlg::Flash { block } => format!("FlashAttention(B={block})"),
            AttentionAlg::Streaming => "Streaming".into(),
            AttentionAlg::SwiftKv => "SwiftKV".into(),
        }
    }
}

/// Per-phase cycle breakdown of one attention computation.
#[derive(Debug, Clone)]
pub struct CycleBreakdown {
    pub alg: AttentionAlg,
    pub phases: Vec<(&'static str, u64)>,
    pub total: u64,
}

impl CycleBreakdown {
    fn new(alg: AttentionAlg, phases: Vec<(&'static str, u64)>) -> Self {
        let total = phases.iter().map(|(_, c)| c).sum();
        CycleBreakdown { alg, phases, total }
    }

    pub fn us(&self, arch: &ArchConfig) -> f64 {
        arch.cycles_to_us(self.total)
    }
}

/// Initiation interval of the `q·k_t` dot product: `ceil(d / fxp_lanes)`
/// (the paper's "4 cycles for each qkᵀ" at d = 128).
fn qk_ii(arch: &ArchConfig, d: usize) -> u64 {
    d.div_ceil(arch.fxp_lanes()) as u64
}

/// Cycles for one decode-attention computation over context length `n`
/// with head dimension `d` on the shared hardware set.
pub fn attention_cycles(arch: &ArchConfig, alg: AttentionAlg, n: usize, d: usize) -> CycleBreakdown {
    assert!(n >= 1 && d >= 1);
    let nn = n as u64;
    let ii = qk_ii(arch, d);
    match alg {
        AttentionAlg::SwiftKv => {
            // one pass; compare/exp/update all hidden under the qk II
            // (§III: "all remaining updates can be scheduled within its
            // latency"); one deferred normalization at the end.
            let fill = arch.dot_latency + 1 + arch.exp_latency + arch.mul_latency;
            let finalize = arch.div_latency + ii; // 1/Z then Y·(1/Z)
            CycleBreakdown::new(
                alg,
                vec![
                    ("single pass (qkᵀ-bound)", ii * nn),
                    ("pipeline fill", fill),
                    ("final normalize", finalize),
                ],
            )
        }
        AttentionAlg::Native => {
            // phase 1: scores to buffer (dot pipelined)
            let scores = ii * nn + arch.dot_latency;
            // phase 2a: max scan over the buffer
            let maxscan = nn;
            // phase 2b: exp pass, serialized through the score-buffer port
            let exp = arch.exp_latency * nn;
            // phase 2c: per-element normalization on the iterative divider
            let div = arch.div_latency * nn;
            // phase 3: PV accumulation
            let pv = ii * nn + arch.dot_latency;
            CycleBreakdown::new(
                alg,
                vec![
                    ("qKᵀ scores", scores),
                    ("max scan", maxscan),
                    ("exp pass (serialized)", exp),
                    ("divide pass (serialized)", div),
                    ("PV", pv),
                ],
            )
        }
        AttentionAlg::Streaming => {
            // pass 1: scores + online max/Z (exp pipelined under the dot),
            // scores written back through the buffer port
            let pass1 = ii * nn + arch.dot_latency + nn;
            // pass 2: reload scores, exp (pipelined), multiply by 1/Z, PV
            let pass2 = nn + nn + nn + ii * nn + arch.dot_latency;
            let recip = arch.div_latency; // one reciprocal of Z
            CycleBreakdown::new(
                alg,
                vec![
                    ("pass 1: qKᵀ + online max/Z", pass1),
                    ("reciprocal 1/Z", recip),
                    ("pass 2: reload+exp+norm+PV", pass2),
                ],
            )
        }
        AttentionAlg::Flash { block } => {
            assert!(block >= 1);
            let b = block as u64;
            let blocks = n.div_ceil(block) as u64; // final block padded
            // within a block the stages serialize on the single hw set:
            let qk = ii * b + arch.dot_latency;
            let bmax = b;
            let exp = arch.exp_latency * b; // serialized via block buffer
            let rescale = 2 + 2 * ii; // α·Z and α·Y sweeps
            let pv = ii * b + arch.dot_latency;
            let drain = 8; // inter-block sync
            let per_block = qk + bmax + exp + rescale + pv + drain;
            CycleBreakdown::new(
                alg,
                vec![
                    ("blocks (incl. padding)", per_block * blocks),
                    ("final normalize", arch.div_latency + ii),
                ],
            )
        }
    }
}

/// Fig. 7(b): speedups over native at a fixed context length.
pub fn fig7b_speedups(arch: &ArchConfig, n: usize, d: usize) -> Vec<(String, f64)> {
    let native = attention_cycles(arch, AttentionAlg::Native, n, d).total as f64;
    [
        AttentionAlg::Native,
        AttentionAlg::Flash { block: 32 },
        AttentionAlg::Streaming,
        AttentionAlg::SwiftKv,
    ]
    .iter()
    .map(|&alg| {
        let c = attention_cycles(arch, alg, n, d).total as f64;
        (alg.label(), native / c)
    })
    .collect()
}

/// Fig. 7(a): attention time (µs) vs context length for SwiftKV and
/// Flash at the paper's block sizes.
pub fn fig7a_curves(
    arch: &ArchConfig,
    contexts: &[usize],
    d: usize,
) -> Vec<(String, Vec<(usize, f64)>)> {
    let algs = [
        AttentionAlg::SwiftKv,
        AttentionAlg::Flash { block: 8 },
        AttentionAlg::Flash { block: 16 },
        AttentionAlg::Flash { block: 32 },
    ];
    algs.iter()
        .map(|&alg| {
            let pts = contexts
                .iter()
                .map(|&n| (n, attention_cycles(arch, alg, n, d).us(arch)))
                .collect();
            (alg.label(), pts)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: usize = 128;
    const N: usize = 512;

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    #[test]
    fn swiftkv_is_4n_cycles() {
        // §IV-B: "Attention over context length N takes about 4N cycles"
        let c = attention_cycles(&arch(), AttentionAlg::SwiftKv, N, D).total;
        assert!(
            (c as f64 - 4.0 * N as f64).abs() < 60.0,
            "swiftkv cycles = {c}, expected ≈ {}",
            4 * N
        );
    }

    /// The paper's headline algorithm numbers (Fig. 7(b)): native = 1×,
    /// Flash(32) ≈ 1.46×, Streaming ≈ 2.15×, SwiftKV ≈ 7.16×.
    #[test]
    fn fig7b_speedups_match_paper_shape() {
        let sp = fig7b_speedups(&arch(), N, D);
        let get = |name: &str| {
            sp.iter()
                .find(|(l, _)| l.contains(name))
                .map(|(_, s)| *s)
                .unwrap()
        };
        assert!((get("Native") - 1.0).abs() < 1e-9);
        let flash = get("Flash");
        let stream = get("Streaming");
        let swift = get("SwiftKV");
        // paper: 7.16× — we must land within a few percent
        assert!(
            (swift - 7.16).abs() < 0.25,
            "SwiftKV speedup {swift:.2} vs paper 7.16"
        );
        // paper: 1.46× — same hardware, modest win
        assert!(
            (flash - 1.46).abs() < 0.35,
            "Flash speedup {flash:.2} vs paper 1.46"
        );
        // paper: 2.15× — between Flash and SwiftKV
        assert!(
            (stream - 2.15).abs() < 0.45,
            "Streaming speedup {stream:.2} vs paper 2.15"
        );
        // strict ordering must hold regardless of calibration
        assert!(swift > stream && stream > flash && flash > 1.0);
    }

    #[test]
    fn fig7a_swiftkv_always_fastest() {
        let curves = fig7a_curves(&arch(), &[64, 128, 256, 512, 1024, 2048, 4096], D);
        let swift = &curves[0];
        assert!(swift.0.contains("SwiftKV"));
        for other in &curves[1..] {
            for (p_s, p_o) in swift.1.iter().zip(&other.1) {
                assert!(
                    p_s.1 < p_o.1,
                    "{} not slower than SwiftKV at n={}",
                    other.0,
                    p_s.0
                );
            }
        }
    }

    #[test]
    fn flash_block_ordering() {
        // larger blocks amortize the per-block overhead better
        let a = arch();
        let f8 = attention_cycles(&a, AttentionAlg::Flash { block: 8 }, N, D).total;
        let f16 = attention_cycles(&a, AttentionAlg::Flash { block: 16 }, N, D).total;
        let f32_ = attention_cycles(&a, AttentionAlg::Flash { block: 32 }, N, D).total;
        assert!(f8 > f16 && f16 > f32_, "{f8} {f16} {f32_}");
    }

    #[test]
    fn linear_scaling_in_context() {
        let a = arch();
        for alg in [AttentionAlg::SwiftKv, AttentionAlg::Native, AttentionAlg::Streaming] {
            let c1 = attention_cycles(&a, alg, 1024, D).total as f64;
            let c2 = attention_cycles(&a, alg, 2048, D).total as f64;
            let ratio = c2 / c1;
            assert!((ratio - 2.0).abs() < 0.05, "{alg:?}: ratio {ratio}");
        }
    }

    #[test]
    fn flash_padding_steps_at_block_boundary() {
        // crossing a block boundary costs a whole extra block
        let a = arch();
        let alg = AttentionAlg::Flash { block: 32 };
        let at_boundary = attention_cycles(&a, alg, 512, D).total;
        let just_past = attention_cycles(&a, alg, 513, D).total;
        let step = just_past - at_boundary;
        let per_block = attention_cycles(&a, alg, 32, D).total
            - attention_cycles(&a, alg, 1, D).total
            + 1; // rough per-block cost
        assert!(step > 100, "boundary step = {step}");
        let _ = per_block;
    }

    #[test]
    fn small_context_still_works() {
        let a = arch();
        for alg in [
            AttentionAlg::Native,
            AttentionAlg::SwiftKv,
            AttentionAlg::Streaming,
            AttentionAlg::Flash { block: 32 },
        ] {
            let c = attention_cycles(&a, alg, 1, D);
            assert!(c.total > 0, "{alg:?}");
        }
    }
}
