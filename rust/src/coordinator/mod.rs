//! L3 coordinator — the decode serving layer.
//!
//! `clippy::unwrap_used` is denied crate-wide outside tests (see
//! `lib.rs`); it originated here — serving-loop code must contain faults
//! per-request, never convert one into a process-wide panic via a stray
//! `.unwrap()` — and the redundant module-level deny stays as the local
//! statement of that intent.
//!
//! Shaped like a serving-system router (the SwiftKV-MHA accelerator is a
//! decode engine; this is the host side that keeps it fed):
//!
//! - [`session`] — per-request decode sessions (prompt feed → generation),
//! - [`batcher`] — continuous batching over a fixed lane count: free
//!   lanes are re-admitted from the queue every iteration,
//! - [`submit`] — the submission API: a cloneable [`ServeHandle`]
//!   (submit → per-request [`TokenEvent`] stream → final
//!   [`SessionOutcome`]) that both the offline path and the async front
//!   door share; requests join a running engine mid-flight,
//! - [`cpu`] — the continuous-batching engine: the pure-Rust tiny model
//!   on the fused decode kernels; the iteration loop polls the intake
//!   channel every step (no drain barrier), and decode-phase lanes step
//!   through one operator-batched `decode_steps_into` call (one shared
//!   weight pass per batch step) over a persistent
//!   [`crate::kernels::WorkerPool`],
//! - [`http`] — the minimal HTTP/SSE front door (`swiftkv serve
//!   --listen`): hand-rolled thread-per-connection over `std::net`, each
//!   connection streaming one request's tokens as server-sent events —
//!   the engine never learns HTTP exists,
//! - [`server`] — the PJRT serving loop over the AOT engine (behind the
//!   `pjrt` feature): gather (token, position) per lane, one engine step,
//!   scatter logits, greedy-sample, retire finished sessions,
//! - [`metrics`] — per-request latency/throughput accounting (TTFT,
//!   TPOT, time-in-queue, queue depth) plus the simulated SwiftKV-MHA
//!   timing for the same schedule (via [`crate::sim::layer_sched`]), so
//!   the E2E example reports both wall-clock and modelled-accelerator
//!   numbers.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod admission;
pub mod batcher;
pub mod cpu;
pub mod faults;
pub mod http;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod session;
pub mod submit;

pub use admission::{AdmissionDecision, AdmissionPolicy, StepEstimate};
pub use batcher::{Batcher, CancelKind, FaultCounters, LaneChunk, LaneState, PreemptOutcome};
pub use cpu::{CpuServeReport, CpuServer, ServeConfig, ServeConfigBuilder, DEFAULT_PREFILL_CHUNK};
pub use faults::{FaultKind, FaultPlan};
pub use http::{serve_http, HttpServeReport, HttpServerConfig};
pub use metrics::{Percentiles, ServeMetrics};
#[cfg(feature = "pjrt")]
pub use server::{ServeOptions, ServeReport, Server};
pub use session::{Session, SessionOutcome, SessionPhase};
pub use submit::{
    EngineGate, EngineStatus, FinishedRequest, PendingRequest, ServeHandle, SubmitError, TokenEvent,
};
